//! Vendored offline subset of [anyhow](https://docs.rs/anyhow).
//!
//! The container this repo builds in has no crates.io access, so this crate
//! reimplements exactly the surface the workspace uses:
//!
//! * [`Result<T>`] / [`Error`] with a context *chain*;
//! * `{e}` prints the outermost message, `{e:#}` the full `a: b: c` chain
//!   (matching anyhow's Display semantics, which the tests assert on);
//! * [`Context::context`] / [`Context::with_context`] on any `Result` whose
//!   error converts into [`Error`];
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Unlike real anyhow there is no downcasting and no backtrace capture: the
//! source chain is flattened to strings at conversion time.  Nothing in this
//! workspace downcasts, so the trade keeps the stub dependency-free.

use std::fmt;

/// `Result<T, anyhow::Error>`, with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error.  `chain[0]` is the outermost context, the last
/// element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap the error in one more layer of context.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The `a: b: c` rendering used by `{:#}` and `Debug`.
    fn full(&self) -> String {
        self.chain.join(": ")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.full())
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.full())
    }
}

// NOTE: like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes the blanket `From` below coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e: Error = Error::from(io_err()).context("reading x");
        assert_eq!(format!("{e}"), "reading x");
        assert_eq!(format!("{e:#}"), "reading x: gone");
    }

    #[test]
    fn context_on_result() {
        let r: Result<()> = Err(io_err()).context("outer");
        let e = r.unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: gone");
        // Context stacks on already-converted errors too.
        let r2: Result<()> = Err(e).with_context(|| format!("layer {}", 2));
        assert_eq!(format!("{:#}", r2.unwrap_err()), "layer 2: outer: gone");
    }

    #[test]
    fn macros() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert_eq!(format!("{}", f(3).unwrap_err()), "unlucky 3");
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        let e = anyhow!("plain {}", "message");
        assert_eq!(format!("{e:#}"), "plain message");
    }
}
