//! Vendored offline surface of the `xla` (xla_extension / PJRT) crate.
//!
//! The reproduction's request path talks to PJRT through exactly the types
//! re-declared here.  Offline there is no libxla to link, so this crate
//! splits the surface in two:
//!
//! * **Host-side [`Literal`] is fully functional** (typed storage, shapes,
//!   tuples, byte round-trips) — `runtime::buffers` and its tests run with
//!   no PJRT present.
//! * **PJRT entry points** ([`PjRtClient::cpu`], [`HloModuleProto`] loading,
//!   execution) return [`Error`] with a descriptive message; callers already
//!   treat "runtime unavailable" as "skip the artifact-backed path", so
//!   `cargo build && cargo test` pass end to end offline.
//!
//! To run real artifacts, point the `xla` dependency of the root crate at a
//! PJRT-backed build of <https://github.com/LaurentMazare/xla-rs> (the API
//! here is name-for-name a subset of it) via `[patch]`, and enable the root
//! crate's `pjrt` feature so intent is recorded in the build graph.

use std::fmt;
use std::path::Path;

/// Error type for every fallible call in this crate.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn offline(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT is unavailable in this offline build (vendored xla \
         stub); patch the `xla` dependency to a PJRT-backed build to execute \
         artifacts"
    ))
}

/// Element types of XLA arrays (the subset with defined host mappings).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U32,
    F16,
    Bf16,
    F32,
    F64,
}

impl ElementType {
    /// Bytes per element on the host.
    pub fn size_bytes(self) -> usize {
        match self {
            ElementType::Pred => 1,
            ElementType::F16 | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::F64 => 8,
        }
    }
}

/// Host types that map onto an [`ElementType`].
pub trait NativeType: Copy {
    const TY: ElementType;
    /// Decode one element from native-endian bytes.
    fn read_ne(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn read_ne(bytes: &[u8]) -> Self {
        f32::from_ne_bytes(bytes.try_into().expect("4 bytes"))
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn read_ne(bytes: &[u8]) -> Self {
        i32::from_ne_bytes(bytes.try_into().expect("4 bytes"))
    }
}

/// Shape of an array literal.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A host-side XLA literal: an array of one element type, or a tuple.
#[derive(Clone, Debug, PartialEq)]
pub enum Literal {
    Array {
        ty: ElementType,
        dims: Vec<i64>,
        /// Native-endian packed element bytes, row-major.
        data: Vec<u8>,
    },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Build an array literal from untyped bytes (length-checked).
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = dims.iter().product();
        let want = numel * ty.size_bytes();
        if data.len() != want {
            return Err(Error(format!(
                "literal data is {} bytes, shape {dims:?} of {ty:?} needs {want}",
                data.len()
            )));
        }
        Ok(Literal::Array {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            data: data.to_vec(),
        })
    }

    /// Shape of an array literal (error on tuples).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { ty, dims, .. } => {
                Ok(ArrayShape { ty: *ty, dims: dims.clone() })
            }
            Literal::Tuple(_) => {
                Err(Error("array_shape called on a tuple literal".to_string()))
            }
        }
    }

    /// Decode the element data as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { ty, data, .. } => {
                if *ty != T::TY {
                    return Err(Error(format!(
                        "to_vec type mismatch: literal is {ty:?}, requested {:?}",
                        T::TY
                    )));
                }
                let size = ty.size_bytes();
                Ok(data.chunks_exact(size).map(T::read_ne).collect())
            }
            Literal::Tuple(_) => {
                Err(Error("to_vec called on a tuple literal".to_string()))
            }
        }
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            Literal::Array { .. } => {
                Err(Error("to_tuple called on an array literal".to_string()))
            }
        }
    }
}

/// PJRT device handle (stub).
pub struct PjRtDevice;

/// PJRT device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(offline("PjRtBuffer::to_literal_sync"))
    }
}

/// PJRT client (stub: construction fails offline).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(offline("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(offline("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(offline("PjRtClient::buffer_from_host_buffer"))
    }
}

/// Compiled-and-loaded executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(offline("PjRtLoadedExecutable::execute_b"))
    }
}

/// Parsed HLO module proto (stub: loading fails offline).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        Err(Error(format!(
            "loading {}: {}",
            path.as_ref().display(),
            offline("HloModuleProto::from_text_file")
        )))
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.5f32, -2.0, 0.25, 3.0, 0.0, -1.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_ne_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 3],
            &bytes,
        )
        .unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_shape_checked() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[4],
            &[0u8; 15],
        )
        .is_err());
    }

    #[test]
    fn tuple_decomposes() {
        let a = Literal::create_from_shape_and_untyped_data(
            ElementType::S32,
            &[1],
            &1i32.to_ne_bytes(),
        )
        .unwrap();
        let t = Literal::Tuple(vec![a.clone()]);
        assert!(t.array_shape().is_err());
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts, vec![a]);
    }

    #[test]
    fn pjrt_is_unavailable_offline() {
        let e = PjRtClient::cpu().err().expect("stub must refuse");
        assert!(format!("{e}").contains("offline"));
    }
}
