//! Integration: artifacts → PJRT → drivers, verified against the host
//! reference.  These tests require `make artifacts` (they are skipped with a
//! note when the manifest is missing so `cargo test` works pre-build).

use fused3s::exec::Engine;
use fused3s::graph::{generators, CsrGraph};
use fused3s::kernels::{
    reference, AttentionBatch, AttentionProblem, Backend, Driver, ExecCtx, Plan,
};
use fused3s::runtime::Runtime;
use fused3s::util::prng::Rng;

fn runtime() -> Option<Runtime> {
    match Runtime::from_default_artifacts() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping integration test (run `make artifacts`): {e:#}");
            None
        }
    }
}

fn problem_data(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (
        rng.normal_vec(n * d, 1.0),
        rng.normal_vec(n * d, 1.0),
        rng.normal_vec(n * d, 1.0),
    )
}

/// bf16 GEMMs + exp amplification: measured worst-case ~7e-2 on std-normal
/// inputs (see python/tests/test_kernel.py for the full error analysis).
const BF16_TOL: f32 = 1.5e-1;

/// Plan + execute one single-head problem through the PJRT context.
fn plan_run(rt: &Runtime, g: &CsrGraph, backend: Backend, x: &AttentionProblem) -> Vec<f32> {
    let engine = Engine::serial();
    let plan = Plan::new(rt.manifest(), g, backend, &engine).expect("plan");
    plan.execute(&mut ExecCtx::pjrt(rt, &engine), &AttentionBatch::single(x))
        .expect("run")
}

fn check_backend_on(g: &CsrGraph, backend: Backend, d: usize, tol: f32) {
    let Some(rt) = runtime() else { return };
    let (q, k, v) = problem_data(g.n, d, 42);
    let x = AttentionProblem::new(g.n, d, &q, &k, &v, 1.0);
    let got = plan_run(&rt, g, backend, &x);
    let want = reference::dense_attention_host(g, &x);
    let err = reference::max_abs_diff(&got, &want);
    assert!(
        err < tol,
        "{}: max err {err} (tol {tol}) on n={} d={d}",
        backend.name(),
        g.n
    );
}

#[test]
fn fused_matches_reference_er() {
    let g = generators::erdos_renyi(300, 5.0, 7).with_self_loops();
    check_backend_on(&g, Backend::Fused3S, 64, BF16_TOL);
}

#[test]
fn fused_matches_reference_power_law() {
    let g = generators::barabasi_albert(700, 6, 8).with_self_loops();
    check_backend_on(&g, Backend::Fused3S, 32, BF16_TOL);
}

#[test]
fn fused_d128() {
    let g = generators::erdos_renyi(200, 4.0, 9).with_self_loops();
    check_backend_on(&g, Backend::Fused3S, 128, BF16_TOL);
}

#[test]
fn fused_noreorder_matches() {
    let g = generators::barabasi_albert(500, 5, 10).with_self_loops();
    check_backend_on(&g, Backend::Fused3SNoReorder, 64, BF16_TOL);
}

#[test]
fn fused_splitr_matches() {
    let g = generators::erdos_renyi(300, 4.0, 11).with_self_loops();
    check_backend_on(&g, Backend::Fused3SSplitR, 64, BF16_TOL);
}

#[test]
fn dfgnn_like_matches_tightly() {
    // f32 end-to-end -> tight tolerance.
    let g = generators::erdos_renyi(300, 5.0, 12).with_self_loops();
    check_backend_on(&g, Backend::DfGnnLike, 64, 1e-4);
}

#[test]
fn unfused_stable_matches() {
    let g = generators::erdos_renyi(300, 5.0, 13).with_self_loops();
    check_backend_on(&g, Backend::UnfusedStable, 64, BF16_TOL);
}

#[test]
fn unfused_naive_matches_small_logits() {
    // Scale down so naive softmax stays in range.
    let Some(rt) = runtime() else { return };
    let g = generators::erdos_renyi(300, 5.0, 14).with_self_loops();
    let (q, k, v) = problem_data(g.n, 32, 15);
    let x = AttentionProblem::new(g.n, 32, &q, &k, &v, 0.05);
    let got = plan_run(&rt, &g, Backend::UnfusedNaive, &x);
    let want = reference::dense_attention_host(&g, &x);
    assert!(reference::max_abs_diff(&got, &want) < BF16_TOL);
}

#[test]
fn dense_backend_matches() {
    let g = generators::erdos_renyi(200, 5.0, 16).with_self_loops();
    check_backend_on(&g, Backend::Dense, 64, 1e-4);
}

#[test]
fn chunked_mega_hub_matches() {
    // Star graph: hub row window needs ceil(2500/8)=313 TCBs > 128 -> the
    // chunk-merge path.  This is the Reddit-tail case of Table 7.
    let g = generators::star(2500).with_self_loops();
    let Some(rt) = runtime() else { return };
    let (q, k, v) = problem_data(g.n, 64, 17);
    let x = AttentionProblem::new(g.n, 64, &q, &k, &v, 0.125);
    let engine = Engine::serial();
    let plan = Plan::new(rt.manifest(), &g, Backend::Fused3S, &engine).unwrap();
    if let Driver::Fused(f) = plan.driver() {
        assert!(!f.plan.chunked.is_empty(), "test premise: chunking required");
    }
    let got = plan
        .execute(&mut ExecCtx::pjrt(&rt, &engine), &AttentionBatch::single(&x))
        .unwrap();
    let want = reference::dense_attention_host(&g, &x);
    let err = reference::max_abs_diff(&got, &want);
    assert!(err < BF16_TOL, "chunked max err {err}");
}

#[test]
fn empty_and_ragged_graph() {
    // n not multiple of 16, with isolated nodes.
    let Some(rt) = runtime() else { return };
    let mut edges = vec![(0u32, 1u32), (1, 0), (5, 9), (9, 5)];
    edges.push((37, 2));
    let g = CsrGraph::from_edges(43, &edges).unwrap();
    let (q, k, v) = problem_data(g.n, 32, 18);
    let x = AttentionProblem::new(g.n, 32, &q, &k, &v, 1.0);
    let got = plan_run(&rt, &g, Backend::Fused3S, &x);
    let want = reference::dense_attention_host(&g, &x);
    assert!(reference::max_abs_diff(&got, &want) < BF16_TOL);
    // Isolated rows exactly zero.
    assert!(got[2 * 32..3 * 32].iter().all(|&z| z == 0.0));
}

#[test]
fn backends_agree_pairwise() {
    // All backends on one graph must agree with each other (not just the
    // reference) — catches systematic scatter/gather offsets.
    let Some(rt) = runtime() else { return };
    let g = generators::sbm(8, 32, 0.15, 0.002, 19).with_self_loops();
    let (q, k, v) = problem_data(g.n, 64, 20);
    let x = AttentionProblem::new(g.n, 64, &q, &k, &v, 0.125);
    let mut results = Vec::new();
    for b in [
        Backend::Fused3S,
        Backend::DfGnnLike,
        Backend::UnfusedStable,
        Backend::Dense,
        Backend::CpuCsr,
    ] {
        results.push((b, plan_run(&rt, &g, b, &x)));
    }
    for w in results.windows(2) {
        let (b1, r1) = &w[0];
        let (b2, r2) = &w[1];
        let err = reference::max_abs_diff(r1, r2);
        assert!(err < BF16_TOL, "{} vs {}: {err}", b1.name(), b2.name());
    }
}

#[test]
fn runtime_stats_accumulate() {
    let Some(rt) = runtime() else { return };
    let g = generators::erdos_renyi(100, 4.0, 21).with_self_loops();
    let (q, k, v) = problem_data(g.n, 32, 22);
    let x = AttentionProblem::new(g.n, 32, &q, &k, &v, 1.0);
    let engine = Engine::serial();
    let plan = Plan::new(rt.manifest(), &g, Backend::Fused3S, &engine).unwrap();
    let batch = AttentionBatch::single(&x);
    rt.reset_stats();
    plan.execute(&mut ExecCtx::pjrt(&rt, &engine), &batch).unwrap();
    let st = rt.stats();
    assert!(st.executions > 0);
    assert!(st.bytes_uploaded > 0);
    // Second run: no new compiles (cache hit).
    let compiles_before = st.compiles;
    plan.execute(&mut ExecCtx::pjrt(&rt, &engine), &batch).unwrap();
    assert_eq!(rt.stats().compiles, compiles_before);
}

#[test]
fn backward_matches_reference() {
    // The §6 extension end-to-end: fused backward kernel + host scatter-add
    // vs the analytic dense reference.
    let Some(rt) = runtime() else { return };
    use fused3s::kernels::backward::{backward_reference, BackwardDriver};
    let g = generators::erdos_renyi(300, 5.0, 23).with_self_loops();
    let d = 64;
    let (q, k, v) = problem_data(g.n, d, 24);
    let d_out = {
        let mut rng = Rng::new(25);
        rng.normal_vec(g.n * d, 1.0)
    };
    let x = AttentionProblem::new(g.n, d, &q, &k, &v, 0.125);
    let driver = BackwardDriver::new(rt.manifest(), &g).unwrap();
    let got = driver.run(&rt, &x, &d_out).unwrap();
    let want = backward_reference(&g, &x, &d_out);
    for (name, a, b) in [
        ("dQ", &got.dq, &want.dq),
        ("dK", &got.dk, &want.dk),
        ("dV", &got.dv, &want.dv),
    ] {
        let err = reference::max_abs_diff(a, b);
        assert!(err < 2e-1, "{name}: max err {err}");
        // sanity: gradients are non-trivial
        assert!(a.iter().any(|&z| z.abs() > 1e-3), "{name} all ~zero");
    }
}

#[test]
fn backward_isolated_nodes_zero_grad() {
    let Some(rt) = runtime() else { return };
    use fused3s::kernels::backward::BackwardDriver;
    let g = CsrGraph::from_edges(64, &[(0, 1), (1, 0), (0, 0), (1, 1)]).unwrap();
    let d = 32;
    let (q, k, v) = problem_data(g.n, d, 30);
    let d_out = vec![1.0f32; g.n * d];
    let x = AttentionProblem::new(g.n, d, &q, &k, &v, 1.0);
    let driver = BackwardDriver::new(rt.manifest(), &g).unwrap();
    let got = driver.run(&rt, &x, &d_out).unwrap();
    // nodes 2.. have no edges in either direction -> all-zero grads
    assert!(got.dq[2 * d..].iter().all(|&z| z == 0.0));
    assert!(got.dk[2 * d..].iter().all(|&z| z == 0.0));
    assert!(got.dv[2 * d..].iter().all(|&z| z == 0.0));
    assert!(got.dv[..d].iter().any(|&z| z != 0.0));
}
