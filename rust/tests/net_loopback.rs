//! Loopback differential suite for the network serving layer (ISSUE 8):
//! responses served over real TCP must bit-match the in-process
//! `Coordinator::submit` path — same backends, same graphs, same
//! features — and the fingerprint handshake must eliminate repeat CSR
//! uploads end to end (client stats, server net counters, and
//! DriverCache hits all agree).
//!
//! Everything runs offline (`ExecutorKind::HostEmulation`, no
//! artifacts).  `scripts/verify.sh` runs this file explicitly with
//! `--test-threads=1`.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use fused3s::coordinator::{
    AttnRequest, Coordinator, CoordinatorConfig, ExecutorKind,
};
use fused3s::exec::ExecPolicy;
use fused3s::graph::{generators, CsrGraph};
use fused3s::kernels::{AttnError, Backend};
use fused3s::net::{NetClient, NetConfig, NetServer, WireRequest};
use fused3s::planner::resolve_offline;
use fused3s::util::prng::Rng;

fn host_config() -> CoordinatorConfig {
    CoordinatorConfig {
        executor: ExecutorKind::HostEmulation,
        preprocess_workers: 2,
        queue_capacity: 16,
        max_batch_requests: 1, // singleton batches: deterministic outputs
        max_batch_delay: Duration::from_millis(300),
        cache_capacity: 16,
        // Serial host execution keeps outputs independent of thread
        // scheduling, so wire vs in-process comparisons are bit-exact.
        exec: ExecPolicy::serial(),
        ..CoordinatorConfig::default()
    }
}

fn serve_host(
    cfg_mut: impl FnOnce(&mut CoordinatorConfig),
    net_mut: impl FnOnce(&mut NetConfig),
) -> (Arc<Coordinator>, NetServer) {
    let mut cfg = host_config();
    cfg_mut(&mut cfg);
    let coord = Arc::new(Coordinator::start(cfg).expect("host coordinator"));
    let mut net = NetConfig::default();
    net_mut(&mut net);
    let server = NetServer::serve(coord.clone(), net).expect("loopback bind");
    (coord, server)
}

fn features(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (
        rng.normal_vec(n * d, 1.0),
        rng.normal_vec(n * d, 1.0),
        rng.normal_vec(n * d, 1.0),
    )
}

/// In-process reference: one blocking submit through the same coordinator.
fn submit_inproc(
    coord: &Coordinator,
    id: u64,
    g: &CsrGraph,
    d: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    backend: Backend,
) -> Vec<f32> {
    let (tx, rx) = channel();
    coord
        .submit(AttnRequest::single_head(
            id,
            g.clone(),
            d,
            q.to_vec(),
            k.to_vec(),
            v.to_vec(),
            0.25,
            backend,
            tx,
        ))
        .expect("in-process submit");
    rx.recv_timeout(Duration::from_secs(120))
        .expect("in-process response")
        .result
        .expect("in-process result")
}

#[test]
fn wire_bit_matches_inprocess_across_backends() {
    let (coord, server) = serve_host(|_| {}, |_| {});
    let mut client =
        NetClient::connect(server.local_addr(), "").expect("connect");
    let d = 16;
    let g = generators::erdos_renyi(300, 5.0, 11).with_self_loops();
    let (q, k, v) = features(g.n, d, 7);
    for (i, backend) in [
        Backend::Fused3S,
        Backend::Hybrid,
        Backend::UnfusedStable,
        Backend::CpuCsr,
    ]
    .into_iter()
    .enumerate()
    {
        let id = 1000 + i as u64;
        let req =
            WireRequest::single_head(id, &g, d, &q, &k, &v, 0.25, backend);
        let wire = client.submit(&req).expect("wire submit");
        assert_eq!(wire.id, id);
        let wire_out = wire.result.expect("wire result");
        let local_out =
            submit_inproc(&coord, 2000 + i as u64, &g, d, &q, &k, &v, backend);
        assert_eq!(
            wire_out,
            local_out,
            "{}: wire response diverged from in-process submit",
            backend.name()
        );
        assert_eq!(wire.batch_size, 1);
    }
    client.close();
    server.shutdown();
    coord.shutdown();
}

#[test]
fn wire_auto_resolves_like_offline_planner() {
    // The resolve-offline-first idiom: a FRESH coordinator's first Auto
    // request resolves with zero observations, i.e. with the same factory
    // cost model `resolve_offline` uses locally.
    let g = generators::erdos_renyi(400, 5.0, 41).with_self_loops();
    let expected = resolve_offline(&g).backend;
    let d = 16;
    let (q, k, v) = features(g.n, d, 42);

    let (coord, server) = serve_host(|_| {}, |_| {});
    let mut client =
        NetClient::connect(server.local_addr(), "").expect("connect");
    let auto = client
        .submit(&WireRequest::single_head(
            1,
            &g,
            d,
            &q,
            &k,
            &v,
            0.25,
            Backend::Auto,
        ))
        .expect("auto over wire");
    let auto_out = auto.result.expect("auto result");
    assert_eq!(
        auto.backend,
        Some(expected),
        "wire response must report the planner's resolution"
    );
    let forced_out = submit_inproc(&coord, 2, &g, d, &q, &k, &v, expected);
    assert_eq!(auto_out, forced_out, "auto-over-wire diverged from forced");
    let m = coord.metrics();
    assert_eq!(m.planner.auto_requests(), 1);
    assert_eq!(m.planner.resolved_counts(), vec![(expected.name(), 1)]);
    client.close();
    server.shutdown();
    coord.shutdown();
}

#[test]
fn fingerprint_handshake_eliminates_repeat_uploads() {
    let (coord, server) = serve_host(|_| {}, |_| {});
    let d = 8;
    let g = generators::erdos_renyi(200, 4.0, 3).with_self_loops();
    let repeats = 5usize;

    let mut client =
        NetClient::connect(server.local_addr(), "").expect("connect");
    for r in 0..=repeats {
        let (q, k, v) = features(g.n, d, 100 + r as u64);
        let resp = client
            .submit(&WireRequest::single_head(
                r as u64,
                &g,
                d,
                &q,
                &k,
                &v,
                0.5,
                Backend::CpuCsr,
            ))
            .expect("submit");
        resp.result.expect("result");
    }
    let s = client.stats();
    assert_eq!(s.graph_uploads, 1, "first sight uploads the CSR once");
    assert_eq!(s.upload_skips, repeats as u64, "repeats ride the fingerprint");
    assert!(
        s.graph_bytes_uploaded * repeats as u64 <= s.graph_bytes_naive,
        "measured upload bytes must drop vs naive: {} vs {}",
        s.graph_bytes_uploaded,
        s.graph_bytes_naive
    );
    client.close();

    let m = coord.metrics();
    assert_eq!(m.net.graph_uploads(), 1);
    assert_eq!(m.net.graph_reuses(), repeats as u64);
    // Behind the wire handshake sits the DriverCache keyed by the same
    // fingerprint: every repeat is also a plan-cache hit.
    assert!(
        m.batching.cache_hits() >= repeats as u64,
        "cache hits {} < {repeats}",
        m.batching.cache_hits()
    );

    // A second connection benefits from the first one's upload: the store
    // is shared server-side, so GraphQuery answers known and this client
    // never uploads at all.
    let mut client2 =
        NetClient::connect(server.local_addr(), "").expect("connect 2");
    let (q, k, v) = features(g.n, d, 999);
    client2
        .submit(&WireRequest::single_head(
            77,
            &g,
            d,
            &q,
            &k,
            &v,
            0.5,
            Backend::CpuCsr,
        ))
        .expect("submit on second connection")
        .result
        .expect("result");
    let s2 = client2.stats();
    assert_eq!(s2.graph_uploads, 0, "cross-connection graph reuse");
    assert_eq!(s2.upload_skips, 1);
    client2.close();
    server.shutdown();
    coord.shutdown();
}

#[test]
fn concurrent_clients_all_bit_match_reference() {
    let (coord, server) = serve_host(|_| {}, |_| {});
    let addr = server.local_addr();
    let d = 8;
    let g = generators::erdos_renyi(150, 4.0, 17).with_self_loops();
    let (q, k, v) = features(g.n, d, 23);
    let reference =
        submit_inproc(&coord, 9000, &g, d, &q, &k, &v, Backend::CpuCsr);

    let shared = Arc::new((g, q, k, v));
    let mut handles = Vec::new();
    for c in 0..4u64 {
        let shared = shared.clone();
        handles.push(std::thread::spawn(move || {
            let (g, q, k, v) = &*shared;
            let mut client = NetClient::connect(addr, "").expect("connect");
            let mut outs = Vec::new();
            for r in 0..3u64 {
                let resp = client
                    .submit(&WireRequest::single_head(
                        c << 8 | r,
                        g,
                        d,
                        q,
                        k,
                        v,
                        0.25,
                        Backend::CpuCsr,
                    ))
                    .expect("submit");
                outs.push(resp.result.expect("result"));
            }
            client.close();
            outs
        }));
    }
    for h in handles {
        for out in h.join().expect("client thread") {
            assert_eq!(out, reference, "concurrent wire output diverged");
        }
    }
    server.shutdown();
    coord.shutdown();
}

#[test]
fn deadline_shed_travels_as_structured_error() {
    // A parked request (large batch-delay, waiting for company that never
    // comes) sheds at its deadline; the shed must cross the wire as the
    // structured `DeadlineExceeded`, not a closed connection.
    let (coord, server) = serve_host(
        |cfg| {
            cfg.max_batch_delay = Duration::from_secs(5);
            cfg.max_batch_requests = 64;
        },
        |_| {},
    );
    let mut client =
        NetClient::connect(server.local_addr(), "").expect("connect");
    let d = 4;
    let g = generators::ring(16).with_self_loops();
    let (q, k, v) = features(g.n, d, 5);
    let mut req =
        WireRequest::single_head(1, &g, d, &q, &k, &v, 1.0, Backend::CpuCsr);
    req.deadline = Some(Duration::from_millis(100));
    let resp = client.submit(&req).expect("transport must stay healthy");
    assert!(
        matches!(resp.result, Err(AttnError::DeadlineExceeded)),
        "want DeadlineExceeded, got {:?}",
        resp.result.map(|v| v.len())
    );
    // The session survives a shed: the next (deadline-free) request works.
    let ok = client
        .submit(&WireRequest::single_head(
            2,
            &g,
            d,
            &q,
            &k,
            &v,
            1.0,
            Backend::CpuCsr,
        ))
        .expect("submit after shed");
    ok.result.expect("post-shed result");
    assert_eq!(coord.metrics().faults.deadline_sheds(), 1);
    client.close();
    server.shutdown();
    coord.shutdown();
}

#[test]
fn pipelined_submits_all_answered() {
    // Hand-rolled pipelining (NetClient is lock-step by design): push 3
    // submit frames before reading any response, then collect all 3.
    // Responses may arrive in any completion order.
    use fused3s::net::frame::{read_frame, write_frame};
    use fused3s::net::proto::{GraphRef, Msg, SubmitMsg, VERSION};

    let (coord, server) = serve_host(|_| {}, |_| {});
    let stream = std::net::TcpStream::connect(server.local_addr())
        .expect("tcp connect");
    let max = 64 << 20;
    let hello = Msg::ClientHello { version: VERSION, token: String::new() };
    write_frame(&mut &stream, &hello.encode(), max).expect("hello");
    let ack = read_frame(&mut &stream, max).expect("server hello");
    assert!(matches!(
        Msg::decode(&ack).expect("decode hello"),
        Msg::ServerHello { ok: true, .. }
    ));

    let d = 4usize;
    let g = generators::ring(24).with_self_loops();
    let (q, k, v) = features(g.n, d, 13);
    for id in 1..=3u64 {
        let msg = Msg::Submit(SubmitMsg {
            id,
            graph: GraphRef::Inline(g.clone()),
            d: d as u32,
            dv: d as u32,
            heads: 1,
            scale: 1.0,
            backend: "cpu_csr".into(),
            deadline_micros: 0,
            q: q.clone(),
            k: k.clone(),
            v: v.clone(),
        });
        write_frame(&mut &stream, &msg.encode(), max).expect("submit frame");
    }
    let mut ids = Vec::new();
    for _ in 0..3 {
        let payload = read_frame(&mut &stream, max).expect("response frame");
        match Msg::decode(&payload).expect("decode response") {
            Msg::Response(r) => {
                r.payload.expect("pipelined request must succeed");
                ids.push(r.id);
            }
            _ => panic!("expected a response frame"),
        }
    }
    ids.sort_unstable();
    assert_eq!(ids, vec![1, 2, 3], "every pipelined submit answered");
    drop(stream);
    server.shutdown();
    coord.shutdown();
}

#[test]
fn shutdown_drains_inflight_requests() {
    let (coord, server) = serve_host(|_| {}, |_| {});
    let addr = server.local_addr();
    let d = 8;
    let g = generators::erdos_renyi(200, 4.0, 29).with_self_loops();
    let (q, k, v) = features(g.n, d, 31);

    let worker = std::thread::spawn(move || {
        let mut client = NetClient::connect(addr, "").expect("connect");
        let mut completed = 0u64;
        for r in 0..10_000u64 {
            let req = WireRequest::single_head(
                r,
                &g,
                d,
                &q,
                &k,
                &v,
                0.25,
                Backend::CpuCsr,
            );
            match client.submit(&req) {
                Ok(resp) => {
                    // Drained responses are real results, not garbage.
                    resp.result.expect("drained response is a result");
                    completed += 1;
                }
                // The drain cut the read side: transport error, clean exit.
                Err(_) => break,
            }
        }
        completed
    });

    // Let a few requests land, then drain mid-stream.
    std::thread::sleep(Duration::from_millis(150));
    server.shutdown();
    let completed =
        worker.join().expect("client thread exits cleanly after drain");
    assert!(completed >= 1, "no request completed before the drain");
    coord.shutdown();
}

#[test]
fn token_auth_happy_path() {
    let (coord, server) = serve_host(
        |_| {},
        |net| net.auth_tokens = vec!["sesame".to_string()],
    );
    let mut client = NetClient::connect(server.local_addr(), "sesame")
        .expect("authorized connect");
    let d = 4;
    let g = generators::ring(16).with_self_loops();
    let (q, k, v) = features(g.n, d, 37);
    client
        .submit(&WireRequest::single_head(
            1,
            &g,
            d,
            &q,
            &k,
            &v,
            1.0,
            Backend::CpuCsr,
        ))
        .expect("submit")
        .result
        .expect("result");
    assert_eq!(coord.metrics().net.auth_failures(), 0);
    client.close();
    server.shutdown();
    coord.shutdown();
}
