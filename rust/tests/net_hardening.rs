//! Malformed-input hardening for the network serving layer (ISSUE 8,
//! satellite 3): truncated frames, oversized length prefixes, bad
//! magic/version/token, unknown tags, invalid CSR payloads, and
//! mid-frame disconnects must surface as a structured error frame or a
//! clean close — never a panic, a leaked quota slot, or a wedged
//! batcher.
//!
//! One test arms the process-global fault hook, so every test in this
//! binary serialises on `GATE` (and `scripts/verify.sh` additionally
//! runs the suite with `--test-threads=1`).

use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

use fused3s::coordinator::{Coordinator, CoordinatorConfig, ExecutorKind};
use fused3s::exec::ExecPolicy;
use fused3s::fault::{self, FaultKind, FaultPlan, FaultSite};
use fused3s::graph::{generators, CsrGraph};
use fused3s::kernels::{AttnError, Backend};
use fused3s::net::frame::{read_frame, write_frame, FrameError, MAGIC};
use fused3s::net::proto::{
    GraphRef, Msg, SubmitMsg, CODE_GRAPH_UNKNOWN, CODE_PROTOCOL, VERSION,
};
use fused3s::net::{NetClient, NetConfig, NetError, NetServer, WireRequest};
use fused3s::util::prng::Rng;

/// Serialises every test in this binary: one of them arms the
/// process-global fault hook.
static GATE: Mutex<()> = Mutex::new(());

fn gate() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(PoisonError::into_inner)
}

const MAX: usize = 64 << 20;
const D: usize = 4;

fn serve(
    cfg_mut: impl FnOnce(&mut CoordinatorConfig),
    net_mut: impl FnOnce(&mut NetConfig),
) -> (Arc<Coordinator>, NetServer) {
    let mut cfg = CoordinatorConfig {
        executor: ExecutorKind::HostEmulation,
        preprocess_workers: 2,
        queue_capacity: 16,
        max_batch_requests: 1,
        max_batch_delay: Duration::from_millis(300),
        cache_capacity: 16,
        exec: ExecPolicy::serial(),
        ..CoordinatorConfig::default()
    };
    cfg_mut(&mut cfg);
    let coord = Arc::new(Coordinator::start(cfg).expect("host coordinator"));
    let mut net = NetConfig::default();
    net_mut(&mut net);
    let server = NetServer::serve(coord.clone(), net).expect("loopback bind");
    (coord, server)
}

fn graph() -> CsrGraph {
    generators::ring(16).with_self_loops()
}

fn features(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (
        rng.normal_vec(n * D, 1.0),
        rng.normal_vec(n * D, 1.0),
        rng.normal_vec(n * D, 1.0),
    )
}

/// Raw TCP connection that has completed a successful hello exchange.
fn raw_connect(addr: SocketAddr) -> TcpStream {
    let stream = TcpStream::connect(addr).expect("tcp connect");
    let hello = Msg::ClientHello { version: VERSION, token: String::new() };
    write_frame(&mut &stream, &hello.encode(), MAX).expect("hello");
    let ack = read_frame(&mut &stream, MAX).expect("server hello");
    assert!(
        matches!(Msg::decode(&ack), Ok(Msg::ServerHello { ok: true, .. })),
        "handshake must succeed before the hostile part of the test"
    );
    stream
}

/// A well-formed inline submit message (valid shapes, cpu_csr backend).
fn good_submit(id: u64, g: &CsrGraph, seed: u64) -> Msg {
    let (q, k, v) = features(g.n, seed);
    Msg::Submit(SubmitMsg {
        id,
        graph: GraphRef::Inline(g.clone()),
        d: D as u32,
        dv: D as u32,
        heads: 1,
        scale: 0.5,
        backend: "cpu_csr".into(),
        deadline_micros: 0,
        q,
        k,
        v,
    })
}

/// Read one frame and decode it as a `Response`, returning
/// `(id, Err((code, detail)))` or `(id, Ok(out_len))`.
fn read_response(stream: &TcpStream) -> (u64, Result<usize, (u8, String)>) {
    let payload = read_frame(&mut &*stream, MAX).expect("response frame");
    match Msg::decode(&payload).expect("decode response") {
        Msg::Response(r) => (r.id, r.payload.map(|ok| ok.out.len())),
        _ => panic!("expected a response frame"),
    }
}

/// The session must be gone: the next read yields EOF (or a reset,
/// depending on how fast the server tore the socket down).
fn assert_closed(stream: &TcpStream) {
    match read_frame(&mut &*stream, MAX) {
        Err(FrameError::Closed) | Err(FrameError::Io(_)) => {}
        other => panic!("expected closed session, got {other:?}"),
    }
}

/// The server survived: a brand-new client can still round-trip.
fn assert_server_alive(addr: SocketAddr) {
    let g = graph();
    let (q, k, v) = features(g.n, 99);
    let mut client = NetClient::connect(addr, "").expect("fresh connect");
    client
        .submit(&WireRequest::single_head(
            424242,
            &g,
            D,
            &q,
            &k,
            &v,
            0.5,
            Backend::CpuCsr,
        ))
        .expect("fresh submit")
        .result
        .expect("fresh result");
    client.close();
}

#[test]
fn bad_magic_is_session_fatal_not_server_fatal() {
    let _g = gate();
    let (coord, server) = serve(|_| {}, |_| {});
    let stream = raw_connect(server.local_addr());
    (&stream)
        .write_all(&[0xDE, 0xAD, 0xBE, 0xEF, 4, 0, 0, 0, 1, 2, 3, 4])
        .expect("write garbage");
    let (id, payload) = read_response(&stream);
    assert_eq!(id, 0, "protocol fatals carry the sentinel id 0");
    assert_eq!(payload.expect_err("must be an error").0, CODE_PROTOCOL);
    assert_closed(&stream);
    assert!(coord.metrics().net.protocol_errors() >= 1);
    assert_server_alive(server.local_addr());
    server.shutdown();
    coord.shutdown();
}

#[test]
fn oversize_length_prefix_is_rejected_before_allocation() {
    let _g = gate();
    let (coord, server) = serve(|_| {}, |_| {});
    let stream = raw_connect(server.local_addr());
    // A hostile header claiming a 4 GiB frame: the server must answer
    // with a structured fatal (it never allocates the claimed buffer).
    let mut hdr = Vec::new();
    hdr.extend_from_slice(&MAGIC.to_le_bytes());
    hdr.extend_from_slice(&u32::MAX.to_le_bytes());
    (&stream).write_all(&hdr).expect("write oversize header");
    let (id, payload) = read_response(&stream);
    assert_eq!(id, 0);
    assert_eq!(payload.expect_err("must be an error").0, CODE_PROTOCOL);
    assert_closed(&stream);
    assert_server_alive(server.local_addr());
    server.shutdown();
    coord.shutdown();
}

#[test]
fn truncated_frame_with_disconnect_cannot_wedge_the_server() {
    let _g = gate();
    let (coord, server) = serve(|_| {}, |_| {});
    let stream = raw_connect(server.local_addr());
    // Header promises 100 payload bytes; deliver 10 and cut the write
    // side.  The server's read_exact sees UnexpectedEof → Truncated.
    let mut partial = Vec::new();
    partial.extend_from_slice(&MAGIC.to_le_bytes());
    partial.extend_from_slice(&100u32.to_le_bytes());
    partial.extend_from_slice(&[7u8; 10]);
    (&stream).write_all(&partial).expect("write partial frame");
    stream
        .shutdown(std::net::Shutdown::Write)
        .expect("half-close write side");
    let (id, payload) = read_response(&stream);
    assert_eq!(id, 0);
    assert_eq!(payload.expect_err("must be an error").0, CODE_PROTOCOL);
    assert_closed(&stream);
    assert_server_alive(server.local_addr());
    server.shutdown();
    coord.shutdown();
}

#[test]
fn wrong_protocol_version_rejected_in_hello() {
    let _g = gate();
    let (coord, server) = serve(|_| {}, |_| {});
    let stream =
        TcpStream::connect(server.local_addr()).expect("tcp connect");
    let hello = Msg::ClientHello { version: 99, token: String::new() };
    write_frame(&mut &stream, &hello.encode(), MAX).expect("hello");
    let ack = read_frame(&mut &stream, MAX).expect("rejection hello");
    match Msg::decode(&ack).expect("decode") {
        Msg::ServerHello { ok, detail, .. } => {
            assert!(!ok);
            assert!(
                detail.contains("version"),
                "rejection must name the version mismatch: {detail:?}"
            );
        }
        _ => panic!("expected a server hello"),
    }
    assert_closed(&stream);
    assert!(coord.metrics().net.protocol_errors() >= 1);
    server.shutdown();
    coord.shutdown();
}

#[test]
fn bad_token_rejected_and_counted_good_token_still_works() {
    let _g = gate();
    let (coord, server) =
        serve(|_| {}, |net| net.auth_tokens = vec!["sesame".to_string()]);
    let err = NetClient::connect(server.local_addr(), "wrong")
        .err()
        .expect("wrong token must be rejected");
    match err {
        NetError::Rejected(detail) => {
            assert!(
                detail.contains("invalid auth token"),
                "unexpected rejection detail {detail:?}"
            );
        }
        other => panic!("expected auth rejection, got {other:?}"),
    }
    assert_eq!(coord.metrics().net.auth_failures(), 1);
    // The failed attempt must not poison the listener for honest clients.
    let g = graph();
    let (q, k, v) = features(g.n, 5);
    let mut client = NetClient::connect(server.local_addr(), "sesame")
        .expect("authorized connect");
    client
        .submit(&WireRequest::single_head(
            1,
            &g,
            D,
            &q,
            &k,
            &v,
            0.5,
            Backend::CpuCsr,
        ))
        .expect("submit")
        .result
        .expect("result");
    client.close();
    server.shutdown();
    coord.shutdown();
}

#[test]
fn unknown_message_tag_is_session_fatal() {
    let _g = gate();
    let (coord, server) = serve(|_| {}, |_| {});
    let stream = raw_connect(server.local_addr());
    write_frame(&mut &stream, &[42u8], MAX).expect("unknown tag frame");
    let (id, payload) = read_response(&stream);
    assert_eq!(id, 0);
    assert_eq!(payload.expect_err("must be an error").0, CODE_PROTOCOL);
    assert_closed(&stream);
    assert_server_alive(server.local_addr());
    server.shutdown();
    coord.shutdown();
}

#[test]
fn malformed_csr_is_rejected_at_decode() {
    let _g = gate();
    let (coord, server) = serve(|_| {}, |_| {});
    let stream = raw_connect(server.local_addr());
    // A CSR no in-process constructor can produce: non-monotone indptr.
    // `Msg::encode` serialises whatever it is given; the server-side
    // decode re-checks every invariant precisely because the network is
    // the one entry point that bypasses `CsrGraph::from_edges`.
    let bad = CsrGraph {
        n: 4,
        indptr: vec![0, 3, 2, 5, 6],
        indices: vec![0, 1, 2, 3, 0, 1],
    };
    let msg = Msg::Submit(SubmitMsg {
        id: 9,
        graph: GraphRef::Inline(bad),
        d: D as u32,
        dv: D as u32,
        heads: 1,
        scale: 0.5,
        backend: "cpu_csr".into(),
        deadline_micros: 0,
        q: vec![0.0; 16],
        k: vec![0.0; 16],
        v: vec![0.0; 16],
    });
    write_frame(&mut &stream, &msg.encode(), MAX).expect("bad csr frame");
    let (id, payload) = read_response(&stream);
    assert_eq!(id, 0, "decode failures are session-fatal, sentinel id");
    assert_eq!(payload.expect_err("must be an error").0, CODE_PROTOCOL);
    assert_closed(&stream);
    assert_server_alive(server.local_addr());
    server.shutdown();
    coord.shutdown();
}

#[test]
fn bad_shape_is_structured_and_the_session_survives() {
    let _g = gate();
    let (coord, server) = serve(|_| {}, |_| {});
    let g = graph();
    let (q, k, v) = features(g.n, 21);
    let mut client =
        NetClient::connect(server.local_addr(), "").expect("connect");
    // q three floats short of n*d: decodes fine (length-prefixed), fails
    // request validation in the batcher, and must come back as a typed
    // BadShape on the same connection.
    let short_q = &q[..q.len() - 3];
    let bad = WireRequest::single_head(
        1,
        &g,
        D,
        short_q,
        &k,
        &v,
        0.5,
        Backend::CpuCsr,
    );
    let resp = client.submit(&bad).expect("transport must stay healthy");
    assert!(
        matches!(resp.result, Err(AttnError::BadShape(_))),
        "want BadShape, got {:?}",
        resp.result.map(|o| o.len())
    );
    // Same client, correct shapes: the error released its quota slot and
    // left the session usable.
    let good =
        WireRequest::single_head(2, &g, D, &q, &k, &v, 0.5, Backend::CpuCsr);
    client.submit(&good).expect("submit").result.expect("result");
    client.close();
    server.shutdown();
    coord.shutdown();
}

#[test]
fn error_paths_do_not_leak_quota_slots() {
    let _g = gate();
    // Tiny per-session quota so a single leaked slot would deadlock the
    // pipelined phase below (and fail the test by timeout).
    let (coord, server) = serve(|_| {}, |net| net.max_inflight = 2);
    let stream = raw_connect(server.local_addr());
    let g = graph();

    // Phase 1: six fingerprint misses — answered without touching quota.
    for id in 1..=6u64 {
        let msg = Msg::Submit(SubmitMsg {
            id,
            graph: GraphRef::Fingerprint {
                fp: 0xDEAD_0000 + id,
                n: g.n as u32,
                nnz: g.indices.len() as u32,
            },
            d: D as u32,
            dv: D as u32,
            heads: 1,
            scale: 0.5,
            backend: "cpu_csr".into(),
            deadline_micros: 0,
            q: vec![0.0; g.n * D],
            k: vec![0.0; g.n * D],
            v: vec![0.0; g.n * D],
        });
        write_frame(&mut &stream, &msg.encode(), MAX).expect("miss frame");
        let (rid, payload) = read_response(&stream);
        assert_eq!(rid, id);
        assert_eq!(
            payload.expect_err("unknown graph must error").0,
            CODE_GRAPH_UNKNOWN
        );
    }

    // Phase 2: four pipelined bad-shape submits.  Each acquires a quota
    // slot; with quota 2, submits 3 and 4 only get admitted if the error
    // responses for 1 and 2 released theirs.
    for id in 10..=13u64 {
        let mut msg = good_submit(id, &g, id);
        if let Msg::Submit(s) = &mut msg {
            s.q.truncate(s.q.len() - 3);
        }
        write_frame(&mut &stream, &msg.encode(), MAX).expect("bad frame");
    }
    let mut ids = Vec::new();
    for _ in 0..4 {
        let (rid, payload) = read_response(&stream);
        payload.expect_err("short q must fail validation");
        ids.push(rid);
    }
    ids.sort_unstable();
    assert_eq!(ids, vec![10, 11, 12, 13]);

    // Phase 3: three pipelined good submits through the same quota.
    for id in 20..=22u64 {
        write_frame(&mut &stream, &good_submit(id, &g, id).encode(), MAX)
            .expect("good frame");
    }
    let mut ids = Vec::new();
    for _ in 0..3 {
        let (rid, payload) = read_response(&stream);
        payload.expect("good submit must succeed");
        ids.push(rid);
    }
    ids.sort_unstable();
    assert_eq!(ids, vec![20, 21, 22]);
    drop(stream);
    server.shutdown();
    coord.shutdown();
}

#[test]
fn mid_frame_disconnect_leaves_other_sessions_unaffected() {
    let _g = gate();
    let (coord, server) = serve(|_| {}, |_| {});
    let addr = server.local_addr();
    let g = graph();
    let (q, k, v) = features(g.n, 33);
    // Honest client connects first …
    let mut honest = NetClient::connect(addr, "").expect("connect");
    // … then a peer dies mid-frame.
    let hostile = raw_connect(addr);
    let mut partial = Vec::new();
    partial.extend_from_slice(&MAGIC.to_le_bytes());
    partial.extend_from_slice(&64u32.to_le_bytes());
    partial.extend_from_slice(&[1u8; 8]);
    (&hostile).write_all(&partial).expect("write partial frame");
    drop(hostile);
    // The honest session keeps serving.
    for id in 0..3u64 {
        honest
            .submit(&WireRequest::single_head(
                id,
                &g,
                D,
                &q,
                &k,
                &v,
                0.5,
                Backend::CpuCsr,
            ))
            .expect("submit")
            .result
            .expect("result");
    }
    honest.close();
    server.shutdown();
    coord.shutdown();
}

#[test]
fn injected_faults_surface_as_structured_wire_errors() {
    let _g = gate();
    // Short quarantine so the post-fault recovery check converges fast.
    let (coord, server) = serve(
        |cfg| cfg.quarantine_ttl = Duration::from_millis(200),
        |_| {},
    );
    let g = graph();
    let (q, k, v) = features(g.n, 44);
    let mut client =
        NetClient::connect(server.local_addr(), "").expect("connect");

    let guard = fault::install(
        FaultPlan::new(7).with(FaultSite::Prepare, FaultKind::Error, 1.0),
    );
    let req =
        WireRequest::single_head(1, &g, D, &q, &k, &v, 0.5, Backend::CpuCsr);
    // The transport must stay healthy whatever the fault does; the
    // degradation ladder may still serve a fallback (Ok) or exhaust into
    // a typed error — both are structured outcomes, never a dead socket.
    let resp = client.submit(&req).expect("transport survives faults");
    if let Err(e) = resp.result {
        assert!(
            matches!(e, AttnError::Prepare(_) | AttnError::Execute(_)),
            "fault must map to a typed prepare/execute error, got {e:?}"
        );
    }
    drop(guard);

    // Recovery: once the hook is gone and any quarantine expires, the
    // same session serves normally again.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    let mut id = 100u64;
    loop {
        let req = WireRequest::single_head(
            id,
            &g,
            D,
            &q,
            &k,
            &v,
            0.5,
            Backend::CpuCsr,
        );
        let resp = client.submit(&req).expect("transport alive");
        if resp.result.is_ok() {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "coordinator did not recover after fault hook removal"
        );
        std::thread::sleep(Duration::from_millis(100));
        id += 1;
    }
    client.close();
    server.shutdown();
    coord.shutdown();
}
