//! Coordinator serving loop: correctness under concurrency, error paths,
//! metrics.  Requires `make artifacts`.

use std::sync::mpsc::channel;

use fused3s::coordinator::{AttnRequest, Coordinator, CoordinatorConfig};
use fused3s::graph::generators;
use fused3s::kernels::{reference, AttentionProblem, Backend};
use fused3s::util::prng::Rng;

fn coordinator() -> Option<Coordinator> {
    match Coordinator::start(CoordinatorConfig::default()) {
        Ok(c) => Some(c),
        Err(e) => {
            eprintln!("skipping (run `make artifacts`): {e:#}");
            None
        }
    }
}

fn features(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (
        rng.normal_vec(n * d, 1.0),
        rng.normal_vec(n * d, 1.0),
        rng.normal_vec(n * d, 1.0),
    )
}

#[test]
fn serves_correct_results() {
    let Some(coord) = coordinator() else { return };
    let g = generators::erdos_renyi(200, 4.0, 1).with_self_loops();
    let (q, k, v) = features(g.n, 64, 2);
    let (tx, rx) = channel();
    coord
        .submit(AttnRequest::single_head(
            7,
            g.clone(),
            64,
            q.clone(),
            k.clone(),
            v.clone(),
            0.125,
            Backend::Fused3S,
            tx,
        ))
        .unwrap();
    let resp = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
    assert_eq!(resp.id, 7);
    let out = resp.result.expect("result");
    let x = AttentionProblem::new(g.n, 64, &q, &k, &v, 0.125);
    let want = reference::dense_attention_host(&g, &x);
    assert!(reference::max_abs_diff(&out, &want) < 0.15);
    assert!(resp.latency_s > 0.0);
    assert!(resp.preprocess_s >= 0.0 && resp.execute_s > 0.0);
    coord.shutdown();
}

#[test]
fn serves_many_requests_in_flight() {
    let Some(coord) = coordinator() else { return };
    let mut rxs = Vec::new();
    let count = 12;
    for i in 0..count {
        let g = generators::erdos_renyi(100 + i * 10, 4.0, i as u64)
            .with_self_loops();
        let (q, k, v) = features(g.n, 32, 100 + i as u64);
        let (tx, rx) = channel();
        coord
            .submit(AttnRequest::single_head(
                i as u64,
                g,
                32,
                q,
                k,
                v,
                1.0,
                Backend::Fused3S,
                tx,
            ))
            .unwrap();
        rxs.push(rx);
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(std::time::Duration::from_secs(300))
            .unwrap_or_else(|_| panic!("request {i} timed out"));
        assert!(resp.result.is_ok(), "request {i}: {:?}", resp.result.err());
    }
    assert_eq!(coord.metrics().completed(), count as u64);
    assert_eq!(coord.metrics().failed(), 0);
    let snap = coord.metrics().latency.snapshot();
    assert_eq!(snap.count, count);
    assert!(snap.p50_s > 0.0);
    coord.shutdown();
}

#[test]
fn invalid_request_fails_gracefully() {
    let Some(coord) = coordinator() else { return };
    let g = generators::ring(64).with_self_loops();
    let (tx, rx) = channel();
    coord
        .submit(AttnRequest::single_head(
            1,
            g,
            32,
            vec![0.0; 10], // wrong size
            vec![0.0; 10],
            vec![0.0; 10],
            1.0,
            Backend::Fused3S,
            tx,
        ))
        .unwrap();
    let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
    assert!(resp.result.is_err());
    assert_eq!(coord.metrics().failed(), 1);
    coord.shutdown();
}

#[test]
fn mixed_backends_served() {
    let Some(coord) = coordinator() else { return };
    let g = generators::sbm(4, 32, 0.1, 0.005, 3).with_self_loops();
    let (q, k, v) = features(g.n, 64, 4);
    let mut outs = Vec::new();
    for (i, b) in [Backend::Fused3S, Backend::UnfusedStable, Backend::CpuCsr]
        .into_iter()
        .enumerate()
    {
        let (tx, rx) = channel();
        coord
            .submit(AttnRequest::single_head(
                i as u64,
                g.clone(),
                64,
                q.clone(),
                k.clone(),
                v.clone(),
                0.5,
                b,
                tx,
            ))
            .unwrap();
        outs.push(
            rx.recv_timeout(std::time::Duration::from_secs(120))
                .unwrap()
                .result
                .unwrap(),
        );
    }
    for pair in outs.windows(2) {
        assert!(reference::max_abs_diff(&pair[0], &pair[1]) < 0.15);
    }
    coord.shutdown();
}
