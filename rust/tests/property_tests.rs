//! Property tests over the L3 coordinator invariants (hand-rolled
//! deterministic case generation; proptest is unavailable offline).
//!
//! Each property runs over a few dozen randomly-generated graphs spanning
//! the generator zoo.  These are the invariants the whole stack leans on:
//! BSB round-trips exactly, plans cover every row window exactly once,
//! padding/reordering are output-invariant, footprint models are monotone,
//! and the scheduler conserves work.

use fused3s::bsb::bucket::{covers_all_rws, plan};
use fused3s::bsb::reorder::{is_permutation, schedule, Order};
use fused3s::bsb::{self, bitmap, footprint, stats};
use fused3s::graph::{batch, generators, CsrGraph};
use fused3s::simulator::{simulate, SimConfig};
use fused3s::util::prng::Rng;

const BUCKETS: &[usize] = &[4, 8, 16, 32, 64, 128];

/// A zoo of random graphs covering the regimes of Table 6.
fn graph_zoo(cases: usize, seed: u64) -> Vec<CsrGraph> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for i in 0..cases {
        let n = rng.range(1, 2000);
        let g = match i % 6 {
            0 => generators::erdos_renyi(n, rng.f64() * 8.0, rng.next_u64()),
            1 => {
                let m = rng.range(1, 6);
                generators::barabasi_albert(n.max(m + 1), m, rng.next_u64())
            }
            2 => generators::rmat(
                (7 + rng.below(4)) as u32,
                1 + rng.below(12),
                0.5,
                0.2,
                0.2,
                rng.next_u64(),
            ),
            3 => generators::grid2d(rng.range(1, 40), rng.range(1, 40)),
            4 => {
                let (g, _) = batch::batched_dataset(
                    rng.range(2, 30),
                    4,
                    40,
                    rng.next_u64(),
                    batch::BatchKind::Molecule,
                );
                g
            }
            _ => generators::star(n.max(2)),
        };
        out.push(if rng.coin(0.5) { g.with_self_loops() } else { g });
    }
    out
}

#[test]
fn prop_bsb_roundtrip_exact() {
    for (i, g) in graph_zoo(36, 100).iter().enumerate() {
        for b in [bsb::build(g), bsb::build_bcsr_like(g)] {
            let mut edges = b.reconstruct_edges();
            edges.sort_unstable();
            let mut expect: Vec<(u32, u32)> = (0..g.n)
                .flat_map(|u| g.row(u).iter().map(move |&v| (u as u32, v)))
                .collect();
            expect.sort_unstable();
            assert_eq!(edges, expect, "case {i}: BSB round-trip mismatch");
        }
    }
}

#[test]
fn prop_bsb_nnz_conserved() {
    for g in graph_zoo(36, 200) {
        let b = bsb::build(&g);
        let total: u32 = b.nnz_per_tcb().iter().sum();
        assert_eq!(total as usize, g.nnz());
        assert_eq!(b.nnz, g.nnz());
    }
}

#[test]
fn prop_compaction_never_increases_tcbs() {
    for g in graph_zoo(24, 300) {
        let c = bsb::build(&g).total_tcbs();
        let nc = bsb::build_bcsr_like(&g).total_tcbs();
        assert!(c <= nc, "compaction increased TCB count ({c} > {nc})");
    }
}

#[test]
fn prop_schedules_are_permutations() {
    for g in graph_zoo(24, 400) {
        let b = bsb::build(&g);
        for order in [Order::Natural, Order::ByTcbDesc] {
            let s = schedule(&b, order);
            assert!(is_permutation(&s, b.num_rw));
        }
    }
}

#[test]
fn prop_plan_partitions_row_windows() {
    let mut rng = Rng::new(500);
    for g in graph_zoo(36, 500) {
        let b = bsb::build(&g);
        let batch_size = rng.range(1, 64);
        let order = if rng.coin(0.5) { Order::Natural } else { Order::ByTcbDesc };
        let p = plan(&b, BUCKETS, batch_size, order, 128);
        assert!(
            covers_all_rws(&p, b.num_rw),
            "plan must cover each RW exactly once (batch={batch_size})"
        );
        // Every dispatched RW fits its bucket.
        for c in &p.calls {
            for &rw in &c.rws {
                assert!(b.rw_tcbs(rw as usize) <= c.t_bucket);
                assert!(b.rw_tcbs(rw as usize) > 0);
            }
            assert!(c.rws.len() <= batch_size);
        }
        // Chunk counts are exact.
        for c in &p.chunked {
            let t = b.rw_tcbs(c.rw as usize);
            assert_eq!(c.n_chunks, t.div_ceil(128));
            assert!(t > *BUCKETS.last().unwrap());
        }
        // Skipped = empty.
        for &rw in &p.skipped {
            assert_eq!(b.rw_tcbs(rw as usize), 0);
        }
    }
}

#[test]
fn prop_bitmap_pack_unpack_identity() {
    let mut rng = Rng::new(600);
    for _ in 0..200 {
        let mut bm = bitmap::EMPTY;
        let mut expect = [[false; 8]; 16];
        for _ in 0..rng.below(40) {
            let (r, c) = (rng.below(16), rng.below(8));
            bitmap::set(&mut bm, r, c);
            expect[r][c] = true;
        }
        for (r, row) in expect.iter().enumerate() {
            for (c, &want) in row.iter().enumerate() {
                assert_eq!(bitmap::get(&bm, r, c), want);
            }
        }
        let nnz: u32 = expect.iter().flatten().map(|&b| b as u32).sum();
        assert_eq!(bitmap::popcount(&bm), nnz);
    }
}

#[test]
fn prop_footprints_positive_and_ordered() {
    for g in graph_zoo(18, 700) {
        if g.nnz() == 0 {
            continue;
        }
        let f = footprint::measure(&g);
        let rows = footprint::table3_rows(&f);
        for &(name, bits) in &rows {
            assert!(bits > 0, "{name} footprint must be positive");
        }
        // Value-storing block formats always dominate BSB (they store
        // b*rc fp32 values where BSB stores b*rc bits).
        let get = |n: &str| rows.iter().find(|(x, _)| *x == n).unwrap().1;
        assert!(get("BSB") < get("BCSR"));
        assert!(get("BSB") < get("SR-BCSR"));
        assert!(get("BSB") < get("ME-BCRS"));
    }
}

#[test]
fn prop_simulator_conserves_work() {
    for g in graph_zoo(18, 800) {
        let b = bsb::build(&g);
        let cfg = SimConfig::default();
        let nat = simulate(&b, Order::Natural, &cfg);
        let reo = simulate(&b, Order::ByTcbDesc, &cfg);
        assert!((nat.total_work - reo.total_work).abs() < 1e-9);
        // Makespan bounds: ideal <= makespan <= total work.
        for r in [&nat, &reo] {
            let ideal = r.total_work / cfg.num_sms as f64;
            assert!(r.makespan + 1e-9 >= ideal);
            assert!(r.makespan <= r.total_work + 1e-9);
            let sum_active: f64 = r.active.iter().sum();
            assert!((sum_active - r.total_work).abs() < 1e-6);
        }
        // LPT is never worse on makespan in this greedy model.
        assert!(reo.makespan <= nat.makespan + 1e-9);
    }
}

#[test]
fn prop_graph_generators_well_formed() {
    for g in graph_zoo(36, 900) {
        // CSR invariants.
        assert_eq!(g.indptr.len(), g.n + 1);
        assert_eq!(g.indptr[0], 0);
        assert_eq!(g.indptr[g.n] as usize, g.nnz());
        for i in 0..g.n {
            let row = g.row(i);
            for w in row.windows(2) {
                assert!(w[0] < w[1], "rows sorted + deduped");
            }
            for &c in row {
                assert!((c as usize) < g.n);
            }
        }
    }
}

#[test]
fn prop_stats_cv_nonnegative_and_scaleless() {
    for g in graph_zoo(12, 1000) {
        let b = bsb::build(&g);
        if b.total_tcbs() == 0 {
            continue;
        }
        let st = stats::compaction_stats(&b);
        assert!(st.tcb_per_rw_cv >= 0.0);
        assert!(st.nnz_per_tcb_cv >= 0.0);
        assert!(st.tcb_per_rw_avg >= 1.0);
        assert!(st.nnz_per_tcb_avg >= 1.0 && st.nnz_per_tcb_avg <= 128.0);
    }
}
