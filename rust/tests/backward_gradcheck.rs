//! Finite-difference gradient checks for `BackwardDriver` (ISSUE 2):
//! analytic dQ/dK/dV from the bucketed backward path vs central differences
//! of the forward computation, on small graphs covering **both**
//! `BWD_BUCKETS` (t = 8 and t = 32).  Runs offline through the host
//! backward emulation (`exec::HostExecutor` as `BackwardExecutor`).
//!
//! Tolerance rationale (documented per the ISSUE):
//! * loss `L = Σ_ij W_ij · O_ij` with O(1) f32 inputs and a fixed random W;
//! * central differences with `eps = 1e-2` have O(eps²) ≈ 1e-4 truncation
//!   error plus ~1e-7/eps ≈ 1e-5 f32 forward-rounding noise;
//! * the analytic path accumulates in f32 (what the device kernel does),
//!   adding ~1e-5-scale rounding on graphs this size.
//! The check therefore uses |analytic − fd| < 5e-3 + 1e-2·|fd| per
//! parameter, with gradients empirically O(0.1..1) on these inputs.

use fused3s::exec::{offline_manifest, HostExecutor, WorkerPool};
use fused3s::graph::batch::random_molecule;
use fused3s::graph::{generators, CsrGraph};
use fused3s::kernels::backward::{
    backward_reference, BackwardDriver, BWD_BUCKETS,
};
use fused3s::kernels::{reference, AttentionProblem};
use fused3s::runtime::Manifest;
use fused3s::util::prng::Rng;

fn manifest() -> Manifest {
    offline_manifest(8, &[4, 8, 16, 32, 64, 128], 128)
}

/// Scalar loss over the forward output: L = Σ_ij W_ij O_ij (f64 sum).
fn loss(g: &CsrGraph, x: &AttentionProblem, w: &[f32]) -> f64 {
    let out = reference::dense_attention_host(g, x);
    out.iter().zip(w).map(|(&o, &wi)| o as f64 * wi as f64).sum()
}

fn assert_close(analytic: f32, fd: f64, what: &str, idx: usize) {
    let tol = 5e-3 + 1e-2 * fd.abs();
    assert!(
        (analytic as f64 - fd).abs() < tol,
        "{what}[{idx}]: analytic {analytic} vs central-diff {fd} (tol {tol})"
    );
}

/// Full gradcheck of one graph: analytic gradients from the bucketed
/// backward driver vs central differences, sampling every `stride`-th
/// parameter of each of Q, K, V.
fn gradcheck(g: &CsrGraph, d: usize, seed: u64, expect_bucket: usize, stride: usize) {
    let man = manifest();
    let driver = BackwardDriver::new(&man, g).expect("backward driver");
    assert!(
        driver.buckets_used().contains(&expect_bucket),
        "graph (n={}) planned into {:?}, expected bucket {expect_bucket}",
        g.n,
        driver.buckets_used()
    );
    for b in driver.buckets_used() {
        assert!(BWD_BUCKETS.contains(&b), "plan used non-backward bucket {b}");
    }

    let n = g.n;
    let mut rng = Rng::new(seed);
    let mut q = rng.normal_vec(n * d, 1.0);
    let mut k = rng.normal_vec(n * d, 1.0);
    let mut v = rng.normal_vec(n * d, 1.0);
    let w = rng.normal_vec(n * d, 1.0);
    let scale = 0.5; // != 1 so the dQ chain-rule rescaling is exercised

    // Analytic gradients through the bucketed backward path (host emulation
    // of the fused3s_bwd kernel), with d_out = ∂L/∂O = W.
    let pool = WorkerPool::new(1);
    let grads = {
        let x = AttentionProblem::new(n, d, &q, &k, &v, scale);
        driver
            .run_exec(&x, &w, &mut HostExecutor::new(&pool))
            .expect("backward run")
    };

    // Cross-check against the independent dense analytic reference first:
    // same math, f64 accumulation, no bucketing/gather/scatter-add.
    {
        let x = AttentionProblem::new(n, d, &q, &k, &v, scale);
        let refg = backward_reference(g, &x, &w);
        for (name, got, want) in [
            ("dq", &grads.dq, &refg.dq),
            ("dk", &grads.dk, &refg.dk),
            ("dv", &grads.dv, &refg.dv),
        ] {
            let err = reference::max_abs_diff(got, want);
            assert!(err < 1e-3, "{name} vs analytic reference: max err {err}");
        }
    }

    // Central differences.  The perturbation is applied in f32, so the
    // effective step is the *representable* difference `hi - lo`, not
    // 2·eps exactly.
    let eps = 1e-2f32;
    for (buf_sel, what) in [(0usize, "dq"), (1, "dk"), (2, "dv")] {
        for idx in (0..n * d).step_by(stride) {
            let old = match buf_sel {
                0 => q[idx],
                1 => k[idx],
                _ => v[idx],
            };
            let hi = old + eps;
            let lo = old - eps;
            let l_hi = perturbed_loss(
                g, &mut q, &mut k, &mut v, &w, d, scale, buf_sel, idx, hi,
            );
            let l_lo = perturbed_loss(
                g, &mut q, &mut k, &mut v, &w, d, scale, buf_sel, idx, lo,
            );
            match buf_sel {
                0 => q[idx] = old,
                1 => k[idx] = old,
                _ => v[idx] = old,
            }
            let fd = (l_hi - l_lo) / ((hi - lo) as f64);
            let analytic = match buf_sel {
                0 => grads.dq[idx],
                1 => grads.dk[idx],
                _ => grads.dv[idx],
            };
            assert_close(analytic, fd, what, idx);
        }
    }
}

/// Set one parameter of the selected buffer and evaluate the loss.
#[allow(clippy::too_many_arguments)]
fn perturbed_loss(
    g: &CsrGraph,
    q: &mut [f32],
    k: &mut [f32],
    v: &mut [f32],
    w: &[f32],
    d: usize,
    scale: f32,
    buf_sel: usize,
    idx: usize,
    value: f32,
) -> f64 {
    {
        let buf = match buf_sel {
            0 => &mut *q,
            1 => &mut *k,
            _ => &mut *v,
        };
        buf[idx] = value;
    }
    let x = AttentionProblem::new(g.n, d, q, k, v, scale);
    loss(g, &x, w)
}

#[test]
fn gradcheck_small_molecule_bucket8() {
    // Molecule-sized graph: every row window fits the t=8 backward bucket.
    let mut rng = Rng::new(31);
    let g = random_molecule(40, &mut rng).with_self_loops();
    gradcheck(&g, 8, 77, 8, 1);
}

#[test]
fn gradcheck_denser_graph_bucket32() {
    // Denser windows (> 8 TCBs) exercise the t=32 backward bucket and the
    // scatter-add of columns repeated across row windows.
    let g = generators::erdos_renyi(150, 12.0, 9).with_self_loops();
    gradcheck(&g, 8, 78, 32, 7);
}

#[test]
fn gradcheck_ragged_star_bucket8() {
    // Ragged n (not a multiple of 16) + hub/leaf imbalance.
    let g = generators::star(45).with_self_loops();
    gradcheck(&g, 4, 79, 8, 1);
}

#[test]
fn oversize_row_window_rejected() {
    // A hub row window beyond the largest backward bucket must refuse at
    // prepare time (chunked backward is future work), not miscompute.
    let man = manifest();
    let g = generators::star(2000);
    let err = BackwardDriver::new(&man, &g).err().expect("must refuse");
    assert!(format!("{err:#}").contains("chunked backward"));
}
