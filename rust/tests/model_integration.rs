//! Model runtimes vs host references: GT block semantics, GAT, AGNN.
//! Requires `make artifacts`.

use fused3s::graph::generators;
use fused3s::kernels::{reference, Backend};
use fused3s::model::agnn::{agnn_reference, AgnnLayer};
use fused3s::model::gat::{gat_reference, GatAttention, GatLayer};
use fused3s::model::weights::random_features;
use fused3s::model::{GraphTransformer, GtConfig};
use fused3s::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    match Runtime::from_default_artifacts() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping (run `make artifacts`): {e:#}");
            None
        }
    }
}

#[test]
fn gt_inference_runs_and_is_finite() {
    let Some(rt) = runtime() else { return };
    let g = generators::erdos_renyi(300, 5.0, 1).with_self_loops();
    let cfg = GtConfig { d: 64, n_blocks: 2, backend: Backend::Fused3S, seed: 3 };
    let model = GraphTransformer::prepare(&rt, &g, cfg).unwrap();
    let h = random_features(4, g.n, 64);
    let (out, t) = model.infer(&rt, &h).unwrap();
    assert_eq!(out.len(), g.n * 64);
    assert!(out.iter().all(|x| x.is_finite()));
    assert!(t.total_s > 0.0);
    assert!(t.attention_s > 0.0 && t.attention_s < t.total_s);
    // LayerNorm at the block output: per-row mean ~ 0 (unit gamma, zero beta).
    for i in 0..g.n {
        let row = &out[i * 64..(i + 1) * 64];
        let mean: f32 = row.iter().sum::<f32>() / 64.0;
        assert!(mean.abs() < 1e-2, "row {i} mean {mean}");
    }
}

#[test]
fn gt_backends_agree() {
    // Fig. 8's premise: all kernels compute the same model.
    let Some(rt) = runtime() else { return };
    let g = generators::sbm(6, 32, 0.12, 0.004, 2).with_self_loops();
    let h = random_features(5, g.n, 64);
    let mut outs = Vec::new();
    for b in [Backend::Fused3S, Backend::UnfusedStable, Backend::DfGnnLike] {
        let cfg = GtConfig { d: 64, n_blocks: 2, backend: b, seed: 3 };
        let model = GraphTransformer::prepare(&rt, &g, cfg).unwrap();
        outs.push(model.infer(&rt, &h).unwrap().0);
    }
    for pair in outs.windows(2) {
        let err = reference::max_abs_diff(&pair[0], &pair[1]);
        // LayerNorm renormalises per block, keeping bf16 drift bounded.
        assert!(err < 0.35, "backends disagree: {err}");
    }
}

#[test]
fn gt_rejects_bad_config() {
    let Some(rt) = runtime() else { return };
    let g = generators::ring(64).with_self_loops();
    // d not multiple of head width
    assert!(GraphTransformer::prepare(
        &rt,
        &g,
        GtConfig { d: 48, n_blocks: 1, backend: Backend::Fused3S, seed: 0 }
    )
    .is_err());
    // d without dense-op artifacts
    assert!(GraphTransformer::prepare(
        &rt,
        &g,
        GtConfig { d: 32, n_blocks: 1, backend: Backend::Fused3S, seed: 0 }
    )
    .is_err());
}

#[test]
fn gat_matches_reference() {
    let Some(rt) = runtime() else { return };
    let g = generators::erdos_renyi(400, 5.0, 7).with_self_loops();
    let layer = GatLayer::generate(8, 16, 64);
    let att = GatAttention::prepare(rt.manifest(), &g).unwrap();
    let h = random_features(9, g.n, 16);
    let got = att.forward(&rt, &layer, &h, g.n).unwrap();
    let want = gat_reference(&g, &layer, &h, g.n);
    let err = reference::max_abs_diff(&got, &want);
    assert!(err < 0.15, "GAT max err {err}");
}

#[test]
fn agnn_matches_reference() {
    let Some(rt) = runtime() else { return };
    let g = generators::barabasi_albert(500, 4, 10).with_self_loops();
    let layer = AgnnLayer::prepare(&rt, &g, 1.8).unwrap();
    let h = random_features(11, g.n, 64);
    let got = layer.forward(&rt, &h, g.n, 64).unwrap();
    let want = agnn_reference(&g, &h, g.n, 64, 1.8);
    let err = reference::max_abs_diff(&got, &want);
    assert!(err < 0.1, "AGNN max err {err}");
}
