//! Differential harness for the hybrid geometry router (DESIGN.md §12):
//! mixed wide/narrow/dense dispatch must be **bit-identical** to the
//! 16-row all-wide reference — and to the fused driver where it applies —
//! across the ISSUE's generator mix, `heads ∈ {1, 4}`, `d ≠ dv`, serial
//! and parallel pipelined engines, and the whole coordinator path under
//! `ExecutorKind::HostEmulation`.
//!
//! Why bit-equality is the right contract: the three paths partition the
//! row windows, so their scatters touch disjoint output rows, and every
//! path visits a row's nonzero columns in ascending original-column order
//! with the same scalar op sequence — routing changes *which call* covers
//! a window, never the arithmetic inside it.  The only merge seam is the
//! wide path's oversize-chunk fold, shared verbatim with the fused
//! driver.  Runs entirely offline through the host kernel; no artifacts
//! needed.

use std::collections::HashMap;
use std::sync::mpsc::channel;
use std::time::Duration;

use fused3s::bsb::geometry::{self, RouteParams, RwPath};
use fused3s::bsb::reorder::Order;
use fused3s::bsb::{self, Bsb};
use fused3s::coordinator::{
    AttnRequest, Coordinator, CoordinatorConfig, ExecutorKind,
};
use fused3s::exec::{offline_manifest, Engine, ExecPolicy};
use fused3s::graph::{generators, CsrGraph};
use fused3s::kernels::hybrid::HybridDriver;
use fused3s::kernels::{
    AttentionBatch, Backend, ExecCtx, Plan, SparseAttentionOp,
};
use fused3s::runtime::Manifest;
use fused3s::util::prng::Rng;

const BUCKETS: &[usize] = &[4, 8, 16, 32, 64, 128];
const HEAD_COUNTS: &[usize] = &[1, 4];

fn manifest() -> Manifest {
    // Matches the coordinator's HostEmulation bucketing configuration.
    offline_manifest(8, BUCKETS, 128)
}

/// Head-major feature buffers for `heads` heads over n nodes.
fn head_features(
    n: usize,
    d: usize,
    dv: usize,
    heads: usize,
    seed: u64,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (
        rng.normal_vec(heads * n * d, 1.0),
        rng.normal_vec(heads * n * d, 1.0),
        rng.normal_vec(heads * n * dv, 1.0),
    )
}

/// The ISSUE's generator mix, chosen so the router exercises every path:
/// ER and power-law windows scatter (narrow), star leaves are
/// single-column (dense) while the hub is oversize (wide + chunked), and
/// the SBM blocks sit in between.
fn graph_suite() -> Vec<(&'static str, CsrGraph)> {
    vec![
        ("er", generators::erdos_renyi(400, 5.0, 3).with_self_loops()),
        ("sbm", generators::sbm(6, 24, 0.3, 0.02, 5).with_self_loops()),
        ("star", generators::star(1500)),
        ("power_law", generators::power_law(512, 6.0, 2.3, 9).with_self_loops()),
    ]
}

/// The 16-row reference: every window forced onto the wide path — the
/// exact pre-geometry plan shape — executed through the same driver code.
fn all_wide_reference(man: &Manifest, bsb: Bsb) -> HybridDriver {
    let params = RouteParams { narrow: false, dense: false, ..Default::default() };
    HybridDriver::from_bsb_with(man, bsb, &params).expect("all-wide reference")
}

/// Routed-hybrid vs all-wide-reference (and vs fused where `d == dv`)
/// differential for one graph across the head sweep and both engine
/// policies.
fn check_graph(name: &str, g: &CsrGraph, d: usize, dv: usize, seed: u64) {
    let man = manifest();
    let serial = Engine::serial();
    let bsb = bsb::build(g);
    let wide_ref = all_wide_reference(&man, bsb.clone());
    let fused = (d == dv)
        .then(|| Plan::new(&man, g, Backend::Fused3S, &serial).expect("fused"));
    for &heads in HEAD_COUNTS {
        let (q, k, v) = head_features(g.n, d, dv, heads, seed + heads as u64);
        let x = AttentionBatch::new(g.n, d, dv, heads, &q, &k, &v, 0.25);
        let want = wide_ref
            .execute(&mut ExecCtx::host(&serial), &x)
            .expect("all-wide reference run");
        assert_eq!(want.len(), x.out_len());
        if let Some(fused) = &fused {
            let fw = fused
                .execute(&mut ExecCtx::host(&serial), &x)
                .expect("fused run");
            assert_eq!(
                fw, want,
                "{name} heads={heads}: all-wide hybrid reference diverged \
                 from the fused driver"
            );
        }
        for policy in [
            ExecPolicy::serial(),
            ExecPolicy { threads: 4, pipeline_depth: 2 },
        ] {
            let engine = Engine::new(policy);
            let plan = Plan::new(&man, g, Backend::Hybrid, &engine)
                .expect("hybrid plan");
            assert_eq!(plan.backend(), Backend::Hybrid);
            let got = plan
                .execute(&mut ExecCtx::host(&engine), &x)
                .expect("hybrid run");
            assert_eq!(
                got, want,
                "{name} heads={heads} d={d} dv={dv} {policy:?}: routed \
                 hybrid diverged from the 16-row all-wide reference"
            );
        }
    }
}

#[test]
fn hybrid_bit_matches_all_wide_reference_and_fused() {
    for (i, (name, g)) in graph_suite().iter().enumerate() {
        check_graph(name, g, 16, 16, 100 * (i as u64 + 1));
    }
}

#[test]
fn hybrid_supports_d_ne_dv() {
    // GAT-shaped problems (rank-2 scores, wide values): the fused driver
    // rejects these, but the hybrid driver's host kernels are general —
    // the all-wide forced routing is the reference.
    for (i, (name, g)) in graph_suite().iter().enumerate() {
        check_graph(name, g, 4, 12, 1000 * (i as u64 + 1));
    }
}

#[test]
fn router_covers_all_three_paths_across_the_suite() {
    let man = manifest();
    let mut wide = 0usize;
    let mut narrow = 0usize;
    let mut dense = 0usize;
    for (name, g) in graph_suite() {
        let bsb = bsb::build(&g);
        let hplan = geometry::plan_hybrid(
            &bsb,
            &man.t_buckets,
            man.rw_batch,
            Order::ByTcbDesc,
            man.chunk_t,
        );
        assert_eq!(hplan.routes.len(), bsb.num_rw, "{name}: route per window");
        let n_narrow =
            hplan.routes.iter().filter(|r| **r == RwPath::Narrow).count();
        let n_dense =
            hplan.routes.iter().filter(|r| **r == RwPath::Dense).count();
        // The stats the planner prices from must agree with the routes the
        // driver dispatches.
        assert_eq!(hplan.stats.narrow_windows, n_narrow, "{name}");
        assert_eq!(hplan.stats.dense_windows, n_dense, "{name}");
        wide += hplan.routes.len() - n_narrow - n_dense;
        narrow += n_narrow;
        dense += n_dense;
        if name == "star" {
            assert!(
                !hplan.wide.chunked.is_empty(),
                "the star hub must stay on the chunked wide path"
            );
            assert!(n_dense > 0, "star leaf windows must route dense");
        }
    }
    assert!(wide > 0, "suite never exercised the wide path");
    assert!(narrow > 0, "suite never exercised the narrow path");
    assert!(dense > 0, "suite never exercised the dense path");
}

#[test]
fn auto_from_bsb_picks_hybrid_only_when_cheaper() {
    let man = manifest();
    let serial = Engine::serial();

    // Scattered ER windows: the router roughly halves dispatched cells
    // (scripts/packing_model.py), far beyond the hybrid cost row's fixed
    // premium — auto must route hybrid, and the hybrid plan must still
    // bit-match the fused driver.
    let g = generators::erdos_renyi(2048, 6.0, 7).with_self_loops();
    let auto =
        Plan::from_bsb(&man, bsb::build(&g), Backend::Auto).expect("auto plan");
    assert_eq!(auto.backend(), Backend::Hybrid, "packing win must route hybrid");
    let (q, k, v) = head_features(g.n, 16, 16, 1, 42);
    let x = AttentionBatch::new(g.n, 16, 16, 1, &q, &k, &v, 0.25);
    let got = auto.execute(&mut ExecCtx::host(&serial), &x).expect("auto run");
    let fused = Plan::new(&man, &g, Backend::Fused3S, &serial).expect("fused");
    let want =
        fused.execute(&mut ExecCtx::host(&serial), &x).expect("fused run");
    assert_eq!(got, want, "auto-routed hybrid diverged from fused");

    // A tiny regular ring saves almost nothing: the fixed premium wins and
    // auto must NOT pick hybrid.
    let g = generators::ring(64);
    let auto =
        Plan::from_bsb(&man, bsb::build(&g), Backend::Auto).expect("auto plan");
    assert_ne!(
        auto.backend(),
        Backend::Hybrid,
        "hybrid must only be selected when the cost model prices it cheaper"
    );
}

/// The full coordinator path with hybrid requests: admission → coalescing
/// → cache → merged hybrid driver → scatter must reproduce per-request
/// serial hybrid runs bit-for-bit under `ExecutorKind::HostEmulation`.
#[test]
fn coordinator_hybrid_host_emulation_bit_matches() {
    let man = manifest();
    let d = 8;
    let heads = 4;
    let scale = 0.25;
    let graphs: Vec<CsrGraph> = vec![
        generators::erdos_renyi(90, 4.0, 11).with_self_loops(),
        generators::star(70),
        generators::sbm(3, 16, 0.2, 0.02, 12).with_self_loops(),
    ];
    let feats: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = graphs
        .iter()
        .enumerate()
        .map(|(i, g)| head_features(g.n, d, d, heads, 3000 + i as u64))
        .collect();
    // Per-request serial hybrid oracle.
    let serial = Engine::serial();
    let expect: Vec<Vec<f32>> = graphs
        .iter()
        .zip(&feats)
        .map(|(g, (q, k, v))| {
            let plan = Plan::new(&man, g, Backend::Hybrid, &serial).unwrap();
            let x = AttentionBatch::new(g.n, d, d, heads, q, k, v, scale);
            plan.execute(&mut ExecCtx::host(&serial), &x).expect("oracle")
        })
        .collect();

    let coord = Coordinator::start(CoordinatorConfig {
        executor: ExecutorKind::HostEmulation,
        preprocess_workers: 2,
        queue_capacity: 16,
        max_batch_delay: Duration::from_millis(500),
        max_batch_requests: 16,
        max_batch_nodes: 1 << 20,
        cache_capacity: 8,
        ..CoordinatorConfig::default()
    })
    .expect("host-emulation coordinator");

    let (tx, rx) = channel();
    for (i, (g, (q, k, v))) in graphs.iter().zip(&feats).enumerate() {
        coord
            .submit(AttnRequest {
                id: i as u64,
                graph: g.clone(),
                d,
                dv: d,
                heads,
                q: q.clone(),
                k: k.clone(),
                v: v.clone(),
                scale,
                backend: Backend::Hybrid,
                deadline: None,
                span: 0,
                reply: tx.clone(),
            })
            .expect("submit");
    }
    drop(tx);
    let mut got: HashMap<u64, Vec<f32>> = HashMap::new();
    while let Ok(resp) = rx.recv_timeout(Duration::from_secs(120)) {
        assert!(resp.batch_size >= 1);
        got.insert(resp.id, resp.result.expect("result"));
        if got.len() == graphs.len() {
            break;
        }
    }
    assert_eq!(got.len(), graphs.len(), "missing responses");
    for (i, want) in expect.iter().enumerate() {
        assert_eq!(
            &got[&(i as u64)], want,
            "component {i}: coordinator hybrid output diverged from the \
             serial per-request oracle"
        );
    }
    coord.shutdown();
}
