//! Determinism contract of the host execution engine (EXPERIMENTS.md
//! §Perf): every `ExecPolicy` — serial reference, threaded gathers,
//! pipelined double buffering — must produce **bit-identical** driver
//! output, and the sharded BSB build must produce a `Bsb` **equal** to the
//! serial build.  Runs entirely offline through the host kernel; no
//! artifacts needed.

use fused3s::bsb;
use fused3s::exec::{offline_manifest, Engine, ExecPolicy, HostExecutor, WorkerPool};
use fused3s::graph::{generators, CsrGraph};
use fused3s::kernels::fused::{FusedDriver, FusedOpts};
use fused3s::kernels::unfused::UnfusedDriver;
use fused3s::kernels::{reference, AttentionBatch, AttentionProblem};
use fused3s::runtime::Manifest;
use fused3s::util::prng::Rng;

const BUCKETS: &[usize] = &[4, 8, 16, 32, 64, 128];

fn manifest() -> Manifest {
    offline_manifest(8, BUCKETS, 128)
}

fn features(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (
        rng.normal_vec(n * d, 1.0),
        rng.normal_vec(n * d, 1.0),
        rng.normal_vec(n * d, 1.0),
    )
}

/// The policy grid the bit-exactness assertions sweep.
fn policies() -> Vec<ExecPolicy> {
    vec![
        ExecPolicy { threads: 1, pipeline_depth: 2 },
        ExecPolicy { threads: 2, pipeline_depth: 1 },
        ExecPolicy { threads: 4, pipeline_depth: 2 },
        ExecPolicy { threads: 4, pipeline_depth: 4 },
    ]
}

/// The graph set: regular, ragged-n (not a multiple of 16), power-law, and
/// a mega-hub star that forces the chunked-RW path.
fn graph_suite() -> Vec<(&'static str, CsrGraph)> {
    vec![
        ("er", generators::erdos_renyi(1000, 6.0, 1).with_self_loops()),
        ("ragged", generators::erdos_renyi(277, 4.0, 2).with_self_loops()),
        ("ba", generators::barabasi_albert(800, 5, 3).with_self_loops()),
        ("star-chunked", generators::star(5000)),
    ]
}

#[test]
fn parallel_bsb_build_equals_serial_on_suite() {
    for threads in [2, 3, 4, 8] {
        let pool = WorkerPool::new(threads);
        for (name, g) in graph_suite() {
            assert_eq!(
                bsb::build(&g),
                bsb::build_with(&g, &pool),
                "{name} threads={threads}"
            );
            assert_eq!(
                bsb::build_bcsr_like(&g),
                bsb::build_bcsr_like_with(&g, &pool),
                "{name} threads={threads} (bcsr)"
            );
        }
    }
}

#[test]
fn fused_engine_is_bit_exact_across_policies() {
    let man = manifest();
    let d = 32;
    for (name, g) in graph_suite() {
        let (q, k, v) = features(g.n, d, 7);
        let x = AttentionProblem::new(g.n, d, &q, &k, &v, 0.5);
        let serial = Engine::serial();
        let driver = FusedDriver::new(&man, &g, FusedOpts::default()).unwrap();
        let want = driver
            .execute_with(&AttentionBatch::single(&x), &serial, &mut HostExecutor::new(&serial.pool))
            .unwrap();
        if name == "star-chunked" {
            assert!(!driver.plan.chunked.is_empty(), "star must chunk");
        }
        // Numerical sanity against the independent dense reference.
        let dense = reference::dense_attention_host(&g, &x);
        let err = reference::max_abs_diff(&want, &dense);
        assert!(err < 5e-3, "{name}: host kernel err {err}");
        for policy in policies() {
            let engine = Engine::new(policy);
            let par_driver =
                FusedDriver::new_with(&man, &g, FusedOpts::default(), &engine)
                    .unwrap();
            assert_eq!(par_driver.bsb, driver.bsb, "{name} {policy:?}");
            let got = par_driver
                .execute_with(&AttentionBatch::single(&x), &engine, &mut HostExecutor::new(&engine.pool))
                .unwrap();
            assert_eq!(got, want, "{name} {policy:?} not bit-identical");
        }
    }
}

#[test]
fn unfused_engine_is_bit_exact_across_policies() {
    let man = manifest();
    let d = 16;
    for (name, g) in [
        ("er", generators::erdos_renyi(900, 5.0, 11).with_self_loops()),
        ("ragged", generators::erdos_renyi(123, 3.0, 12).with_self_loops()),
    ] {
        let (q, k, v) = features(g.n, d, 13);
        let x = AttentionProblem::new(g.n, d, &q, &k, &v, 1.0);
        let serial = Engine::serial();
        let driver = UnfusedDriver::new(
            &man,
            &g,
            true,
            fused3s::bsb::reorder::Order::ByTcbDesc,
        )
        .unwrap();
        let want = driver
            .execute_with(&AttentionBatch::single(&x), &serial, &mut HostExecutor::new(&serial.pool))
            .unwrap();
        let dense = reference::dense_attention_host(&g, &x);
        let err = reference::max_abs_diff(&want, &dense);
        assert!(err < 1e-3, "{name}: host kernel err {err}");
        for policy in policies() {
            let engine = Engine::new(policy);
            let got = driver
                .execute_with(&AttentionBatch::single(&x), &engine, &mut HostExecutor::new(&engine.pool))
                .unwrap();
            assert_eq!(got, want, "{name} {policy:?} not bit-identical");
        }
    }
}

#[test]
fn chunked_merge_matches_reference_closely() {
    // The star hub row attends to 5000 columns across ~5 chunks; the
    // host-side online-softmax merge must agree with the exact reference.
    let man = manifest();
    let g = generators::star(5000);
    let d = 16;
    let (q, k, v) = features(g.n, d, 21);
    let x = AttentionProblem::new(g.n, d, &q, &k, &v, 1.0);
    let engine = Engine::new(ExecPolicy { threads: 4, pipeline_depth: 2 });
    let driver = FusedDriver::new_with(&man, &g, FusedOpts::default(), &engine)
        .unwrap();
    let got = driver
        .execute_with(&AttentionBatch::single(&x), &engine, &mut HostExecutor::new(&engine.pool))
        .unwrap();
    let want = reference::dense_attention_host(&g, &x);
    let err = reference::max_abs_diff(&got, &want);
    assert!(err < 1e-2, "chunked merge err {err}");
}

#[test]
fn buffer_arena_recycles_across_runs() {
    let man = manifest();
    let g = generators::erdos_renyi(512, 5.0, 31).with_self_loops();
    let d = 16;
    let (q, k, v) = features(g.n, d, 32);
    let x = AttentionProblem::new(g.n, d, &q, &k, &v, 1.0);
    let engine = Engine::new(ExecPolicy { threads: 2, pipeline_depth: 2 });
    let driver = FusedDriver::new_with(&man, &g, FusedOpts::default(), &engine)
        .unwrap();
    let a = driver
        .execute_with(&AttentionBatch::single(&x), &engine, &mut HostExecutor::new(&engine.pool))
        .unwrap();
    let pooled = engine.buffers.available();
    assert!(pooled >= 1, "pipeline must return buffers to the arena");
    let b = driver
        .execute_with(&AttentionBatch::single(&x), &engine, &mut HostExecutor::new(&engine.pool))
        .unwrap();
    assert_eq!(a, b, "recycled buffers must not perturb results");
    assert_eq!(
        engine.buffers.available(),
        pooled,
        "steady state must not grow the arena"
    );
}
