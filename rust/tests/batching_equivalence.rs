//! Differential harness for dynamic batching (ISSUE 2): block-diagonal
//! batched 3S execution must be **bit-identical** to serial per-graph
//! runs, at the driver level and through the whole coordinator path —
//! including fingerprint-cache-hit replays.
//!
//! Why bit-equality is the right contract: the BSB builder sorts each row
//! window's compacted columns ascending, and block-diagonal concatenation
//! preserves each row's neighbour order (offsets are monotone), so every
//! row's score/softmax/accumulate sequence is the *same f32 op sequence*
//! in the batched and per-graph runs.  Runs entirely offline through the
//! host kernel; no artifacts needed.

use std::collections::HashMap;
use std::sync::mpsc::channel;
use std::time::Duration;

use fused3s::coordinator::{
    AttnRequest, Coordinator, CoordinatorConfig, ExecutorKind,
};
use fused3s::exec::{offline_manifest, Engine, ExecPolicy};
use fused3s::graph::batch::{batch_graphs, random_molecule};
use fused3s::graph::{generators, CsrGraph};
use fused3s::kernels::{AttentionBatch, AttentionProblem, Backend, ExecCtx, Plan};
use fused3s::runtime::Manifest;
use fused3s::util::prng::Rng;

const BUCKETS: &[usize] = &[4, 8, 16, 32, 64, 128];

fn manifest() -> Manifest {
    // Matches the coordinator's HostEmulation bucketing configuration.
    offline_manifest(8, BUCKETS, 128)
}

fn features(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (
        rng.normal_vec(n * d, 1.0),
        rng.normal_vec(n * d, 1.0),
        rng.normal_vec(n * d, 1.0),
    )
}

/// The ISSUE's generator mix: erdos_renyi / random_molecule / sbm / star,
/// all small (the coalescing regime).  One ER graph is left without
/// self-loops so empty rows cross the batch path too.
fn graph_mix(seed: u64, count: usize) -> Vec<CsrGraph> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|i| match i % 5 {
            0 => generators::erdos_renyi(rng.range(20, 200), 4.0, rng.next_u64())
                .with_self_loops(),
            1 => random_molecule(rng.range(20, 120), &mut rng).with_self_loops(),
            2 => generators::sbm(3, rng.range(8, 24), 0.2, 0.01, rng.next_u64())
                .with_self_loops(),
            3 => generators::star(rng.range(17, 80)),
            _ => generators::erdos_renyi(rng.range(20, 90), 3.0, rng.next_u64()),
        })
        .collect()
}

/// Serial per-graph reference: plan + execute on the serial engine through
/// the offline host kernel.
#[allow(clippy::too_many_arguments)]
fn serial_run(
    man: &Manifest,
    g: &CsrGraph,
    backend: Backend,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    d: usize,
    scale: f32,
) -> Vec<f32> {
    let engine = Engine::serial();
    let plan = Plan::new(man, g, backend, &engine).expect("plan");
    let x = AttentionProblem::new(g.n, d, q, k, v, scale);
    plan.execute(&mut ExecCtx::host(&engine), &AttentionBatch::single(&x))
        .expect("serial run")
}

/// Plan-level differential check for one backend over one graph mix.
fn check_batched_equals_serial(backend: Backend, seed: u64) {
    let man = manifest();
    let d = 16;
    let scale = 0.25;
    let graphs = graph_mix(seed, 10);
    let per_graph: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = graphs
        .iter()
        .enumerate()
        .map(|(i, g)| features(g.n, d, seed * 100 + i as u64))
        .collect();

    // Serial per-graph oracle runs.
    let expect: Vec<Vec<f32>> = graphs
        .iter()
        .zip(&per_graph)
        .map(|(g, (q, k, v))| serial_run(&man, g, backend, q, k, v, d, scale))
        .collect();

    // One block-diagonal batched run.
    let (merged, offsets) = batch_graphs(&graphs);
    let n_total = merged.n;
    let mut q = Vec::with_capacity(n_total * d);
    let mut k = Vec::with_capacity(n_total * d);
    let mut v = Vec::with_capacity(n_total * d);
    for (qq, kk, vv) in &per_graph {
        q.extend_from_slice(qq);
        k.extend_from_slice(kk);
        v.extend_from_slice(vv);
    }
    let x = AttentionProblem::new(n_total, d, &q, &k, &v, scale);

    // Both the serial engine and a parallel pipelined engine must agree.
    for policy in [
        ExecPolicy::serial(),
        ExecPolicy { threads: 4, pipeline_depth: 2 },
    ] {
        let engine = Engine::new(policy);
        let plan = Plan::new(&man, &merged, backend, &engine).expect("plan");
        let out = plan
            .execute(&mut ExecCtx::host(&engine), &AttentionBatch::single(&x))
            .expect("batched run");
        assert_eq!(out.len(), n_total * d);
        for (i, want) in expect.iter().enumerate() {
            let lo = offsets[i] as usize * d;
            let hi = offsets[i + 1] as usize * d;
            assert_eq!(
                &out[lo..hi],
                &want[..],
                "{backend:?} seed={seed} component {i} (n={}) diverged \
                 under {policy:?}",
                graphs[i].n
            );
        }
    }
}

#[test]
fn fused_batched_bit_matches_serial() {
    for seed in [1, 2, 3] {
        check_batched_equals_serial(Backend::Fused3S, seed);
    }
}

#[test]
fn dfgnn_like_batched_bit_matches_serial() {
    check_batched_equals_serial(Backend::DfGnnLike, 4);
}

#[test]
fn unfused_batched_bit_matches_serial() {
    check_batched_equals_serial(Backend::UnfusedStable, 5);
    check_batched_equals_serial(Backend::UnfusedNaive, 6);
}

#[test]
fn cpu_csr_batched_bit_matches_serial() {
    check_batched_equals_serial(Backend::CpuCsr, 7);
}

/// Coordinator-level differential check: the full admission → coalescing →
/// cache → merged-driver → scatter path reproduces serial per-request
/// outputs bit-for-bit, and a replay of the same workload hits the
/// fingerprint cache without changing a single bit.
#[test]
fn coordinator_batch_bit_matches_serial_including_cache_replay() {
    let man = manifest();
    let d = 16;
    let scale = 0.125;
    let graphs = graph_mix(11, 8);
    let per_graph: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = graphs
        .iter()
        .enumerate()
        .map(|(i, g)| features(g.n, d, 1100 + i as u64))
        .collect();
    let expect: Vec<Vec<f32>> = graphs
        .iter()
        .zip(&per_graph)
        .map(|(g, (q, k, v))| {
            serial_run(&man, g, Backend::Fused3S, q, k, v, d, scale)
        })
        .collect();

    let coord = Coordinator::start(CoordinatorConfig {
        executor: ExecutorKind::HostEmulation,
        preprocess_workers: 2,
        queue_capacity: 32,
        // Generous delay + caps: each submitted burst coalesces into
        // exactly one block-diagonal batch even on a loaded CI machine
        // (submission takes microseconds; the window is half a second).
        max_batch_delay: Duration::from_millis(500),
        max_batch_requests: 64,
        max_batch_nodes: 1 << 20,
        cache_capacity: 16,
        ..CoordinatorConfig::default()
    })
    .expect("host-emulation coordinator");

    let submit_burst = |round: u64| -> HashMap<u64, Vec<f32>> {
        let (tx, rx) = channel();
        for (i, (g, (q, k, v))) in graphs.iter().zip(&per_graph).enumerate() {
            coord
                .submit(AttnRequest::single_head(
                    round * 100 + i as u64,
                    g.clone(),
                    d,
                    q.clone(),
                    k.clone(),
                    v.clone(),
                    scale,
                    Backend::Fused3S,
                    tx.clone(),
                ))
                .expect("submit");
        }
        drop(tx);
        let mut got = HashMap::new();
        while let Ok(resp) = rx.recv_timeout(Duration::from_secs(120)) {
            assert_eq!(
                resp.batch_size,
                graphs.len(),
                "burst must coalesce into one batch"
            );
            got.insert(resp.id, resp.result.expect("result"));
            if got.len() == graphs.len() {
                break;
            }
        }
        assert_eq!(got.len(), graphs.len(), "round {round}: missing responses");
        got
    };

    // Round 1: cold — the merged BSB is built once.
    let round1 = submit_burst(0);
    for (i, want) in expect.iter().enumerate() {
        assert_eq!(&round1[&(i as u64)], want, "round 1 component {i}");
    }
    let m = coord.metrics();
    assert_eq!(m.batching.largest_batch(), graphs.len() as u64);
    assert_eq!(m.batching.cache_hits(), 0);
    assert_eq!(m.batching.cache_misses(), 1);

    // Round 2: identical workload — same merged fingerprint, so the build
    // is skipped (cache hit) and the outputs are bit-identical.
    let round2 = submit_burst(1);
    for (i, want) in expect.iter().enumerate() {
        assert_eq!(&round2[&(100 + i as u64)], want, "replay component {i}");
    }
    assert_eq!(m.batching.cache_hits(), 1, "replay must hit the BSB cache");
    assert_eq!(m.batching.cache_misses(), 1);
    assert_eq!(m.completed(), 2 * graphs.len() as u64);
    assert_eq!(m.failed(), 0);
    coord.shutdown();
}
