//! Differential harness for partition-parallel execution (ISSUE 5): a
//! [`ShardedPlan`] must produce **bit-identical** output to the unsharded
//! plan — for every shardable backend, shard counts {1, 2, 3, 7}, both
//! partition strategies, heads ∈ {1, 4}, `d ≠ dv`, mega-hub chunked row
//! windows, ragged n — and through the whole coordinator under
//! `ExecutorKind::HostEmulation`, where graphs above `max_plan_nodes`
//! route through the sharded path the seed coordinator had no answer for.
//!
//! Why bit-equality is the right contract: the halo layout keeps the
//! global→local column remap monotone and the own-row block window-
//! aligned, so every shard's row windows build the same TCB structure —
//! and hence run the same per-row float sequences — as the unsharded BSB;
//! shards write disjoint output rows.  Runs entirely offline through the
//! host kernel; no artifacts needed.

use std::sync::mpsc::channel;
use std::time::Duration;

use fused3s::bsb::stats::halo_fraction;
use fused3s::coordinator::{
    AttnRequest, Coordinator, CoordinatorConfig, ExecutorKind,
};
use fused3s::exec::{offline_manifest, Engine, ExecPolicy};
use fused3s::graph::{generators, CsrGraph};
use fused3s::kernels::{AttentionBatch, AttnError, Backend, ExecCtx, Plan};
use fused3s::runtime::Manifest;
use fused3s::shard::{partition, ShardPolicy, ShardedPlan, Strategy};
use fused3s::util::prng::Rng;

const BUCKETS: &[usize] = &[4, 8, 16, 32, 64, 128];
const SHARD_COUNTS: &[usize] = &[1, 2, 3, 7];

fn manifest() -> Manifest {
    offline_manifest(8, BUCKETS, 128)
}

fn head_features(
    n: usize,
    d: usize,
    dv: usize,
    heads: usize,
    seed: u64,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (
        rng.normal_vec(heads * n * d, 1.0),
        rng.normal_vec(heads * n * d, 1.0),
        rng.normal_vec(heads * n * dv, 1.0),
    )
}

/// Sharded-vs-unsharded differential for one backend on one graph, across
/// the shard-count sweep, both strategies, both engine policies and the
/// head sweep.
fn check_backend(backend: Backend, g: &CsrGraph, d: usize, dv: usize, seed: u64) {
    let man = manifest();
    let serial = Engine::serial();
    for &heads in &[1usize, 4] {
        let (q, k, v) = head_features(g.n, d, dv, heads, seed + heads as u64);
        let x = AttentionBatch::new(g.n, d, dv, heads, &q, &k, &v, 0.25);
        // The unsharded oracle on the serial reference engine.
        let plain = Plan::new(&man, g, backend, &serial).expect("plan");
        let want = plain
            .execute(&mut ExecCtx::host(&serial), &x)
            .expect("unsharded run");
        for &shards in SHARD_COUNTS {
            for strategy in [Strategy::BalancedTcb, Strategy::Contiguous] {
                let policy = ShardPolicy { shards, strategy };
                let sp = ShardedPlan::new(&man, g, backend, &serial, policy)
                    .expect("sharded plan");
                let got = sp
                    .execute(&mut ExecCtx::host(&serial), &x)
                    .expect("sharded run");
                assert_eq!(
                    got, want,
                    "{backend:?} shards={shards} {strategy:?} heads={heads} \
                     d={d} dv={dv}: sharded output diverged"
                );
                // Parallel pipelined engine: still bit-identical.
                let wide =
                    Engine::new(ExecPolicy { threads: 4, pipeline_depth: 2 });
                let got = sp
                    .execute(&mut ExecCtx::host(&wide), &x)
                    .expect("sharded run (parallel)");
                assert_eq!(
                    got, want,
                    "{backend:?} shards={shards} {strategy:?} heads={heads}: \
                     parallel sharded output diverged"
                );
            }
        }
    }
}

#[test]
fn fused_sharded_bit_matches_unsharded() {
    let g = generators::erdos_renyi(500, 5.0, 1).with_self_loops();
    check_backend(Backend::Fused3S, &g, 16, 16, 100);
    // Ragged n (not a multiple of 16): the tail shard owns a partial RW.
    let g = generators::erdos_renyi(277, 4.0, 2).with_self_loops();
    check_backend(Backend::Fused3S, &g, 16, 16, 200);
}

#[test]
fn fused_sharded_power_law_hubs() {
    // The tunable-exponent power-law workload: hubs at low ids, the
    // adversarial case for contiguous partitions.
    let g = generators::power_law(800, 8.0, 2.3, 5).with_self_loops();
    check_backend(Backend::Fused3S, &g, 16, 16, 300);
    check_backend(Backend::DfGnnLike, &g, 16, 16, 350);
}

#[test]
fn fused_sharded_chunked_megahub() {
    // star(3000): the hub row window overflows every bucket and runs the
    // chunked partial-softmax path; its chunk/merge sequence must be
    // reproduced exactly inside whichever shard owns it (its halo is the
    // whole graph).
    let g = generators::star(3000);
    check_backend(Backend::Fused3S, &g, 16, 16, 400);
}

#[test]
fn unfused_sharded_bit_matches() {
    let g = generators::barabasi_albert(400, 5, 3).with_self_loops();
    check_backend(Backend::UnfusedStable, &g, 16, 16, 500);
    check_backend(Backend::UnfusedNaive, &g, 16, 16, 600);
}

#[test]
fn cpu_csr_sharded_bit_matches_with_d_ne_dv() {
    let g = generators::sbm(4, 64, 0.15, 0.01, 7).with_self_loops();
    check_backend(Backend::CpuCsr, &g, 8, 8, 700);
    // Rank-2 GAT-style scores: d = 2, dv = 8 (cpu_csr supports d != dv).
    check_backend(Backend::CpuCsr, &g, 2, 8, 800);
}

#[test]
fn halo_accounting_matches_the_estimator() {
    // The realised halo of a built ShardedPlan must equal the no-build
    // estimator over the same partition's row ranges.
    let man = manifest();
    let engine = Engine::serial();
    let g = generators::power_law(1024, 8.0, 2.5, 9).with_self_loops();
    for &shards in &[2usize, 3, 7] {
        let part = partition::partition(&g, shards, Strategy::BalancedTcb);
        let estimated = halo_fraction(&g, &part.row_ranges(g.n));
        let sp = ShardedPlan::new(
            &man,
            &g,
            Backend::Fused3S,
            &engine,
            ShardPolicy::balanced(shards),
        )
        .unwrap();
        assert_eq!(sp.stats().shards, part.shards());
        let realised = sp.halo_fraction();
        assert!(
            (realised - estimated).abs() < 1e-12,
            "shards={shards}: realised {realised} vs estimated {estimated}"
        );
        assert!(realised > 0.0, "a real cut must replicate something");
    }
}

/// Submit one single-head request and wait for its response.
fn round_trip(
    coord: &Coordinator,
    id: u64,
    g: &CsrGraph,
    d: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    backend: Backend,
) -> Result<Vec<f32>, AttnError> {
    let (tx, rx) = channel();
    coord
        .submit(AttnRequest::single_head(
            id,
            g.clone(),
            d,
            q.to_vec(),
            k.to_vec(),
            v.to_vec(),
            0.25,
            backend,
            tx,
        ))
        .expect("submit");
    rx.recv().expect("response").result
}

#[test]
fn coordinator_serves_graphs_past_max_plan_nodes() {
    // n = 1024 > max_plan_nodes = 256: the seed path refuses a graph this
    // size under the cap (pinned below with sharding disabled); the
    // sharded path serves it bit-exactly.
    let g = generators::erdos_renyi(1024, 6.0, 11).with_self_loops();
    let d = 16;
    let (q, k, v) = head_features(g.n, d, d, 1, 900);

    // The unsharded oracle, computed directly.
    let man = manifest();
    let serial = Engine::serial();
    let plain = Plan::new(&man, &g, Backend::Fused3S, &serial).unwrap();
    let x = AttentionBatch::new(g.n, d, d, 1, &q, &k, &v, 0.25);
    let want = plain.execute(&mut ExecCtx::host(&serial), &x).unwrap();

    let coord = Coordinator::start(CoordinatorConfig {
        executor: ExecutorKind::HostEmulation,
        preprocess_workers: 2,
        queue_capacity: 16,
        max_batch_requests: 4,
        max_batch_delay: Duration::from_millis(1),
        exec: ExecPolicy::serial(),
        max_plan_nodes: 256,
        max_shards: 16,
        ..CoordinatorConfig::default()
    })
    .expect("coordinator");

    let got = round_trip(&coord, 1, &g, d, &q, &k, &v, Backend::Fused3S)
        .expect("sharded request served");
    assert_eq!(got, want, "coordinator sharded output diverged");

    // Sharding metrics recorded and reported.
    let m = coord.metrics();
    assert_eq!(m.sharding.sharded_batches(), 1);
    assert!(m.sharding.shards_executed() >= 4, "cap 256 over n=1024");
    assert!(m.sharding.halo_rows_gathered() > 0);
    assert!(m.report().contains("sharding batches=1"), "{}", m.report());

    // Replay: per-shard plans are cached by shard-local fingerprint, so
    // the second pass hits the cache once per shard and stays bit-exact.
    let hits_before = m.batching.cache_hits();
    let got = round_trip(&coord, 2, &g, d, &q, &k, &v, Backend::Fused3S)
        .expect("replayed sharded request");
    assert_eq!(got, want);
    let m = coord.metrics();
    assert!(
        m.batching.cache_hits() >= hits_before + 4,
        "replay must hit every shard's cached plan (hits {} -> {})",
        hits_before,
        m.batching.cache_hits()
    );

    // Backend::Auto routes oversize graphs through the sharded cost
    // candidate and still bit-matches (auto resolves to a shardable
    // backend; under factory constants on this graph that is the fused
    // family, but equality holds for any shardable choice only if it is
    // the same backend — so compare against a direct run of the resolved
    // backend instead of assuming).
    let auto_out = round_trip(&coord, 3, &g, d, &q, &k, &v, Backend::Auto)
        .expect("auto-routed sharded request");
    assert_eq!(auto_out.len(), want.len());
    assert!(coord.metrics().planner.auto_requests() >= 1);
    coord.shutdown();
}

#[test]
fn coordinator_refuses_oversize_when_sharding_disabled() {
    let g = generators::erdos_renyi(600, 5.0, 13).with_self_loops();
    let d = 8;
    let (q, k, v) = head_features(g.n, d, d, 1, 901);
    let coord = Coordinator::start(CoordinatorConfig {
        executor: ExecutorKind::HostEmulation,
        preprocess_workers: 1,
        queue_capacity: 8,
        max_batch_requests: 1,
        exec: ExecPolicy::serial(),
        max_plan_nodes: 256,
        max_shards: 0, // sharding off: the seed behaviour, made explicit
        ..CoordinatorConfig::default()
    })
    .expect("coordinator");
    let err = round_trip(&coord, 1, &g, d, &q, &k, &v, Backend::Fused3S)
        .expect_err("must refuse");
    assert!(matches!(err, AttnError::Unsupported(_)), "{err}");
    assert!(format!("{err}").contains("max_plan_nodes"), "{err}");
    // Small graphs still serve normally under the same config.
    let small = generators::ring(64);
    let (q2, k2, v2) = head_features(64, d, d, 1, 902);
    round_trip(&coord, 2, &small, d, &q2, &k2, &v2, Backend::Fused3S)
        .expect("small graph unaffected");
    coord.shutdown();
}
