//! Differential harness for the plan-based multi-head API (ISSUE 3): one
//! head-batched `AttentionBatch` call must be **bit-identical** to the old
//! per-head loop (one single-head call per head), for every backend, every
//! engine policy, `heads ∈ {1, 2, 4, 8}`, and `d ≠ dv` — and through the
//! whole coordinator path under `ExecutorKind::HostEmulation`.
//!
//! Why bit-equality is the right contract: for each head, the multi-head
//! schedule runs exactly the single-head (gather, dispatch, scatter)
//! sequence — the batch only interleaves *when* heads run, never what they
//! compute — and heads write disjoint output blocks.  Runs entirely
//! offline through the host kernel; no artifacts needed.

use std::collections::HashMap;
use std::sync::mpsc::channel;
use std::time::Duration;

use fused3s::coordinator::{
    AttnRequest, Coordinator, CoordinatorConfig, ExecutorKind,
};
use fused3s::exec::{offline_manifest, Engine, ExecPolicy};
use fused3s::graph::{generators, CsrGraph};
use fused3s::kernels::{
    reference, AttentionBatch, AttnError, Backend, ExecCtx, Plan,
};
use fused3s::runtime::Manifest;
use fused3s::util::prng::Rng;

const BUCKETS: &[usize] = &[4, 8, 16, 32, 64, 128];
const HEAD_COUNTS: &[usize] = &[1, 2, 4, 8];

fn manifest() -> Manifest {
    offline_manifest(8, BUCKETS, 128)
}

/// Head-major feature buffers for `heads` heads over n nodes.
fn head_features(
    n: usize,
    d: usize,
    dv: usize,
    heads: usize,
    seed: u64,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (
        rng.normal_vec(heads * n * d, 1.0),
        rng.normal_vec(heads * n * d, 1.0),
        rng.normal_vec(heads * n * dv, 1.0),
    )
}

/// The old shape: one single-head call per head, concatenated head-major.
fn per_head_loop(plan: &Plan, engine: &Engine, x: &AttentionBatch) -> Vec<f32> {
    let mut out = Vec::with_capacity(x.out_len());
    for h in 0..x.heads {
        let xh = x.head(h);
        let oh = plan
            .execute(&mut ExecCtx::host(engine), &AttentionBatch::single(&xh))
            .expect("per-head run");
        out.extend_from_slice(&oh);
    }
    out
}

/// Batched-vs-loop differential for one backend on one graph across the
/// head sweep and both serial and parallel pipelined policies.
fn check_backend(backend: Backend, g: &CsrGraph, d: usize, dv: usize, seed: u64) {
    let man = manifest();
    for &heads in HEAD_COUNTS {
        let (q, k, v) = head_features(g.n, d, dv, heads, seed + heads as u64);
        let x = AttentionBatch::new(g.n, d, dv, heads, &q, &k, &v, 0.25);
        // The per-head oracle on the serial reference engine.
        let serial = Engine::serial();
        let plan = Plan::new(&man, g, backend, &serial).expect("plan");
        let want = per_head_loop(&plan, &serial, &x);
        for policy in [
            ExecPolicy::serial(),
            ExecPolicy { threads: 4, pipeline_depth: 2 },
        ] {
            let engine = Engine::new(policy);
            let got = plan
                .execute(&mut ExecCtx::host(&engine), &x)
                .expect("batched run");
            assert_eq!(got.len(), x.out_len());
            assert_eq!(
                got, want,
                "{backend:?} heads={heads} d={d} dv={dv} {policy:?}: \
                 batched call diverged from the per-head loop"
            );
        }
        // Numerical sanity: every head agrees with the dense reference.
        for h in 0..heads {
            let xh = x.head(h);
            let dense = reference::dense_attention_host(g, &xh);
            let err = reference::max_abs_diff(
                &want[h * g.n * dv..(h + 1) * g.n * dv],
                &dense,
            );
            // 1e-2 covers the chunked-merge case (see exec_parallel.rs).
            assert!(err < 1e-2, "{backend:?} head {h}: err {err}");
        }
    }
}

#[test]
fn fused_multihead_bit_matches_per_head_loop() {
    let g = generators::erdos_renyi(300, 5.0, 1).with_self_loops();
    check_backend(Backend::Fused3S, &g, 16, 16, 100);
    // Ragged n (not a multiple of 16).
    let g = generators::erdos_renyi(277, 4.0, 2).with_self_loops();
    check_backend(Backend::Fused3S, &g, 16, 16, 200);
}

#[test]
fn fused_multihead_chunked_megahub() {
    // The star hub forces the chunked partial-softmax path; its per-head
    // merge sequences must also be reproduced exactly by the batched call.
    let g = generators::star(3000);
    check_backend(Backend::Fused3S, &g, 16, 16, 300);
}

#[test]
fn dfgnn_and_unfused_multihead_bit_match() {
    let g = generators::barabasi_albert(400, 5, 3).with_self_loops();
    check_backend(Backend::DfGnnLike, &g, 16, 16, 400);
    check_backend(Backend::UnfusedStable, &g, 16, 16, 500);
    check_backend(Backend::UnfusedNaive, &g, 16, 16, 600);
}

#[test]
fn cpu_csr_multihead_bit_matches() {
    let g = generators::sbm(4, 32, 0.15, 0.01, 4).with_self_loops();
    check_backend(Backend::CpuCsr, &g, 16, 16, 700);
}

#[test]
fn d_ne_dv_multihead_bit_matches() {
    // GAT-shaped problems (rank-2 scores, wide values): d ≠ dv flows
    // through the unfused and CPU-CSR paths.
    let g = generators::erdos_renyi(200, 4.0, 5).with_self_loops();
    check_backend(Backend::UnfusedStable, &g, 4, 12, 800);
    check_backend(Backend::CpuCsr, &g, 4, 12, 900);
}

#[test]
fn fused_rejects_d_ne_dv_with_bad_shape() {
    let man = manifest();
    let g = generators::erdos_renyi(64, 3.0, 6).with_self_loops();
    let engine = Engine::serial();
    let plan = Plan::new(&man, &g, Backend::Fused3S, &engine).expect("plan");
    let (q, k, v) = head_features(g.n, 4, 12, 2, 1000);
    let x = AttentionBatch::new(g.n, 4, 12, 2, &q, &k, &v, 1.0);
    let err = plan
        .execute(&mut ExecCtx::host(&engine), &x)
        .err()
        .expect("fused must reject d != dv");
    assert!(matches!(err, AttnError::BadShape(_)), "{err:?}");
}

/// The full coordinator path with multi-head requests: coalesced
/// block-diagonal multi-head batches must reproduce per-head, per-request
/// serial runs bit-for-bit under `ExecutorKind::HostEmulation`.
#[test]
fn coordinator_multihead_host_emulation_bit_matches() {
    let man = manifest();
    let d = 8;
    let heads = 4;
    let scale = 0.25;
    let graphs: Vec<CsrGraph> = vec![
        generators::erdos_renyi(60, 3.0, 7).with_self_loops(),
        generators::sbm(3, 16, 0.2, 0.02, 8).with_self_loops(),
        generators::erdos_renyi(90, 4.0, 9).with_self_loops(),
    ];
    let feats: Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> = graphs
        .iter()
        .enumerate()
        .map(|(i, g)| head_features(g.n, d, d, heads, 2000 + i as u64))
        .collect();
    // Per-request, per-head serial oracle.
    let serial = Engine::serial();
    let expect: Vec<Vec<f32>> = graphs
        .iter()
        .zip(&feats)
        .map(|(g, (q, k, v))| {
            let plan = Plan::new(&man, g, Backend::Fused3S, &serial).unwrap();
            let x = AttentionBatch::new(g.n, d, d, heads, q, k, v, scale);
            per_head_loop(&plan, &serial, &x)
        })
        .collect();

    let coord = Coordinator::start(CoordinatorConfig {
        executor: ExecutorKind::HostEmulation,
        preprocess_workers: 2,
        queue_capacity: 16,
        max_batch_delay: Duration::from_millis(500),
        max_batch_requests: 16,
        max_batch_nodes: 1 << 20,
        cache_capacity: 8,
        ..CoordinatorConfig::default()
    })
    .expect("host-emulation coordinator");

    let (tx, rx) = channel();
    for (i, (g, (q, k, v))) in graphs.iter().zip(&feats).enumerate() {
        coord
            .submit(AttnRequest {
                id: i as u64,
                graph: g.clone(),
                d,
                dv: d,
                heads,
                q: q.clone(),
                k: k.clone(),
                v: v.clone(),
                scale,
                backend: Backend::Fused3S,
                deadline: None,
                span: 0,
                reply: tx.clone(),
            })
            .expect("submit");
    }
    drop(tx);
    let mut got: HashMap<u64, Vec<f32>> = HashMap::new();
    while let Ok(resp) = rx.recv_timeout(Duration::from_secs(120)) {
        // The bit-exactness contract holds whatever the batch composition
        // (a loaded CI box may flush a partial batch before the burst
        // completes), so batch_size is only sanity-checked, not pinned.
        assert!(resp.batch_size >= 1);
        got.insert(resp.id, resp.result.expect("result"));
        if got.len() == graphs.len() {
            break;
        }
    }
    assert_eq!(got.len(), graphs.len(), "missing responses");
    assert!(
        coord.metrics().batching.batches() >= 1,
        "requests must have flowed through the batching path"
    );
    for (i, want) in expect.iter().enumerate() {
        assert_eq!(
            &got[&(i as u64)], want,
            "component {i}: coordinator multi-head output diverged"
        );
    }
    coord.shutdown();
}
