//! Streaming differential harness (ROADMAP item 3): incremental BSB
//! maintenance under edge churn must be **indistinguishable** from
//! throwing the old structures away and rebuilding from scratch.
//!
//! Three layers of the contract, each checked bit-for-bit:
//!
//! 1. **structure** — `incremental::rebuild` (dirty windows recomputed,
//!    clean windows spliced from the old BSB) equals `bsb::build` on the
//!    patched CSR, including the per-window hybrid routing decisions
//!    derived from it;
//! 2. **arithmetic** — plans built from the incremental BSB produce
//!    bit-identical attention outputs to plans built from the scratch
//!    BSB, across the generator suite × delta mixes × `heads ∈ {1,4}` ×
//!    serial/parallel engines;
//! 3. **serving** — `Coordinator::update_graph` atomically swaps the
//!    cached plans: a replay burst on the patched fingerprint is
//!    cache-hot (zero new misses), the retired fingerprint is evicted,
//!    and outputs match a fresh serial oracle on the patched graph.
//!
//! A seeded fuzz walk (satellite 2) additionally pins the dirty-window
//! contract itself: after every cumulative batch, the patched CSR is
//! canonical, its fingerprint equals a from-scratch recompute, and the
//! reported dirty set is *exactly* the windows whose row contents
//! changed.  Everything runs offline under `ExecutorKind::HostEmulation`.

use std::collections::HashSet;
use std::sync::mpsc::channel;
use std::time::Duration;

use fused3s::bsb::geometry;
use fused3s::bsb::incremental;
use fused3s::bsb::reorder::Order;
use fused3s::bsb::{self, Bsb};
use fused3s::coordinator::{
    AttnRequest, Coordinator, CoordinatorConfig, ExecutorKind,
};
use fused3s::exec::{offline_manifest, Engine, ExecPolicy};
use fused3s::graph::{generators, CsrGraph, GraphDelta};
use fused3s::kernels::{AttentionBatch, Backend, ExecCtx, Plan};
use fused3s::runtime::Manifest;
use fused3s::util::prng::Rng;
use fused3s::TCB_R;

const BUCKETS: &[usize] = &[4, 8, 16, 32, 64, 128];
const HEAD_COUNTS: &[usize] = &[1, 4];
const D: usize = 16;
const SCALE: f32 = 0.25;
const LONG: Duration = Duration::from_secs(120);

fn manifest() -> Manifest {
    // Matches the coordinator's HostEmulation bucketing configuration.
    offline_manifest(8, BUCKETS, 128)
}

/// The ISSUE's generator mix (same shapes as `packing_equivalence.rs`,
/// so the router exercises wide, narrow, and dense windows).
fn graph_suite() -> Vec<(&'static str, CsrGraph)> {
    vec![
        ("er", generators::erdos_renyi(400, 5.0, 3).with_self_loops()),
        ("sbm", generators::sbm(6, 24, 0.3, 0.02, 5).with_self_loops()),
        ("star", generators::star(1500)),
        ("power_law", generators::power_law(512, 6.0, 2.3, 9).with_self_loops()),
    ]
}

#[derive(Clone, Copy, Debug)]
enum DeltaMix {
    InsertOnly,
    RemoveOnly,
    Mixed,
}

const MIXES: &[DeltaMix] = &[DeltaMix::InsertOnly, DeltaMix::RemoveOnly, DeltaMix::Mixed];

/// A seeded edit batch of the requested mix.  Removes are sampled from
/// resident edges so they take effect; inserts are fresh random pairs
/// (the occasional duplicate of an existing edge is a legal no-op).
fn edit_batch(
    g: &CsrGraph,
    mix: DeltaMix,
    edits: usize,
    rng: &mut Rng,
) -> (Vec<(u32, u32)>, Vec<(u32, u32)>) {
    let mut ins = Vec::new();
    let mut rem = Vec::new();
    for _ in 0..edits {
        let remove = match mix {
            DeltaMix::InsertOnly => false,
            DeltaMix::RemoveOnly => true,
            DeltaMix::Mixed => rng.coin(0.5),
        };
        if remove {
            let u = rng.below(g.n);
            let row = g.row(u);
            if !row.is_empty() {
                rem.push((u as u32, row[rng.below(row.len())]));
            }
            continue;
        }
        ins.push((rng.below(g.n) as u32, rng.below(g.n) as u32));
    }
    // The same edge on both sides is rejected as ambiguous; keep the
    // batch well-formed.
    ins.retain(|e| !rem.contains(e));
    (ins, rem)
}

fn head_features(n: usize, heads: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (
        rng.normal_vec(heads * n * D, 1.0),
        rng.normal_vec(heads * n * D, 1.0),
        rng.normal_vec(heads * n * D, 1.0),
    )
}

/// Structural + routing + arithmetic differential for one (graph, mix)
/// cell: incremental rebuild vs. from-scratch build on the patched CSR.
fn check_delta_cell(name: &str, base: &CsrGraph, mix: DeltaMix, seed: u64) {
    let mut rng = Rng::new(seed);
    let (ins, rem) = edit_batch(base, mix, 40, &mut rng);
    let delta = GraphDelta::against(base, ins, rem);
    let (patched, report) = delta
        .applied(base)
        .unwrap_or_else(|e| panic!("{name} {mix:?}: delta rejected: {e:#}"));

    let tag = format!("{name} {mix:?}");
    let old = bsb::build(base);
    assert!(incremental::compatible(&old, &patched), "{tag}: same n, same windows");
    let (inc, stats) = incremental::rebuild(&old, &patched, &report.dirty_rws);
    let scratch = bsb::build(&patched);
    assert_eq!(inc, scratch, "{tag}: incremental BSB diverged from scratch");
    assert_eq!(
        stats.rebuilt,
        report.dirty_rws.len(),
        "{tag}: every dirty window rebuilt, nothing else"
    );
    assert_eq!(
        stats.rebuilt + stats.spliced,
        scratch.num_rw,
        "{tag}: rebuild/splice must partition the windows"
    );

    // Hybrid routing decisions per RW: pure function of the BSB, so the
    // incremental build must route every window identically.
    let man = manifest();
    let route = |b: &Bsb| {
        geometry::plan_hybrid(b, &man.t_buckets, man.rw_batch, Order::ByTcbDesc, man.chunk_t)
    };
    let (hp_inc, hp_scr) = (route(&inc), route(&scratch));
    assert_eq!(hp_inc.routes, hp_scr.routes, "{tag}: per-window routing diverged");
    assert_eq!(
        hp_inc.stats.narrow_windows, hp_scr.stats.narrow_windows,
        "{tag}: narrow-window accounting diverged"
    );
    assert_eq!(
        hp_inc.stats.dense_windows, hp_scr.stats.dense_windows,
        "{tag}: dense-window accounting diverged"
    );

    // Plan-output bit-match: hybrid plans built from each BSB, executed
    // across the head sweep on both engine policies.
    let inc_plan = Plan::from_bsb(&man, inc, Backend::Hybrid).expect("incremental plan");
    let scr_plan = Plan::from_bsb(&man, scratch, Backend::Hybrid).expect("scratch plan");
    for &heads in HEAD_COUNTS {
        let (q, k, v) = head_features(patched.n, heads, seed ^ ((heads as u64) << 32));
        let x = AttentionBatch::new(patched.n, D, D, heads, &q, &k, &v, SCALE);
        for policy in [ExecPolicy::serial(), ExecPolicy { threads: 4, pipeline_depth: 2 }] {
            let engine = Engine::new(policy);
            let want = scr_plan
                .execute(&mut ExecCtx::host(&engine), &x)
                .expect("scratch run");
            let got = inc_plan
                .execute(&mut ExecCtx::host(&engine), &x)
                .expect("incremental run");
            assert_eq!(
                got, want,
                "{tag} heads={heads} {policy:?}: incremental plan output \
                 diverged from scratch"
            );
        }
    }
}

#[test]
fn incremental_rebuild_bit_matches_scratch_across_suite() {
    for (i, (name, g)) in graph_suite().iter().enumerate() {
        for (j, &mix) in MIXES.iter().enumerate() {
            check_delta_cell(name, g, mix, 1 + 100 * (i as u64 + 1) + j as u64);
        }
    }
}

/// All distinct columns per row window (the coarser "distinct column set"
/// invalidation criterion the per-row contract refines).
fn window_columns(g: &CsrGraph) -> Vec<HashSet<u32>> {
    let num_rw = g.n.div_ceil(TCB_R);
    let mut cols = vec![HashSet::new(); num_rw];
    for u in 0..g.n {
        cols[u / TCB_R].extend(g.row(u).iter().copied());
    }
    cols
}

/// Exact dirty set by brute force: windows where any row's adjacency
/// differs between the two versions.
fn changed_windows(old: &CsrGraph, new: &CsrGraph) -> Vec<u32> {
    assert_eq!(old.n, new.n);
    let num_rw = old.n.div_ceil(TCB_R);
    (0..num_rw as u32)
        .filter(|&w| {
            let lo = w as usize * TCB_R;
            let hi = (lo + TCB_R).min(old.n);
            (lo..hi).any(|u| old.row(u) != new.row(u))
        })
        .collect()
}

/// CSR canonical-form invariants: monotone `indptr`, strictly ascending
/// (hence duplicate-free) in-range rows.
fn assert_csr_canonical(tag: &str, g: &CsrGraph) {
    assert_eq!(g.indptr.len(), g.n + 1, "{tag}: indptr length");
    assert_eq!(g.indptr[0], 0, "{tag}: indptr origin");
    assert_eq!(g.indptr[g.n] as usize, g.indices.len(), "{tag}: indptr end");
    for u in 0..g.n {
        assert!(g.indptr[u] <= g.indptr[u + 1], "{tag}: indptr monotone at {u}");
        let row = g.row(u);
        for w in row.windows(2) {
            assert!(w[0] < w[1], "{tag}: row {u} not strictly sorted: {w:?}");
        }
        if let Some(&last) = row.last() {
            assert!((last as usize) < g.n, "{tag}: row {u} column out of range");
        }
    }
}

/// Satellite 2 — seeded fuzz walk: 1–50 cumulative delta batches; after
/// every step the patched fingerprint equals a from-scratch recompute on
/// the surviving edge set, the CSR stays canonical, the dirty-window set
/// is exact, and the incrementally-maintained BSB (carried across steps,
/// never rebuilt whole) still equals the scratch build.
#[test]
fn fuzz_cumulative_deltas_keep_every_invariant() {
    for seed in [0xF0u64, 0xF1, 0xF2] {
        let mut rng = Rng::new(seed);
        let n = 64 + rng.below(192);
        let mut g = generators::erdos_renyi(n, 4.0, seed).with_self_loops();
        let mut bsb = bsb::build(&g);
        let mut model: HashSet<(u32, u32)> = (0..g.n)
            .flat_map(|u| g.row(u).iter().map(move |&v| (u as u32, v)).collect::<Vec<_>>())
            .collect();
        let steps = 1 + rng.below(50);
        for step in 0..steps {
            let tag = format!("seed={seed:#x} step={step}");
            let mix = MIXES[rng.below(MIXES.len())];
            let (ins, rem) = edit_batch(&g, mix, 1 + rng.below(24), &mut rng);
            let delta = GraphDelta::against(&g, ins.clone(), rem.clone());
            let (patched, report) = delta
                .applied(&g)
                .unwrap_or_else(|e| panic!("{tag}: delta rejected: {e:#}"));

            // Versioned fingerprints: patched-in-place == from-scratch on
            // the model edge set maintained independently.
            for e in &rem {
                model.remove(e);
            }
            model.extend(ins.iter().copied());
            let edges: Vec<(u32, u32)> = model.iter().copied().collect();
            let scratch_csr = CsrGraph::from_edges(g.n, &edges).expect("model edges");
            assert_eq!(patched, scratch_csr, "{tag}: patched CSR != from-scratch");
            assert_eq!(
                report.new_fp,
                scratch_csr.fingerprint(),
                "{tag}: fingerprint != from-scratch recompute"
            );
            assert_eq!(report.old_fp, g.fingerprint(), "{tag}: old fingerprint");
            assert_csr_canonical(&tag, &patched);

            // Dirty-window exactness: precisely the windows whose row
            // contents changed...
            assert_eq!(
                report.dirty_rws,
                changed_windows(&g, &patched),
                "{tag}: dirty set != brute-force row diff"
            );
            // ...and every window whose *distinct column set* changed is
            // among them (the per-row contract refines the column one).
            let (before, after) = (window_columns(&g), window_columns(&patched));
            let dirty: HashSet<u32> = report.dirty_rws.iter().copied().collect();
            for w in 0..before.len() {
                if before[w] != after[w] {
                    assert!(
                        dirty.contains(&(w as u32)),
                        "{tag}: window {w} changed columns but was not dirtied"
                    );
                }
            }

            // The BSB maintained only through incremental rebuilds stays
            // bit-identical to scratch — drift cannot accumulate.
            let (next, stats) = incremental::rebuild(&bsb, &patched, &report.dirty_rws);
            assert_eq!(stats.rebuilt, report.dirty_rws.len(), "{tag}");
            assert_eq!(next, bsb::build(&patched), "{tag}: BSB drift");
            bsb = next;
            g = patched;
        }
    }
}

fn host_config() -> CoordinatorConfig {
    CoordinatorConfig {
        executor: ExecutorKind::HostEmulation,
        preprocess_workers: 2,
        queue_capacity: 16,
        max_batch_requests: 1,
        max_batch_nodes: 1 << 20,
        max_batch_delay: Duration::from_millis(1),
        cache_capacity: 8,
        ..CoordinatorConfig::default()
    }
}

/// Submit one single-head request per seed and return the outputs, in
/// order.  `max_batch_requests = 1` keeps one cache lookup per request,
/// so hit/miss deltas are exact.
fn burst(coord: &Coordinator, g: &CsrGraph, backend: Backend, seeds: &[u64]) -> Vec<Vec<f32>> {
    let mut pending = Vec::new();
    for &s in seeds {
        let (q, k, v) = head_features(g.n, 1, s);
        let (tx, rx) = channel();
        coord
            .submit(AttnRequest {
                id: s,
                graph: g.clone(),
                d: D,
                dv: D,
                heads: 1,
                q,
                k,
                v,
                scale: SCALE,
                backend,
                deadline: None,
                span: 0,
                reply: tx,
            })
            .expect("submit");
        pending.push(rx);
    }
    pending
        .into_iter()
        .map(|rx| {
            let resp = rx.recv_timeout(LONG).expect("response");
            resp.result.expect("burst request must succeed")
        })
        .collect()
}

/// Fresh serial oracle for one graph (no shared state with the
/// coordinator under test).
fn oracle(g: &CsrGraph, backend: Backend, seeds: &[u64]) -> Vec<Vec<f32>> {
    let man = manifest();
    let serial = Engine::serial();
    let plan = Plan::new(&man, g, backend, &serial).expect("oracle plan");
    seeds
        .iter()
        .map(|&s| {
            let (q, k, v) = head_features(g.n, 1, s);
            let x = AttentionBatch::new(g.n, D, D, 1, &q, &k, &v, SCALE);
            plan.execute(&mut ExecCtx::host(&serial), &x).expect("oracle run")
        })
        .collect()
}

/// Satellite 1 (serving leg) + the PR's acceptance criterion: after
/// `update_graph`, a replay burst on the patched fingerprint takes zero
/// new cache misses (the swapped-in plans are hot), the retired
/// fingerprint is gone (probing it misses), and everything served before,
/// during, and after bit-matches the per-version serial oracle.
#[test]
fn coordinator_update_swaps_cache_without_stale_hits() {
    let backend = Backend::Fused3S;
    let seeds: Vec<u64> = (0..4).map(|i| 7000 + i).collect();
    let coord = Coordinator::start(host_config()).expect("start");
    let g0 = generators::erdos_renyi(160, 5.0, 21).with_self_loops();

    // Warm the base version: first burst populates the cache...
    let got = burst(&coord, &g0, backend, &seeds);
    assert_eq!(got, oracle(&g0, backend, &seeds), "base burst vs oracle");
    let m = coord.metrics();
    let warm_misses = m.batching.cache_misses();
    assert!(warm_misses >= 1, "warm burst must have built the plan");
    // ...and a second burst is fully cache-hot.
    let _ = burst(&coord, &g0, backend, &seeds);
    assert_eq!(m.batching.cache_misses(), warm_misses, "warm replay must not miss");

    // First delta: nothing in the BSB registry yet, so the rebuild is
    // full — but the swap contract is identical.
    let mut rng = Rng::new(99);
    let (ins, rem) = edit_batch(&g0, DeltaMix::Mixed, 30, &mut rng);
    let delta = GraphDelta::against(&g0, ins, rem);
    let (g1, local) = delta.applied(&g0).expect("local mirror");
    let rep = coord.update_graph(&g0, &delta).expect("update_graph");
    assert_eq!(rep.old_fp, g0.fingerprint());
    assert_eq!(rep.new_fp, g1.fingerprint(), "server fp == local recompute");
    assert_eq!(rep.dirty_rws, local.dirty_rws.len());
    assert!(rep.full_rebuild, "no prior BSB registered: must fall back to full");
    assert!(
        rep.plans_swapped.contains(&backend),
        "the served backend must be re-planned: {:?}",
        rep.plans_swapped
    );

    // Replay burst on the patched version: ZERO new misses — the swap
    // left the new fingerprint cache-hot — and outputs match a fresh
    // oracle on the patched graph.
    let miss_before = m.batching.cache_misses();
    let got = burst(&coord, &g1, backend, &seeds);
    assert_eq!(
        m.batching.cache_misses(),
        miss_before,
        "stale-plan hit: replay after update_graph must be cache-hot"
    );
    assert_eq!(got, oracle(&g1, backend, &seeds), "patched burst vs oracle");

    // The retired version is evicted: probing the old graph misses (a
    // fresh plan gets built — it still *serves* correctly, it is just no
    // longer resident).
    let miss_before = m.batching.cache_misses();
    let _ = burst(&coord, &g0, backend, &[seeds[0]]);
    assert!(
        m.batching.cache_misses() > miss_before,
        "old fingerprint must have been evicted by the swap"
    );

    // Second delta chains off the registered BSB: incremental this time,
    // with clean windows spliced, and the same zero-miss replay contract.
    let (ins, rem) = edit_batch(&g1, DeltaMix::Mixed, 20, &mut rng);
    let delta = GraphDelta::against(&g1, ins, rem);
    let (g2, _) = delta.applied(&g1).expect("local mirror");
    let rep = coord.update_graph(&g1, &delta).expect("second update");
    assert_eq!(rep.new_fp, g2.fingerprint());
    assert!(!rep.full_rebuild, "chained delta must rebuild incrementally");
    assert!(rep.spliced_rws > 0, "clean windows must be spliced");
    let miss_before = m.batching.cache_misses();
    let got = burst(&coord, &g2, backend, &seeds);
    assert_eq!(m.batching.cache_misses(), miss_before, "chained replay cache-hot");
    assert_eq!(got, oracle(&g2, backend, &seeds), "chained burst vs oracle");

    // Streaming counters reconcile with the two reports.
    assert_eq!(m.streaming.deltas_applied(), 2);
    assert_eq!(m.streaming.full_rebuilds(), 1);
    assert!(m.streaming.rws_dirtied() > 0);
    assert_eq!(m.streaming.rws_spliced() as usize, rep.spliced_rws);
    coord.shutdown();
}

/// A malformed delta (edge out of range / ambiguous edit) is rejected
/// without touching the served version: the base plan stays resident and
/// keeps answering bit-identically.
#[test]
fn rejected_delta_leaves_the_old_version_serving() {
    let backend = Backend::CpuCsr;
    let coord = Coordinator::start(host_config()).expect("start");
    let g = generators::sbm(4, 20, 0.25, 0.02, 8).with_self_loops();
    let want = oracle(&g, backend, &[1]);
    assert_eq!(burst(&coord, &g, backend, &[1]), want);
    let m = coord.metrics();

    let bad = GraphDelta::against(&g, vec![(0, 9999)], vec![]);
    assert!(coord.update_graph(&g, &bad).is_err(), "out-of-range must reject");
    let ambiguous = GraphDelta::against(&g, vec![(0, 1)], vec![(0, 1)]);
    assert!(coord.update_graph(&g, &ambiguous).is_err(), "ambiguous must reject");
    assert_eq!(m.streaming.deltas_applied(), 0, "rejected deltas must not count");

    let miss_before = m.batching.cache_misses();
    assert_eq!(burst(&coord, &g, backend, &[1]), want, "old version still serves");
    assert_eq!(
        m.batching.cache_misses(),
        miss_before,
        "rejected delta must not evict the served plan"
    );
    coord.shutdown();
}

/// A no-op delta (every edit cancels) keeps the fingerprint — the swap
/// must not evict the plans it just refreshed.
#[test]
fn noop_delta_keeps_the_version_hot() {
    let backend = Backend::CpuCsr;
    let coord = Coordinator::start(host_config()).expect("start");
    let g = generators::ring(64).with_self_loops();
    let want = oracle(&g, backend, &[5]);
    assert_eq!(burst(&coord, &g, backend, &[5]), want);
    let m = coord.metrics();

    // Insert an edge that already exists, remove one that does not.
    let delta = GraphDelta::against(&g, vec![(0, 0)], vec![(1, 63)]);
    let rep = coord.update_graph(&g, &delta).expect("no-op update");
    assert_eq!(rep.old_fp, rep.new_fp, "no effective change keeps the version");
    assert_eq!(rep.dirty_rws, 0);

    let miss_before = m.batching.cache_misses();
    assert_eq!(burst(&coord, &g, backend, &[5]), want);
    assert_eq!(
        m.batching.cache_misses(),
        miss_before,
        "self-swap must not evict the refreshed plan"
    );
    coord.shutdown();
}
