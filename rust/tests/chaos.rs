//! Chaos differential suite (ISSUE 6): the coordinator under seeded,
//! deterministic fault injection ([`fused3s::fault`]).
//!
//! The locked invariants, per run:
//!
//! 1. **exactly-one response** — every accepted request gets exactly one
//!    `AttnResponse` (never zero, never two), whatever faults fire;
//! 2. **differential bit-match** — a successful response served on the
//!    *requested* backend is bit-identical to the fault-free baseline
//!    (retries and delays must not perturb the arithmetic); a response
//!    served on a *fallback* backend (degradation ladder) agrees with the
//!    dense reference within the cross-backend tolerance;
//! 3. **structured failure** — an exhausted ladder surfaces a typed
//!    [`AttnError`], never a dropped responder or a dead stage thread;
//! 4. **clean drain** — `shutdown()` returns (joins every stage), even
//!    after panics were injected into those stages;
//! 5. **metrics reconcile** — `Metrics.faults` counters are consistent
//!    with the injection log recorded by the `FaultPlan`.
//!
//! The fault hook is process-global, so every test serialises on `GATE`
//! (and `scripts/verify.sh` additionally runs this suite with
//! `--test-threads=1`).  Everything here runs offline under
//! `ExecutorKind::HostEmulation` — no artifacts needed.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, TryRecvError};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

use fused3s::coordinator::{
    AttnRequest, AttnResponse, Coordinator, CoordinatorConfig, ExecutorKind,
};
use fused3s::fault::{self, FaultKind, FaultPlan, FaultSite};
use fused3s::graph::{generators, CsrGraph, GraphDelta};
use fused3s::kernels::{reference, AttentionProblem, AttnError, Backend};
use fused3s::util::prng::Rng;

/// Serialises every test in this binary: the fault hook is process-global.
static GATE: Mutex<()> = Mutex::new(());

const D: usize = 8;
const SCALE: f32 = 0.5;
const LONG: Duration = Duration::from_secs(120);

/// Injected panics unwind to the coordinator's catch boundaries, but the
/// default panic hook would still spray expected backtraces over the test
/// output.  Silence the messages that seeded chaos legitimately produces;
/// anything else (a *real* bug) still prints.
fn quiet_panics() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let msg = fused3s::fault::panic_message(info.payload());
            if msg.contains("fault-injection:")
                || msg.contains("a scoped thread panicked")
                || msg.contains("receiver alive")
            {
                return;
            }
            prev(info);
        }));
    });
}

fn gate() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(PoisonError::into_inner)
}

fn config() -> CoordinatorConfig {
    CoordinatorConfig {
        executor: ExecutorKind::HostEmulation,
        preprocess_workers: 2,
        queue_capacity: 16,
        max_batch_requests: 4,
        max_batch_nodes: 1 << 20,
        max_batch_delay: Duration::from_millis(2),
        cache_capacity: 16,
        quarantine_ttl: Duration::from_millis(800),
        ..CoordinatorConfig::default()
    }
}

/// Deterministic head-major features for request `id` (same id ⇒ same
/// features in every run, so outputs are comparable across runs).
fn features(heads: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (
        rng.normal_vec(heads * n * D, 1.0),
        rng.normal_vec(heads * n * D, 1.0),
        rng.normal_vec(heads * n * D, 1.0),
    )
}

fn request(
    id: u64,
    g: &CsrGraph,
    heads: usize,
    backend: Backend,
    deadline: Option<Duration>,
) -> (AttnRequest, Receiver<AttnResponse>) {
    let (q, k, v) = features(heads, g.n, 1000 + id);
    let (tx, rx) = channel();
    let req = AttnRequest {
        id,
        graph: g.clone(),
        d: D,
        dv: D,
        heads,
        q,
        k,
        v,
        scale: SCALE,
        backend,
        deadline,
        span: 0,
        reply: tx,
    };
    (req, rx)
}

fn submit_one(coord: &Coordinator, id: u64, g: &CsrGraph, backend: Backend) -> AttnResponse {
    let (req, rx) = request(id, g, 1, backend, None);
    coord.submit(req).expect("submit");
    rx.recv_timeout(LONG).expect("response")
}

/// The chaos workload: three graph shapes × three backends, mixed head
/// counts.  Request ids index into this fixed spec, so the same id always
/// means the same (graph, heads, backend, features) in every run.
fn workload_specs() -> Vec<(u64, CsrGraph, usize, Backend)> {
    let graphs = [
        generators::ring(48).with_self_loops(),
        generators::erdos_renyi(96, 4.0, 11).with_self_loops(),
        generators::sbm(3, 24, 0.12, 0.02, 5).with_self_loops(),
    ];
    let backends = [Backend::Fused3S, Backend::UnfusedStable, Backend::CpuCsr];
    let mut specs = Vec::new();
    let mut id = 0u64;
    for (gi, g) in graphs.iter().enumerate() {
        for (bi, b) in backends.iter().enumerate() {
            let heads = 1 + (gi + bi) % 2;
            specs.push((id, g.clone(), heads, *b));
            id += 1;
        }
    }
    specs
}

fn submit_workload(coord: &Coordinator) -> Vec<(u64, Backend, Receiver<AttnResponse>)> {
    workload_specs()
        .into_iter()
        .map(|(id, g, heads, backend)| {
            let (req, rx) = request(id, &g, heads, backend, None);
            coord.submit(req).expect("submit");
            (id, backend, rx)
        })
        .collect()
}

/// Per-head dense-reference check for a fallback-served response (bit
/// equality with the baseline is only contractual on the requested
/// backend; a different backend answers to the dense oracle instead).
fn close_to_dense(id: u64, g: &CsrGraph, heads: usize, out: &[f32]) {
    let (q, k, v) = features(heads, g.n, 1000 + id);
    for h in 0..heads {
        let slab = |x: &[f32]| x[h * g.n * D..(h + 1) * g.n * D].to_vec();
        let (qh, kh, vh) = (slab(&q), slab(&k), slab(&v));
        let p = AttentionProblem::new(g.n, D, &qh, &kh, &vh, SCALE);
        let want = reference::dense_attention_host(g, &p);
        let got = &out[h * g.n * D..(h + 1) * g.n * D];
        let err = reference::max_abs_diff(got, &want);
        assert!(err < 0.15, "request {id} head {h}: fallback err {err}");
    }
}

/// One seeded chaos run: install the plan, replay the workload, check the
/// five invariants against the fault-free `baseline`.
fn chaos_run(seed: u64, rate: f64, baseline: &HashMap<u64, Vec<f32>>) {
    let tag = format!("seed={seed} rate={rate}");
    let guard = fault::install(
        FaultPlan::uniform(seed, rate).with_delay(Duration::from_millis(1)),
    );
    let coord = Coordinator::start(config()).expect("start");
    let pending = submit_workload(&coord);
    let total = pending.len();
    let specs: HashMap<u64, (CsrGraph, usize)> = workload_specs()
        .into_iter()
        .map(|(id, g, heads, _)| (id, (g, heads)))
        .collect();

    let mut channels = Vec::new();
    let mut ok_on_requested = 0usize;
    let mut ok_on_fallback = 0usize;
    let mut failed = 0usize;
    for (id, requested, rx) in pending {
        let resp = rx
            .recv_timeout(LONG)
            .unwrap_or_else(|_| panic!("{tag}: request {id} never answered"));
        assert_eq!(resp.id, id, "{tag}: response routed to the wrong channel");
        match resp.result {
            Ok(out) => match resp.backend {
                Some(b) if b == requested => {
                    assert_eq!(
                        out, baseline[&id],
                        "{tag}: request {id} on {requested:?} diverged from \
                         the fault-free baseline"
                    );
                    ok_on_requested += 1;
                }
                Some(_) => {
                    let (g, heads) = &specs[&id];
                    close_to_dense(id, g, *heads, &out);
                    ok_on_fallback += 1;
                }
                None => panic!("{tag}: Ok response without a serving backend"),
            },
            Err(e) => {
                assert!(
                    matches!(
                        e,
                        AttnError::Prepare(_)
                            | AttnError::Execute(_)
                            | AttnError::Unsupported(_)
                    ),
                    "{tag}: request {id}: unexpected failure class {e:?}"
                );
                failed += 1;
            }
        }
        channels.push((id, rx));
    }

    // Read counters, then drain.  `shutdown` returning at all is invariant
    // 4 (a hung or dead stage would time the test out here).
    let m = coord.metrics();
    let (panics, retries, fallbacks, sheds, quarantines) = (
        m.faults.panics_caught_count(),
        m.faults.retries(),
        m.faults.fallbacks(),
        m.faults.deadline_sheds(),
        m.faults.quarantines(),
    );
    coord.shutdown();

    // Exactly-one: after shutdown every reply sender is gone, so a second
    // response would still be buffered — `try_recv` must see Disconnected.
    for (id, rx) in &channels {
        assert!(
            matches!(rx.try_recv(), Err(TryRecvError::Disconnected)),
            "{tag}: request {id} got more than one response"
        );
    }

    // Reconcile the counters with the injection log.
    let log = guard.plan().log();
    let injected_panics = guard.plan().injected_of_kind(FaultKind::Panic);
    let injected_errors = guard.plan().injected_of_kind(FaultKind::Error);
    assert_eq!(sheds, 0, "{tag}: no request carried a deadline");
    if rate == 0.0 {
        assert!(log.is_empty(), "{tag}: disabled plan must not inject");
        assert_eq!(ok_on_requested, total, "{tag}: fault-free run must succeed");
        assert_eq!((ok_on_fallback, failed), (0, 0), "{tag}");
        assert_eq!(
            (panics, retries, fallbacks, quarantines),
            (0, 0, 0, 0),
            "{tag}: counters must stay zero with no faults"
        );
    }
    if injected_panics == 0 {
        assert_eq!(panics, 0, "{tag}: caught panics nobody injected");
    } else {
        // Each injected panic unwinds to exactly one catch boundary; a
        // double-panic inside a pipelined scope can collapse two injections
        // into one caught payload, hence the range.
        assert!(
            (1..=injected_panics as u64).contains(&panics),
            "{tag}: caught {panics} of {injected_panics} injected panics"
        );
    }
    if injected_panics + injected_errors == 0 {
        assert_eq!(
            (retries, fallbacks, quarantines),
            (0, 0, 0),
            "{tag}: delay-only injection must not trigger the ladder"
        );
    }
    if fallbacks > 0 {
        assert!(quarantines > 0, "{tag}: fallback without quarantine");
    }
    if quarantines > 0 {
        assert!(retries > 0, "{tag}: quarantine without a prior retry");
    }
    if ok_on_fallback > 0 {
        assert!(
            fallbacks > 0,
            "{tag}: fallback-served response but fallbacks counter is zero"
        );
    }
    assert_eq!(
        ok_on_requested + ok_on_fallback + failed,
        total,
        "{tag}: response accounting"
    );
}

/// Invariants 1–5 across the pinned grid: seeds {1,2,3} × fault rates
/// {0%, 5%, 25%}, differential against one fault-free baseline.
#[test]
fn chaos_differential_grid() {
    let _gate = gate();
    quiet_panics();
    let baseline: HashMap<u64, Vec<f32>> = {
        let coord = Coordinator::start(config()).expect("start");
        let mut outs = HashMap::new();
        for (id, requested, rx) in submit_workload(&coord) {
            let resp = rx.recv_timeout(LONG).expect("baseline response");
            assert_eq!(resp.backend, Some(requested), "baseline must not degrade");
            outs.insert(id, resp.result.expect("baseline ok"));
        }
        let m = coord.metrics();
        assert!(!m.faults.any(), "baseline run must not count faults");
        coord.shutdown();
        outs
    };
    for seed in [1u64, 2, 3] {
        for rate in [0.0, 0.05, 0.25] {
            chaos_run(seed, rate, &baseline);
        }
    }
}

/// Lifecycle edge: submits racing `shutdown` either observe `QueueClosed`
/// or land before the close — and every accepted request is drained and
/// answered.  A responder is never silently dropped.
#[test]
fn submit_racing_shutdown_never_drops_a_responder() {
    let _gate = gate();
    quiet_panics();
    let coord = Arc::new(Coordinator::start(config()).expect("start"));
    let g = generators::ring(16).with_self_loops();
    let mut submitters = Vec::new();
    for t in 0..4u64 {
        let coord = Arc::clone(&coord);
        let g = g.clone();
        submitters.push(std::thread::spawn(move || {
            let mut pending = Vec::new();
            for i in 0..50u64 {
                let id = 10_000 + t * 1000 + i;
                let (req, rx) = request(id, &g, 1, Backend::CpuCsr, None);
                match coord.submit(req) {
                    Ok(()) => pending.push((id, rx)),
                    Err(AttnError::QueueClosed) => {} // raced the teardown
                    Err(e) => panic!("unexpected submit error: {e:?}"),
                }
            }
            pending
        }));
    }
    std::thread::sleep(Duration::from_millis(10));
    coord.shutdown(); // concurrent with the submitters above
    let mut accepted = 0usize;
    for h in submitters {
        for (id, rx) in h.join().expect("submitter thread") {
            accepted += 1;
            let resp = rx
                .recv_timeout(LONG)
                .unwrap_or_else(|_| panic!("accepted request {id} never answered"));
            assert_eq!(resp.id, id);
            assert!(
                resp.result.is_ok(),
                "request {id} failed: {:?}",
                resp.result.err()
            );
            assert!(matches!(rx.try_recv(), Err(TryRecvError::Disconnected)));
        }
    }
    // The 10ms head start all but guarantees some submits landed; the
    // assertion documents that the test exercised the accepted path at all.
    assert!(accepted > 0, "no submit landed before shutdown");
}

/// Lifecycle edge: a request parked in the coalescer past its deadline is
/// shed with `DeadlineExceeded` when the deadline passes — not when the
/// (much later) batch-delay flush would have fired.
#[test]
fn parked_request_sheds_at_deadline() {
    let _gate = gate();
    quiet_panics();
    let coord = Coordinator::start(CoordinatorConfig {
        max_batch_delay: Duration::from_secs(5),
        max_batch_requests: 64,
        ..config()
    })
    .expect("start");
    let g = generators::ring(16).with_self_loops();
    let (req, rx) = request(1, &g, 1, Backend::CpuCsr, Some(Duration::from_millis(100)));
    coord.submit(req).expect("submit");
    let resp = rx
        .recv_timeout(Duration::from_secs(4))
        .expect("shed response must arrive at the deadline, not the flush");
    assert!(
        matches!(resp.result, Err(AttnError::DeadlineExceeded)),
        "want DeadlineExceeded, got {:?}",
        resp.result.map(|v| v.len())
    );
    assert_eq!(resp.backend, None);
    assert!(
        resp.latency_s >= 0.1,
        "shed before the deadline: {}s",
        resp.latency_s
    );
    assert_eq!(coord.metrics().faults.deadline_sheds(), 1);
    assert_eq!(coord.metrics().failed(), 1);
    coord.shutdown();
}

/// Degradation-ladder edge: a backend whose prepare keeps failing is
/// quarantined (request served on a fallback), stays quarantined for the
/// TTL even after the fault heals, and is re-admitted once it expires.
#[test]
fn quarantined_backend_readmitted_after_ttl() {
    let _gate = gate();
    quiet_panics();
    let coord = Coordinator::start(config()).expect("start"); // ttl = 800ms
    let g = generators::erdos_renyi(64, 4.0, 3).with_self_loops();
    // First two prepare attempts fail deterministically, then the budget
    // runs dry and the "hardware" heals.
    let guard = fault::install(
        FaultPlan::new(5)
            .with(FaultSite::Prepare, FaultKind::Error, 1.0)
            .with_budget(2),
    );
    let resp = submit_one(&coord, 1, &g, Backend::Fused3S);
    let out = resp.result.expect("served via the fallback ladder");
    assert_ne!(
        resp.backend,
        Some(Backend::Fused3S),
        "must not report the quarantined backend as the server"
    );
    assert!(resp.backend.is_some());
    let m = coord.metrics();
    assert_eq!(m.faults.retries(), 1, "exactly one retry before quarantine");
    assert_eq!(m.faults.quarantines(), 1);
    assert!(m.faults.fallbacks() >= 1);
    drop(guard); // injection healed; the quarantine entry remains

    let resp2 = submit_one(&coord, 2, &g, Backend::Fused3S);
    assert!(resp2.result.is_ok());
    assert_ne!(
        resp2.backend,
        Some(Backend::Fused3S),
        "inside the TTL the ladder must keep steering away"
    );

    std::thread::sleep(Duration::from_millis(1200)); // past the 800ms TTL
    let resp3 = submit_one(&coord, 3, &g, Backend::Fused3S);
    let out3 = resp3.result.expect("healed backend serves again");
    assert_eq!(
        resp3.backend,
        Some(Backend::Fused3S),
        "expired quarantine must re-admit the backend"
    );
    // Fallback-served and healed outputs agree within cross-backend
    // tolerance (they ran different kernels, so no bit contract).
    assert!(reference::max_abs_diff(&out, &out3) < 0.15);
    coord.shutdown();
}

/// Seeded mixed edit batch for the streaming chaos tests: removes are
/// sampled from resident edges so they take effect.
fn churn(g: &CsrGraph, edits: usize, rng: &mut Rng) -> GraphDelta {
    let mut ins = Vec::new();
    let mut rem = Vec::new();
    for _ in 0..edits {
        if rng.coin(0.5) {
            let u = rng.below(g.n);
            let row = g.row(u);
            if !row.is_empty() {
                rem.push((u as u32, row[rng.below(row.len())]));
                continue;
            }
        }
        ins.push((rng.below(g.n) as u32, rng.below(g.n) as u32));
    }
    ins.retain(|e| !rem.contains(e));
    GraphDelta::against(g, ins, rem)
}

/// Streaming chaos (ISSUE 9 satellite): a fault injected into the
/// incremental BSB rebuild — panic or typed error — must not lose the
/// update.  `update_graph` falls back to a full from-scratch rebuild,
/// still swaps the version in, counts the fallback, and keeps serving
/// correct answers afterwards.
#[test]
fn update_graph_fault_falls_back_to_full_rebuild() {
    let _gate = gate();
    quiet_panics();
    let coord = Coordinator::start(config()).expect("start");
    let g0 = generators::erdos_renyi(96, 4.0, 17).with_self_loops();
    let mut rng = Rng::new(31);

    // Seed the BSB registry: the first delta has nothing to splice from.
    let d1 = churn(&g0, 20, &mut rng);
    let (g1, _) = d1.applied(&g0).expect("mirror");
    let r1 = coord.update_graph(&g0, &d1).expect("first update");
    assert!(r1.full_rebuild, "no registered BSB yet");

    // Panic inside the incremental rebuild: caught, full rebuild, swap
    // still lands.
    let guard = fault::install(
        FaultPlan::new(7)
            .with(FaultSite::Prepare, FaultKind::Panic, 1.0)
            .with_budget(1),
    );
    let d2 = churn(&g1, 20, &mut rng);
    let (g2, _) = d2.applied(&g1).expect("mirror");
    let r2 = coord.update_graph(&g1, &d2).expect("update must survive the panic");
    assert_eq!(r2.new_fp, g2.fingerprint());
    assert!(r2.full_rebuild, "panic must route to the full rebuild");
    assert_eq!(r2.spliced_rws, 0, "nothing spliced on the fallback path");
    assert_eq!(guard.plan().injected_of_kind(FaultKind::Panic), 1);
    drop(guard);

    // A typed error takes the same fallback without a panic.
    let guard = fault::install(
        FaultPlan::new(9)
            .with(FaultSite::Prepare, FaultKind::Error, 1.0)
            .with_budget(1),
    );
    let d3 = churn(&g2, 20, &mut rng);
    let (g3, _) = d3.applied(&g2).expect("mirror");
    let r3 = coord.update_graph(&g2, &d3).expect("update must survive the error");
    assert!(r3.full_rebuild);
    drop(guard);

    let m = coord.metrics();
    assert_eq!(m.streaming.deltas_applied(), 3);
    assert_eq!(m.streaming.full_rebuilds(), 3);
    assert_eq!(m.faults.panics_caught_count(), 1, "exactly the injected panic");

    // The fallback-built plan still answers to the dense oracle.
    let resp = submit_one(&coord, 42, &g3, Backend::CpuCsr);
    let out = resp.result.expect("serve after chaos");
    close_to_dense(42, &g3, 1, &out);
    coord.shutdown();
}

/// Streaming chaos: deltas racing live submits.  Every response must
/// bit-match the fault-free baseline *for the graph version the request
/// carried* — a half-patched plan, or a plan swapped under the wrong
/// fingerprint, would perturb the bits.  Exactly-one-response holds
/// throughout.
#[test]
fn update_graph_racing_submits_serves_each_version_bit_exact() {
    let _gate = gate();
    quiet_panics();
    // Version chain g0 → g4, mirrored locally before any serving starts.
    let mut rng = Rng::new(77);
    let mut versions = vec![generators::erdos_renyi(80, 4.0, 23).with_self_loops()];
    let mut deltas = Vec::new();
    for _ in 0..4 {
        let d = churn(versions.last().unwrap(), 16, &mut rng);
        let (next, _) = d.applied(versions.last().unwrap()).expect("mirror");
        deltas.push(d);
        versions.push(next);
    }

    // Fault-free per-version baseline from an isolated coordinator.
    let baseline: HashMap<u64, Vec<f32>> = {
        let coord = Coordinator::start(config()).expect("baseline start");
        let mut outs = HashMap::new();
        for (vi, g) in versions.iter().enumerate() {
            for slot in 0..3u64 {
                let id = vi as u64 * 100 + slot;
                let resp = submit_one(&coord, id, g, Backend::CpuCsr);
                outs.insert(id, resp.result.expect("baseline ok"));
            }
        }
        coord.shutdown();
        outs
    };

    let coord = Arc::new(Coordinator::start(config()).expect("start"));
    let versions = Arc::new(versions);
    let mut submitters = Vec::new();
    for t in 0..3usize {
        let coord = Arc::clone(&coord);
        let versions = Arc::clone(&versions);
        submitters.push(std::thread::spawn(move || {
            let mut pending = Vec::new();
            for round in 0..versions.len() {
                let vi = (round + t) % versions.len();
                for slot in 0..3u64 {
                    let id = vi as u64 * 100 + slot;
                    let (req, rx) = request(id, &versions[vi], 1, Backend::CpuCsr, None);
                    coord.submit(req).expect("submit");
                    pending.push((id, rx));
                }
            }
            pending
        }));
    }
    // Race the whole delta chain against the submitters.
    for (i, d) in deltas.iter().enumerate() {
        let rep = coord.update_graph(&versions[i], d).expect("racing update");
        assert_eq!(rep.new_fp, versions[i + 1].fingerprint());
    }
    let mut channels = Vec::new();
    for h in submitters {
        for (id, rx) in h.join().expect("submitter thread") {
            let resp = rx.recv_timeout(LONG).expect("response");
            assert_eq!(resp.id, id);
            let out = resp.result.expect("racing request must succeed");
            assert_eq!(
                out, baseline[&id],
                "request {id}: a racing delta perturbed the served output — \
                 a half-patched or wrong-version plan answered"
            );
            channels.push((id, rx));
        }
    }
    coord.shutdown();
    for (id, rx) in &channels {
        assert!(
            matches!(rx.try_recv(), Err(TryRecvError::Disconnected)),
            "request {id} got more than one response"
        );
    }
}

/// Streaming chaos: `update_graph` racing `shutdown`.  The out-of-band
/// swap path does not ride the ingress queue, so it completes even while
/// the stages drain — and every request accepted before the close is
/// still answered exactly once.
#[test]
fn update_graph_racing_shutdown_stays_safe() {
    let _gate = gate();
    quiet_panics();
    let coord = Arc::new(Coordinator::start(config()).expect("start"));
    let g0 = generators::erdos_renyi(64, 4.0, 29).with_self_loops();
    let mut rng = Rng::new(41);
    let mut pending = Vec::new();
    for id in 0..8u64 {
        let (req, rx) = request(500 + id, &g0, 1, Backend::CpuCsr, None);
        match coord.submit(req) {
            Ok(()) => pending.push((500 + id, rx)),
            Err(AttnError::QueueClosed) => {}
            Err(e) => panic!("unexpected submit error: {e:?}"),
        }
    }
    let delta = churn(&g0, 12, &mut rng);
    let updater = {
        let coord = Arc::clone(&coord);
        let g0 = g0.clone();
        std::thread::spawn(move || coord.update_graph(&g0, &delta))
    };
    coord.shutdown(); // concurrent with the updater
    let rep = updater
        .join()
        .expect("updater thread")
        .expect("out-of-band update must not depend on the live queue");
    assert_eq!(rep.old_fp, g0.fingerprint());
    for (id, rx) in &pending {
        let resp = rx
            .recv_timeout(LONG)
            .unwrap_or_else(|_| panic!("accepted request {id} never answered"));
        assert_eq!(resp.id, *id);
        assert!(resp.result.is_ok(), "request {id}: {:?}", resp.result.err());
        assert!(matches!(rx.try_recv(), Err(TryRecvError::Disconnected)));
    }
}

/// Regression (ISSUE 6 satellite): a per-shard prepare failure inside a
/// sharded plan fails *that request* with a structured error naming the
/// shard — it must not kill the preprocessing worker or hang the batch.
#[test]
fn sharded_prepare_panic_fails_only_that_request() {
    let _gate = gate();
    quiet_panics();
    let coord = Coordinator::start(CoordinatorConfig {
        max_plan_nodes: 64,
        max_shards: 8,
        quarantine_ttl: Duration::from_millis(200),
        ..config()
    })
    .expect("start");
    let g = generators::erdos_renyi(300, 4.0, 7).with_self_loops();
    let guard = fault::install(
        FaultPlan::new(11).with(FaultSite::Prepare, FaultKind::Panic, 1.0),
    );
    let (req, rx) = request(1, &g, 1, Backend::Fused3S, None);
    coord.submit(req).expect("submit");
    let resp = rx.recv_timeout(LONG).expect("failing request still answered");
    // Rate 1.0 with no budget panics every backend's prepare: the ladder
    // exhausts the candidate set and reports the per-shard failure.
    match resp.result.expect_err("prepare must fail") {
        AttnError::Prepare(msg) => assert!(
            msg.contains("shard"),
            "error must name the failing shard: {msg}"
        ),
        other => panic!("want AttnError::Prepare, got {other:?}"),
    }
    assert_eq!(resp.backend, None);
    let m = coord.metrics();
    assert!(m.faults.retries() >= 1, "ladder must have retried");
    assert!(m.faults.quarantines() >= 1, "ladder must have quarantined");
    drop(guard); // heal

    // The worker survived: after the quarantine TTL expires the identical
    // request plans and executes fine.
    std::thread::sleep(Duration::from_millis(400));
    let resp2 = submit_one(&coord, 2, &g, Backend::Fused3S);
    assert!(
        resp2.result.is_ok(),
        "coordinator must recover: {:?}",
        resp2.result.err()
    );
    assert_eq!(resp2.backend, Some(Backend::Fused3S));
    coord.shutdown();
}
