//! Metrics report matrix (ISSUE 10 satellite): every conditional
//! `Metrics::report()` section appears exactly when its counter group has
//! recorded traffic, and the structured `Metrics::to_json()` snapshot —
//! the payload of the wire `MetricsReport` message — round-trips through
//! the repo's own JSON writer/parser unchanged.

use fused3s::coordinator::metrics::{
    bucket_floor_s, Metrics, HIST_BUCKETS,
};
use fused3s::kernels::Backend;
use fused3s::util::json::{self, Json};

/// Each conditional report section, the marker substring that identifies
/// it, and a recorder that makes its `any()`/count gate fire.
fn section_matrix() -> Vec<(&'static str, &'static str, fn(&Metrics))> {
    vec![
        ("planner", "planner auto=", |m: &Metrics| {
            m.planner.auto_resolved(Backend::Fused3S)
        }),
        ("sharding", "sharding batches=", |m: &Metrics| {
            m.sharding.record_batch(2, 10)
        }),
        ("faults", "faults panics=", |m: &Metrics| m.faults.retry()),
        ("streaming", "streaming deltas=", |m: &Metrics| {
            m.streaming.delta_applied(1, 3)
        }),
        ("net", "net conns=", |m: &Metrics| m.net.connection()),
    ]
}

#[test]
fn conditional_sections_appear_iff_traffic_exists() {
    for (name, marker, arm) in section_matrix() {
        // Quiet metrics: the section must be absent (old log shape).
        let quiet = Metrics::new();
        assert!(
            !quiet.report().contains(marker),
            "section '{name}' leaked into a quiet report"
        );
        // One recorded event: the section must appear.
        let busy = Metrics::new();
        arm(&busy);
        assert!(
            busy.report().contains(marker),
            "section '{name}' missing after traffic: {}",
            busy.report()
        );
        // Arming one section must not drag in the others.
        for (other, other_marker, _) in section_matrix() {
            if other != name {
                assert!(
                    !busy.report().contains(other_marker),
                    "arming '{name}' surfaced unrelated section '{other}'"
                );
            }
        }
    }
}

#[test]
fn base_line_always_present() {
    let m = Metrics::new();
    let r = m.report();
    for marker in ["requests=", "failed=", "latency", "batches=", "bsb-cache"] {
        assert!(r.contains(marker), "base report lost '{marker}': {r}");
    }
}

/// Populate every counter group so the JSON snapshot exercises all
/// sections with nonzero values.
fn populated() -> Metrics {
    let m = Metrics::new();
    m.request_done(true);
    m.request_done(true);
    m.request_done(false);
    m.latency.record(0.004);
    m.latency.record(0.012);
    m.preprocess.record(0.002);
    m.execute.record(0.0015);
    m.batching.record_batch(3);
    m.batching.cache_hit();
    m.batching.cache_miss();
    m.batching.cache_evicted(1);
    m.planner.auto_resolved(Backend::Fused3S);
    m.planner.auto_resolved(Backend::Hybrid);
    m.planner.observation();
    m.planner.invalidation();
    m.sharding.record_batch(4, 64);
    m.faults.panic_caught();
    m.faults.retry();
    m.faults.fallback();
    m.faults.deadline_shed();
    m.faults.quarantine();
    m.net.connection();
    m.net.request();
    m.net.graph_upload();
    m.net.graph_reuse();
    m.net.read(256);
    m.net.wrote(128);
    m.streaming.delta_applied(5, 27);
    m.streaming.full_rebuild();
    m
}

#[test]
fn to_json_has_every_section_even_when_idle() {
    // Unlike report(), the structured snapshot never omits a section:
    // wire consumers must not have to probe for keys.
    let idle = Metrics::new().to_json();
    for key in [
        "requests", "latency", "preprocess", "execute", "batching",
        "planner", "sharding", "faults", "net", "streaming",
    ] {
        assert!(idle.get(key).is_some(), "idle to_json missing '{key}'");
    }
}

#[test]
fn to_json_roundtrips_through_util_json() {
    let j = populated().to_json();
    let text = json::to_string(&j);
    let back = Json::parse(&text).expect("to_json output must reparse");
    assert_eq!(back, j, "to_json round-trip changed the tree");
}

#[test]
fn to_json_values_reconcile_with_counters() {
    let m = populated();
    let j = m.to_json();
    let n = |path: &[&str]| -> f64 {
        let mut v = &j;
        for k in path {
            v = v.req(k).expect("key present");
        }
        v.as_f64().expect("number")
    };
    assert_eq!(n(&["requests", "completed"]), 2.0);
    assert_eq!(n(&["requests", "failed"]), 1.0);
    assert_eq!(n(&["latency", "count"]), 2.0);
    assert_eq!(n(&["latency", "max_s"]), 0.012);
    assert_eq!(n(&["batching", "batches"]), 1.0);
    assert_eq!(n(&["batching", "coalesced_requests"]), 3.0);
    assert_eq!(n(&["planner", "auto_requests"]), 2.0);
    assert_eq!(n(&["planner", "resolved", "fused3s"]), 1.0);
    assert_eq!(n(&["planner", "resolved", "hybrid"]), 1.0);
    assert_eq!(n(&["sharding", "halo_rows_gathered"]), 64.0);
    assert_eq!(n(&["faults", "retries"]), 1.0);
    assert_eq!(n(&["net", "bytes_in"]), 256.0);
    assert_eq!(n(&["streaming", "rws_spliced"]), 27.0);
    assert_eq!(n(&["streaming", "full_rebuilds"]), 1.0);

    // Histogram arrays are complete, aligned, and closed-form.
    let floors = j
        .req("latency")
        .and_then(|l| l.req("histogram_floors_s"))
        .and_then(|a| a.as_arr().map(<[Json]>::to_vec))
        .expect("floors array");
    let counts = j
        .req("latency")
        .and_then(|l| l.req("histogram_counts"))
        .and_then(|a| a.as_arr().map(<[Json]>::to_vec))
        .expect("counts array");
    assert_eq!(floors.len(), HIST_BUCKETS);
    assert_eq!(counts.len(), HIST_BUCKETS);
    for (i, f) in floors.iter().enumerate() {
        assert_eq!(f.as_f64().expect("floor"), bucket_floor_s(i));
    }
    let total: f64 = counts
        .iter()
        .map(|c| c.as_f64().expect("count"))
        .sum();
    assert_eq!(total, 2.0, "histogram total == latency sample count");
}
