//! Tracing differential harness (ISSUE 10): arming the process-global
//! tracer must be **bit-invisible** to every numeric output — standalone
//! plans and the full coordinator, including the sharded path — and the
//! captured event stream must obey the span discipline the Chrome
//! exporter depends on: balanced begin/end pairs per span, children
//! strictly inside their request span, ring order consistent with the
//! happens-before chain each request threads through the pipeline.
//!
//! The tracer is process-global (`trace::install` is latest-wins), so
//! every test here serialises on one mutex; `scripts/verify.sh` also
//! runs this suite with `--test-threads=1`.

use std::collections::{HashMap, HashSet};
use std::sync::mpsc::channel;
use std::sync::Mutex;
use std::time::Duration;

use fused3s::coordinator::{
    AttnRequest, Coordinator, CoordinatorConfig, ExecutorKind,
};
use fused3s::exec::{offline_manifest, Engine, ExecPolicy};
use fused3s::graph::{generators, CsrGraph};
use fused3s::kernels::{AttentionBatch, Backend, ExecCtx, Plan};
use fused3s::runtime::Manifest;
use fused3s::trace::{self, TraceConfig, TraceKind, TraceSite};
use fused3s::util::prng::Rng;

/// One tracer per process: serialise every test in this file.
static SERIAL: Mutex<()> = Mutex::new(());

fn manifest() -> Manifest {
    offline_manifest(8, &[4, 8, 16, 32, 64, 128], 128)
}

fn features(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (
        rng.normal_vec(n * d, 1.0),
        rng.normal_vec(n * d, 1.0),
        rng.normal_vec(n * d, 1.0),
    )
}

/// The workload both sides of the differential run: standalone fused and
/// hybrid plans, then the coordinator over normal, hybrid-routed and
/// sharded requests.  Returns every output vector in a fixed order.
fn run_workload() -> Vec<Vec<f32>> {
    let man = manifest();
    let serial = Engine::serial();
    let d = 16;
    let mut outs = Vec::new();

    // Standalone plans: the library path with no coordinator at all.
    let standalone: &[(CsrGraph, Backend)] = &[
        (
            generators::erdos_renyi(120, 5.0, 21).with_self_loops(),
            Backend::Fused3S,
        ),
        (
            generators::sbm(3, 24, 0.3, 0.02, 22).with_self_loops(),
            Backend::Hybrid,
        ),
    ];
    for (g, backend) in standalone {
        let (q, k, v) = features(g.n, d, 7000 + g.n as u64);
        let x = AttentionBatch::new(g.n, d, d, 1, &q, &k, &v, 0.25);
        let plan = Plan::new(&man, g, *backend, &serial).expect("plan");
        outs.push(
            plan.execute(&mut ExecCtx::host(&serial), &x).expect("run"),
        );
    }

    // The coordinator: normal, hybrid and sharded (n = 300 > cap 128).
    let coord = Coordinator::start(CoordinatorConfig {
        executor: ExecutorKind::HostEmulation,
        preprocess_workers: 2,
        queue_capacity: 16,
        max_batch_requests: 4,
        max_batch_delay: Duration::from_millis(1),
        exec: ExecPolicy::serial(),
        max_plan_nodes: 128,
        max_shards: 8,
        ..CoordinatorConfig::default()
    })
    .expect("coordinator");
    let served: &[(CsrGraph, Backend)] = &[
        (
            generators::erdos_renyi(90, 4.0, 23).with_self_loops(),
            Backend::Fused3S,
        ),
        (generators::star(70), Backend::Hybrid),
        (
            generators::erdos_renyi(300, 5.0, 24).with_self_loops(),
            Backend::Fused3S,
        ),
    ];
    let (tx, rx) = channel();
    for (i, (g, backend)) in served.iter().enumerate() {
        let (q, k, v) = features(g.n, d, 8000 + i as u64);
        coord
            .submit(AttnRequest::single_head(
                i as u64,
                g.clone(),
                d,
                q,
                k,
                v,
                0.25,
                *backend,
                tx.clone(),
            ))
            .expect("submit");
    }
    drop(tx);
    let mut got: HashMap<u64, Vec<f32>> = HashMap::new();
    while let Ok(resp) = rx.recv_timeout(Duration::from_secs(120)) {
        got.insert(resp.id, resp.result.expect("served result"));
        if got.len() == served.len() {
            break;
        }
    }
    coord.shutdown();
    assert_eq!(got.len(), served.len(), "missing coordinator responses");
    for i in 0..served.len() {
        outs.push(got.remove(&(i as u64)).expect("indexed response"));
    }
    outs
}

/// The acceptance contract: running the identical workload with the
/// tracer armed at `sample_rate = 1.0` changes no output bit anywhere —
/// tracing observes the pipeline, it never participates in it.
#[test]
fn armed_tracing_is_bit_invisible() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let baseline = run_workload();
    assert!(!trace::enabled(), "no tracer may be armed for the baseline");

    let armed = {
        let guard = trace::install(TraceConfig {
            sample_rate: 1.0,
            ..TraceConfig::default()
        });
        let outs = run_workload();
        assert!(
            guard.recorded() > 0,
            "the armed run must actually have traced something"
        );
        outs
    };
    assert!(!trace::enabled(), "guard drop must disarm the tracer");

    assert_eq!(baseline.len(), armed.len());
    for (i, (want, got)) in baseline.iter().zip(&armed).enumerate() {
        assert_eq!(
            want, got,
            "workload output {i}: tracing perturbed the numerics"
        );
    }
}

/// Seeded sampling is a pure function of `(seed, request id)`: the same
/// config picks the same requests on every install, a different seed
/// picks a different subset, and the boundary rates pick all or nothing.
#[test]
fn sampling_is_seeded_and_reproducible() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let picks = |seed: u64, rate: f64| -> Vec<bool> {
        let guard = trace::install(TraceConfig {
            seed,
            sample_rate: rate,
            ..TraceConfig::default()
        });
        (0..512).map(|id| guard.sample_request(id) != 0).collect()
    };
    let a = picks(1, 0.5);
    let b = picks(1, 0.5);
    assert_eq!(a, b, "same (seed, rate) must sample the same requests");
    let hits = a.iter().filter(|&&s| s).count();
    assert!(
        (1..512).contains(&hits),
        "rate 0.5 over 512 ids picked {hits}: sampler is stuck"
    );
    let c = picks(2, 0.5);
    assert_ne!(a, c, "a different seed must pick a different subset");
    assert!(picks(3, 0.0).iter().all(|&s| !s), "rate 0 samples nothing");
    assert!(picks(3, 1.0).iter().all(|&s| s), "rate 1 samples everything");
    // Disarmed, the module hook refuses every request.
    assert_eq!(trace::sample_request(42), 0);
}

/// Span discipline over a real traced serving run, checked in **ring
/// order** (claim order respects the happens-before chain each request
/// rides through submit → batcher → prepare → execute → respond):
/// begin/end pairs balance per span, every stage happens inside its open
/// request span, the sharded request emits per-shard prepare spans, and
/// the Chrome export carries `tid` = span for every event.
#[test]
fn captured_spans_nest_and_export() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let guard = trace::install(TraceConfig {
        sample_rate: 1.0,
        ..TraceConfig::default()
    });
    run_workload();
    let events = guard.snapshot();
    assert_eq!(
        guard.dropped(),
        0,
        "workload must fit the default ring for a complete check"
    );
    assert!(!events.is_empty());

    let mut stacks: HashMap<u64, Vec<TraceSite>> = HashMap::new();
    let mut open_requests: HashSet<u64> = HashSet::new();
    let mut sites_seen: HashSet<&'static str> = HashSet::new();
    let mut shard_prepares = 0usize;
    for e in &events {
        assert_ne!(e.span, 0, "span 0 events must never reach the ring");
        sites_seen.insert(e.site.name());
        match e.kind {
            TraceKind::Begin => {
                if e.site == TraceSite::Request {
                    assert!(
                        open_requests.insert(e.span),
                        "span {} opened twice",
                        e.span
                    );
                } else if matches!(
                    e.site,
                    TraceSite::Admission
                        | TraceSite::Prepare
                        | TraceSite::Execute
                        | TraceSite::ShardPrepare
                ) {
                    assert!(
                        open_requests.contains(&e.span),
                        "{} began outside its request span {}",
                        e.site.name(),
                        e.span
                    );
                }
                if e.site == TraceSite::ShardPrepare {
                    shard_prepares += 1;
                }
                stacks.entry(e.span).or_default().push(e.site);
            }
            TraceKind::End => {
                let stack = stacks.entry(e.span).or_default();
                let top = stack.pop().unwrap_or_else(|| {
                    panic!("{} end on span {} with an empty stack",
                        e.site.name(), e.span)
                });
                assert_eq!(
                    top,
                    e.site,
                    "span {}: {} ended while {} was open",
                    e.span,
                    e.site.name(),
                    top.name()
                );
                if e.site == TraceSite::Request {
                    open_requests.remove(&e.span);
                }
            }
            TraceKind::Instant => {}
        }
    }
    for (span, stack) in &stacks {
        assert!(
            stack.is_empty(),
            "span {span} left {:?} open at quiescence",
            stack.iter().map(|s| s.name()).collect::<Vec<_>>()
        );
    }
    assert!(open_requests.is_empty(), "unclosed request spans");
    for site in
        ["request", "admission", "prepare", "execute", "respond"]
    {
        assert!(sites_seen.contains(site), "no '{site}' events captured");
    }
    assert!(
        shard_prepares >= 2,
        "the n=300 request under cap 128 must emit per-shard prepare spans"
    );

    // The Chrome export: an object the viewer loads directly, one event
    // per ring entry, `tid` = span so requests render as tracks.
    let chrome = guard.chrome_json();
    let traced = chrome
        .req("traceEvents")
        .and_then(|t| t.as_arr().map(<[_]>::to_vec))
        .expect("traceEvents array");
    assert_eq!(traced.len(), events.len());
    for (e, j) in events.iter().zip(&traced) {
        let tid = j
            .req("tid")
            .and_then(fused3s::util::json::Json::as_f64)
            .expect("tid");
        assert_eq!(tid as u64, e.span, "tid must be the span id");
        let ph = j
            .req("ph")
            .and_then(|p| p.as_str().map(str::to_string))
            .expect("ph");
        assert_eq!(ph, e.kind.ph());
    }
}
