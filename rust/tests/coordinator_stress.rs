//! Coordinator stress suite (ISSUE 2): N concurrent submitters × mixed
//! graph sizes under a tiny `queue_capacity`, asserting that backpressure
//! blocks rather than drops, that responses route to the correct
//! requester with correct (bit-exact) payloads, that shutdown drains the
//! coalescing queue, and that the fingerprint cache reports hits on
//! repeated-graph workloads.  Runs entirely offline
//! (`ExecutorKind::HostEmulation`); `scripts/verify.sh` runs this file
//! with `--test-threads=1` so the stress tests don't interleave.

use std::collections::HashMap;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use fused3s::coordinator::{
    AttnRequest, Coordinator, CoordinatorConfig, ExecutorKind,
};
use fused3s::exec::{offline_manifest, Engine, ExecPolicy};
use fused3s::graph::batch::random_molecule;
use fused3s::graph::{generators, CsrGraph};
use fused3s::kernels::{AttentionBatch, AttentionProblem, Backend, ExecCtx, Plan};
use fused3s::runtime::Manifest;
use fused3s::util::prng::Rng;

fn manifest() -> Manifest {
    offline_manifest(8, &[4, 8, 16, 32, 64, 128], 128)
}

fn features(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (
        rng.normal_vec(n * d, 1.0),
        rng.normal_vec(n * d, 1.0),
        rng.normal_vec(n * d, 1.0),
    )
}

fn serial_expected(
    man: &Manifest,
    g: &CsrGraph,
    d: usize,
    scale: f32,
    seed: u64,
) -> Vec<f32> {
    let engine = Engine::serial();
    let (q, k, v) = features(g.n, d, seed);
    let plan = Plan::new(man, g, Backend::Fused3S, &engine).unwrap();
    let x = AttentionProblem::new(g.n, d, &q, &k, &v, scale);
    plan.execute(&mut ExecCtx::host(&engine), &AttentionBatch::single(&x))
        .unwrap()
}

/// Mixed graph sizes/shapes shared by all submitters (repeats feed the
/// batch compositions).
fn graph_pool() -> Vec<CsrGraph> {
    let mut rng = Rng::new(0x57AE55);
    vec![
        generators::erdos_renyi(24, 3.0, 1).with_self_loops(),
        random_molecule(60, &mut rng).with_self_loops(),
        generators::star(33),
        generators::sbm(3, 16, 0.2, 0.02, 5).with_self_loops(),
        generators::erdos_renyi(160, 5.0, 2).with_self_loops(),
    ]
}

const D: usize = 8;
const SCALE: f32 = 0.25;

#[test]
fn concurrent_submitters_backpressure_and_routing() {
    let coord = Arc::new(
        Coordinator::start(CoordinatorConfig {
            executor: ExecutorKind::HostEmulation,
            preprocess_workers: 2,
            // Tiny ingress bound: submitters must block on backpressure,
            // and every accepted request must still complete (never drop).
            queue_capacity: 4,
            exec: ExecPolicy { threads: 2, pipeline_depth: 2 },
            max_batch_requests: 16,
            max_batch_nodes: 2048,
            // Wide enough that 6 racing submitters reliably overlap inside
            // one window even on a loaded single-core CI machine.
            max_batch_delay: Duration::from_millis(25),
            cache_capacity: 32,
            ..CoordinatorConfig::default()
        })
        .expect("host-emulation coordinator"),
    );
    let pool = graph_pool();
    let threads = 6usize;
    let per_thread = 20usize;
    // id → (graph index, feature seed); invalid requests are excluded.
    let mut handles = Vec::new();
    for t in 0..threads {
        let coord = coord.clone();
        let pool = pool.clone();
        handles.push(std::thread::spawn(move || {
            let (tx, rx) = channel();
            let mut sent: HashMap<u64, Option<(usize, u64)>> = HashMap::new();
            for i in 0..per_thread {
                let id = (t * 1000 + i) as u64;
                let gi = (t + i) % pool.len();
                let g = pool[gi].clone();
                if i == 7 {
                    // One malformed request per submitter: wrong buffer
                    // sizes must fail gracefully, not poison the batch.
                    coord
                        .submit(AttnRequest::single_head(
                            id,
                            g,
                            D,
                            vec![0.0; 3],
                            vec![0.0; 3],
                            vec![0.0; 3],
                            SCALE,
                            Backend::Fused3S,
                            tx.clone(),
                        ))
                        .expect("submit");
                    sent.insert(id, None);
                    continue;
                }
                let seed = id * 7 + 13;
                let (q, k, v) = features(g.n, D, seed);
                coord
                    .submit(AttnRequest::single_head(
                        id,
                        g,
                        D,
                        q,
                        k,
                        v,
                        SCALE,
                        Backend::Fused3S,
                        tx.clone(),
                    ))
                    .expect("submit");
                sent.insert(id, Some((gi, seed)));
            }
            drop(tx);
            // Collect exactly this thread's responses.
            let mut got = Vec::new();
            for _ in 0..per_thread {
                let resp = rx
                    .recv_timeout(Duration::from_secs(120))
                    .expect("response within timeout");
                assert!(
                    sent.contains_key(&resp.id),
                    "thread {t}: got response for foreign id {}",
                    resp.id
                );
                got.push(resp);
            }
            assert!(
                rx.recv_timeout(Duration::from_millis(50)).is_err(),
                "thread {t}: more responses than requests"
            );
            (sent, got)
        }));
    }

    let man = manifest();
    // Expected outputs are deterministic per (graph, seed): verify every
    // routed response bit-exactly against a serial per-request run.
    let mut completed = 0u64;
    let mut failed = 0u64;
    for h in handles {
        let (sent, got) = h.join().expect("submitter");
        assert_eq!(got.len(), per_thread);
        for resp in got {
            match &sent[&resp.id] {
                None => {
                    assert!(resp.result.is_err(), "malformed request must fail");
                    failed += 1;
                }
                Some((gi, seed)) => {
                    let out = resp.result.as_ref().expect("result");
                    let want =
                        serial_expected(&man, &pool[*gi], D, SCALE, *seed);
                    assert_eq!(out, &want, "id {} payload diverged", resp.id);
                    assert!(resp.batch_size >= 1);
                    completed += 1;
                }
            }
        }
    }
    assert_eq!(completed + failed, (threads * per_thread) as u64);
    let m = coord.metrics();
    assert_eq!(m.completed(), completed, "no request may be dropped");
    assert_eq!(m.failed(), failed);
    assert_eq!(m.failed(), threads as u64);
    // With 6 submitters racing a 1 ms window, coalescing must actually
    // have happened.
    assert!(
        m.batching.largest_batch() >= 2,
        "expected at least one coalesced batch: {}",
        m.report()
    );
    let coord = Arc::try_unwrap(coord).ok().expect("sole owner");
    coord.shutdown();
}

#[test]
fn repeated_graphs_hit_the_fingerprint_cache() {
    // Coalescing disabled: every request is a singleton, so the same graph
    // keys the same fingerprint on every submission.
    let coord = Coordinator::start(CoordinatorConfig {
        executor: ExecutorKind::HostEmulation,
        preprocess_workers: 1,
        queue_capacity: 8,
        exec: ExecPolicy::serial(),
        max_batch_requests: 1,
        cache_capacity: 8,
        ..CoordinatorConfig::default()
    })
    .expect("host-emulation coordinator");
    let g = graph_pool()[1].clone();
    let (q, k, v) = features(g.n, D, 99);
    let mut outputs: Vec<Vec<f32>> = Vec::new();
    for i in 0..10u64 {
        let (tx, rx) = channel();
        coord
            .submit(AttnRequest::single_head(
                i,
                g.clone(),
                D,
                q.clone(),
                k.clone(),
                v.clone(),
                SCALE,
                Backend::Fused3S,
                tx,
            ))
            .expect("submit");
        let resp = rx.recv_timeout(Duration::from_secs(60)).expect("response");
        assert_eq!(resp.id, i);
        assert_eq!(resp.batch_size, 1);
        outputs.push(resp.result.expect("result"));
    }
    // The steady state skips the BSB build: 1 miss, 9 hits — and cached
    // replays are bit-identical.
    let m = coord.metrics();
    assert_eq!(m.batching.cache_misses(), 1);
    assert_eq!(m.batching.cache_hits(), 9);
    assert!(m.batching.cache_hits() > 0, "repeated graphs must hit");
    for o in &outputs[1..] {
        assert_eq!(o, &outputs[0], "cache hits must not change a bit");
    }
    coord.shutdown();
}

#[test]
fn shutdown_drains_the_coalescing_queue() {
    // A huge batch delay parks requests in the coalescer; shutdown must
    // flush and serve them rather than dropping them.
    let coord = Coordinator::start(CoordinatorConfig {
        executor: ExecutorKind::HostEmulation,
        preprocess_workers: 2,
        queue_capacity: 16,
        exec: ExecPolicy { threads: 2, pipeline_depth: 2 },
        max_batch_requests: 64,
        max_batch_nodes: 1 << 20,
        max_batch_delay: Duration::from_secs(30),
        cache_capacity: 8,
        ..CoordinatorConfig::default()
    })
    .expect("host-emulation coordinator");
    let pool = graph_pool();
    let man = manifest();
    let count = 6u64;
    let (tx, rx) = channel();
    for i in 0..count {
        let g = pool[i as usize % pool.len()].clone();
        let (q, k, v) = features(g.n, D, 500 + i);
        coord
            .submit(AttnRequest::single_head(
                i,
                g,
                D,
                q,
                k,
                v,
                SCALE,
                Backend::Fused3S,
                tx.clone(),
            ))
            .expect("submit");
    }
    drop(tx);
    // Immediately shut down: the 30 s deadline never fires, so any served
    // response can only come from the drain path.
    coord.shutdown();
    let mut got = HashMap::new();
    while let Ok(resp) = rx.try_recv() {
        got.insert(resp.id, resp);
    }
    assert_eq!(got.len(), count as usize, "drain must serve every request");
    for i in 0..count {
        let resp = &got[&i];
        let out = resp.result.as_ref().expect("result");
        let g = &pool[i as usize % pool.len()];
        let want = serial_expected(&man, g, D, SCALE, 500 + i);
        assert_eq!(out, &want, "drained request {i} diverged");
    }
}
