//! Adaptive-planner acceptance suite (ISSUE 4): synthetic extremes pick
//! the expected backend, `Backend::Auto` output bit-matches the same
//! request forced to the resolved backend — standalone and through the
//! coordinator — auto traffic coalesces and hits the plan cache under the
//! *resolved* backend key, and the cost-model calibration persists.
//!
//! Everything runs offline (`ExecutorKind::HostEmulation` / `ExecCtx::host`,
//! no artifacts).  `scripts/verify.sh` runs this file explicitly.

use std::sync::mpsc::channel;
use std::time::Duration;

use fused3s::bsb;
use fused3s::coordinator::{
    AttnRequest, Coordinator, CoordinatorConfig, ExecutorKind,
};
use fused3s::exec::{offline_manifest, Engine, ExecPolicy};
use fused3s::graph::generators::{self, clique};
use fused3s::kernels::{AttentionBatch, Backend, ExecCtx, Plan};
use fused3s::planner::{resolve, resolve_offline, CostModel, DEFAULT_BUCKETS};
use fused3s::runtime::Manifest;
use fused3s::util::prng::Rng;

fn manifest() -> Manifest {
    offline_manifest(8, DEFAULT_BUCKETS, 128)
}

fn features(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (
        rng.normal_vec(n * d, 1.0),
        rng.normal_vec(n * d, 1.0),
        rng.normal_vec(n * d, 1.0),
    )
}

fn host_coordinator(cfg_mut: impl FnOnce(&mut CoordinatorConfig)) -> Coordinator {
    let mut cfg = CoordinatorConfig {
        executor: ExecutorKind::HostEmulation,
        preprocess_workers: 2,
        queue_capacity: 16,
        max_batch_requests: 1, // coalescing off unless a test opts in
        max_batch_delay: Duration::from_millis(300),
        cache_capacity: 16,
        // Serial host execution: keeps tiny-graph execute times free of
        // thread-spawn noise, so refinement observations stay sane.
        exec: ExecPolicy::serial(),
        ..CoordinatorConfig::default()
    };
    cfg_mut(&mut cfg);
    Coordinator::start(cfg).expect("host-emulation coordinator")
}

#[test]
fn dense_clique_picks_dense() {
    // Small and saturated: the dense fallback's n² is the same work with
    // none of the sparse-path overhead, exactly the paper's observation
    // about dense baselines on tiny dense inputs.
    let d = resolve(&clique(200));
    assert_eq!(d.backend, Backend::Dense, "scores: {:?}", d.scores);
}

#[test]
fn power_law_hub_picks_fused_chunked() {
    // A mega-hub row overflows every bucket: the unfused baseline is
    // infeasible (its OOM analog) and the fused backend must take the
    // chunked partial-softmax path.
    let g = generators::star(5000).with_self_loops();
    let d = resolve(&g);
    assert_eq!(d.backend, Backend::Fused3S, "scores: {:?}", d.scores);
    assert!(d.chunked, "hub graph must route through chunked dispatch");
    let unfused =
        d.scores.iter().find(|s| s.backend == Backend::UnfusedStable).unwrap();
    assert!(unfused.predicted_s.is_none(), "unfused must be infeasible");
}

#[test]
fn auto_plan_bit_matches_forced_backend() {
    let man = manifest();
    let engine = Engine::new(ExecPolicy { threads: 4, pipeline_depth: 2 });
    let d = 16;
    for (seed, g) in [
        (1u64, generators::erdos_renyi(1500, 6.0, 11).with_self_loops()),
        (2, generators::star(3000).with_self_loops()), // chunked mega-hub
        (3, generators::ring(32)),                     // tiny: cpu regime
    ] {
        let forced_backend = resolve_offline(&g).backend;
        let (q, k, v) = features(g.n, d, 100 + seed);
        let x = AttentionBatch::new(g.n, d, d, 1, &q, &k, &v, 0.25);
        // `Plan::new` resolves Auto itself, over the candidates the
        // manifest can dispatch — this offline manifest has no dense
        // executables, so the resolution must match `resolve_offline` and
        // the plan must always be host-executable.
        let auto_plan = Plan::new(&man, &g, Backend::Auto, &engine).unwrap();
        assert_eq!(
            auto_plan.backend(),
            forced_backend,
            "auto must resolve over the manifest's candidate set"
        );
        let forced_plan = Plan::new(&man, &g, forced_backend, &engine).unwrap();
        let a = auto_plan
            .execute(&mut ExecCtx::host(&engine), &x)
            .expect("auto executes");
        let f = forced_plan
            .execute(&mut ExecCtx::host(&engine), &x)
            .expect("forced executes");
        assert_eq!(a, f, "n={}: auto diverged from forced", g.n);
    }
}

#[test]
fn auto_from_bsb_resolves_over_bsb_candidates() {
    let man = manifest();
    let g = generators::erdos_renyi(800, 5.0, 21).with_self_loops();
    let plan = Plan::from_bsb(&man, bsb::build(&g), Backend::Auto).unwrap();
    assert!(
        matches!(
            plan.backend(),
            Backend::Fused3S | Backend::Hybrid | Backend::UnfusedStable
        ),
        "from_bsb resolves over BSB-plannable backends, got {}",
        plan.backend().name()
    );
    let d = 8;
    let (q, k, v) = features(g.n, d, 31);
    let x = AttentionBatch::new(g.n, d, d, 1, &q, &k, &v, 0.5);
    let engine = Engine::serial();
    let out = plan.execute(&mut ExecCtx::host(&engine), &x).unwrap();
    assert_eq!(out.len(), g.n * d);
}

#[test]
fn coordinator_auto_bit_matches_forced() {
    let g = generators::erdos_renyi(400, 5.0, 41).with_self_loops();
    let expected = resolve_offline(&g).backend;
    let d = 16;
    let (q, k, v) = features(g.n, d, 42);
    let coord = host_coordinator(|_| {});

    let run = |backend: Backend, id: u64| {
        let (tx, rx) = channel();
        coord
            .submit(AttnRequest::single_head(
                id,
                g.clone(),
                d,
                q.clone(),
                k.clone(),
                v.clone(),
                0.25,
                backend,
                tx,
            ))
            .expect("submit");
        rx.recv_timeout(Duration::from_secs(120))
            .expect("response")
            .result
            .expect("result")
    };
    // The first auto request resolves with zero observations, i.e. with
    // the same factory model `resolve_offline` uses locally.
    let auto_out = run(Backend::Auto, 1);
    let forced_out = run(expected, 2);
    assert_eq!(auto_out, forced_out, "auto diverged through the coordinator");

    let m = coord.metrics();
    assert_eq!(m.planner.auto_requests(), 1);
    assert_eq!(m.planner.resolved_counts(), vec![(expected.name(), 1)]);
    assert!(
        m.planner.observations() >= 1,
        "auto batch must refine the cost model"
    );
    // The refinement actually reached the model.
    assert!(coord.planner().snapshot().calibration(expected).samples >= 1);
    // The forced request hit the plan the auto request built: same
    // fingerprint, same *resolved* backend key.
    assert!(m.batching.cache_hits() >= 1, "resolved-key cache hit expected");
    coord.shutdown();
}

#[test]
fn auto_coalesces_with_fixed_traffic_under_resolved_key() {
    // Tiny rings resolve to cpu_csr under factory *and* refined constants
    // (scalar launch cost is negligible at this size), so the decision is
    // stable across the whole test.
    let g = generators::ring(48);
    let expected = resolve_offline(&g).backend;
    assert_eq!(expected, Backend::CpuCsr, "test premise: tiny ⇒ cpu_csr");
    let d = 8;
    let (q, k, v) = features(g.n, d, 51);
    let coord = host_coordinator(|cfg| {
        cfg.max_batch_requests = 2;
        cfg.max_batch_nodes = 1 << 20;
    });

    // One auto + one explicitly-routed request, same (d, dv, heads, scale):
    // after resolution they share a group key, so they must coalesce into
    // one block-diagonal batch.
    let (tx, rx) = channel();
    for (id, backend) in [(1u64, Backend::Auto), (2, expected)] {
        coord
            .submit(AttnRequest::single_head(
                id,
                g.clone(),
                d,
                q.clone(),
                k.clone(),
                v.clone(),
                1.0,
                backend,
                tx.clone(),
            ))
            .expect("submit");
    }
    drop(tx);
    let mut outs = Vec::new();
    while let Ok(resp) = rx.recv_timeout(Duration::from_secs(120)) {
        assert_eq!(resp.batch_size, 2, "auto must coalesce with fixed traffic");
        outs.push(resp.result.expect("result"));
        if outs.len() == 2 {
            break;
        }
    }
    assert_eq!(outs.len(), 2);
    assert_eq!(outs[0], outs[1], "identical components, identical rows");
    assert_eq!(coord.metrics().planner.auto_requests(), 1);

    // Replaying the same burst rebuilds the same merged structure, so the
    // plan comes from the cache under (merged fingerprint, resolved
    // backend) — no new misses.
    let misses_before = coord.metrics().batching.cache_misses();
    let (tx, rx) = channel();
    for (id, backend) in [(3u64, Backend::Auto), (4, expected)] {
        coord
            .submit(AttnRequest::single_head(
                id,
                g.clone(),
                d,
                q.clone(),
                k.clone(),
                v.clone(),
                1.0,
                backend,
                tx.clone(),
            ))
            .expect("submit");
    }
    drop(tx);
    let mut replays = 0;
    while let Ok(resp) = rx.recv_timeout(Duration::from_secs(120)) {
        assert_eq!(resp.batch_size, 2);
        resp.result.expect("result");
        replays += 1;
        if replays == 2 {
            break;
        }
    }
    assert_eq!(
        coord.metrics().batching.cache_misses(),
        misses_before,
        "replayed burst must not rebuild the plan"
    );
    assert!(coord.metrics().batching.cache_hits() >= 1);
    coord.shutdown();
}

#[test]
fn calibration_persists_across_coordinator_restarts() {
    let dir = std::env::temp_dir().join("f3s_planner_calibration_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("calibration.json");
    std::fs::remove_file(&path).ok();

    let g = generators::ring(48);
    let d = 8;
    let (q, k, v) = features(g.n, d, 61);
    let coord = host_coordinator(|cfg| cfg.calibration_path = Some(path.clone()));
    let (tx, rx) = channel();
    coord
        .submit(AttnRequest::single_head(
            1,
            g.clone(),
            d,
            q,
            k,
            v,
            1.0,
            Backend::Auto,
            tx,
        ))
        .expect("submit");
    rx.recv_timeout(Duration::from_secs(120))
        .expect("response")
        .result
        .expect("result");
    let tuned = coord.planner().snapshot();
    coord.shutdown(); // persists the table

    let reloaded = CostModel::load(&path).expect("calibration file written");
    assert_eq!(reloaded, tuned, "shutdown must persist the live table");
    assert!(reloaded.calibration(Backend::CpuCsr).samples >= 1);

    // A fresh coordinator seeds its planner from the persisted table.
    let coord2 = host_coordinator(|cfg| cfg.calibration_path = Some(path.clone()));
    assert_eq!(coord2.planner().snapshot(), reloaded);
    coord2.shutdown();
    std::fs::remove_file(&path).ok();
}
