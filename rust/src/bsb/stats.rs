//! Sparsity characterisation after BSB compaction — the paper's Table 6
//! (TCB/RW and nnz/TCB, average + CV), Table 7 (decile ranges of the
//! TCB/RW distribution), and the per-row-window load view
//! ([`nnz_per_rw`]) the adaptive planner's
//! [`GraphProfile`](crate::planner::GraphProfile) is built from.

use crate::util::stats as ustats;

use super::Bsb;

/// The Table-6 row for one graph.
#[derive(Clone, Debug)]
pub struct CompactionStats {
    pub nodes: usize,
    pub edges: usize,
    pub num_rw: usize,
    pub total_tcbs: usize,
    pub tcb_per_rw_avg: f64,
    pub tcb_per_rw_cv: f64,
    pub nnz_per_tcb_avg: f64,
    pub nnz_per_tcb_cv: f64,
}

/// Compute Table-6 metrics.  Empty row windows are excluded from the TCB/RW
/// distribution (they are never dispatched), matching the paper's
/// post-compaction accounting.
pub fn compaction_stats(bsb: &Bsb) -> CompactionStats {
    let tcb_rw: Vec<f64> = bsb
        .tcbs_per_rw()
        .iter()
        .filter(|&&t| t > 0)
        .map(|&t| t as f64)
        .collect();
    let nnz_tcb: Vec<f64> =
        bsb.nnz_per_tcb().iter().map(|&z| z as f64).collect();
    CompactionStats {
        nodes: bsb.n,
        edges: bsb.nnz,
        num_rw: bsb.num_rw,
        total_tcbs: bsb.total_tcbs(),
        tcb_per_rw_avg: ustats::mean(&tcb_rw),
        tcb_per_rw_cv: ustats::cv(&tcb_rw),
        nnz_per_tcb_avg: ustats::mean(&nnz_tcb),
        nnz_per_tcb_cv: ustats::cv(&nnz_tcb),
    }
}

/// The Table-7 row: (min, max) TCB count in each decile of row windows
/// (sorted ascending by TCB count, like the paper).
pub fn tcb_deciles(bsb: &Bsb) -> Vec<(usize, usize)> {
    let tcb_rw: Vec<f64> = bsb
        .tcbs_per_rw()
        .iter()
        .filter(|&&t| t > 0)
        .map(|&t| t as f64)
        .collect();
    ustats::decile_ranges(&tcb_rw)
        .into_iter()
        .map(|(lo, hi)| (lo as usize, hi as usize))
        .collect()
}

/// Decile group size (the paper's "decile size" column).
pub fn decile_size(bsb: &Bsb) -> usize {
    let nonempty = bsb.tcbs_per_rw().iter().filter(|&&t| t > 0).count();
    nonempty / 10
}

/// Nonzeros per row window (the window *load*, as opposed to its TCB
/// *shape*): one entry per RW, empty windows included as 0.  A planner
/// input — nnz/RW variance separates "many medium rows" from "one hub
/// row" even when the TCB counts agree.
pub fn nnz_per_rw(bsb: &Bsb) -> Vec<u32> {
    let per_tcb = bsb.nnz_per_tcb();
    (0..bsb.num_rw)
        .map(|i| {
            per_tcb[bsb.tro[i] as usize..bsb.tro[i + 1] as usize]
                .iter()
                .sum()
        })
        .collect()
}


#[cfg(test)]
mod tests {
    use crate::bsb::build;
    use crate::graph::generators;

    use super::*;

    #[test]
    fn uniform_graph_low_cv() {
        let g = generators::ring(4096).with_self_loops();
        let bsb = build(&g);
        let s = compaction_stats(&bsb);
        assert!(s.tcb_per_rw_cv < 0.2, "ring CV {}", s.tcb_per_rw_cv);
        assert_eq!(s.edges, g.nnz());
    }

    #[test]
    fn power_law_graph_high_cv() {
        let g = generators::barabasi_albert(4096, 4, 3).with_self_loops();
        let bsb = build(&g);
        let s = compaction_stats(&bsb);
        let ring = build(&generators::ring(4096).with_self_loops());
        assert!(
            s.tcb_per_rw_cv > 2.0 * compaction_stats(&ring).tcb_per_rw_cv,
            "BA CV {}",
            s.tcb_per_rw_cv
        );
    }

    #[test]
    fn deciles_are_monotone() {
        let g = generators::barabasi_albert(8192, 5, 4);
        let bsb = build(&g);
        let d = tcb_deciles(&bsb);
        assert_eq!(d.len(), 10);
        for w in d.windows(2) {
            assert!(w[0].1 <= w[1].1 + 1, "deciles roughly increasing");
            assert!(w[0].0 <= w[1].0);
        }
        // long tail: last decile max far above first decile max
        assert!(d[9].1 > 2 * d[0].1);
    }

    #[test]
    fn nnz_per_rw_sums_to_graph_nnz() {
        let g = generators::barabasi_albert(2048, 4, 9).with_self_loops();
        let bsb = build(&g);
        let per_rw = nnz_per_rw(&bsb);
        assert_eq!(per_rw.len(), bsb.num_rw);
        assert_eq!(per_rw.iter().map(|&z| z as usize).sum::<usize>(), g.nnz());
    }

    #[test]
    fn nnz_per_tcb_bounded() {
        let g = generators::erdos_renyi(2048, 6.0, 5);
        let bsb = build(&g);
        let s = compaction_stats(&bsb);
        assert!(s.nnz_per_tcb_avg > 0.0);
        assert!(s.nnz_per_tcb_avg <= 128.0); // 16*8 block capacity
    }
}
