//! Sparsity characterisation after BSB compaction — the paper's Table 6
//! (TCB/RW and nnz/TCB, average + CV), Table 7 (decile ranges of the
//! TCB/RW distribution), the per-row-window load view ([`nnz_per_rw`])
//! the adaptive planner's [`GraphProfile`](crate::planner::GraphProfile)
//! is built from, and the sharding layer's halo-replication estimator
//! ([`halo_fraction`]).

use crate::graph::CsrGraph;
use crate::util::stats as ustats;

use super::Bsb;

/// The Table-6 row for one graph.
#[derive(Clone, Debug)]
pub struct CompactionStats {
    pub nodes: usize,
    pub edges: usize,
    pub num_rw: usize,
    pub total_tcbs: usize,
    pub tcb_per_rw_avg: f64,
    pub tcb_per_rw_cv: f64,
    pub nnz_per_tcb_avg: f64,
    pub nnz_per_tcb_cv: f64,
}

/// Compute Table-6 metrics.  Empty row windows are excluded from the TCB/RW
/// distribution (they are never dispatched), matching the paper's
/// post-compaction accounting.
pub fn compaction_stats(bsb: &Bsb) -> CompactionStats {
    let tcb_rw: Vec<f64> = bsb
        .tcbs_per_rw()
        .iter()
        .filter(|&&t| t > 0)
        .map(|&t| t as f64)
        .collect();
    let nnz_tcb: Vec<f64> =
        bsb.nnz_per_tcb().iter().map(|&z| z as f64).collect();
    CompactionStats {
        nodes: bsb.n,
        edges: bsb.nnz,
        num_rw: bsb.num_rw,
        total_tcbs: bsb.total_tcbs(),
        tcb_per_rw_avg: ustats::mean(&tcb_rw),
        tcb_per_rw_cv: ustats::cv(&tcb_rw),
        nnz_per_tcb_avg: ustats::mean(&nnz_tcb),
        nnz_per_tcb_cv: ustats::cv(&nnz_tcb),
    }
}

/// The Table-7 row: (min, max) TCB count in each decile of row windows
/// (sorted ascending by TCB count, like the paper).
pub fn tcb_deciles(bsb: &Bsb) -> Vec<(usize, usize)> {
    let tcb_rw: Vec<f64> = bsb
        .tcbs_per_rw()
        .iter()
        .filter(|&&t| t > 0)
        .map(|&t| t as f64)
        .collect();
    ustats::decile_ranges(&tcb_rw)
        .into_iter()
        .map(|(lo, hi)| (lo as usize, hi as usize))
        .collect()
}

/// Decile group size (the paper's "decile size" column).
pub fn decile_size(bsb: &Bsb) -> usize {
    let nonempty = bsb.tcbs_per_rw().iter().filter(|&&t| t > 0).count();
    nonempty / 10
}

/// Nonzeros per row window (the window *load*, as opposed to its TCB
/// *shape*): one entry per RW, empty windows included as 0.  A planner
/// input — nnz/RW variance separates "many medium rows" from "one hub
/// row" even when the TCB counts agree.
pub fn nnz_per_rw(bsb: &Bsb) -> Vec<u32> {
    let per_tcb = bsb.nnz_per_tcb();
    (0..bsb.num_rw)
        .map(|i| {
            per_tcb[bsb.tro[i] as usize..bsb.tro[i + 1] as usize]
                .iter()
                .sum()
        })
        .collect()
}

/// Halo replication cost of a row partition: replicated K/V rows ÷ n.
///
/// `shards` are contiguous global **row** (node) ranges (what
/// [`Partition::row_ranges`](crate::shard::Partition::row_ranges)
/// produces).  For each shard this counts the *distinct* source rows its
/// rows reference outside the shard's own range — exactly the K/V rows the
/// sharded executor gathers (`rust/tests/shard_equivalence.rs` pins the
/// two against each other) — and normalises by the node count, so 0 means
/// a perfectly separable partition and S−1 is the worst case (every shard
/// replicates everything).  The planner's sharded cost candidate and the
/// shard bench both consume this estimate; it needs no BSB build.
pub fn halo_fraction(g: &CsrGraph, shards: &[std::ops::Range<usize>]) -> f64 {
    if g.n == 0 {
        return 0.0;
    }
    // Epoch-stamped membership: O(n + nnz) over all shards, no per-shard
    // hash set.  Stamp value = shard index + 1 (0 = never seen).
    let mut stamp = vec![0u32; g.n];
    let mut replicated = 0usize;
    for (si, r) in shards.iter().enumerate() {
        let mark = si as u32 + 1;
        for u in r.clone() {
            for &v in g.row(u) {
                let v = v as usize;
                let outside = v < r.start || v >= r.end;
                if outside && stamp[v] != mark {
                    stamp[v] = mark;
                    replicated += 1;
                }
            }
        }
    }
    replicated as f64 / g.n as f64
}


#[cfg(test)]
mod tests {
    use crate::bsb::build;
    use crate::graph::generators;

    use super::*;

    #[test]
    fn uniform_graph_low_cv() {
        let g = generators::ring(4096).with_self_loops();
        let bsb = build(&g);
        let s = compaction_stats(&bsb);
        assert!(s.tcb_per_rw_cv < 0.2, "ring CV {}", s.tcb_per_rw_cv);
        assert_eq!(s.edges, g.nnz());
    }

    #[test]
    fn power_law_graph_high_cv() {
        let g = generators::barabasi_albert(4096, 4, 3).with_self_loops();
        let bsb = build(&g);
        let s = compaction_stats(&bsb);
        let ring = build(&generators::ring(4096).with_self_loops());
        assert!(
            s.tcb_per_rw_cv > 2.0 * compaction_stats(&ring).tcb_per_rw_cv,
            "BA CV {}",
            s.tcb_per_rw_cv
        );
    }

    #[test]
    fn deciles_are_monotone() {
        let g = generators::barabasi_albert(8192, 5, 4);
        let bsb = build(&g);
        let d = tcb_deciles(&bsb);
        assert_eq!(d.len(), 10);
        for w in d.windows(2) {
            assert!(w[0].1 <= w[1].1 + 1, "deciles roughly increasing");
            assert!(w[0].0 <= w[1].0);
        }
        // long tail: last decile max far above first decile max
        assert!(d[9].1 > 2 * d[0].1);
    }

    #[test]
    fn nnz_per_rw_sums_to_graph_nnz() {
        let g = generators::barabasi_albert(2048, 4, 9).with_self_loops();
        let bsb = build(&g);
        let per_rw = nnz_per_rw(&bsb);
        assert_eq!(per_rw.len(), bsb.num_rw);
        assert_eq!(per_rw.iter().map(|&z| z as usize).sum::<usize>(), g.nnz());
    }

    #[test]
    fn halo_fraction_extremes() {
        // One shard: no halo at all.
        let g = generators::erdos_renyi(512, 6.0, 3).with_self_loops();
        assert_eq!(halo_fraction(&g, &[0..512]), 0.0);
        // A ring cut into two arcs: each arc references exactly its two
        // boundary neighbours in the other arc -> 4 replicated rows.
        let ring = generators::ring(512);
        let f = halo_fraction(&ring, &[0..256, 256..512]);
        assert!((f - 4.0 / 512.0).abs() < 1e-12, "{f}");
        // Star: every shard not containing the hub replicates it, and the
        // hub's shard replicates every leaf outside it.
        let star = generators::star(512).with_self_loops();
        let f = halo_fraction(&star, &[0..256, 256..512]);
        // Shard 0 (hub): leaves 256..512 -> 256 rows; shard 1: hub -> 1.
        assert!((f - 257.0 / 512.0).abs() < 1e-12, "{f}");
    }

    /// Regression: an empty graph must yield 0.0, not 0/0 = NaN — a NaN
    /// here silently poisons every planner cost comparison it reaches
    /// (NaN never compares less-than, so the sharded candidate would win
    /// or lose arbitrarily).
    #[test]
    fn halo_fraction_empty_graph_is_zero_not_nan() {
        let g = crate::graph::CsrGraph::from_edges(0, &[]).unwrap();
        let f = halo_fraction(&g, &[]);
        assert_eq!(f, 0.0);
        assert!(!f.is_nan());
        // Degenerate shard lists on an empty graph are equally safe.
        let f = halo_fraction(&g, &[0..0]);
        assert_eq!(f, 0.0);
    }

    #[test]
    fn halo_fraction_grows_with_shards() {
        let g = generators::erdos_renyi(2048, 8.0, 9).with_self_loops();
        let cut = |s: usize| {
            let per = g.n / s;
            let ranges: Vec<std::ops::Range<usize>> = (0..s)
                .map(|i| i * per..if i == s - 1 { g.n } else { (i + 1) * per })
                .collect();
            halo_fraction(&g, &ranges)
        };
        assert!(cut(2) < cut(4));
        assert!(cut(4) < cut(8));
    }

    #[test]
    fn nnz_per_tcb_bounded() {
        let g = generators::erdos_renyi(2048, 6.0, 5);
        let bsb = build(&g);
        let s = compaction_stats(&bsb);
        assert!(s.nnz_per_tcb_avg > 0.0);
        assert!(s.nnz_per_tcb_avg <= 128.0); // 16*8 block capacity
    }
}
