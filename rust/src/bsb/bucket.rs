//! TCB-count bucketing: mapping variable-size row windows onto the fixed
//! shapes of the AOT executable suite (DESIGN.md §1).
//!
//! Each compiled fused3s executable is specialised to a TCB capacity `t` and
//! processes `batch` row windows per dispatch.  The planner:
//!
//! * skips empty row windows (their output rows are zero by convention);
//! * routes each RW to the smallest bucket with capacity ≥ its TCB count,
//!   padding the remainder with all-zero bitmaps (numerically exact);
//! * RWs larger than the biggest bucket are *chunked*: split into ≤`chunk_t`
//!   pieces whose partial softmax states (m, l) are merged on the host —
//!   the online-softmax generalisation of the paper's "multiple thread
//!   blocks per row window" future-work item.  This is how the reproduction
//!   handles the Reddit-style mega-hubs that overflow any static bucket.
//!
//! The walk order of row windows follows the reordering schedule (§3.2), so
//! heavyweight windows are dispatched first.

use super::reorder::{self, Order};
use super::Bsb;

/// One dispatch of a bucket executable: `rws.len() <= batch` row windows,
/// each padded to `t_bucket` TCBs.
#[derive(Clone, Debug, PartialEq)]
pub struct Call {
    pub t_bucket: usize,
    pub rws: Vec<u32>,
}

/// An oversize row window processed in `n_chunks` pieces of `chunk_t`.
#[derive(Clone, Debug, PartialEq)]
pub struct ChunkedRw {
    pub rw: u32,
    pub n_chunks: usize,
}

/// Cells (scalar score-matrix entries) per wide 16×8 TCB slot.
pub const WIDE_TCB_CELLS: usize = crate::TCB_R * crate::TCB_C;
/// Cells per narrow 8×1 tile (one column lane of a half-height window).
pub const NARROW_TILE_CELLS: usize = crate::TCB_R / 2;
/// Cells per dense 16×1 column lane (full-height window, one column).
pub const DENSE_LANE_CELLS: usize = crate::TCB_R;

/// Padding/coverage accounting for the plan, denominated in *cells* so the
/// three dispatch geometries (wide 16×8 TCBs, narrow 8×1 tiles, dense 16×1
/// lanes) are comparable.  Every dispatched unit is either real (covers at
/// least one structural nonzero octet/lane), structural padding (bucket or
/// chunk round-up inside a row window), or batch-slot padding (empty slots
/// in a final partial batch, dispatched because executables have static
/// shapes).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PlanStats {
    /// TCBs actually present in dispatched row windows.
    pub real_tcbs: usize,
    /// Zero-bitmap TCB slots added by bucket + chunk padding.
    pub padded_tcbs: usize,
    /// Empty batch slots in final partial batches (wide + chunked calls).
    pub padded_slots: usize,
    /// TCB-denominated cost of `padded_slots`: each empty slot in a flushed
    /// partial batch still dispatches `t_bucket` (or `chunk_t`) zero TCBs.
    pub padded_slot_tcbs: usize,
    pub n_calls: usize,
    pub n_chunked_rws: usize,
    pub n_skipped_rws: usize,
    /// Half-height (8-row) windows routed to the narrow geometry.
    pub narrow_windows: usize,
    /// Narrow 8×1 tiles carrying at least one structural nonzero.
    pub real_narrow_tiles: usize,
    /// Zero narrow tiles from rounding a window up to its tile bucket.
    pub padded_narrow_tiles: usize,
    /// Zero narrow tiles from empty batch slots in partial narrow calls.
    pub padded_narrow_slot_tiles: usize,
    pub n_narrow_calls: usize,
    /// Full-height windows routed to the dense (per-column-lane) path.
    pub dense_windows: usize,
    /// Real 16×1 column lanes dispatched for dense windows.
    pub dense_cols: usize,
    /// Zero lanes from rounding a dense window's width up to a multiple of 8.
    pub padded_dense_cols: usize,
    /// Zero lanes from empty batch slots in partial dense calls.
    pub padded_dense_slot_cols: usize,
    pub n_dense_calls: usize,
}

impl PlanStats {
    /// Total cells dispatched to executables, including every kind of
    /// padding.  This is the quantity the cost model's per-cell term prices.
    pub fn dispatched_cells(&self) -> usize {
        (self.real_tcbs + self.padded_tcbs + self.padded_slot_tcbs) * WIDE_TCB_CELLS
            + (self.real_narrow_tiles
                + self.padded_narrow_tiles
                + self.padded_narrow_slot_tiles)
                * NARROW_TILE_CELLS
            + (self.dense_cols + self.padded_dense_cols + self.padded_dense_slot_cols)
                * DENSE_LANE_CELLS
    }

    /// Cells dispatched with all-zero content: structural round-up padding
    /// *plus* batch-slot padding (empty slots in final partial batches cost
    /// exactly as much as occupied ones on static-shape executables).
    pub fn padded_cells(&self) -> usize {
        (self.padded_tcbs + self.padded_slot_tcbs) * WIDE_TCB_CELLS
            + (self.padded_narrow_tiles + self.padded_narrow_slot_tiles) * NARROW_TILE_CELLS
            + (self.padded_dense_cols + self.padded_dense_slot_cols) * DENSE_LANE_CELLS
    }

    /// Dispatched cells excluding batch-slot padding.  Batch-free, so a
    /// CSR-side estimate (`GraphProfile`) can pin it exactly without knowing
    /// the dispatch batch size.
    pub fn structural_cells(&self) -> usize {
        (self.real_tcbs + self.padded_tcbs) * WIDE_TCB_CELLS
            + (self.real_narrow_tiles + self.padded_narrow_tiles) * NARROW_TILE_CELLS
            + (self.dense_cols + self.padded_dense_cols) * DENSE_LANE_CELLS
    }

    /// Fraction of dispatched cells that are padding (lower is better; the
    /// bucket-granularity ablation sweeps this).  Includes batch-slot
    /// padding: a flushed partial batch dispatches its empty slots too.
    pub fn padding_ratio(&self) -> f64 {
        let total = self.dispatched_cells();
        if total == 0 {
            0.0
        } else {
            self.padded_cells() as f64 / total as f64
        }
    }
}

/// The full dispatch plan for one BSB matrix.
#[derive(Clone, Debug)]
pub struct Plan {
    pub batch: usize,
    pub chunk_t: usize,
    pub calls: Vec<Call>,
    pub chunked: Vec<ChunkedRw>,
    pub skipped: Vec<u32>,
    pub stats: PlanStats,
}

/// Build the dispatch plan.
///
/// * `buckets` — available TCB capacities, ascending (from the manifest).
/// * `batch` — row windows per dispatch (the manifest's `rw_batch`).
/// * `order` — row-window schedule policy.
/// * `chunk_t` — chunk capacity for oversize RWs (a bucket size with a
///   "partial" executable available; usually the largest bucket).
pub fn plan(
    bsb: &Bsb,
    buckets: &[usize],
    batch: usize,
    order: Order,
    chunk_t: usize,
) -> Plan {
    plan_filtered(bsb, buckets, batch, order, chunk_t, |_| true)
}

/// [`plan`] restricted to the row windows accepted by `keep`; rejected RWs
/// are excluded from the plan entirely (they belong to another geometry's
/// plan — the hybrid dispatcher is responsible for overall coverage).
pub fn plan_filtered(
    bsb: &Bsb,
    buckets: &[usize],
    batch: usize,
    order: Order,
    chunk_t: usize,
    keep: impl Fn(u32) -> bool,
) -> Plan {
    assert!(!buckets.is_empty());
    assert!(buckets.windows(2).all(|w| w[0] < w[1]), "buckets ascending");
    let max_bucket = *buckets.last().unwrap();
    let sched = reorder::schedule(bsb, order);

    let mut stats = PlanStats::default();
    let mut skipped = Vec::new();
    let mut chunked = Vec::new();
    // Open batch per bucket, flushed when full.
    let mut open: Vec<Vec<u32>> = vec![Vec::new(); buckets.len()];
    let mut calls: Vec<Call> = Vec::new();

    for &rw in &sched {
        if !keep(rw) {
            continue;
        }
        let t = bsb.rw_tcbs(rw as usize);
        if t == 0 {
            skipped.push(rw);
            continue;
        }
        if t > max_bucket {
            let n_chunks = t.div_ceil(chunk_t);
            stats.real_tcbs += t;
            stats.padded_tcbs += n_chunks * chunk_t - t;
            chunked.push(ChunkedRw { rw, n_chunks });
            continue;
        }
        let bi = buckets.iter().position(|&b| b >= t).unwrap();
        stats.real_tcbs += t;
        stats.padded_tcbs += buckets[bi] - t;
        open[bi].push(rw);
        if open[bi].len() == batch {
            calls.push(Call {
                t_bucket: buckets[bi],
                rws: std::mem::take(&mut open[bi]),
            });
        }
    }
    for (bi, rws) in open.into_iter().enumerate() {
        if !rws.is_empty() {
            stats.padded_slots += batch - rws.len();
            stats.padded_slot_tcbs += (batch - rws.len()) * buckets[bi];
            calls.push(Call { t_bucket: buckets[bi], rws });
        }
    }
    // Chunked RWs dispatch their chunks through the `chunk_t` partial
    // executable in batches of `batch`; the final partial chunk batch pads
    // with empty slots exactly like a flushed bucket batch does.
    let total_chunks: usize = chunked.iter().map(|c| c.n_chunks).sum();
    let chunk_rem = total_chunks % batch;
    if chunk_rem != 0 {
        stats.padded_slots += batch - chunk_rem;
        stats.padded_slot_tcbs += (batch - chunk_rem) * chunk_t;
    }
    stats.n_calls = calls.len();
    stats.n_chunked_rws = chunked.len();
    stats.n_skipped_rws = skipped.len();
    Plan { batch, chunk_t, calls, chunked, skipped, stats }
}

/// Every row window must appear exactly once across calls/chunked/skipped.
pub fn covers_all_rws(plan: &Plan, num_rw: usize) -> bool {
    let mut seen = vec![false; num_rw];
    let mut mark = |i: u32| {
        let i = i as usize;
        if i >= num_rw || seen[i] {
            return false;
        }
        seen[i] = true;
        true
    };
    for c in &plan.calls {
        for &rw in &c.rws {
            if !mark(rw) {
                return false;
            }
        }
    }
    for c in &plan.chunked {
        if !mark(c.rw) {
            return false;
        }
    }
    for &rw in &plan.skipped {
        if !mark(rw) {
            return false;
        }
    }
    seen.iter().all(|&b| b)
}

#[cfg(test)]
mod tests {
    use crate::bsb::build;
    use crate::graph::generators;

    use super::*;

    const BUCKETS: &[usize] = &[4, 8, 16, 32, 64, 128];

    #[test]
    fn plan_covers_everything() {
        for (n, deg, seed) in [(500, 3.0, 1u64), (2048, 12.0, 2), (100, 0.5, 3)] {
            let g = generators::erdos_renyi(n, deg, seed);
            let bsb = build(&g);
            let p = plan(&bsb, BUCKETS, 8, Order::ByTcbDesc, 128);
            assert!(covers_all_rws(&p, bsb.num_rw), "n={n} deg={deg}");
        }
    }

    #[test]
    fn batches_respect_capacity() {
        let g = generators::erdos_renyi(4096, 8.0, 4);
        let bsb = build(&g);
        let p = plan(&bsb, BUCKETS, 16, Order::Natural, 128);
        for c in &p.calls {
            assert!(!c.rws.is_empty() && c.rws.len() <= 16);
            assert!(BUCKETS.contains(&c.t_bucket));
            for &rw in &c.rws {
                assert!(bsb.rw_tcbs(rw as usize) <= c.t_bucket);
            }
        }
    }

    #[test]
    fn oversize_rws_are_chunked() {
        // A star graph: hub row attends to all 5000 nodes -> RW 0 has
        // ceil(5000/8) = 625 TCBs > 128.
        let g = generators::star(5000);
        let bsb = build(&g);
        let p = plan(&bsb, BUCKETS, 8, Order::ByTcbDesc, 128);
        assert_eq!(p.chunked.len(), 1);
        let c = &p.chunked[0];
        assert_eq!(c.rw, 0);
        assert_eq!(c.n_chunks, bsb.rw_tcbs(0).div_ceil(128));
        assert!(covers_all_rws(&p, bsb.num_rw));
    }

    #[test]
    fn empty_windows_skipped() {
        let g = crate::graph::CsrGraph::from_edges(64, &[(40, 1)]).unwrap();
        let bsb = build(&g);
        let p = plan(&bsb, BUCKETS, 4, Order::Natural, 128);
        assert_eq!(p.skipped.len(), 3);
        assert_eq!(p.calls.len(), 1);
        assert!(covers_all_rws(&p, bsb.num_rw));
    }

    #[test]
    fn reordering_front_loads_heavy_windows() {
        let g = generators::barabasi_albert(4096, 6, 5);
        let bsb = build(&g);
        let p = plan(&bsb, BUCKETS, 8, Order::ByTcbDesc, 128);
        // Among *full* batches, buckets are non-increasing (partial leftover
        // batches are flushed at the end regardless of size).
        let full: Vec<usize> = p
            .calls
            .iter()
            .filter(|c| c.rws.len() == 8)
            .map(|c| c.t_bucket)
            .collect();
        assert!(full.len() > 1);
        assert!(
            full.windows(2).all(|w| w[0] >= w[1]),
            "full batches not front-loaded: {full:?}"
        );
    }

    #[test]
    fn finer_buckets_reduce_padding() {
        let g = generators::erdos_renyi(4096, 10.0, 6);
        let bsb = build(&g);
        let coarse = plan(&bsb, &[128], 8, Order::Natural, 128);
        let fine = plan(&bsb, BUCKETS, 8, Order::Natural, 128);
        assert!(
            fine.stats.padding_ratio() < coarse.stats.padding_ratio(),
            "fine {} vs coarse {}",
            fine.stats.padding_ratio(),
            coarse.stats.padding_ratio()
        );
    }

    /// Satellite fix pin: a hand-built plan whose every stat is known in
    /// closed form.  buckets=[4], batch=4, chunk_t=4, Order::Natural over a
    /// 5-RW graph:
    ///
    /// * RW0: row 0 → cols 0..40 → 5 TCBs > 4 ⇒ chunked (2 chunks, 3 pad)
    /// * RW1: row 16 → 1 col → 1 TCB (3 pad), RW2: row 32 → 2 cols → 1 TCB
    /// * RW3: empty ⇒ skipped, RW4: row 64 → 1 col → 1 TCB
    ///
    /// Bucket flush [RW1,RW2,RW4] leaves 1 empty slot × 4 TCBs; the chunk
    /// stream (2 chunks) leaves 2 empty slots × chunk_t=4 TCBs — the two
    /// contributions the pre-fix accounting dropped.
    #[test]
    fn hand_built_plan_pins_slot_padding() {
        let mut edges: Vec<(u32, u32)> = (0..40).map(|c| (0, c)).collect();
        edges.extend([(16, 1), (32, 2), (32, 9), (64, 3)]);
        let g = crate::graph::CsrGraph::from_edges(80, &edges).unwrap();
        let bsb = build(&g);
        assert_eq!(bsb.num_rw, 5);
        assert_eq!(bsb.rw_tcbs(0), 5);
        let p = plan(&bsb, &[4], 4, Order::Natural, 4);

        assert_eq!(p.chunked, vec![ChunkedRw { rw: 0, n_chunks: 2 }]);
        assert_eq!(p.skipped, vec![3]);
        assert_eq!(p.calls.len(), 1);
        assert_eq!(p.calls[0].rws, vec![1, 2, 4]);

        assert_eq!(p.stats.real_tcbs, 8); // 5 + 1 + 1 + 1
        assert_eq!(p.stats.padded_tcbs, 12); // 3 (chunk) + 3×3 (bucket)
        // 1 empty bucket slot + 2 empty chunk-batch slots.
        assert_eq!(p.stats.padded_slots, 3);
        // ... costed in TCBs: 1×4 (bucket) + 2×4 (chunk_t).
        assert_eq!(p.stats.padded_slot_tcbs, 12);
        let cells = |t: usize| t * WIDE_TCB_CELLS;
        assert_eq!(p.stats.dispatched_cells(), cells(8 + 12 + 12));
        assert_eq!(p.stats.padded_cells(), cells(12 + 12));
        assert_eq!(p.stats.structural_cells(), cells(8 + 12));
        assert!((p.stats.padding_ratio() - 24.0 / 32.0).abs() < 1e-12);
    }

    #[test]
    fn filtered_plan_keeps_only_requested_rws() {
        let g = generators::erdos_renyi(1024, 5.0, 11);
        let bsb = build(&g);
        let keep = |rw: u32| rw % 2 == 0;
        let p = plan_filtered(&bsb, BUCKETS, 8, Order::ByTcbDesc, 128, keep);
        for c in &p.calls {
            assert!(c.rws.iter().all(|&rw| keep(rw)));
        }
        assert!(p.chunked.iter().all(|c| keep(c.rw)));
        assert!(p.skipped.iter().all(|&rw| keep(rw)));
        let full = plan(&bsb, BUCKETS, 8, Order::ByTcbDesc, 128);
        let covered: usize =
            p.calls.iter().map(|c| c.rws.len()).sum::<usize>() + p.chunked.len() + p.skipped.len();
        let full_covered: usize = full.calls.iter().map(|c| c.rws.len()).sum::<usize>()
            + full.chunked.len()
            + full.skipped.len();
        assert_eq!(full_covered, bsb.num_rw);
        assert_eq!(covered, (0..bsb.num_rw as u32).filter(|&rw| keep(rw)).count());
    }

    #[test]
    fn stats_account_tcbs() {
        let g = generators::erdos_renyi(1024, 5.0, 7);
        let bsb = build(&g);
        let p = plan(&bsb, BUCKETS, 8, Order::Natural, 128);
        let dispatched: usize = p
            .calls
            .iter()
            .flat_map(|c| c.rws.iter().map(|&rw| bsb.rw_tcbs(rw as usize)))
            .sum();
        let chunked: usize = p
            .chunked
            .iter()
            .map(|c| bsb.rw_tcbs(c.rw as usize))
            .sum();
        assert_eq!(p.stats.real_tcbs, dispatched + chunked);
    }
}
