//! TCB-count bucketing: mapping variable-size row windows onto the fixed
//! shapes of the AOT executable suite (DESIGN.md §1).
//!
//! Each compiled fused3s executable is specialised to a TCB capacity `t` and
//! processes `batch` row windows per dispatch.  The planner:
//!
//! * skips empty row windows (their output rows are zero by convention);
//! * routes each RW to the smallest bucket with capacity ≥ its TCB count,
//!   padding the remainder with all-zero bitmaps (numerically exact);
//! * RWs larger than the biggest bucket are *chunked*: split into ≤`chunk_t`
//!   pieces whose partial softmax states (m, l) are merged on the host —
//!   the online-softmax generalisation of the paper's "multiple thread
//!   blocks per row window" future-work item.  This is how the reproduction
//!   handles the Reddit-style mega-hubs that overflow any static bucket.
//!
//! The walk order of row windows follows the reordering schedule (§3.2), so
//! heavyweight windows are dispatched first.

use super::reorder::{self, Order};
use super::Bsb;

/// One dispatch of a bucket executable: `rws.len() <= batch` row windows,
/// each padded to `t_bucket` TCBs.
#[derive(Clone, Debug, PartialEq)]
pub struct Call {
    pub t_bucket: usize,
    pub rws: Vec<u32>,
}

/// An oversize row window processed in `n_chunks` pieces of `chunk_t`.
#[derive(Clone, Debug, PartialEq)]
pub struct ChunkedRw {
    pub rw: u32,
    pub n_chunks: usize,
}

/// Padding/coverage accounting for the plan.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PlanStats {
    /// TCBs actually present in dispatched row windows.
    pub real_tcbs: usize,
    /// Zero-bitmap TCB slots added by bucket + chunk padding.
    pub padded_tcbs: usize,
    /// Empty batch slots in final partial batches.
    pub padded_slots: usize,
    pub n_calls: usize,
    pub n_chunked_rws: usize,
    pub n_skipped_rws: usize,
}

impl PlanStats {
    /// Fraction of dispatched TCB slots that are padding (lower is better;
    /// the bucket-granularity ablation sweeps this).
    pub fn padding_ratio(&self) -> f64 {
        let total = self.real_tcbs + self.padded_tcbs;
        if total == 0 {
            0.0
        } else {
            self.padded_tcbs as f64 / total as f64
        }
    }
}

/// The full dispatch plan for one BSB matrix.
#[derive(Clone, Debug)]
pub struct Plan {
    pub batch: usize,
    pub chunk_t: usize,
    pub calls: Vec<Call>,
    pub chunked: Vec<ChunkedRw>,
    pub skipped: Vec<u32>,
    pub stats: PlanStats,
}

/// Build the dispatch plan.
///
/// * `buckets` — available TCB capacities, ascending (from the manifest).
/// * `batch` — row windows per dispatch (the manifest's `rw_batch`).
/// * `order` — row-window schedule policy.
/// * `chunk_t` — chunk capacity for oversize RWs (a bucket size with a
///   "partial" executable available; usually the largest bucket).
pub fn plan(
    bsb: &Bsb,
    buckets: &[usize],
    batch: usize,
    order: Order,
    chunk_t: usize,
) -> Plan {
    assert!(!buckets.is_empty());
    assert!(buckets.windows(2).all(|w| w[0] < w[1]), "buckets ascending");
    let max_bucket = *buckets.last().unwrap();
    let sched = reorder::schedule(bsb, order);

    let mut stats = PlanStats::default();
    let mut skipped = Vec::new();
    let mut chunked = Vec::new();
    // Open batch per bucket, flushed when full.
    let mut open: Vec<Vec<u32>> = vec![Vec::new(); buckets.len()];
    let mut calls: Vec<Call> = Vec::new();

    for &rw in &sched {
        let t = bsb.rw_tcbs(rw as usize);
        if t == 0 {
            skipped.push(rw);
            continue;
        }
        if t > max_bucket {
            let n_chunks = t.div_ceil(chunk_t);
            stats.real_tcbs += t;
            stats.padded_tcbs += n_chunks * chunk_t - t;
            chunked.push(ChunkedRw { rw, n_chunks });
            continue;
        }
        let bi = buckets.iter().position(|&b| b >= t).unwrap();
        stats.real_tcbs += t;
        stats.padded_tcbs += buckets[bi] - t;
        open[bi].push(rw);
        if open[bi].len() == batch {
            calls.push(Call {
                t_bucket: buckets[bi],
                rws: std::mem::take(&mut open[bi]),
            });
        }
    }
    for (bi, rws) in open.into_iter().enumerate() {
        if !rws.is_empty() {
            stats.padded_slots += batch - rws.len();
            calls.push(Call { t_bucket: buckets[bi], rws });
        }
    }
    stats.n_calls = calls.len();
    stats.n_chunked_rws = chunked.len();
    stats.n_skipped_rws = skipped.len();
    Plan { batch, chunk_t, calls, chunked, skipped, stats }
}

/// Every row window must appear exactly once across calls/chunked/skipped.
pub fn covers_all_rws(plan: &Plan, num_rw: usize) -> bool {
    let mut seen = vec![false; num_rw];
    let mut mark = |i: u32| {
        let i = i as usize;
        if i >= num_rw || seen[i] {
            return false;
        }
        seen[i] = true;
        true
    };
    for c in &plan.calls {
        for &rw in &c.rws {
            if !mark(rw) {
                return false;
            }
        }
    }
    for c in &plan.chunked {
        if !mark(c.rw) {
            return false;
        }
    }
    for &rw in &plan.skipped {
        if !mark(rw) {
            return false;
        }
    }
    seen.iter().all(|&b| b)
}

#[cfg(test)]
mod tests {
    use crate::bsb::build;
    use crate::graph::generators;

    use super::*;

    const BUCKETS: &[usize] = &[4, 8, 16, 32, 64, 128];

    #[test]
    fn plan_covers_everything() {
        for (n, deg, seed) in [(500, 3.0, 1u64), (2048, 12.0, 2), (100, 0.5, 3)] {
            let g = generators::erdos_renyi(n, deg, seed);
            let bsb = build(&g);
            let p = plan(&bsb, BUCKETS, 8, Order::ByTcbDesc, 128);
            assert!(covers_all_rws(&p, bsb.num_rw), "n={n} deg={deg}");
        }
    }

    #[test]
    fn batches_respect_capacity() {
        let g = generators::erdos_renyi(4096, 8.0, 4);
        let bsb = build(&g);
        let p = plan(&bsb, BUCKETS, 16, Order::Natural, 128);
        for c in &p.calls {
            assert!(!c.rws.is_empty() && c.rws.len() <= 16);
            assert!(BUCKETS.contains(&c.t_bucket));
            for &rw in &c.rws {
                assert!(bsb.rw_tcbs(rw as usize) <= c.t_bucket);
            }
        }
    }

    #[test]
    fn oversize_rws_are_chunked() {
        // A star graph: hub row attends to all 5000 nodes -> RW 0 has
        // ceil(5000/8) = 625 TCBs > 128.
        let g = generators::star(5000);
        let bsb = build(&g);
        let p = plan(&bsb, BUCKETS, 8, Order::ByTcbDesc, 128);
        assert_eq!(p.chunked.len(), 1);
        let c = &p.chunked[0];
        assert_eq!(c.rw, 0);
        assert_eq!(c.n_chunks, bsb.rw_tcbs(0).div_ceil(128));
        assert!(covers_all_rws(&p, bsb.num_rw));
    }

    #[test]
    fn empty_windows_skipped() {
        let g = crate::graph::CsrGraph::from_edges(64, &[(40, 1)]).unwrap();
        let bsb = build(&g);
        let p = plan(&bsb, BUCKETS, 4, Order::Natural, 128);
        assert_eq!(p.skipped.len(), 3);
        assert_eq!(p.calls.len(), 1);
        assert!(covers_all_rws(&p, bsb.num_rw));
    }

    #[test]
    fn reordering_front_loads_heavy_windows() {
        let g = generators::barabasi_albert(4096, 6, 5);
        let bsb = build(&g);
        let p = plan(&bsb, BUCKETS, 8, Order::ByTcbDesc, 128);
        // Among *full* batches, buckets are non-increasing (partial leftover
        // batches are flushed at the end regardless of size).
        let full: Vec<usize> = p
            .calls
            .iter()
            .filter(|c| c.rws.len() == 8)
            .map(|c| c.t_bucket)
            .collect();
        assert!(full.len() > 1);
        assert!(
            full.windows(2).all(|w| w[0] >= w[1]),
            "full batches not front-loaded: {full:?}"
        );
    }

    #[test]
    fn finer_buckets_reduce_padding() {
        let g = generators::erdos_renyi(4096, 10.0, 6);
        let bsb = build(&g);
        let coarse = plan(&bsb, &[128], 8, Order::Natural, 128);
        let fine = plan(&bsb, BUCKETS, 8, Order::Natural, 128);
        assert!(
            fine.stats.padding_ratio() < coarse.stats.padding_ratio(),
            "fine {} vs coarse {}",
            fine.stats.padding_ratio(),
            coarse.stats.padding_ratio()
        );
    }

    #[test]
    fn stats_account_tcbs() {
        let g = generators::erdos_renyi(1024, 5.0, 7);
        let bsb = build(&g);
        let p = plan(&bsb, BUCKETS, 8, Order::Natural, 128);
        let dispatched: usize = p
            .calls
            .iter()
            .flat_map(|c| c.rws.iter().map(|&rw| bsb.rw_tcbs(rw as usize)))
            .sum();
        let chunked: usize = p
            .chunked
            .iter()
            .map(|c| bsb.rw_tcbs(c.rw as usize))
            .sum();
        assert_eq!(p.stats.real_tcbs, dispatched + chunked);
    }
}
