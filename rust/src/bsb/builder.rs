//! BSB construction from CSR (paper §3.1, Figure 1).
//!
//! Two build modes:
//!
//! * [`build`] — the paper's BSB: all-zero columns inside each row window are
//!   eliminated before tiling, maximising nnz density per TCB.
//! * [`build_bcsr_like`] — the no-compaction ablation: TCBs are aligned to
//!   fixed 8-column blocks of the *original* column space (a 16×8 BCSR).
//!   This is what generic block formats do; the TCB count (and hence FLOPs)
//!   is strictly larger.  Used by the DF-GNN-analog baseline and the
//!   compaction ablation.

use crate::exec::WorkerPool;
use crate::graph::CsrGraph;
use crate::{TCB_C, TCB_R};

use super::bitmap::{self, Bitmap};

/// A sparse matrix in Binary Sparse Block format.
#[derive(Clone, Debug, PartialEq)]
pub struct Bsb {
    /// Number of matrix rows (n of the N×N attention mask).
    pub n: usize,
    /// Number of row windows = ceil(n / 16).
    pub num_rw: usize,
    /// TCB row offsets: `tro[i+1] - tro[i]` = TCB count of RW i
    /// (the paper's `tcb_row_offset`); len = num_rw + 1.
    pub tro: Vec<u32>,
    /// Compacted→original column map, concatenated per RW and padded to a
    /// multiple of 8 per RW with `u32::MAX` sentinels (the paper's
    /// `col_sparse_to_dense`).  Column j of TCB t of RW i is
    /// `sptd[(tro[i] + t) * 8 + j]`.
    pub sptd: Vec<u32>,
    /// One 128-bit bitmap per TCB; len = tro[num_rw].
    pub bitmaps: Vec<Bitmap>,
    /// Total nonzeros represented (= CSR nnz).
    pub nnz: usize,
}

/// Sentinel for padded sptd slots (gathers row 0; bitmap masks it out).
pub const PAD_COL: u32 = u32::MAX;

impl Bsb {
    /// TCB count of row window i.
    #[inline]
    pub fn rw_tcbs(&self, i: usize) -> usize {
        (self.tro[i + 1] - self.tro[i]) as usize
    }

    /// Total number of TCBs.
    pub fn total_tcbs(&self) -> usize {
        self.tro[self.num_rw] as usize
    }

    /// Column indices (original space) of TCB t in RW i.
    pub fn tcb_cols(&self, i: usize, t: usize) -> &[u32] {
        let base = (self.tro[i] as usize + t) * TCB_C;
        &self.sptd[base..base + TCB_C]
    }

    /// Bitmap of TCB t in RW i.
    pub fn tcb_bitmap(&self, i: usize, t: usize) -> &Bitmap {
        &self.bitmaps[self.tro[i] as usize + t]
    }

    /// Reconstruct the full edge set (for round-trip tests): (row, col).
    pub fn reconstruct_edges(&self) -> Vec<(u32, u32)> {
        let mut edges = Vec::with_capacity(self.nnz);
        for i in 0..self.num_rw {
            for t in 0..self.rw_tcbs(i) {
                let cols = self.tcb_cols(i, t);
                let bm = self.tcb_bitmap(i, t);
                for r in 0..TCB_R {
                    let row = i * TCB_R + r;
                    if row >= self.n {
                        continue;
                    }
                    for c in 0..TCB_C {
                        if bitmap::get(bm, r, c) {
                            debug_assert_ne!(cols[c], PAD_COL);
                            edges.push((row as u32, cols[c]));
                        }
                    }
                }
            }
        }
        edges
    }

    /// Nonzeros per TCB, flattened (for Table 6's nnz/TCB metric).
    pub fn nnz_per_tcb(&self) -> Vec<u32> {
        self.bitmaps.iter().map(bitmap::popcount).collect()
    }

    /// TCB counts per RW (for Table 6/7 metrics and reordering).
    pub fn tcbs_per_rw(&self) -> Vec<u32> {
        (0..self.num_rw).map(|i| self.rw_tcbs(i) as u32).collect()
    }
}

/// Build BSB with column compaction (the paper's format), serially.
pub fn build(g: &CsrGraph) -> Bsb {
    build_impl(g, true, &WorkerPool::new(1))
}

/// Build without compaction: TCBs on fixed 8-column boundaries (BCSR-like).
pub fn build_bcsr_like(g: &CsrGraph) -> Bsb {
    build_impl(g, false, &WorkerPool::new(1))
}

/// Build BSB with row windows sharded across the pool.  Row windows are
/// independent; shards are contiguous RW ranges stitched back in order, so
/// the result is **equal** (`==`) to the serial [`build`] for every pool
/// width (pinned by `rust/tests/exec_parallel.rs`).
pub fn build_with(g: &CsrGraph, pool: &WorkerPool) -> Bsb {
    build_impl(g, true, pool)
}

/// Parallel variant of [`build_bcsr_like`].
pub fn build_bcsr_like_with(g: &CsrGraph, pool: &WorkerPool) -> Bsb {
    build_impl(g, false, pool)
}

/// One shard's contribution: per-RW TCB counts plus the shard's stretch of
/// the `sptd` / `bitmaps` arrays.
struct ShardBlocks {
    tcb_counts: Vec<u32>,
    sptd: Vec<u32>,
    bitmaps: Vec<Bitmap>,
}

fn build_impl(g: &CsrGraph, compact: bool, pool: &WorkerPool) -> Bsb {
    let n = g.n;
    let num_rw = n.div_ceil(TCB_R);
    // Below ~4 RWs per worker the scoped-spawn overhead beats the win.
    let go_serial = pool.is_serial() || num_rw < 4 * pool.threads();
    let shards: Vec<ShardBlocks> = if go_serial {
        vec![build_rw_range(g, compact, 0..num_rw)]
    } else {
        pool.map_ranges(num_rw, |rws| build_rw_range(g, compact, rws))
    };

    // Stitch: shard results arrive in RW order, so concatenation plus a
    // running prefix sum over TCB counts reproduces the serial layout.
    let total_tcbs: usize = shards.iter().map(|s| s.bitmaps.len()).sum();
    let mut tro = Vec::with_capacity(num_rw + 1);
    tro.push(0u32);
    let mut sptd: Vec<u32> = Vec::with_capacity(total_tcbs * TCB_C);
    let mut bitmaps: Vec<Bitmap> = Vec::with_capacity(total_tcbs);
    for shard in shards {
        for count in shard.tcb_counts {
            let next = *tro.last().unwrap() + count;
            tro.push(next);
        }
        sptd.extend_from_slice(&shard.sptd);
        bitmaps.extend_from_slice(&shard.bitmaps);
    }

    Bsb { n, num_rw, tro, sptd, bitmaps, nnz: g.nnz() }
}

fn build_rw_range(
    g: &CsrGraph,
    compact: bool,
    rws: std::ops::Range<usize>,
) -> ShardBlocks {
    let mut out = ShardBlocks {
        tcb_counts: Vec::with_capacity(rws.len()),
        sptd: Vec::new(),
        bitmaps: Vec::new(),
    };
    let mut scratch = WindowScratch::new(g.n);
    for rw in rws {
        let count =
            build_window(g, rw, compact, &mut scratch, &mut out.sptd, &mut out.bitmaps);
        out.tcb_counts.push(count);
    }
    out
}

/// Per-worker scratch reused across the shard's row windows.  `pub(crate)`
/// so the incremental rebuilder (`bsb::incremental`) runs the *same*
/// per-window code path as the from-scratch build — bit-identity between
/// the two is by construction, not by parallel implementation.
pub(crate) struct WindowScratch {
    /// Distinct (sorted) column ids present in the current row window.
    cols: Vec<u32>,
    /// Expanded block-column list (BCSR-like mode only).
    bcsr_cols: Vec<u32>,
    pos: ColPosMap,
}

impl WindowScratch {
    pub(crate) fn new(n: usize) -> WindowScratch {
        WindowScratch {
            cols: Vec::new(),
            bcsr_cols: Vec::new(),
            pos: ColPosMap::new(n + TCB_C),
        }
    }
}

/// Epoch-stamped column → compacted-position map: O(w) to rebuild per
/// window, O(1) exact lookups per edge.  Replaces the former per-edge
/// `binary_search` over the window column list, which was O(nnz·log w) on
/// the preprocessing path the coordinator runs per request.
struct ColPosMap {
    pos: Vec<u32>,
    stamp: Vec<u32>,
    epoch: u32,
}

impl ColPosMap {
    fn new(n: usize) -> ColPosMap {
        ColPosMap { pos: vec![0; n], stamp: vec![0; n], epoch: 0 }
    }

    /// Point the map at a new window's column list (stamps invalidate the
    /// previous window's entries in O(1)).
    fn rebuild(&mut self, cols: &[u32]) {
        self.epoch += 1;
        for (p, &c) in cols.iter().enumerate() {
            self.pos[c as usize] = p as u32;
            self.stamp[c as usize] = self.epoch;
        }
    }

    fn get(&self, col: u32) -> u32 {
        debug_assert_eq!(self.stamp[col as usize], self.epoch, "col present");
        self.pos[col as usize]
    }
}

/// Append one row window's TCBs to `sptd`/`bitmaps`; returns its TCB count.
pub(crate) fn build_window(
    g: &CsrGraph,
    rw: usize,
    compact: bool,
    scratch: &mut WindowScratch,
    sptd: &mut Vec<u32>,
    bitmaps: &mut Vec<Bitmap>,
) -> u32 {
    let n = g.n;
    let row_lo = rw * TCB_R;
    let row_hi = (row_lo + TCB_R).min(n);

    let cols = &mut scratch.cols;
    cols.clear();
    for row in row_lo..row_hi {
        cols.extend_from_slice(g.row(row));
    }
    cols.sort_unstable();
    cols.dedup();
    if cols.is_empty() {
        return 0;
    }

    // The window's column list: compacted = the distinct nonzero columns
    // (used in place, no copy); BCSR-like = every column of each occupied
    // 8-aligned block.
    let window_cols: &[u32] = if compact {
        cols
    } else {
        let bcsr = &mut scratch.bcsr_cols;
        bcsr.clear();
        let mut last_block = u32::MAX;
        for &c in cols.iter() {
            let block = c / TCB_C as u32;
            if block != last_block {
                last_block = block;
                bcsr.extend((0..TCB_C as u32).map(|j| block * TCB_C as u32 + j));
            }
        }
        bcsr
    };

    let num_tcb = window_cols.len().div_ceil(TCB_C);
    let tcb_base = bitmaps.len();
    for t in 0..num_tcb {
        let lo = t * TCB_C;
        let hi = (lo + TCB_C).min(window_cols.len());
        for j in 0..TCB_C {
            // BCSR-like 8-aligned blocks can nominally cover columns
            // beyond n-1; those slots carry no nonzeros — store the
            // sentinel so gathers never touch out-of-range rows.
            let col = if lo + j < hi { window_cols[lo + j] } else { PAD_COL };
            sptd.push(if col != PAD_COL && (col as usize) < n {
                col
            } else {
                PAD_COL
            });
        }
        bitmaps.push(bitmap::EMPTY);
    }

    // Fill bitmaps through the O(1) column→position map.
    scratch.pos.rebuild(window_cols);
    for row in row_lo..row_hi {
        let r = row - row_lo;
        for &c in g.row(row) {
            let pos = scratch.pos.get(c) as usize;
            let t = pos / TCB_C;
            let j = pos % TCB_C;
            bitmap::set(&mut bitmaps[tcb_base + t], r, j);
        }
    }
    num_tcb as u32
}

#[cfg(test)]
mod tests {
    use crate::graph::generators;
    use crate::util::prng::Rng;

    use super::*;

    fn roundtrip_check(g: &CsrGraph, bsb: &Bsb) {
        let mut edges = bsb.reconstruct_edges();
        edges.sort_unstable();
        let mut expect: Vec<(u32, u32)> = Vec::new();
        for u in 0..g.n {
            for &v in g.row(u) {
                expect.push((u as u32, v));
            }
        }
        expect.sort_unstable();
        assert_eq!(edges, expect);
    }

    #[test]
    fn figure1_example() {
        // A small handmade matrix exercising compaction.
        // Rows 0..16 (one RW), nonzero columns {3, 17, 18, 40, 41, 42, 99,
        // 100, 101, 102}: 10 distinct columns -> 2 TCBs after compaction.
        let mut edges = Vec::new();
        let cols = [3u32, 17, 18, 40, 41, 42, 99, 100, 101, 102];
        for (r, &c) in cols.iter().enumerate() {
            edges.push((r as u32, c));
        }
        edges.push((15, 3)); // reuse a column in another row
        let g = CsrGraph::from_edges(128, &edges).unwrap();
        let bsb = build(&g);
        assert_eq!(bsb.num_rw, 8);
        assert_eq!(bsb.rw_tcbs(0), 2);
        assert_eq!(bsb.total_tcbs(), 2);
        // Compacted column map covers exactly the distinct columns + padding.
        assert_eq!(bsb.tcb_cols(0, 0), &[3, 17, 18, 40, 41, 42, 99, 100]);
        assert_eq!(
            bsb.tcb_cols(0, 1),
            &[101, 102, PAD_COL, PAD_COL, PAD_COL, PAD_COL, PAD_COL, PAD_COL]
        );
        roundtrip_check(&g, &bsb);
    }

    #[test]
    fn bcsr_like_has_more_tcbs() {
        let g = generators::erdos_renyi(1024, 6.0, 42);
        let compacted = build(&g);
        let bcsr = build_bcsr_like(&g);
        assert!(bcsr.total_tcbs() >= compacted.total_tcbs());
        roundtrip_check(&g, &compacted);
        roundtrip_check(&g, &bcsr);
        // Same nnz either way.
        let nc: u32 = compacted.nnz_per_tcb().iter().sum();
        let nb: u32 = bcsr.nnz_per_tcb().iter().sum();
        assert_eq!(nc as usize, g.nnz());
        assert_eq!(nb as usize, g.nnz());
    }

    #[test]
    fn roundtrip_random_graphs() {
        let mut rng = Rng::new(7);
        for _ in 0..10 {
            let n = rng.range(1, 400);
            let deg = 1.0 + rng.f64() * 8.0;
            let g = generators::erdos_renyi(n, deg, rng.next_u64());
            roundtrip_check(&g, &build(&g));
            roundtrip_check(&g, &build_bcsr_like(&g));
        }
    }

    #[test]
    fn empty_rows_and_windows() {
        // Graph where only row 40 has edges: RWs 0 and 1 are empty.
        let g = CsrGraph::from_edges(64, &[(40, 1), (40, 63)]).unwrap();
        let bsb = build(&g);
        assert_eq!(bsb.num_rw, 4);
        assert_eq!(bsb.rw_tcbs(0), 0);
        assert_eq!(bsb.rw_tcbs(1), 0);
        assert_eq!(bsb.rw_tcbs(2), 1);
        assert_eq!(bsb.rw_tcbs(3), 0);
        roundtrip_check(&g, &bsb);
    }

    #[test]
    fn ragged_last_window() {
        // n not a multiple of 16.
        let g = generators::erdos_renyi(37, 3.0, 9);
        let bsb = build(&g);
        assert_eq!(bsb.num_rw, 3);
        roundtrip_check(&g, &bsb);
    }

    #[test]
    fn dense_window_many_tcbs() {
        // One row attending to 100 distinct columns -> ceil(100/8) TCBs.
        let edges: Vec<(u32, u32)> = (0..100).map(|c| (0u32, c as u32)).collect();
        let g = CsrGraph::from_edges(128, &edges).unwrap();
        let bsb = build(&g);
        assert_eq!(bsb.rw_tcbs(0), 13);
        roundtrip_check(&g, &bsb);
    }

    #[test]
    fn parallel_build_equals_serial() {
        let pool = WorkerPool::new(4);
        for (n, deg, seed) in [(1500, 6.0, 1u64), (4096, 3.0, 2), (257, 9.0, 3)] {
            let g = generators::erdos_renyi(n, deg, seed);
            assert_eq!(build(&g), build_with(&g, &pool), "n={n}");
            assert_eq!(
                build_bcsr_like(&g),
                build_bcsr_like_with(&g, &pool),
                "n={n} (bcsr)"
            );
        }
    }

    #[test]
    fn nnz_density_improves_with_compaction() {
        use crate::util::stats;
        let g = generators::barabasi_albert(2048, 5, 11);
        let c = build(&g);
        let b = build_bcsr_like(&g);
        let dens = |x: &Bsb| {
            stats::mean(&x.nnz_per_tcb().iter().map(|&v| v as f64).collect::<Vec<_>>())
        };
        assert!(
            dens(&c) > dens(&b),
            "compaction should raise nnz/TCB ({} vs {})",
            dens(&c),
            dens(&b)
        );
    }
}
