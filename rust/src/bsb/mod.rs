//! The Binary Sparse Block (BSB) format — paper §3.1.
//!
//! BSB maps a binary sparse matrix onto tensor-core operand shapes:
//!
//! 1. split rows into **row windows** (RW) of r = 16 rows;
//! 2. within each RW, **compact away all-zero columns**;
//! 3. partition the compacted RW into 16×8 **tensor-core blocks** (TCB);
//! 4. store per-RW TCB counts (`tro`), the compacted→original column map
//!    (`sptd`), and a 128-bit **bitmap** per TCB.
//!
//! Extensions built here on top of the paper's format, needed by the AOT
//! static-shape contract (DESIGN.md §1):
//!
//! * [`reorder`] — row-window reordering by TCB count (paper §3.2's load
//!   balancing optimisation);
//! * [`bucket`] — grouping RWs into TCB-count buckets matching the compiled
//!   executable suite, with exact zero-bitmap padding;
//! * [`geometry`] — the second TCB geometry (narrow 8×1 tiles) and the
//!   per-RW hybrid dense/sparse router (DESIGN.md §12);
//! * [`footprint`] — the Table-3 memory-footprint models for BSB and the
//!   seven formats it is compared against;
//! * [`stats`] — the Table-6/7 sparsity characterisation metrics.
//!
//! Build once, reuse everywhere: a built [`Bsb`] is plain owned data
//! (`Send + Sync`).  The driver constructors split building from planning
//! ([`FusedDriver::from_bsb`](crate::kernels::fused::FusedDriver::from_bsb),
//! [`UnfusedDriver::from_bsb`](crate::kernels::unfused::UnfusedDriver::from_bsb)
//! accept a pre-built BSB and only rebuild the cheap bucket plan), and the
//! coordinator's fingerprint-keyed preprocessing cache
//! ([`coordinator::DriverCache`](crate::coordinator::DriverCache)) shares
//! whole prepared drivers behind `Arc`, so repeated graphs in the serving
//! steady state skip steps 1–4 entirely.

pub mod bitmap;
pub mod bucket;
pub mod builder;
pub mod footprint;
pub mod geometry;
pub mod incremental;
pub mod reorder;
pub mod serialize;
pub mod stats;

pub use builder::{build, build_bcsr_like, build_bcsr_like_with, build_with, Bsb};
pub use incremental::{rebuild as rebuild_incremental, IncrementalStats};

/// Row-window height r (rows per window = rows per TCB).
pub const RW: usize = crate::TCB_R;
