//! Memory-footprint models for sparse formats — the paper's Table 3.
//!
//! Each function returns the storage in **bits** for an N×N binary matrix
//! with z nonzeros under the respective format, using the paper's symbols:
//! r = row-window height (16), b = number of blocks, bc = stored columns
//! after compaction, rc = elements per block (16·8 = 128).  Sizes assume
//! 32-bit indices/values exactly as in the table.
//!
//! The block-dependent quantities (b, bc, per-format) are measured from the
//! actual BSB / BCSR-like builds, so `repro table3` reports real numbers for
//! real graphs rather than plugging an assumed density into formulas.

use crate::graph::CsrGraph;
use crate::{TCB_C, TCB_R};

use super::{build, build_bcsr_like, Bsb};

/// Measured inputs to the footprint formulas for one graph.
#[derive(Clone, Debug)]
pub struct FootprintInputs {
    /// Matrix dimension (graph nodes).
    pub n: usize,
    /// nonzeros
    pub z: usize,
    /// row-window height
    pub r: usize,
    /// elements per block (r*c)
    pub rc: usize,
    /// blocks in the *compacted* (BSB/ME-TCF-style) build
    pub b_compacted: usize,
    /// stored columns after compaction = 8 * b_compacted (padded map)
    pub bc_compacted: usize,
    /// blocks in the non-compacted (BCSR-style) build
    pub b_bcsr: usize,
}

/// Measure the block-dependent formula inputs by running both the
/// compacted (BSB) and non-compacted (BCSR-like) builds on `g`.
pub fn measure(g: &CsrGraph) -> FootprintInputs {
    let bsb: Bsb = build(g);
    let bcsr = build_bcsr_like(g);
    // bc = columns actually stored after compaction (without the 8-per-block
    // padding of our in-memory sptd layout — the format itself stores exactly
    // the distinct columns, as in the paper's Table 3).
    let bc = bsb
        .sptd
        .iter()
        .filter(|&&c| c != super::builder::PAD_COL)
        .count();
    FootprintInputs {
        n: g.n,
        z: g.nnz(),
        r: TCB_R,
        rc: TCB_R * TCB_C,
        b_compacted: bsb.total_tcbs(),
        bc_compacted: bc,
        b_bcsr: bcsr.total_tcbs(),
    }
}

/// One Table-3 row: (format name, bits).
pub fn table3_rows(f: &FootprintInputs) -> Vec<(&'static str, u64)> {
    vec![
        ("CSR", csr_bits(f)),
        ("SR-BCSR", sr_bcsr_bits(f)),
        ("ME-BCRS", me_bcrs_bits(f)),
        ("BCSR", bcsr_bits(f)),
        ("TCF", tcf_bits(f)),
        ("ME-TCF", me_tcf_bits(f)),
        ("BitTCF", bittcf_bits(f)),
        ("BSB", bsb_bits(f)),
    ]
}

/// CSR: 32(N + 2z) — indptr + column index + fp32 value per nonzero.
pub fn csr_bits(f: &FootprintInputs) -> u64 {
    32 * (f.n as u64 + 2 * f.z as u64)
}

/// SR-BCSR (Magicube): 32(2N/r + bc + b·rc) with explicit fp32 block values.
pub fn sr_bcsr_bits(f: &FootprintInputs) -> u64 {
    32 * (2 * (f.n / f.r) as u64
        + block_cols(f.b_bcsr) as u64
        + (f.b_bcsr * f.rc) as u64)
}

/// ME-BCRS (FlashSparse): 32(N/r + bc + b·rc).
pub fn me_bcrs_bits(f: &FootprintInputs) -> u64 {
    32 * ((f.n / f.r) as u64
        + block_cols(f.b_bcsr) as u64
        + (f.b_bcsr * f.rc) as u64)
}

/// BCSR: 32(N/r + b + b·rc) — block pointer + block col id + dense values.
pub fn bcsr_bits(f: &FootprintInputs) -> u64 {
    32 * ((f.n / f.r) as u64 + f.b_bcsr as u64 + (f.b_bcsr * f.rc) as u64)
}

/// TCF (TC-GNN): 32(N/r + N + 3z) — binary values, integer indices.
pub fn tcf_bits(f: &FootprintInputs) -> u64 {
    32 * ((f.n / f.r) as u64 + f.n as u64 + 3 * f.z as u64)
}

/// ME-TCF (DTC-SpMM): 32(N/r + b + z) + 8z — 8-bit local nnz indices.
pub fn me_tcf_bits(f: &FootprintInputs) -> u64 {
    32 * ((f.n / f.r) as u64 + f.b_compacted as u64 + f.z as u64)
        + 8 * f.z as u64
}

/// BitTCF (Acc-SpMM): 32(N/r + b + z) + z — 1 bit per nonzero on top.
pub fn bittcf_bits(f: &FootprintInputs) -> u64 {
    32 * ((f.n / f.r) as u64 + f.b_compacted as u64 + f.z as u64) + f.z as u64
}

/// BSB (ours): 32(N/r + bc) + b·rc — column map + one bit per block slot.
pub fn bsb_bits(f: &FootprintInputs) -> u64 {
    32 * ((f.n / f.r) as u64 + f.bc_compacted as u64)
        + (f.b_compacted * f.rc) as u64
}

/// Stored columns for non-compacted block formats: 8 per block.
fn block_cols(b: usize) -> usize {
    b * TCB_C
}

#[cfg(test)]
mod tests {
    use crate::graph::generators;

    use super::*;

    fn inputs() -> FootprintInputs {
        measure(&generators::erdos_renyi(4096, 8.0, 42).with_self_loops())
    }

    #[test]
    fn bsb_beats_value_storing_block_formats() {
        let f = inputs();
        assert!(bsb_bits(&f) < bcsr_bits(&f));
        assert!(bsb_bits(&f) < sr_bcsr_bits(&f));
        assert!(bsb_bits(&f) < me_bcrs_bits(&f));
    }

    #[test]
    fn bsb_beats_index_storing_tc_formats_when_dense() {
        // The bitmap costs a fixed 128 bits per block while ME-TCF/BitTCF pay
        // ~40/33 bits per nonzero, so BSB wins once blocks are dense enough
        // (nnz/TCB above ~4; the paper's datasets sit at 7.5-16.5).  A
        // clustered graph gives dense blocks.
        let g = crate::graph::generators::sbm(32, 128, 0.25, 0.0001, 7)
            .with_self_loops();
        let f = measure(&g);
        let density = f.z as f64 / f.b_compacted as f64;
        assert!(density > 6.0, "test premise: dense blocks ({density:.1})");
        assert!(bsb_bits(&f) < me_tcf_bits(&f));
        assert!(bsb_bits(&f) < bittcf_bits(&f));
        assert!(bsb_bits(&f) < tcf_bits(&f));
    }

    #[test]
    fn me_tcf_crossover_on_hypersparse_blocks() {
        // Document the crossover the formulas imply: with nearly-empty
        // blocks the 128-bit bitmap is pure overhead and per-nonzero index
        // formats can be smaller.  (The paper's datasets are all on the
        // dense side of this line.)
        // Block density floors at ~8 for any graph whose windows hold >=8
        // distinct columns, so hypersparse blocks require near-empty windows.
        let g = crate::graph::generators::erdos_renyi(8192, 0.15, 8);
        let f = measure(&g);
        let density = f.z as f64 / f.b_compacted as f64;
        assert!(density < 4.0, "test premise: sparse blocks ({density:.1})");
        assert!(bsb_bits(&f) < csr_bits(&f) * 2, "sanity: same order");
    }

    #[test]
    fn table_has_all_eight_formats() {
        let rows = table3_rows(&inputs());
        assert_eq!(rows.len(), 8);
        assert!(rows.iter().all(|&(_, bits)| bits > 0));
    }

    #[test]
    fn footprints_grow_with_nnz() {
        let small = measure(&generators::erdos_renyi(2048, 2.0, 1));
        let large = measure(&generators::erdos_renyi(2048, 16.0, 1));
        for ((_, a), (_, b)) in
            table3_rows(&small).iter().zip(table3_rows(&large).iter())
        {
            assert!(b > a, "footprint must grow with density");
        }
    }

    #[test]
    fn csr_formula_exact() {
        let f = FootprintInputs {
            n: 100,
            z: 500,
            r: 16,
            rc: 128,
            b_compacted: 0,
            bc_compacted: 0,
            b_bcsr: 0,
        };
        assert_eq!(csr_bits(&f), 32 * (100 + 1000));
    }
}
