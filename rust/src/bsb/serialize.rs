//! Binary serialization of preprocessed BSB matrices.
//!
//! Preprocessing (compaction + bitmap construction) is cheap but not free on
//! very large graphs; serving deployments preprocess once and cache.  The
//! format is a flat little-endian layout with a magic/version header and a
//! trailing checksum, so a truncated or corrupted cache is detected rather
//! than silently producing a wrong sparsity pattern.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::BITMAP_WORDS;

use super::builder::Bsb;

const MAGIC: &[u8; 8] = b"F3SBSB01";

/// FNV-1a over the payload (cheap integrity check; not cryptographic).
fn fnv1a(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Serialize to bytes.
pub fn to_bytes(bsb: &Bsb) -> Vec<u8> {
    let mut out = Vec::with_capacity(
        40 + 4 * (bsb.tro.len() + bsb.sptd.len())
            + 16 * bsb.bitmaps.len(),
    );
    out.extend_from_slice(MAGIC);
    for x in [
        bsb.n as u64,
        bsb.num_rw as u64,
        bsb.nnz as u64,
        bsb.tro.len() as u64,
        bsb.sptd.len() as u64,
        bsb.bitmaps.len() as u64,
    ] {
        out.extend_from_slice(&x.to_le_bytes());
    }
    for &x in &bsb.tro {
        out.extend_from_slice(&x.to_le_bytes());
    }
    for &x in &bsb.sptd {
        out.extend_from_slice(&x.to_le_bytes());
    }
    for bm in &bsb.bitmaps {
        for &w in bm {
            out.extend_from_slice(&w.to_le_bytes());
        }
    }
    let checksum = fnv1a(&out[8..]);
    out.extend_from_slice(&checksum.to_le_bytes());
    out
}

/// Deserialize from bytes (validates header, sizes, and checksum).
pub fn from_bytes(buf: &[u8]) -> Result<Bsb> {
    if buf.len() < 64 || &buf[..8] != MAGIC {
        bail!("not a fused3s BSB cache file");
    }
    let payload = &buf[8..buf.len() - 8];
    let stored = u64::from_le_bytes(buf[buf.len() - 8..].try_into().unwrap());
    if fnv1a(&buf[8..buf.len() - 8]) != stored {
        bail!("BSB cache checksum mismatch (corrupted file)");
    }
    let mut off = 0usize;
    let mut read_u64 = || {
        let v = u64::from_le_bytes(payload[off..off + 8].try_into().unwrap());
        off += 8;
        v as usize
    };
    let n = read_u64();
    let num_rw = read_u64();
    let nnz = read_u64();
    let tro_len = read_u64();
    let sptd_len = read_u64();
    let bm_len = read_u64();
    if tro_len != num_rw + 1 || sptd_len != bm_len * crate::TCB_C {
        bail!("inconsistent BSB header");
    }
    let need = 48 + 4 * (tro_len + sptd_len) + 4 * BITMAP_WORDS * bm_len;
    if payload.len() != need {
        bail!("truncated BSB cache: {} != {}", payload.len(), need);
    }
    let mut read_u32s = |count: usize| -> Vec<u32> {
        let mut v = Vec::with_capacity(count);
        for _ in 0..count {
            v.push(u32::from_le_bytes(payload[off..off + 4].try_into().unwrap()));
            off += 4;
        }
        v
    };
    let tro = read_u32s(tro_len);
    let sptd = read_u32s(sptd_len);
    let flat = read_u32s(BITMAP_WORDS * bm_len);
    let bitmaps: Vec<[u32; BITMAP_WORDS]> = flat
        .chunks_exact(BITMAP_WORDS)
        .map(|c| [c[0], c[1], c[2], c[3]])
        .collect();
    if tro[num_rw] as usize != bm_len {
        bail!("inconsistent tro/bitmap count");
    }
    Ok(Bsb { n, num_rw, tro, sptd, bitmaps, nnz })
}

/// Write to a file.
pub fn write(bsb: &Bsb, path: &Path) -> Result<()> {
    std::fs::write(path, to_bytes(bsb))
        .with_context(|| format!("writing {}", path.display()))
}

/// Read from a file.
pub fn read(path: &Path) -> Result<Bsb> {
    let buf = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    from_bytes(&buf)
}

#[cfg(test)]
mod tests {
    use crate::bsb::build;
    use crate::graph::generators;
    use crate::util::prng::Rng;

    use super::*;

    #[test]
    fn roundtrip_exact() {
        let mut rng = Rng::new(3);
        for _ in 0..8 {
            let n = rng.range(1, 800);
            let g = generators::erdos_renyi(n, 1.0 + rng.f64() * 6.0, rng.next_u64());
            let b = build(&g);
            let back = from_bytes(&to_bytes(&b)).unwrap();
            assert_eq!(b, back);
        }
    }

    #[test]
    fn detects_corruption() {
        let g = generators::erdos_renyi(200, 4.0, 1);
        let b = build(&g);
        let mut bytes = to_bytes(&b);
        // flip one bitmap bit in the middle
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        let err = from_bytes(&bytes).unwrap_err();
        assert!(format!("{err}").contains("checksum"), "{err}");
    }

    #[test]
    fn detects_truncation_and_garbage() {
        let g = generators::ring(64);
        let b = build(&g);
        let bytes = to_bytes(&b);
        assert!(from_bytes(&bytes[..bytes.len() - 9]).is_err());
        assert!(from_bytes(b"hello world, not a bsb").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let g = generators::barabasi_albert(300, 3, 7);
        let b = build(&g);
        let dir = std::env::temp_dir().join("f3s_bsb_cache");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("g.bsb");
        write(&b, &p).unwrap();
        assert_eq!(read(&p).unwrap(), b);
    }
}
