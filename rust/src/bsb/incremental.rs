//! Delta-aware BSB maintenance: rebuild only the dirty row windows.
//!
//! Row windows are the builder's unit of independence (PR 1 shards the
//! from-scratch build per RW), which makes them the natural unit of
//! *invalidation* under topology churn: a [`GraphDelta`]
//! (crate::graph::GraphDelta) reports exactly which windows changed, and
//! [`rebuild`] recomputes those — column re-compaction, bucket re-packing,
//! fresh bitmaps — through the **same** `build_window` code path the
//! from-scratch builder uses, while splicing every clean window's
//! `tro`/`sptd`/`bitmaps` stretch verbatim from the old BSB.
//!
//! Because dirty windows run the identical per-window code and clean
//! windows are byte-copied, the result is `==` to
//! [`builder::build`](super::builder::build) on the patched CSR *by
//! construction* — and since the hybrid geometry router
//! ([`route`](super::geometry::route)) is a pure function of the BSB and
//! CSR shapes, every per-RW wide/narrow/dense decision is reproduced
//! bit-identically too.  `rust/tests/streaming_equivalence.rs` pins both.

use crate::graph::CsrGraph;
use crate::TCB_C;

use super::builder::{build_window, Bsb, WindowScratch};

/// What an incremental rebuild did — feeds `Metrics.streaming`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct IncrementalStats {
    /// Row windows recomputed from the patched CSR.
    pub rebuilt: usize,
    /// Row windows spliced verbatim from the old BSB.
    pub spliced: usize,
}

/// True when `old` can be incrementally patched toward `g`: same node
/// count (deltas never add/remove nodes) and a consistent window count.
/// Anything else must take the full-rebuild fallback.
pub fn compatible(old: &Bsb, g: &CsrGraph) -> bool {
    old.n == g.n && old.num_rw == g.n.div_ceil(crate::TCB_R)
}

/// Rebuild the compacted BSB for the patched graph `g`, recomputing only
/// `dirty_rws` (sorted or not; out-of-range entries are a caller bug and
/// panic) and splicing every other window from `old`.
///
/// `old` must be a *compacted* BSB of the pre-patch graph with the same
/// `n` (see [`compatible`]); the BCSR-like ablation format has no
/// incremental path.  Returns the new BSB plus splice statistics.
pub fn rebuild(old: &Bsb, g: &CsrGraph, dirty_rws: &[u32]) -> (Bsb, IncrementalStats) {
    assert!(compatible(old, g), "incremental rebuild needs matching n/num_rw");
    let num_rw = old.num_rw;
    let mut dirty = vec![false; num_rw];
    for &rw in dirty_rws {
        dirty[rw as usize] = true;
    }

    let mut tro: Vec<u32> = Vec::with_capacity(num_rw + 1);
    tro.push(0);
    // Dirty windows change TCB counts by at most their edit size; the old
    // totals are the right ballpark for preallocation.
    let mut sptd: Vec<u32> = Vec::with_capacity(old.sptd.len());
    let mut bitmaps = Vec::with_capacity(old.bitmaps.len());
    let mut scratch = WindowScratch::new(g.n);
    let mut stats = IncrementalStats::default();

    for rw in 0..num_rw {
        let count = if dirty[rw] {
            stats.rebuilt += 1;
            build_window(g, rw, true, &mut scratch, &mut sptd, &mut bitmaps)
        } else {
            stats.spliced += 1;
            let lo = old.tro[rw] as usize;
            let hi = old.tro[rw + 1] as usize;
            sptd.extend_from_slice(&old.sptd[lo * TCB_C..hi * TCB_C]);
            bitmaps.extend_from_slice(&old.bitmaps[lo..hi]);
            (hi - lo) as u32
        };
        // invariant: tro starts non-empty and grows every iteration.
        let next = *tro.last().unwrap() + count;
        tro.push(next);
    }

    (Bsb { n: g.n, num_rw, tro, sptd, bitmaps, nnz: g.nnz() }, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsb::builder;
    use crate::graph::delta::GraphDelta;
    use crate::graph::generators;
    use crate::util::prng::Rng;

    #[test]
    fn rebuild_equals_scratch() {
        let g0 = generators::erdos_renyi(500, 5.0, 3);
        let old = builder::build(&g0);
        let delta = GraphDelta::against(
            &g0,
            vec![(1, 250), (100, 7), (499, 499)],
            vec![(g0.row(0).first().map(|&v| (0u32, v))).unwrap_or((0, 0))]
                .into_iter()
                .filter(|&(u, v)| g0.has_edge(u as usize, v))
                .collect(),
        );
        let (g1, report) = delta.applied(&g0).unwrap();
        let (inc, stats) = rebuild(&old, &g1, &report.dirty_rws);
        assert_eq!(inc, builder::build(&g1));
        assert_eq!(stats.rebuilt, report.dirty_rws.len());
        assert_eq!(stats.rebuilt + stats.spliced, old.num_rw);
    }

    #[test]
    fn empty_dirty_set_is_identity() {
        let g = generators::power_law(300, 4.0, 2.3, 9);
        let old = builder::build(&g);
        let (inc, stats) = rebuild(&old, &g, &[]);
        assert_eq!(inc, old);
        assert_eq!(stats.rebuilt, 0);
        assert_eq!(stats.spliced, old.num_rw);
    }

    #[test]
    fn all_dirty_equals_scratch() {
        let g0 = generators::sbm(4, 64, 0.2, 0.01, 5);
        let old = builder::build(&g0);
        let all: Vec<u32> = (0..old.num_rw as u32).collect();
        let (inc, stats) = rebuild(&old, &g0, &all);
        assert_eq!(inc, old);
        assert_eq!(stats.rebuilt, old.num_rw);
    }

    #[test]
    fn window_emptied_by_delta() {
        // Remove the only edge of RW 1: its TCB count drops to zero and
        // downstream windows' tro offsets shift.
        let g0 = crate::graph::CsrGraph::from_edges(48, &[(0, 1), (20, 2), (40, 3)])
            .unwrap();
        let old = builder::build(&g0);
        let delta = GraphDelta::against(&g0, vec![], vec![(20, 2)]);
        let (g1, report) = delta.applied(&g0).unwrap();
        assert_eq!(report.dirty_rws, vec![1]);
        let (inc, _) = rebuild(&old, &g1, &report.dirty_rws);
        assert_eq!(inc, builder::build(&g1));
        assert_eq!(inc.rw_tcbs(1), 0);
    }

    #[test]
    fn randomized_churn_stays_bit_identical() {
        let mut rng = Rng::new(21);
        for _ in 0..6 {
            let n = rng.range(17, 600);
            let mut g = generators::erdos_renyi(n, 4.0, rng.next_u64());
            let mut bsb = builder::build(&g);
            for _step in 0..5 {
                let mut ins = Vec::new();
                let mut rem = Vec::new();
                for _ in 0..rng.range(1, 20) {
                    let u = rng.below(n) as u32;
                    let v = rng.below(n) as u32;
                    if rng.coin(0.5) {
                        ins.push((u, v));
                    } else {
                        rem.push((u, v));
                    }
                }
                ins.retain(|e| !rem.contains(e));
                let delta = GraphDelta::against(&g, ins, rem);
                let report = delta.apply(&mut g).unwrap();
                let (next, _) = rebuild(&bsb, &g, &report.dirty_rws);
                assert_eq!(next, builder::build(&g));
                bsb = next;
            }
        }
    }
}
