//! 128-bit TCB bitmaps: the BSB innovation over ME-TCF/TCF index lists.
//!
//! Encoding contract (shared with `python/compile/kernels/ref.py` — tests on
//! both sides pin it): bit `i = row * 8 + col` of the 16×8 block lives in
//! u32 word `i / 32` at bit position `i % 32`, words little-endian.

use crate::{BITMAP_WORDS, TCB_C, TCB_R};

/// One TCB's sparsity pattern.
pub type Bitmap = [u32; BITMAP_WORDS];

/// All-zero bitmap (fully masked TCB — used for bucket padding).
pub const EMPTY: Bitmap = [0; BITMAP_WORDS];

/// Set the bit for (row, col) within the TCB.
#[inline]
pub fn set(bm: &mut Bitmap, row: usize, col: usize) {
    debug_assert!(row < TCB_R && col < TCB_C);
    let i = row * TCB_C + col;
    bm[i / 32] |= 1 << (i % 32);
}

/// Test the bit for (row, col).
#[inline]
pub fn get(bm: &Bitmap, row: usize, col: usize) -> bool {
    let i = row * TCB_C + col;
    (bm[i / 32] >> (i % 32)) & 1 == 1
}

/// Number of nonzeros in the TCB.
#[inline]
pub fn popcount(bm: &Bitmap) -> u32 {
    bm.iter().map(|w| w.count_ones()).sum()
}

/// Rows of the TCB that contain at least one nonzero (bitmask over 16 rows).
pub fn row_occupancy(bm: &Bitmap) -> u16 {
    let mut occ = 0u16;
    for row in 0..TCB_R {
        for col in 0..TCB_C {
            if get(bm, row, col) {
                occ |= 1 << row;
                break;
            }
        }
    }
    occ
}

/// Reinterpret the bitmap words as i32 for the kernel's i32 input buffer
/// (bit patterns are identical).
#[inline]
pub fn as_i32(bm: &Bitmap) -> [i32; BITMAP_WORDS] {
    [bm[0] as i32, bm[1] as i32, bm[2] as i32, bm[3] as i32]
}

/// Row mask of one TCB column (bit `r` set iff `(r, col)` is a nonzero).
/// This is the 16×1 *column lane* view the dense dispatch path uses.
#[inline]
pub fn col_mask(bm: &Bitmap, col: usize) -> u16 {
    debug_assert!(col < TCB_C);
    let mut m = 0u16;
    for row in 0..TCB_R {
        if get(bm, row, col) {
            m |= 1 << row;
        }
    }
    m
}

/// Row masks of one TCB column split at the half-window boundary: `(lo, hi)`
/// where `lo` bit `r` covers block row `r` (0..8) and `hi` bit `r` covers
/// block row `8 + r`.  These are the two 8×1 narrow tiles the FlashSparse
/// geometry carves out of a wide TCB column.
#[inline]
pub fn col_half_masks(bm: &Bitmap, col: usize) -> (u8, u8) {
    let m = col_mask(bm, col);
    ((m & 0xff) as u8, (m >> 8) as u8)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_all_positions() {
        for row in 0..TCB_R {
            for col in 0..TCB_C {
                let mut bm = EMPTY;
                set(&mut bm, row, col);
                assert!(get(&bm, row, col));
                assert_eq!(popcount(&bm), 1);
                // exactly one bit anywhere
                let total: u32 = bm.iter().map(|w| w.count_ones()).sum();
                assert_eq!(total, 1);
            }
        }
    }

    #[test]
    fn word_layout_matches_python_contract() {
        // bit i = row*8+col -> word i/32, bit i%32 (see test_bitmap.py)
        let mut bm = EMPTY;
        set(&mut bm, 0, 0); // i=0 -> word0 bit0
        set(&mut bm, 3, 7); // i=31 -> word0 bit31
        set(&mut bm, 4, 0); // i=32 -> word1 bit0
        set(&mut bm, 15, 7); // i=127 -> word3 bit31
        assert_eq!(bm[0], 1 | (1 << 31));
        assert_eq!(bm[1], 1);
        assert_eq!(bm[2], 0);
        assert_eq!(bm[3], 1 << 31);
    }

    #[test]
    fn popcount_counts() {
        let mut bm = EMPTY;
        for i in 0..10 {
            set(&mut bm, i, i % 8);
        }
        assert_eq!(popcount(&bm), 10);
    }

    #[test]
    fn row_occupancy_flags() {
        let mut bm = EMPTY;
        set(&mut bm, 2, 5);
        set(&mut bm, 2, 6);
        set(&mut bm, 9, 0);
        assert_eq!(row_occupancy(&bm), (1 << 2) | (1 << 9));
    }

    #[test]
    fn col_masks_match_get() {
        let mut bm = EMPTY;
        set(&mut bm, 0, 3);
        set(&mut bm, 7, 3);
        set(&mut bm, 8, 3);
        set(&mut bm, 15, 3);
        set(&mut bm, 5, 0);
        assert_eq!(col_mask(&bm, 3), 1 | (1 << 7) | (1 << 8) | (1 << 15));
        let (lo, hi) = col_half_masks(&bm, 3);
        assert_eq!(lo, 1 | (1 << 7));
        assert_eq!(hi, 1 | (1 << 7));
        let (lo, hi) = col_half_masks(&bm, 0);
        assert_eq!((lo, hi), (1 << 5, 0));
        assert_eq!(col_half_masks(&bm, 6), (0, 0));
    }

    #[test]
    fn i32_view_preserves_bits() {
        let mut bm = EMPTY;
        set(&mut bm, 15, 7);
        let i = as_i32(&bm);
        assert_eq!(i[3] as u32, 1 << 31); // sign bit round-trips
    }
}
