//! 128-bit TCB bitmaps: the BSB innovation over ME-TCF/TCF index lists.
//!
//! Encoding contract (shared with `python/compile/kernels/ref.py` — tests on
//! both sides pin it): bit `i = row * 8 + col` of the 16×8 block lives in
//! u32 word `i / 32` at bit position `i % 32`, words little-endian.

use crate::{BITMAP_WORDS, TCB_C, TCB_R};

/// One TCB's sparsity pattern.
pub type Bitmap = [u32; BITMAP_WORDS];

/// All-zero bitmap (fully masked TCB — used for bucket padding).
pub const EMPTY: Bitmap = [0; BITMAP_WORDS];

/// Set the bit for (row, col) within the TCB.
#[inline]
pub fn set(bm: &mut Bitmap, row: usize, col: usize) {
    debug_assert!(row < TCB_R && col < TCB_C);
    let i = row * TCB_C + col;
    bm[i / 32] |= 1 << (i % 32);
}

/// Test the bit for (row, col).
#[inline]
pub fn get(bm: &Bitmap, row: usize, col: usize) -> bool {
    let i = row * TCB_C + col;
    (bm[i / 32] >> (i % 32)) & 1 == 1
}

/// Number of nonzeros in the TCB.
#[inline]
pub fn popcount(bm: &Bitmap) -> u32 {
    bm.iter().map(|w| w.count_ones()).sum()
}

/// Rows of the TCB that contain at least one nonzero (bitmask over 16 rows).
pub fn row_occupancy(bm: &Bitmap) -> u16 {
    let mut occ = 0u16;
    for row in 0..TCB_R {
        for col in 0..TCB_C {
            if get(bm, row, col) {
                occ |= 1 << row;
                break;
            }
        }
    }
    occ
}

/// Reinterpret the bitmap words as i32 for the kernel's i32 input buffer
/// (bit patterns are identical).
#[inline]
pub fn as_i32(bm: &Bitmap) -> [i32; BITMAP_WORDS] {
    [bm[0] as i32, bm[1] as i32, bm[2] as i32, bm[3] as i32]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_all_positions() {
        for row in 0..TCB_R {
            for col in 0..TCB_C {
                let mut bm = EMPTY;
                set(&mut bm, row, col);
                assert!(get(&bm, row, col));
                assert_eq!(popcount(&bm), 1);
                // exactly one bit anywhere
                let total: u32 = bm.iter().map(|w| w.count_ones()).sum();
                assert_eq!(total, 1);
            }
        }
    }

    #[test]
    fn word_layout_matches_python_contract() {
        // bit i = row*8+col -> word i/32, bit i%32 (see test_bitmap.py)
        let mut bm = EMPTY;
        set(&mut bm, 0, 0); // i=0 -> word0 bit0
        set(&mut bm, 3, 7); // i=31 -> word0 bit31
        set(&mut bm, 4, 0); // i=32 -> word1 bit0
        set(&mut bm, 15, 7); // i=127 -> word3 bit31
        assert_eq!(bm[0], 1 | (1 << 31));
        assert_eq!(bm[1], 1);
        assert_eq!(bm[2], 0);
        assert_eq!(bm[3], 1 << 31);
    }

    #[test]
    fn popcount_counts() {
        let mut bm = EMPTY;
        for i in 0..10 {
            set(&mut bm, i, i % 8);
        }
        assert_eq!(popcount(&bm), 10);
    }

    #[test]
    fn row_occupancy_flags() {
        let mut bm = EMPTY;
        set(&mut bm, 2, 5);
        set(&mut bm, 2, 6);
        set(&mut bm, 9, 0);
        assert_eq!(row_occupancy(&bm), (1 << 2) | (1 << 9));
    }

    #[test]
    fn i32_view_preserves_bits() {
        let mut bm = EMPTY;
        set(&mut bm, 15, 7);
        let i = as_i32(&bm);
        assert_eq!(i[3] as u32, 1 << 31); // sign bit round-trips
    }
}
