//! Row-window reordering (paper §3.2, "Load Balancing via Row Window
//! Reordering"): schedule dense row windows first so lightweight ones fill
//! the tail — improves SM utilisation (Fig. 7) and, in this reproduction,
//! batching efficiency (denser windows land in the same bucket batches).
//!
//! Reordering is a *schedule* permutation only: outputs are scattered back by
//! original row-window id, so results are bit-identical (property-tested in
//! `rust/tests/`).

use super::Bsb;

/// Execution order of row windows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Order {
    /// Natural order 0..num_rw.
    Natural,
    /// Decreasing TCB count (the paper's policy), ties by original id
    /// (stable, deterministic).
    ByTcbDesc,
}

/// Compute the RW schedule under the given policy.
pub fn schedule(bsb: &Bsb, order: Order) -> Vec<u32> {
    let mut ids: Vec<u32> = (0..bsb.num_rw as u32).collect();
    match order {
        Order::Natural => ids,
        Order::ByTcbDesc => {
            ids.sort_by_key(|&i| std::cmp::Reverse(bsb.rw_tcbs(i as usize)));
            ids
        }
    }
}

/// Verify a schedule is a permutation of 0..num_rw (used by tests and debug
/// assertions in the coordinator).
pub fn is_permutation(sched: &[u32], num_rw: usize) -> bool {
    if sched.len() != num_rw {
        return false;
    }
    let mut seen = vec![false; num_rw];
    for &i in sched {
        let i = i as usize;
        if i >= num_rw || seen[i] {
            return false;
        }
        seen[i] = true;
    }
    true
}

#[cfg(test)]
mod tests {
    use crate::bsb::build;
    use crate::graph::generators;

    use super::*;

    #[test]
    fn natural_is_identity() {
        let g = generators::erdos_renyi(256, 4.0, 1);
        let bsb = build(&g);
        let s = schedule(&bsb, Order::Natural);
        assert_eq!(s, (0..bsb.num_rw as u32).collect::<Vec<_>>());
    }

    #[test]
    fn desc_order_is_sorted_and_permutation() {
        let g = generators::barabasi_albert(2048, 4, 2);
        let bsb = build(&g);
        let s = schedule(&bsb, Order::ByTcbDesc);
        assert!(is_permutation(&s, bsb.num_rw));
        for w in s.windows(2) {
            assert!(
                bsb.rw_tcbs(w[0] as usize) >= bsb.rw_tcbs(w[1] as usize),
                "not descending"
            );
        }
    }

    #[test]
    fn stable_ties() {
        // A ring: every RW has the same TCB count -> order must stay natural.
        let g = generators::ring(256);
        let bsb = build(&g);
        let s = schedule(&bsb, Order::ByTcbDesc);
        assert_eq!(s, (0..bsb.num_rw as u32).collect::<Vec<_>>());
    }

    #[test]
    fn is_permutation_rejects() {
        assert!(!is_permutation(&[0, 0], 2));
        assert!(!is_permutation(&[0, 2], 2));
        assert!(!is_permutation(&[0], 2));
        assert!(is_permutation(&[1, 0], 2));
    }
}
