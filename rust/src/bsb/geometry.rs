//! Second TCB geometry (FlashSparse-style narrow 8×1 tiles) + per-row-window
//! hybrid dispatch (HC-SpMM-style dense/sparse routing) — ROADMAP item 2,
//! DESIGN.md §12.
//!
//! The wide 16×8 TCB geometry pays for 128 cells per slot even when a row
//! window holds a handful of scattered nonzeros.  This module adds two
//! cheaper shapes and a router that picks, per row window, the one that
//! dispatches the fewest cells:
//!
//! * **Narrow** — the window is split into two 8-row halves; each half
//!   dispatches one 8×1 *tile* per distinct column it touches, padded up a
//!   tile-count bucket ladder ([`NARROW_BUCKETS`]).  Wins on scattered
//!   sparsity, where a wide TCB's 16×8 slot covers mostly zeros.
//! * **Dense** — near-dense windows (occupancy ≥ [`DENSE_OCCUPANCY`])
//!   dispatch one 16×1 *lane* per distinct column, width padded to a
//!   multiple of 8.  Wins when the window's columns are shared by most of
//!   its rows (hub leaves, cliques), where even narrow tiles would pay the
//!   bucket round-up twice.
//! * **Wide** — everything else, including every oversize (chunked) window,
//!   stays on the existing bucketed 16×8 path unchanged.
//!
//! Routing depends only on [`WindowShape`] — five integers derivable
//! *identically* from the CSR graph ([`window_shapes_from_csr`]) and from
//! the built BSB ([`window_shapes_from_bsb`]) — so the planner's CSR-side
//! cell estimate equals the built plan's accounting exactly (pinned by
//! tests here and in `planner::profile`).
//!
//! Bit-exactness: every path visits a row's nonzero columns in ascending
//! original-column order (BSB compaction sorts columns; halving and lane
//! extraction preserve that order) and applies the same scalar op sequence
//! as the wide reference kernel, so outputs are bit-identical — the hybrid
//! win is pure packing, not numerics.

use super::bitmap;
use super::bucket::{
    self, PlanStats, DENSE_LANE_CELLS, NARROW_TILE_CELLS, WIDE_TCB_CELLS,
};
use super::reorder::Order;
use super::Bsb;
use crate::graph::CsrGraph;
use crate::{TCB_C, TCB_R};

/// Rows per narrow half-window.
pub const NARROW_ROWS: usize = TCB_R / 2;

/// Tile-count bucket ladder for narrow half-windows (ascending).  The top
/// rung bounds narrow feasibility: a half touching more distinct columns
/// than this stays on the wide path.
pub const NARROW_BUCKETS: &[usize] = &[8, 16, 32, 64, 128, 256, 512, 1024];

/// Minimum occupancy (nnz ÷ rows·distinct-cols) for the dense lane path.
/// Below this, dense lanes ship mostly zeros and the router never prefers
/// them over narrow tiles.
pub const DENSE_OCCUPANCY: f64 = 0.5;

/// Per-row-window shape features the router consumes.  `rows` is the
/// live row count (the last window of a graph may be short).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WindowShape {
    pub rows: usize,
    /// Distinct columns touched by the whole window.
    pub w: usize,
    /// Distinct columns touched by rows \[0, 8).
    pub w0: usize,
    /// Distinct columns touched by rows \[8, 16).
    pub w1: usize,
    /// Nonzeros in the window.
    pub z: usize,
}

/// Which dispatch path a row window takes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RwPath {
    Wide,
    Narrow,
    Dense,
}

/// Router knobs.  The defaults are the production configuration; tests use
/// the flags to force a single geometry (all-wide is the bit-exactness
/// reference).
#[derive(Clone, Copy, Debug)]
pub struct RouteParams {
    pub dense_occupancy: f64,
    pub narrow: bool,
    pub dense: bool,
}

impl Default for RouteParams {
    fn default() -> Self {
        Self { dense_occupancy: DENSE_OCCUPANCY, narrow: true, dense: true }
    }
}

/// Smallest bucket ≥ `t`, or `None` if `t` overflows the ladder.
fn bucket_ceil(buckets: &[usize], t: usize) -> Option<usize> {
    buckets.iter().copied().find(|&b| b >= t)
}

/// Narrow tile cost of one half-window: 0 lanes for an untouched half,
/// otherwise the bucket round-up.  `None` if the half overflows the ladder.
fn narrow_half_tiles(w_half: usize) -> Option<usize> {
    if w_half == 0 {
        Some(0)
    } else {
        bucket_ceil(NARROW_BUCKETS, w_half)
    }
}

/// Dense lane width: distinct columns padded to a multiple of 8 (the lane
/// executables' static width quantum).
#[inline]
fn dense_width(w: usize) -> usize {
    w.div_ceil(TCB_C) * TCB_C
}

/// Route one row window.  Pure function of the shape + the wide bucket
/// ladder, so CSR-side estimates and BSB-side plans agree by construction.
pub fn route(
    shape: &WindowShape,
    wide_buckets: &[usize],
    chunk_t: usize,
    params: &RouteParams,
) -> RwPath {
    if shape.z == 0 {
        return RwPath::Wide; // lands in the wide plan's skipped list
    }
    let t = shape.w.div_ceil(TCB_C);
    let wide_cells = match bucket_ceil(wide_buckets, t) {
        Some(b) => b * WIDE_TCB_CELLS,
        // Oversize windows are chunked; the merge seam only exists on the
        // wide path, so they are never rerouted.
        None => {
            debug_assert!(chunk_t > 0);
            return RwPath::Wide;
        }
    };
    let narrow_cells = if params.narrow {
        match (narrow_half_tiles(shape.w0), narrow_half_tiles(shape.w1)) {
            (Some(t0), Some(t1)) => Some((t0 + t1) * NARROW_TILE_CELLS),
            _ => None,
        }
    } else {
        None
    };
    let occupancy = shape.z as f64 / (shape.rows * shape.w) as f64;
    let dense_cells = if params.dense && occupancy >= params.dense_occupancy {
        Some(dense_width(shape.w) * DENSE_LANE_CELLS)
    } else {
        None
    };
    // Pick the fewest dispatched cells; ties resolve Wide ≤ Dense ≤ Narrow
    // (prefer the path with the least bookkeeping at equal cost).
    let mut best = (wide_cells, RwPath::Wide);
    if let Some(c) = dense_cells {
        if c < best.0 {
            best = (c, RwPath::Dense);
        }
    }
    if let Some(c) = narrow_cells {
        if c < best.0 {
            best = (c, RwPath::Narrow);
        }
    }
    best.1
}

/// Shape of every row window, straight from CSR (no BSB build needed —
/// this is what `GraphProfile` uses).
pub fn window_shapes_from_csr(g: &CsrGraph) -> Vec<WindowShape> {
    let num_rw = g.n.div_ceil(TCB_R);
    let mut shapes = vec![WindowShape::default(); num_rw];
    // Epoch-stamped distinct-column counting: stamp value identifies the
    // (window, half) that last saw the column; no per-window hash sets.
    let mut seen_any = vec![u32::MAX; g.n];
    let mut seen_half = vec![u32::MAX; g.n];
    for (wid, shape) in shapes.iter_mut().enumerate() {
        let base = wid * TCB_R;
        shape.rows = TCB_R.min(g.n - base);
        for half in 0..2 {
            let half_epoch = (wid * 2 + half) as u32;
            let r0 = base + half * NARROW_ROWS;
            let r1 = (r0 + NARROW_ROWS).min(base + shape.rows);
            for r in r0..r1.max(r0) {
                for &c in g.row(r) {
                    let c = c as usize;
                    shape.z += 1;
                    if seen_any[c] != wid as u32 {
                        seen_any[c] = wid as u32;
                        shape.w += 1;
                    }
                    if seen_half[c] != half_epoch {
                        seen_half[c] = half_epoch;
                        if half == 0 {
                            shape.w0 += 1;
                        } else {
                            shape.w1 += 1;
                        }
                    }
                }
            }
        }
    }
    shapes
}

/// Shape of every row window, from the built BSB.  Equal to
/// [`window_shapes_from_csr`] on the same graph for compacted builds
/// (compaction keeps exactly the touched columns, sorted).
pub fn window_shapes_from_bsb(bsb: &Bsb) -> Vec<WindowShape> {
    let mut shapes = vec![WindowShape::default(); bsb.num_rw];
    for (wid, shape) in shapes.iter_mut().enumerate() {
        shape.rows = TCB_R.min(bsb.n - wid * TCB_R);
        for t in 0..bsb.rw_tcbs(wid) {
            let cols = bsb.tcb_cols(wid, t);
            let bm = bsb.tcb_bitmap(wid, t);
            shape.z += bitmap::popcount(bm) as usize;
            for (c, &col) in cols.iter().enumerate() {
                if col == super::builder::PAD_COL {
                    continue;
                }
                shape.w += 1;
                let (lo, hi) = bitmap::col_half_masks(bm, c);
                if lo != 0 {
                    shape.w0 += 1;
                }
                if hi != 0 {
                    shape.w1 += 1;
                }
            }
        }
    }
    shapes
}

/// Column lanes for one geometry: each lane is a `rows`×1 strip of one
/// window, identified by its original column and a row-occupancy mask.
/// Windows not routed to this geometry have zero lanes
/// (`offsets[wid+1] == offsets[wid]`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LaneSet {
    /// Rows per window: [`NARROW_ROWS`] for narrow, [`TCB_R`] for dense.
    /// Window `wid` covers global rows `wid*rows .. wid*rows + rows`.
    pub rows: usize,
    /// Lane offsets per window; len = window count + 1.
    pub offsets: Vec<u32>,
    /// Original column per lane, ascending within each window.
    pub cols: Vec<u32>,
    /// Row mask per lane (bit r ⇔ local row r is a nonzero; low `rows`
    /// bits meaningful).
    pub masks: Vec<u16>,
}

impl LaneSet {
    /// Lane range of window `wid`.
    #[inline]
    pub fn lanes(&self, wid: usize) -> std::ops::Range<usize> {
        self.offsets[wid] as usize..self.offsets[wid + 1] as usize
    }

    /// Number of windows addressable by this set.
    #[inline]
    pub fn num_windows(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }
}

/// One dispatch of a lane executable: ≤ batch windows, each padded to
/// `t_lanes` lanes (zero-mask lanes are numerically inert, exactly like
/// zero-bitmap TCB padding on the wide path).
#[derive(Clone, Debug, PartialEq)]
pub struct LaneCall {
    pub t_lanes: usize,
    pub windows: Vec<u32>,
}

/// A mixed-geometry dispatch plan: the wide bucket plan over wide-routed
/// windows (including all chunked ones), plus narrow and dense lane calls.
/// Row windows are partitioned across the three paths ([`hybrid_covers`]),
/// so the per-path scatters touch disjoint output rows and the merge seam
/// is trivial: no cross-path merge exists, only the wide path's existing
/// chunk merge.
#[derive(Clone, Debug)]
pub struct HybridPlan {
    pub batch: usize,
    pub routes: Vec<RwPath>,
    pub wide: bucket::Plan,
    pub narrow: LaneSet,
    pub narrow_calls: Vec<LaneCall>,
    pub dense: LaneSet,
    pub dense_calls: Vec<LaneCall>,
    /// Combined accounting: the wide plan's stats plus narrow/dense fields.
    pub stats: PlanStats,
}

/// Build the mixed-geometry plan.  `buckets`/`batch`/`order`/`chunk_t` are
/// the wide path's knobs, identical to [`bucket::plan`]'s.
pub fn plan_hybrid(
    bsb: &Bsb,
    buckets: &[usize],
    batch: usize,
    order: Order,
    chunk_t: usize,
) -> HybridPlan {
    plan_hybrid_with(bsb, buckets, batch, order, chunk_t, &RouteParams::default())
}

/// [`plan_hybrid`] with explicit router knobs (tests force single-geometry
/// references through this).
pub fn plan_hybrid_with(
    bsb: &Bsb,
    buckets: &[usize],
    batch: usize,
    order: Order,
    chunk_t: usize,
    params: &RouteParams,
) -> HybridPlan {
    let shapes = window_shapes_from_bsb(bsb);
    let routes: Vec<RwPath> = shapes
        .iter()
        .map(|s| route(s, buckets, chunk_t, params))
        .collect();

    let wide = bucket::plan_filtered(bsb, buckets, batch, order, chunk_t, |rw| {
        routes[rw as usize] == RwPath::Wide
    });
    let mut stats = wide.stats.clone();

    let (narrow, narrow_calls) = build_narrow(bsb, &routes, batch, &mut stats);
    let (dense, dense_calls) = build_dense(bsb, &routes, batch, &mut stats);

    HybridPlan {
        batch,
        routes,
        wide,
        narrow,
        narrow_calls,
        dense,
        dense_calls,
        stats,
    }
}

/// Extract the narrow lane set + calls for narrow-routed windows.
fn build_narrow(
    bsb: &Bsb,
    routes: &[RwPath],
    batch: usize,
    stats: &mut PlanStats,
) -> (LaneSet, Vec<LaneCall>) {
    let mut set = LaneSet {
        rows: NARROW_ROWS,
        offsets: Vec::with_capacity(bsb.num_rw * 2 + 1),
        ..LaneSet::default()
    };
    set.offsets.push(0);
    // Open batch per tile bucket, flushed at `batch` windows.
    let mut open: Vec<Vec<u32>> = vec![Vec::new(); NARROW_BUCKETS.len()];
    let mut calls = Vec::new();
    for rw in 0..bsb.num_rw {
        for half in 0..2 {
            let wid = (rw * 2 + half) as u32;
            if routes[rw] == RwPath::Narrow {
                let before = set.cols.len();
                for t in 0..bsb.rw_tcbs(rw) {
                    let cols = bsb.tcb_cols(rw, t);
                    let bm = bsb.tcb_bitmap(rw, t);
                    for (c, &col) in cols.iter().enumerate() {
                        if col == super::builder::PAD_COL {
                            continue;
                        }
                        let (lo, hi) = bitmap::col_half_masks(bm, c);
                        let m = if half == 0 { lo } else { hi };
                        if m != 0 {
                            set.cols.push(col);
                            set.masks.push(m as u16);
                        }
                    }
                }
                let lanes = set.cols.len() - before;
                if lanes > 0 {
                    let bi = NARROW_BUCKETS
                        .iter()
                        .position(|&b| b >= lanes)
                        .unwrap_or(NARROW_BUCKETS.len() - 1);
                    stats.real_narrow_tiles += lanes;
                    stats.padded_narrow_tiles += NARROW_BUCKETS[bi] - lanes;
                    open[bi].push(wid);
                    if open[bi].len() == batch {
                        calls.push(LaneCall {
                            t_lanes: NARROW_BUCKETS[bi],
                            windows: std::mem::take(&mut open[bi]),
                        });
                    }
                }
            }
            set.offsets.push(set.cols.len() as u32);
        }
        if routes[rw] == RwPath::Narrow {
            stats.narrow_windows += 1;
        }
    }
    for (bi, windows) in open.into_iter().enumerate() {
        if !windows.is_empty() {
            stats.padded_narrow_slot_tiles += (batch - windows.len()) * NARROW_BUCKETS[bi];
            calls.push(LaneCall { t_lanes: NARROW_BUCKETS[bi], windows });
        }
    }
    stats.n_narrow_calls = calls.len();
    (set, calls)
}

/// Extract the dense lane set + calls for dense-routed windows.  Windows
/// batch with others of the same padded width (static-shape executables).
fn build_dense(
    bsb: &Bsb,
    routes: &[RwPath],
    batch: usize,
    stats: &mut PlanStats,
) -> (LaneSet, Vec<LaneCall>) {
    let mut set = LaneSet {
        rows: TCB_R,
        offsets: Vec::with_capacity(bsb.num_rw + 1),
        ..LaneSet::default()
    };
    set.offsets.push(0);
    let mut open: std::collections::BTreeMap<usize, Vec<u32>> =
        std::collections::BTreeMap::new();
    let mut calls = Vec::new();
    for rw in 0..bsb.num_rw {
        if routes[rw] == RwPath::Dense {
            let before = set.cols.len();
            for t in 0..bsb.rw_tcbs(rw) {
                let cols = bsb.tcb_cols(rw, t);
                let bm = bsb.tcb_bitmap(rw, t);
                for (c, &col) in cols.iter().enumerate() {
                    if col == super::builder::PAD_COL {
                        continue;
                    }
                    set.cols.push(col);
                    set.masks.push(bitmap::col_mask(bm, c));
                }
            }
            let w = set.cols.len() - before;
            debug_assert!(w > 0, "dense-routed window has no columns");
            let t_lanes = dense_width(w);
            stats.dense_windows += 1;
            stats.dense_cols += w;
            stats.padded_dense_cols += t_lanes - w;
            let slot = open.entry(t_lanes).or_default();
            slot.push(rw as u32);
            if slot.len() == batch {
                let windows = std::mem::take(slot);
                calls.push(LaneCall { t_lanes, windows });
            }
        }
        set.offsets.push(set.cols.len() as u32);
    }
    for (t_lanes, windows) in open {
        if !windows.is_empty() {
            stats.padded_dense_slot_cols += (batch - windows.len()) * t_lanes;
            calls.push(LaneCall { t_lanes, windows });
        }
    }
    stats.n_dense_calls = calls.len();
    (set, calls)
}

/// Coverage invariant: the three paths partition the row windows, every
/// dispatched lane/call references a window of its own path, and the total
/// nonzeros across paths reconstruct the BSB's nnz exactly.
pub fn hybrid_covers(bsb: &Bsb, plan: &HybridPlan) -> bool {
    if plan.routes.len() != bsb.num_rw {
        return false;
    }
    // Wide plan covers exactly the wide-routed windows.
    let mut wide_seen = vec![false; bsb.num_rw];
    let mut mark = |rw: u32| {
        let rw = rw as usize;
        if rw >= wide_seen.len() || wide_seen[rw] {
            return false;
        }
        wide_seen[rw] = true;
        true
    };
    for c in &plan.wide.calls {
        for &rw in &c.rws {
            if !mark(rw) {
                return false;
            }
        }
    }
    for c in &plan.wide.chunked {
        if !mark(c.rw) {
            return false;
        }
    }
    for &rw in &plan.wide.skipped {
        if !mark(rw) {
            return false;
        }
    }
    for (rw, route) in plan.routes.iter().enumerate() {
        if wide_seen[rw] != (*route == RwPath::Wide) {
            return false;
        }
        // Lane sets hold lanes only for their own path's windows.
        let narrow_lanes = plan.narrow.lanes(rw * 2).len() + plan.narrow.lanes(rw * 2 + 1).len();
        if (narrow_lanes > 0) != (*route == RwPath::Narrow) {
            return false;
        }
        if (!plan.dense.lanes(rw).is_empty()) != (*route == RwPath::Dense) {
            return false;
        }
    }
    // Every call window is in range and dispatched at most once, with
    // enough lane capacity.
    let check_calls = |set: &LaneSet, calls: &[LaneCall]| {
        let mut seen = vec![false; set.num_windows()];
        for c in calls {
            for &wid in &c.windows {
                let wid = wid as usize;
                if wid >= seen.len() || seen[wid] || set.lanes(wid).len() > c.t_lanes {
                    return false;
                }
                seen[wid] = true;
            }
        }
        // Every window with lanes is dispatched.
        (0..set.num_windows()).all(|wid| seen[wid] || set.lanes(wid).is_empty())
    };
    if !check_calls(&plan.narrow, &plan.narrow_calls)
        || !check_calls(&plan.dense, &plan.dense_calls)
    {
        return false;
    }
    // nnz conservation across the three paths.
    let wide_nnz: usize = (0..bsb.num_rw)
        .filter(|&rw| plan.routes[rw] == RwPath::Wide)
        .map(|rw| {
            (0..bsb.rw_tcbs(rw))
                .map(|t| bitmap::popcount(bsb.tcb_bitmap(rw, t)) as usize)
                .sum::<usize>()
        })
        .sum();
    let lane_nnz = |set: &LaneSet| -> usize {
        set.masks.iter().map(|m| m.count_ones() as usize).sum()
    };
    wide_nnz + lane_nnz(&plan.narrow) + lane_nnz(&plan.dense) == bsb.nnz
}

/// Batch-free cell estimate of a hybrid plan, from shapes alone — the
/// `GraphProfile` side of the profile↔plan pinning contract.  Equals
/// `plan_hybrid(..).stats.structural_cells()` on the same graph.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HybridCells {
    pub structural_cells: usize,
    /// Structural padding cells only (no batch-slot term, which needs the
    /// dispatch batch size).
    pub padded_cells: usize,
    pub narrow_rws: usize,
    pub dense_rws: usize,
}

/// Estimate hybrid cells from window shapes (CSR- or BSB-derived).
pub fn hybrid_cells(
    shapes: &[WindowShape],
    wide_buckets: &[usize],
    chunk_t: usize,
    params: &RouteParams,
) -> HybridCells {
    let mut out = HybridCells::default();
    for s in shapes {
        if s.z == 0 {
            continue;
        }
        match route(s, wide_buckets, chunk_t, params) {
            RwPath::Wide => {
                let t = s.w.div_ceil(TCB_C);
                let slots = match bucket_ceil(wide_buckets, t) {
                    Some(b) => b,
                    None => t.div_ceil(chunk_t) * chunk_t,
                };
                out.structural_cells += slots * WIDE_TCB_CELLS;
                out.padded_cells += (slots - t) * WIDE_TCB_CELLS;
            }
            RwPath::Narrow => {
                let t0 = narrow_half_tiles(s.w0).unwrap_or(0);
                let t1 = narrow_half_tiles(s.w1).unwrap_or(0);
                out.structural_cells += (t0 + t1) * NARROW_TILE_CELLS;
                out.padded_cells += (t0 + t1 - s.w0 - s.w1) * NARROW_TILE_CELLS;
                out.narrow_rws += 1;
            }
            RwPath::Dense => {
                let width = dense_width(s.w);
                out.structural_cells += width * DENSE_LANE_CELLS;
                out.padded_cells += (width - s.w) * DENSE_LANE_CELLS;
                out.dense_rws += 1;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsb::build;
    use crate::graph::generators;

    const BUCKETS: &[usize] = &[4, 8, 16, 32, 64, 128];

    fn shapes_agree(g: &CsrGraph) {
        let bsb = build(g);
        assert_eq!(window_shapes_from_csr(g), window_shapes_from_bsb(&bsb));
    }

    #[test]
    fn csr_and_bsb_shapes_agree() {
        shapes_agree(&generators::erdos_renyi(777, 5.0, 1).with_self_loops());
        shapes_agree(&generators::star(300).with_self_loops());
        shapes_agree(&generators::power_law(1000, 6.0, 2.5, 3));
        shapes_agree(&generators::sbm(20, 30, 0.4, 0.02, 4).with_self_loops());
        shapes_agree(&generators::ring(33)); // short last window
    }

    #[test]
    fn star_leaves_route_dense() {
        let g = generators::star(5000);
        let shapes = window_shapes_from_csr(&g);
        let p = RouteParams::default();
        // Hub window (RW 0) is oversize -> wide/chunked.
        assert_eq!(route(&shapes[0], BUCKETS, 128, &p), RwPath::Wide);
        // Leaf windows: 16 rows × 1 shared column -> occupancy 1.0 -> dense
        // at 8×16 = 128 cells vs. wide's 4×128 = 512.
        assert_eq!(route(&shapes[10], BUCKETS, 128, &p), RwPath::Dense);
    }

    #[test]
    fn scattered_windows_route_narrow() {
        // ER deg 6: each window touches ~90 distinct columns with ~96 nnz;
        // wide pays a 16-TCB bucket (2048 cells), narrow two ~64-tile
        // halves (~1024 cells), dense is occupancy-ineligible.
        let g = generators::erdos_renyi(2048, 6.0, 7).with_self_loops();
        let shapes = window_shapes_from_csr(&g);
        let p = RouteParams::default();
        let narrow = shapes
            .iter()
            .filter(|s| route(s, BUCKETS, 128, &p) == RwPath::Narrow)
            .count();
        assert!(
            narrow > shapes.len() / 2,
            "only {narrow}/{} windows routed narrow",
            shapes.len()
        );
    }

    #[test]
    fn disabled_paths_force_wide() {
        let g = generators::star(2000);
        let bsb = build(&g);
        let off = RouteParams { narrow: false, dense: false, ..RouteParams::default() };
        let p = plan_hybrid_with(&bsb, BUCKETS, 8, Order::ByTcbDesc, 128, &off);
        assert!(p.routes.iter().all(|r| *r == RwPath::Wide));
        assert!(p.narrow_calls.is_empty() && p.dense_calls.is_empty());
        assert!(hybrid_covers(&bsb, &p));
        // All-wide hybrid accounting matches the plain wide plan.
        let wide = bucket::plan(&bsb, BUCKETS, 8, Order::ByTcbDesc, 128);
        assert_eq!(p.stats, wide.stats);
    }

    #[test]
    fn hybrid_covers_generators() {
        for g in [
            generators::erdos_renyi(1500, 5.0, 5).with_self_loops(),
            generators::star(3000).with_self_loops(),
            generators::power_law(2000, 8.0, 2.2, 6),
            generators::sbm(30, 30, 0.4, 0.02, 7).with_self_loops(),
        ] {
            let bsb = build(&g);
            let p = plan_hybrid(&bsb, BUCKETS, 8, Order::ByTcbDesc, 128);
            assert!(hybrid_covers(&bsb, &p), "coverage failed n={}", g.n);
        }
    }

    #[test]
    fn hybrid_cells_estimate_matches_plan_exactly() {
        for g in [
            generators::erdos_renyi(1024, 6.0, 9).with_self_loops(),
            generators::star(4000),
            generators::power_law(1500, 7.0, 2.4, 10),
        ] {
            let bsb = build(&g);
            let p = plan_hybrid(&bsb, BUCKETS, 8, Order::ByTcbDesc, 128);
            let est = hybrid_cells(
                &window_shapes_from_csr(&g),
                BUCKETS,
                128,
                &RouteParams::default(),
            );
            assert_eq!(est.structural_cells, p.stats.structural_cells());
            assert_eq!(est.narrow_rws, p.stats.narrow_windows);
            assert_eq!(est.dense_rws, p.stats.dense_windows);
        }
    }

    #[test]
    fn hub_skewed_graphs_cut_padded_cells_by_30_percent() {
        // Exact expected ratios: scripts/packing_model.py reproduces this
        // arithmetic in Python (star ≈ 0.51, power_law ≈ 0.50).  Note the
        // star must NOT carry self loops here: with a dense diagonal the
        // leaf windows widen to 17 columns and the narrow ladder's
        // round-up nearly cancels the wide bucket's, leaving only a ~5%
        // cut — the win comes from hub-dominated *shared-column* windows.
        for g in [
            generators::star(5000),
            generators::power_law(4096, 4.0, 2.5, 11),
        ] {
            let bsb = build(&g);
            let wide = bucket::plan(&bsb, BUCKETS, 8, Order::ByTcbDesc, 128);
            let hybrid = plan_hybrid(&bsb, BUCKETS, 8, Order::ByTcbDesc, 128);
            let (w, h) = (wide.stats.padded_cells(), hybrid.stats.padded_cells());
            assert!(
                (h as f64) <= 0.7 * w as f64,
                "padded cells {h} vs wide {w} (n={})",
                g.n
            );
        }
    }
}
