//! Host tensors crossing the Rust↔PJRT boundary.

use anyhow::{bail, Result};

use super::manifest::{DType, TensorSpec};

/// A borrowed executable argument — the zero-copy hot-path type: the
/// runtime uploads straight from the borrowed slice into a PJRT device
/// buffer (one copy total, no intermediate Literal).
#[derive(Clone, Copy, Debug)]
pub enum Arg<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

impl<'a> Arg<'a> {
    pub fn shape(&self) -> &'a [usize] {
        match self {
            Arg::F32(_, s) | Arg::I32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Arg::F32(..) => DType::F32,
            Arg::I32(..) => DType::I32,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn len(&self) -> usize {
        match self {
            Arg::F32(d, _) => d.len(),
            Arg::I32(d, _) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Validate against a manifest input spec.
    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        if self.dtype() != spec.dtype {
            bail!("dtype mismatch: {:?} vs {:?}", self.dtype(), spec.dtype);
        }
        if self.shape() != spec.shape.as_slice() {
            bail!("shape mismatch: {:?} vs {:?}", self.shape(), spec.shape);
        }
        if self.len() != self.numel() {
            bail!("data length {} != shape numel {}", self.len(), self.numel());
        }
        Ok(())
    }
}

/// A host-side tensor (row-major).
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> Tensor {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::F32(data, shape)
    }

    pub fn i32(data: Vec<i32>, shape: Vec<usize>) -> Tensor {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::I32(data, shape)
    }

    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        Tensor::F32(vec![0.0; shape.iter().product()], shape.to_vec())
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32(_, s) | Tensor::I32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32(..) => DType::F32,
            Tensor::I32(..) => DType::I32,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(d, _) => Ok(d),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(d, _) => Ok(d),
            _ => bail!("expected i32 tensor"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32(d, _) => Ok(d),
            _ => bail!("expected f32 tensor"),
        }
    }

    /// Borrow as a zero-copy argument.
    pub fn as_arg(&self) -> Arg<'_> {
        match self {
            Tensor::F32(d, s) => Arg::F32(d, s),
            Tensor::I32(d, s) => Arg::I32(d, s),
        }
    }

    /// Validate against a manifest input spec.
    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        if self.dtype() != spec.dtype {
            bail!("dtype mismatch: {:?} vs {:?}", self.dtype(), spec.dtype);
        }
        if self.shape() != spec.shape.as_slice() {
            bail!(
                "shape mismatch: {:?} vs {:?}",
                self.shape(),
                spec.shape
            );
        }
        Ok(())
    }

    /// Build the PJRT literal (one copy across the C boundary).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let (ty, bytes): (xla::ElementType, &[u8]) = match self {
            Tensor::F32(d, _) => (xla::ElementType::F32, bytemuck_f32(d)),
            Tensor::I32(d, _) => (xla::ElementType::S32, bytemuck_i32(d)),
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            ty,
            self.shape(),
            bytes,
        )?)
    }

    /// Read back from a PJRT literal (shape taken from the literal).
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::F32(lit.to_vec::<f32>()?, dims)),
            xla::ElementType::S32 => Ok(Tensor::I32(lit.to_vec::<i32>()?, dims)),
            ty => bail!("unsupported output element type {ty:?}"),
        }
    }
}

fn bytemuck_f32(d: &[f32]) -> &[u8] {
    // Safe: f32 has no invalid bit patterns and alignment of u8 is 1.
    unsafe { std::slice::from_raw_parts(d.as_ptr() as *const u8, d.len() * 4) }
}

fn bytemuck_i32(d: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(d.as_ptr() as *const u8, d.len() * 4) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.numel(), 4);
        assert_eq!(t.dtype(), DType::F32);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn spec_check() {
        let t = Tensor::i32(vec![0; 6], vec![2, 3]);
        let good = TensorSpec { shape: vec![2, 3], dtype: DType::I32 };
        let bad_shape = TensorSpec { shape: vec![3, 2], dtype: DType::I32 };
        let bad_type = TensorSpec { shape: vec![2, 3], dtype: DType::F32 };
        assert!(t.check(&good).is_ok());
        assert!(t.check(&bad_shape).is_err());
        assert!(t.check(&bad_type).is_err());
    }

    #[test]
    fn zeros() {
        let t = Tensor::zeros_f32(&[3, 5]);
        assert_eq!(t.numel(), 15);
        assert!(t.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::f32(vec![1.5, -2.0, 0.0, 7.25, 3.0, -1.0], vec![2, 3]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
        let ti = Tensor::i32(vec![1, -2, i32::MAX, i32::MIN], vec![4]);
        let back = Tensor::from_literal(&ti.to_literal().unwrap()).unwrap();
        assert_eq!(ti, back);
    }
}
