//! The PJRT runtime: lazy-compiling executable cache over the manifest.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

use super::buffers::{Arg, Tensor};
use super::manifest::{ExecutableSpec, Manifest};

/// Execution statistics (dispatch counting for the metrics/bench layer).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub executions: u64,
    pub compiles: u64,
    pub bytes_uploaded: u64,
    pub bytes_downloaded: u64,
}

/// A compiled executable plus its manifest spec.
pub struct Executable {
    spec: ExecutableSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT client + manifest + executable cache.  Single-threaded by design:
/// the serving loop owns one `Runtime` on a dedicated executor thread.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn new(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    /// Default artifact location: `<crate root>/artifacts`.
    pub fn from_default_artifacts() -> Result<Runtime> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Runtime::new(&dir)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.borrow().clone()
    }

    pub fn reset_stats(&self) {
        *self.stats.borrow_mut() = RuntimeStats::default();
    }

    /// Get (compiling and caching on first use) an executable by name.
    pub fn executable(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.spec(name)?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("loading {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.stats.borrow_mut().compiles += 1;
        let e = Rc::new(Executable { spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Pre-compile a list of executables (hides compile latency at startup).
    pub fn warmup(&self, names: &[String]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute by name with input validation; returns the output tensors.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let exe = self.executable(name)?;
        self.run_exe(&exe, inputs)
    }

    /// Execute a cached executable from owned tensors.
    pub fn run_exe(
        &self,
        exe: &Executable,
        inputs: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        let args: Vec<Arg> = inputs.iter().map(|t| t.as_arg()).collect();
        self.run_exe_raw(exe, &args)
    }

    /// Hot-path execution from borrowed slices: each input is uploaded
    /// directly into a PJRT device buffer (`buffer_from_host_buffer` +
    /// `execute_b`).  NOTE: the Literal-based `execute` path of
    /// xla_extension 0.5.1 leaks the device copies of its input literals
    /// (~input size per call, measured in EXPERIMENTS.md §Perf); the
    /// buffer path does not, and also saves the host-side literal copy.
    pub fn run_exe_raw(
        &self,
        exe: &Executable,
        inputs: &[Arg],
    ) -> Result<Vec<Tensor>> {
        let spec = &exe.spec;
        if inputs.len() != spec.inputs.len() {
            anyhow::bail!(
                "{}: expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        let mut uploaded = 0u64;
        let mut bufs = Vec::with_capacity(inputs.len());
        for (a, s) in inputs.iter().zip(&spec.inputs) {
            a.check(s)
                .with_context(|| format!("input to {}", spec.name))?;
            uploaded += (a.numel() * 4) as u64;
            let buf = match a {
                Arg::F32(d, shape) => {
                    self.client.buffer_from_host_buffer::<f32>(d, shape, None)?
                }
                Arg::I32(d, shape) => {
                    self.client.buffer_from_host_buffer::<i32>(d, shape, None)?
                }
            };
            bufs.push(buf);
        }
        let result = exe.exe.execute_b::<xla::PjRtBuffer>(&bufs)?;
        // Lowered with return_tuple=True: single tuple output on device 0.
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        anyhow::ensure!(
            parts.len() == spec.n_outputs,
            "{}: expected {} outputs, got {}",
            spec.name,
            spec.n_outputs,
            parts.len()
        );
        let mut outs = Vec::with_capacity(parts.len());
        let mut downloaded = 0u64;
        for p in &parts {
            let t = Tensor::from_literal(p)?;
            downloaded += (t.numel() * 4) as u64;
            outs.push(t);
        }
        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.bytes_uploaded += uploaded;
        st.bytes_downloaded += downloaded;
        Ok(outs)
    }
}

impl Executable {
    pub fn spec(&self) -> &ExecutableSpec {
        &self.spec
    }
}

// Tests that need real artifacts live in rust/tests/runtime_integration.rs;
// unit-level behaviour (manifest validation, tensor checks) is covered in
// the sibling modules.
