//! PJRT runtime: load AOT HLO-text artifacts and execute them from Rust.
//!
//! The wiring follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  Executables compile lazily on first use
//! and are cached for the life of the [`client::Runtime`].
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serialises protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see `python/compile/aot.py`).

pub mod buffers;
pub mod client;
pub mod manifest;

pub use buffers::Tensor;
pub use client::Runtime;
pub use manifest::{DType, ExecutableSpec, Manifest, TensorSpec};
