//! The AOT manifest: what executables exist, their shapes, and the global
//! bucketing configuration the coordinator must follow.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Element type of an executable input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            _ => bail!("unknown dtype '{s}'"),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// Shape + dtype of one executable input.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One entry of `manifest.json`.
#[derive(Clone, Debug)]
pub struct ExecutableSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub n_outputs: usize,
    /// kind-specific parameters (t, d, dv, b, precision, …) kept as JSON.
    pub params: Json,
}

impl ExecutableSpec {
    pub fn param_usize(&self, key: &str) -> Result<usize> {
        self.params.req(key)?.as_usize()
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub rw_batch: usize,
    pub t_buckets: Vec<usize>,
    pub d_kernel: Vec<usize>,
    pub d_model: Vec<usize>,
    pub m_tile: usize,
    pub chunk_t: usize,
    pub d_head: usize,
    pub entries: BTreeMap<String, ExecutableSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let v = Json::parse(text).context("parsing manifest.json")?;
        let version = v.req("version")?.as_usize()?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut entries = BTreeMap::new();
        for e in v.req("executables")?.as_arr()? {
            let name = e.req("name")?.as_str()?.to_string();
            let mut inputs = Vec::new();
            for i in e.req("inputs")?.as_arr()? {
                inputs.push(TensorSpec {
                    shape: i.req("shape")?.usize_arr()?,
                    dtype: DType::parse(i.req("dtype")?.as_str()?)?,
                });
            }
            entries.insert(
                name.clone(),
                ExecutableSpec {
                    file: e.req("file")?.as_str()?.to_string(),
                    inputs,
                    n_outputs: e.req("n_outputs")?.as_usize()?,
                    params: e.req("params")?.clone(),
                    name,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            rw_batch: v.req("rw_batch")?.as_usize()?,
            t_buckets: v.req("t_buckets")?.usize_arr()?,
            d_kernel: v.req("d_kernel")?.usize_arr()?,
            d_model: v.req("d_model")?.usize_arr()?,
            m_tile: v.req("m_tile")?.as_usize()?,
            chunk_t: v.req("chunk_t")?.as_usize()?,
            d_head: v.req("d_head")?.as_usize()?,
            entries,
        })
    }

    pub fn spec(&self, name: &str) -> Result<&ExecutableSpec> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("no executable '{name}' in manifest"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    /// Smallest bucket with capacity >= t (None if t exceeds all buckets).
    pub fn bucket_for(&self, t: usize) -> Option<usize> {
        self.t_buckets.iter().copied().find(|&b| b >= t)
    }

    // -- canonical artifact names (kept in sync with aot.py) ---------------

    pub fn fused3s_name(t: usize, d: usize, precision: &str, variant: &str) -> String {
        match (precision, variant) {
            ("bf16", "splitc") => format!("fused3s_t{t}_d{d}"),
            ("f32", "splitc") => format!("fused3s_f32nc_t{t}_d{d}"),
            ("bf16", "splitr") => format!("fused3s_splitr_t{t}_d{d}"),
            _ => format!("fused3s_{precision}_{variant}_t{t}_d{d}"),
        }
    }

    pub fn partial_name(t: usize, d: usize) -> String {
        format!("fused3s_partial_t{t}_d{d}")
    }

    pub fn gat_name(t: usize, dv: usize) -> String {
        format!("fused3s_gat_t{t}_dv{dv}")
    }

    pub fn sddmm_name(t: usize, d: usize) -> String {
        format!("sddmm_t{t}_d{d}")
    }

    pub fn softmax_name(t: usize, stable: bool) -> String {
        if stable {
            format!("softmax_stable_t{t}")
        } else {
            format!("softmax_naive_t{t}")
        }
    }

    pub fn spmm_name(t: usize, d: usize) -> String {
        format!("spmm_t{t}_d{d}")
    }

    pub fn dense_name(n: usize, d: usize) -> String {
        format!("dense_n{n}_d{d}")
    }

    pub fn qkv_name(m: usize, d: usize) -> String {
        format!("qkv_proj_m{m}_d{d}")
    }

    pub fn linear_name(m: usize, d: usize) -> String {
        format!("linear_m{m}_d{d}")
    }

    pub fn ffn_name(m: usize, d: usize) -> String {
        format!("ffn_m{m}_d{d}")
    }

    pub fn add_ln_name(m: usize, d: usize) -> String {
        format!("add_ln_m{m}_d{d}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
 "version": 1, "rw_batch": 32, "t_buckets": [4, 8], "d_kernel": [32],
 "d_model": [64], "m_tile": 1024, "chunk_t": 128, "d_head": 32,
 "tcb_r": 16, "tcb_c": 8, "bitmap_words": 4,
 "executables": [
  {"name": "fused3s_t4_d32", "file": "fused3s_t4_d32.hlo.txt",
   "params": {"kind": "fused3s", "t": 4, "d": 32, "b": 32},
   "inputs": [
    {"shape": [32, 16, 32], "dtype": "f32"},
    {"shape": [32, 32, 32], "dtype": "f32"},
    {"shape": [32, 32, 32], "dtype": "f32"},
    {"shape": [32, 4, 4], "dtype": "i32"}],
   "n_outputs": 1}
 ]}"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.rw_batch, 32);
        assert_eq!(m.t_buckets, vec![4, 8]);
        let s = m.spec("fused3s_t4_d32").unwrap();
        assert_eq!(s.inputs.len(), 4);
        assert_eq!(s.inputs[3].dtype, DType::I32);
        assert_eq!(s.param_usize("t").unwrap(), 4);
    }

    #[test]
    fn bucket_selection() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.bucket_for(1), Some(4));
        assert_eq!(m.bucket_for(4), Some(4));
        assert_eq!(m.bucket_for(5), Some(8));
        assert_eq!(m.bucket_for(9), None);
    }

    #[test]
    fn missing_executable_is_error() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert!(m.spec("nope").is_err());
        assert!(!m.has("nope"));
    }

    #[test]
    fn names_match_aot_convention() {
        assert_eq!(
            Manifest::fused3s_name(8, 64, "bf16", "splitc"),
            "fused3s_t8_d64"
        );
        assert_eq!(
            Manifest::fused3s_name(8, 64, "f32", "splitc"),
            "fused3s_f32nc_t8_d64"
        );
        assert_eq!(Manifest::partial_name(128, 32), "fused3s_partial_t128_d32");
        assert_eq!(Manifest::softmax_name(4, false), "softmax_naive_t4");
    }

    #[test]
    fn real_manifest_if_present() {
        // When artifacts are built, validate the real file parses and has the
        // kernel suite.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.has("fused3s_t4_d32"));
            assert!(m.has(&Manifest::partial_name(m.chunk_t, 64)));
        }
    }
}
