//! Deterministic PRNG (splitmix64 seeding + xoshiro256** core).
//!
//! Every stochastic component of the reproduction — graph generators, weight
//! initialisation, property-test case generation, workload traces — draws
//! from this generator, so every experiment is reproducible from a seed.

/// xoshiro256** with splitmix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for parallel / per-component use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Bernoulli(p).
    pub fn coin(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample from a power-law-ish discrete distribution over [0, n) by
    /// inverse transform on p(k) ∝ (k+1)^-alpha.  Used by generators that
    /// need heavy-tailed degree targets.
    pub fn zipf(&mut self, n: usize, alpha: f64) -> usize {
        // Rejection-free approximate inverse CDF; fine for workload synthesis.
        // x lands in [1, n]; shift to [0, n).
        let u = self.f64();
        let x = ((n as f64).powf(1.0 - alpha) * u + (1.0 - u)).powf(1.0 / (1.0 - alpha));
        (x.floor().max(1.0) as usize - 1).min(n - 1)
    }

    /// Vector of standard-normal f32 (weights, features).
    pub fn normal_vec(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| self.normal_f32() * scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_valid() {
        let mut r = Rng::new(13);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i as usize]);
            seen[i as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn zipf_is_heavy_tailed() {
        let mut r = Rng::new(17);
        let xs: Vec<usize> = (0..50_000).map(|_| r.zipf(1000, 2.0)).collect();
        let zeros = xs.iter().filter(|&&x| x == 0).count();
        let large = xs.iter().filter(|&&x| x > 100).count();
        assert!(zeros > large, "zipf(2.0) should concentrate at small values");
        assert!(large > 0, "but still have a tail");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
