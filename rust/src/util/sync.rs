//! Poison-tolerant synchronisation helpers.
//!
//! Every shared structure in the serving path (buffer arena, plan cache,
//! metrics, cost model) holds plain data whose invariants are restored by
//! the next writer, so a mutex poisoned by a panicking worker must not
//! cascade: [`lock_unpoisoned`] recovers the guard and lets serving
//! continue.  Structures whose partial updates *would* be unsound must not
//! use this helper — none exist in this crate today (see DESIGN.md §11).

use std::sync::{Mutex, MutexGuard};

/// Lock `m`, recovering the guard if a previous holder panicked.  The
/// protected value is whatever the panicking thread left behind; callers
/// must only protect state that every operation leaves structurally valid
/// (counters, free lists, maps with atomic insert/remove).
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn recovers_after_a_panicking_holder() {
        let m = Mutex::new(7u32);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("poison it");
        }));
        assert!(r.is_err());
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        let mut g = lock_unpoisoned(&m);
        assert_eq!(*g, 7);
        *g = 8;
        drop(g);
        assert_eq!(*lock_unpoisoned(&m), 8);
    }
}
