//! Bench harness: warmup + timed iterations + robust summary statistics.
//!
//! criterion is unavailable offline; this is the measurement core used by
//! every `cargo bench` target and the experiment binaries.  Reported numbers
//! are medians with p10/p90 spread over per-iteration wall-clock times.

use std::time::{Duration, Instant};

use super::stats;

/// Summary of one benchmarked operation.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn median_ms(&self) -> f64 {
        self.median_s * 1e3
    }

    pub fn median_us(&self) -> f64 {
        self.median_s * 1e6
    }

    /// One human-readable row, used by the bench binaries.
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>12.3} ms  (p10 {:>10.3}, p90 {:>10.3}, n={})",
            self.name,
            self.median_ms(),
            self.p10_s * 1e3,
            self.p90_s * 1e3,
            self.iters
        )
    }
}

/// Measurement configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop adding iterations once this much total time is spent measuring.
    pub target_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 200,
            target_time: Duration::from_secs(2),
        }
    }
}

impl BenchConfig {
    /// Faster settings for CI / smoke runs.
    pub fn quick() -> Self {
        BenchConfig {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            target_time: Duration::from_millis(300),
        }
    }
}

/// Time `f` under `cfg`, returning robust summary statistics.
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.min_iters);
    let start = Instant::now();
    while samples.len() < cfg.max_iters
        && (samples.len() < cfg.min_iters || start.elapsed() < cfg.target_time)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(name, &samples)
}

/// Build a [`BenchResult`] from raw per-iteration samples.
pub fn summarize(name: &str, samples: &[f64]) -> BenchResult {
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchResult {
        name: name.to_string(),
        iters: v.len(),
        median_s: stats::percentile_sorted(&v, 50.0),
        mean_s: stats::mean(&v),
        p10_s: stats::percentile_sorted(&v, 10.0),
        p90_s: stats::percentile_sorted(&v, 90.0),
        min_s: v.first().copied().unwrap_or(0.0),
    }
}

/// Simple scoped timer for coarse phase measurements.
pub struct Timer {
    t0: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { t0: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0usize;
        let cfg = BenchConfig {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 5,
            target_time: Duration::from_millis(1),
        };
        let r = bench("noop", &cfg, || n += 1);
        assert_eq!(r.iters, 5);
        assert_eq!(n, 7); // warmup + measured
    }

    #[test]
    fn summarize_orders_samples() {
        let r = summarize("x", &[3.0, 1.0, 2.0]);
        assert_eq!(r.median_s, 2.0);
        assert_eq!(r.min_s, 1.0);
        assert_eq!(r.iters, 3);
    }

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }
}
