//! Descriptive statistics used across dataset characterisation (Table 6/7)
//! and the bench harness (mean / CV / percentiles / geomean).

/// Mean of a slice (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation σ/μ — the paper's irregularity metric (Table 6).
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        return 0.0;
    }
    std_dev(xs) / m
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, p)
}

/// p-th percentile of an already-sorted slice.
pub fn percentile_sorted(v: &[f64], p: f64) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Geometric mean (the paper's cross-dataset speedup summary).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Decile (min, max) ranges of a value distribution — the paper's Table 7.
/// Sorts ascending, splits into 10 equal-size groups, reports each group's
/// (min, max).  Returns fewer groups for n < 10.
pub fn decile_ranges(xs: &[f64]) -> Vec<(f64, f64)> {
    if xs.is_empty() {
        return vec![];
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let groups = 10.min(v.len());
    let mut out = Vec::with_capacity(groups);
    for g in 0..groups {
        let lo = g * v.len() / groups;
        let hi = ((g + 1) * v.len() / groups).max(lo + 1);
        out.push((v[lo], v[hi - 1]));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_cv() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
        assert!((cv(&xs) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let xs = [2.0, 8.0];
        assert!((geomean(&xs) - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn deciles_cover_range() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let d = decile_ranges(&xs);
        assert_eq!(d.len(), 10);
        assert_eq!(d[0], (1.0, 10.0));
        assert_eq!(d[9], (91.0, 100.0));
    }

    #[test]
    fn deciles_small_input() {
        let d = decile_ranges(&[3.0, 1.0, 2.0]);
        assert_eq!(d.len(), 3);
        assert_eq!(d[0], (1.0, 1.0));
        assert_eq!(d[2], (3.0, 3.0));
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
        assert_eq!(cv(&[]), 0.0);
        assert!(decile_ranges(&[]).is_empty());
    }
}
