//! Tiny argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positionals.
//! Used by the `repro` CLI and every example binary.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(body.to_string(), v);
                } else {
                    out.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key}: expected integer, got '{v}'")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key}: expected integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key}: expected number, got '{v}'")),
        }
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positionals_and_flags() {
        let a = parse(&["fig5", "--dataset", "reddit-sim", "--iters=10", "--quick"]);
        assert_eq!(a.positional, vec!["fig5"]);
        assert_eq!(a.get("dataset"), Some("reddit-sim"));
        assert_eq!(a.usize_or("iters", 1).unwrap(), 10);
        assert!(a.bool("quick"));
        assert!(!a.bool("verbose"));
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse(&["--a", "--b", "x"]);
        assert!(a.bool("a"));
        assert_eq!(a.get("b"), Some("x"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
        assert_eq!(a.f64_or("p", 0.5).unwrap(), 0.5);
        assert_eq!(a.get_or("mode", "fast"), "fast");
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse(&["--n", "abc"]);
        assert!(a.usize_or("n", 1).is_err());
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse(&["--x=-3.5"]);
        assert_eq!(a.f64_or("x", 0.0).unwrap(), -3.5);
    }
}
