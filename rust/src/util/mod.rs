//! Small, dependency-free substrates: PRNG, JSON, stats, timing, CLI.
//!
//! The build environment vendors only the `xla` dependency closure, so the
//! usual crates (rand, serde, criterion, clap) are unavailable; these modules
//! are deliberately small, well-tested replacements covering exactly what the
//! reproduction needs.

pub mod cli;
pub mod json;
pub mod prng;
pub mod stats;
pub mod sync;
pub mod timing;
