//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Used for the AOT `artifacts/manifest.json` (read) and for experiment
//! reports (write).  Supports the full JSON grammar except `\u` surrogate
//! pairs beyond the BMP, which the manifest never contains.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn usize_arr(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected '{}' at offset {}, found '{}'",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = (start + len).min(self.b.len());
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

// ---------------------------------------------------------------------------
// Writer — a small builder used by experiment reports.
// ---------------------------------------------------------------------------

/// Serialise a [`Json`] value (keys sorted, stable output).
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 9e15 {
                let _ = write!(out, "{}", *x as i64);
            } else {
                let _ = write!(out, "{x}");
            }
        }
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report generation.
pub fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr(xs: Vec<Json>) -> Json {
    Json::Arr(xs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.req("a").unwrap().as_arr().unwrap()[2]
                .req("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x"
        );
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
  "version": 1,
  "t_buckets": [4, 8, 16],
  "executables": [
   {"name": "fused3s_t4_d32",
    "inputs": [{"shape": [32, 16, 32], "dtype": "f32"}],
    "params": {"kind": "fused3s", "t": 4}}
  ]
}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.req("version").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            v.req("t_buckets").unwrap().usize_arr().unwrap(),
            vec![4, 8, 16]
        );
        let exes = v.req("executables").unwrap().as_arr().unwrap();
        let shape = exes[0].req("inputs").unwrap().as_arr().unwrap()[0]
            .req("shape")
            .unwrap()
            .usize_arr()
            .unwrap();
        assert_eq!(shape, vec![32, 16, 32]);
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(to_string(&v), text);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{}extra").is_err());
    }

    #[test]
    fn unicode_escape_and_utf8() {
        assert_eq!(
            Json::parse("\"\\u00e9\"").unwrap(),
            Json::Str("é".to_string())
        );
        assert_eq!(
            Json::parse("\"héllo\"").unwrap(),
            Json::Str("héllo".to_string())
        );
    }
}
