//! # Fused3S — fast sparse attention, reproduced as a three-layer stack
//!
//! This crate is the Layer-3 runtime of the reproduction of
//! *Fused3S: Fast Sparse Attention on Tensor Cores* (Li &
//! Chandramowlishwaran, ICS '25): everything that surrounds the fused
//! SDDMM → online-softmax → SpMM kernel — the BSB sparse format, the
//! bucketing/batching coordinator, the Graph-Transformer inference runtime,
//! the baselines, the SM scheduling simulator, and the benchmark harness.
//!
//! The kernel itself is authored in Pallas (Python, `python/compile/`) and
//! AOT-lowered to HLO-text artifacts at build time (`make artifacts`); this
//! crate loads and executes those artifacts through the PJRT C API (the
//! [`xla`] crate).  **Python never runs on the request path.**
//!
//! ## The plan/batch API
//!
//! Every kernel entry point goes through two types in [`kernels`]:
//!
//! * [`kernels::AttentionBatch`] — `heads` Q/K/V problems sharing one
//!   graph (head-major layout); a single-head problem adapts in with zero
//!   copies via `AttentionBatch::single`.
//! * [`kernels::Plan`] — the graph-specialised op: `Backend::plan(...)`
//!   runs the per-graph preprocessing once (BSB build, reordering, bucket
//!   plan), then `Plan::execute(&mut ExecCtx, &AttentionBatch)` runs every
//!   head through one [`kernels::ExecCtx`] — PJRT artifacts online or the
//!   host emulation offline — amortizing the BSB over all heads of all
//!   layers (the paper's §4.5 lever) and pipelining head *h+1*'s gather
//!   over head *h*'s dispatch.  Each driver implements the
//!   [`kernels::SparseAttentionOp`] trait behind the plan; failures are
//!   the structured [`kernels::AttnError`].
//!
//! Module map (see DESIGN.md §2 for the full system inventory):
//!
//! * [`util`] — PRNG, JSON, timing/stats, CLI: the offline-environment
//!   substitutes for rand/serde/criterion/clap.
//! * [`graph`] — CSR graphs, synthetic generators, the dataset suite
//!   calibrated to the paper's Table 6, and graph batching (LRGB/OGB analog).
//! * [`bsb`] — the paper's Binary Sparse Block format (§3.1): row windows,
//!   column compaction, 128-bit TCB bitmaps, row-window reordering,
//!   TCB-count bucketing, and the Table-3 footprint models.
//! * [`runtime`] — PJRT client + executable cache over the AOT manifest.
//! * [`fault`] — seeded deterministic fault injection (panic / error /
//!   delay at the prepare/gather/dispatch/scatter/admission seams) behind
//!   the default-on `fault-injection` feature; the chaos suite
//!   (`rust/tests/chaos.rs`) arms it around full coordinator runs
//!   (DESIGN.md §11, EXPERIMENTS.md §Faults).
//! * [`trace`] — structured per-request tracing: seeded-sampling spans in
//!   a lock-free bounded ring at every serving seam, exported as Chrome
//!   `trace_event` JSON (`repro trace`) and scraped live over the wire
//!   (`MetricsQuery`/`MetricsReport`, `repro metrics --connect`) behind
//!   the default-on `tracing` feature (DESIGN.md §15,
//!   EXPERIMENTS.md §Tracing).
//! * [`exec`] — the parallel pipelined host execution engine: scoped-thread
//!   worker pool, call-buffer arena, the double-buffered
//!   gather→dispatch→scatter pipeline (now over calls × heads), and the
//!   offline host kernel (EXPERIMENTS.md §Perf, §Multi-head).
//! * [`kernels`] — the plan/batch API (`AttentionBatch`, `Plan`,
//!   `SparseAttentionOp`, `ExecCtx`, `AttnError`) over the driver zoo:
//!   fused (the paper's system), unfused (FlashSparse analog), dense, and
//!   a scalar CSR CPU baseline (PyG analog).
//! * [`planner`] — the adaptive backend planner: [`planner::GraphProfile`]
//!   sparsity features, the calibratable per-backend cost model, and the
//!   online refinement loop behind [`kernels::Backend::Auto`]
//!   (DESIGN.md §5, EXPERIMENTS.md §Planner).
//! * [`shard`] — partition-parallel execution: row-window partitioners
//!   (contiguous / TCB-work-balanced), per-shard halo K/V gathers with the
//!   bit-exact global→local remap, and [`shard::ShardedPlan`] — one plan
//!   per shard behind the same [`kernels::SparseAttentionOp`] seam
//!   (DESIGN.md §10, EXPERIMENTS.md §Sharding).
//! * [`coordinator`] — the serving layer: `Backend::Auto` resolution at
//!   admission, dynamic request coalescing on
//!   (d, dv, heads, scale, resolved backend), fingerprint-keyed plan
//!   cache, sharded routing of graphs above `max_plan_nodes`, request
//!   server, metrics.
//! * [`net`] — the network serving layer in front of the coordinator:
//!   versioned length-prefixed binary wire protocol, threaded TCP
//!   listener whose per-session flow control composes with the bounded
//!   ingress queue, fingerprint handshake against a shared graph store,
//!   and the blocking client library (DESIGN.md §13,
//!   EXPERIMENTS.md §Serving).
//! * [`model`] — Graph Transformer / GAT / AGNN inference runtimes; the GT
//!   issues one multi-head `AttentionBatch` call per layer.
//! * [`simulator`] — the SM active-time scheduling simulator (Fig. 7).
//! * [`experiments`] — regenerators for every table and figure in §4.

pub mod bsb;
pub mod coordinator;
pub mod exec;
pub mod experiments;
pub mod fault;
pub mod graph;
pub mod kernels;
pub mod model;
pub mod net;
pub mod planner;
pub mod runtime;
pub mod shard;
pub mod simulator;
pub mod trace;
pub mod util;

/// TCB row count (the paper's r; fixed by the m16n8k16 MMA shape).
pub const TCB_R: usize = 16;
/// TCB column count (the paper's c).
pub const TCB_C: usize = 8;
/// u32 words per TCB bitmap (16*8 bits / 32).
pub const BITMAP_WORDS: usize = (TCB_R * TCB_C) / 32;
