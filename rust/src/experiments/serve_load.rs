//! Wire-serving loadgen (EXPERIMENTS.md §Serving): N concurrent TCP
//! clients × R requests over G shared graphs against a loopback
//! [`NetServer`](crate::net::NetServer), measuring throughput and the
//! fingerprint handshake's upload savings.
//!
//! Each client cycles through the shared graph set with fresh features
//! per request, so after the first pass every submit travels as a bare
//! fingerprint reference — the steady state the handshake exists for.
//! The report pairs client-side [`ClientStats`] (uploads vs. skips,
//! actual vs. naive CSR bytes) with the server's `Metrics` (net counters
//! + `DriverCache` hits), tying the wire optimization to the
//! preprocessing cache it fronts.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::{Coordinator, CoordinatorConfig};
use crate::graph::{generators, CsrGraph};
use crate::kernels::Backend;
use crate::net::{ClientStats, NetClient, NetConfig, NetServer, WireRequest};
use crate::util::json::{self, Json};
use crate::util::prng::Rng;

use super::report::Table;

/// Workload shape for one loadgen run.
#[derive(Clone)]
pub struct LoadSpec {
    /// Concurrent client connections.
    pub clients: usize,
    /// Requests each client submits.
    pub requests_per_client: usize,
    /// Distinct graphs shared by every client (cycled round-robin).
    pub graphs: usize,
    /// Feature dim (single-head, dv = d).
    pub d: usize,
    pub backend: Backend,
    pub seed: u64,
    /// Auth token presented by every client; `""` for an open server.
    pub token: String,
}

impl Default for LoadSpec {
    fn default() -> LoadSpec {
        LoadSpec {
            clients: 4,
            requests_per_client: 16,
            graphs: 4,
            d: 32,
            backend: Backend::Auto,
            seed: 0x5E12_F00D,
            token: String::new(),
        }
    }
}

struct ClientOutcome {
    ok: u64,
    failed: u64,
    stats: ClientStats,
}

/// Run the loadgen against a coordinator started from `coord_cfg` and a
/// listener from `net_cfg`, print the tables, and return the JSON report
/// (the caller decides where to write it).
pub fn run(
    coord_cfg: CoordinatorConfig,
    net_cfg: NetConfig,
    spec: &LoadSpec,
) -> Result<Json> {
    let coord = Arc::new(Coordinator::start(coord_cfg)?);
    let server = NetServer::serve(coord.clone(), net_cfg)
        .context("starting loopback listener")?;
    let addr = server.local_addr();

    let mut rng = Rng::new(spec.seed);
    let graphs: Arc<Vec<CsrGraph>> = Arc::new(
        (0..spec.graphs.max(1))
            .map(|i| {
                let n = rng.range(64, 512);
                let deg = 2.0 + rng.f64() * 6.0;
                generators::erdos_renyi(n, deg, spec.seed ^ i as u64)
                    .with_self_loops()
            })
            .collect(),
    );
    println!(
        "serving on {addr}: {} clients x {} requests over {} graphs \
         (d={}, backend={})",
        spec.clients,
        spec.requests_per_client,
        graphs.len(),
        spec.d,
        spec.backend.name()
    );

    let t0 = Instant::now();
    let (out_tx, out_rx) = channel::<ClientOutcome>();
    let mut workers = Vec::new();
    for c in 0..spec.clients.max(1) {
        let graphs = graphs.clone();
        let spec = spec.clone();
        let out_tx = out_tx.clone();
        workers.push(std::thread::spawn(move || {
            let outcome = drive_client(addr, &graphs, &spec, c as u64);
            let _ = out_tx.send(outcome);
        }));
    }
    drop(out_tx);

    let mut ok = 0u64;
    let mut failed = 0u64;
    let mut stats = ClientStats::default();
    while let Ok(o) = out_rx.recv() {
        ok += o.ok;
        failed += o.failed;
        stats.requests += o.stats.requests;
        stats.graph_uploads += o.stats.graph_uploads;
        stats.upload_skips += o.stats.upload_skips;
        stats.graph_bytes_uploaded += o.stats.graph_bytes_uploaded;
        stats.graph_bytes_naive += o.stats.graph_bytes_naive;
        stats.bytes_sent += o.stats.bytes_sent;
        stats.bytes_received += o.stats.bytes_received;
    }
    for w in workers {
        let _ = w.join();
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let total = (spec.clients.max(1) * spec.requests_per_client) as u64;
    let rps = if wall_s > 0.0 { ok as f64 / wall_s } else { 0.0 };
    let savings = if stats.graph_bytes_naive > 0 {
        1.0 - stats.graph_bytes_uploaded as f64 / stats.graph_bytes_naive as f64
    } else {
        0.0
    };

    let m = coord.metrics();
    let mut t = Table::new(&["metric", "value"]);
    t.row(vec!["requests ok".into(), format!("{ok}/{total}")]);
    t.row(vec!["wall".into(), format!("{wall_s:.2}s")]);
    t.row(vec!["throughput".into(), format!("{rps:.1} req/s")]);
    t.row(vec![
        "graph uploads / skips".into(),
        format!("{} / {}", stats.graph_uploads, stats.upload_skips),
    ]);
    t.row(vec![
        "CSR bytes uploaded".into(),
        format!(
            "{} (naive {}, saved {:.0}%)",
            stats.graph_bytes_uploaded,
            stats.graph_bytes_naive,
            savings * 100.0
        ),
    ]);
    t.row(vec![
        "wire bytes sent / received".into(),
        format!("{} / {}", stats.bytes_sent, stats.bytes_received),
    ]);
    t.row(vec![
        "server bsb-cache hit / miss".into(),
        format!("{} / {}", m.batching.cache_hits(), m.batching.cache_misses()),
    ]);
    t.print();
    println!("{}", m.report());

    let j = json::obj(vec![
        ("clients", json::num(spec.clients as f64)),
        ("requests_per_client", json::num(spec.requests_per_client as f64)),
        ("graphs", json::num(graphs.len() as f64)),
        ("d", json::num(spec.d as f64)),
        ("backend", json::s(spec.backend.name())),
        ("ok", json::num(ok as f64)),
        ("failed", json::num(failed as f64)),
        ("wall_s", json::num(wall_s)),
        ("throughput_rps", json::num(rps)),
        ("graph_uploads", json::num(stats.graph_uploads as f64)),
        ("upload_skips", json::num(stats.upload_skips as f64)),
        (
            "graph_bytes_uploaded",
            json::num(stats.graph_bytes_uploaded as f64),
        ),
        ("graph_bytes_naive", json::num(stats.graph_bytes_naive as f64)),
        ("upload_savings_ratio", json::num(savings)),
        ("bytes_sent", json::num(stats.bytes_sent as f64)),
        ("bytes_received", json::num(stats.bytes_received as f64)),
        (
            "server",
            json::obj(vec![
                ("connections", json::num(m.net.connections() as f64)),
                ("net_requests", json::num(m.net.requests() as f64)),
                ("graph_uploads", json::num(m.net.graph_uploads() as f64)),
                ("graph_reuses", json::num(m.net.graph_reuses() as f64)),
                ("bytes_in", json::num(m.net.bytes_in() as f64)),
                ("bytes_out", json::num(m.net.bytes_out() as f64)),
                ("cache_hits", json::num(m.batching.cache_hits() as f64)),
                ("cache_misses", json::num(m.batching.cache_misses() as f64)),
            ]),
        ),
    ]);

    server.shutdown();
    coord.shutdown();
    Ok(j)
}

/// One client thread's life: connect, cycle graphs, submit, tally.
fn drive_client(
    addr: std::net::SocketAddr,
    graphs: &[CsrGraph],
    spec: &LoadSpec,
    client_id: u64,
) -> ClientOutcome {
    let mut rng = Rng::new(spec.seed ^ (client_id.wrapping_mul(0x9E37_79B9)));
    let mut ok = 0u64;
    let mut failed = 0u64;
    let mut client = match NetClient::connect(addr, &spec.token) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("client {client_id}: connect failed: {e}");
            return ClientOutcome {
                ok: 0,
                failed: spec.requests_per_client as u64,
                stats: ClientStats::default(),
            };
        }
    };
    for r in 0..spec.requests_per_client {
        let g = &graphs[(client_id as usize + r) % graphs.len()];
        let nd = g.n * spec.d;
        let q = rng.normal_vec(nd, 1.0);
        let k = rng.normal_vec(nd, 1.0);
        let v = rng.normal_vec(nd, 1.0);
        let req = WireRequest::single_head(
            client_id << 32 | r as u64,
            g,
            spec.d,
            &q,
            &k,
            &v,
            1.0 / (spec.d as f32).sqrt(),
            spec.backend,
        );
        match client.submit(&req) {
            Ok(resp) if resp.result.is_ok() => ok += 1,
            Ok(_) | Err(_) => failed += 1,
        }
    }
    let stats = client.stats();
    client.close();
    ClientOutcome { ok, failed, stats }
}

/// Convenience used by tests and the report: upload savings implied by a
/// stats aggregate.
pub fn savings_ratio(stats: &ClientStats) -> f64 {
    if stats.graph_bytes_naive == 0 {
        return 0.0;
    }
    1.0 - stats.graph_bytes_uploaded as f64 / stats.graph_bytes_naive as f64
}
