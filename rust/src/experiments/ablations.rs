//! §4.3 ablations: each optimisation of the Fused3S design, toggled
//! individually (the F3S_splitC → F3S_reorderRW → F3S_permuteQKV stack of
//! the paper, mapped to this substrate's knobs), plus the bucket-granularity
//! ablation that is specific to the AOT reproduction.

use anyhow::Result;

use crate::bsb;
use crate::bsb::bucket;
use crate::bsb::reorder::Order;
use crate::exec::Engine;
use crate::graph::datasets;
use crate::kernels::{
    AttentionBatch, AttentionProblem, Backend, ExecCtx, Plan, SparseAttentionOp,
};
use crate::runtime::Runtime;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::prng::Rng;
use crate::util::timing::{bench, BenchConfig};

use super::report::{self, Table};

/// Warp-partitioning ablation (split-column vs split-row SDDMM).
pub fn split(rt: &Runtime, names: &[String], d: usize, cfg: &BenchConfig) -> Result<Json> {
    compare_backends(
        rt,
        names,
        d,
        cfg,
        &[Backend::Fused3S, Backend::Fused3SSplitR],
        "ablation: split-column vs split-row (paper §3.3 / F3S_splitR)",
    )
}

/// Row-window reordering ablation (on the real dispatch path; the simulated
/// SM view is `repro fig7`).
pub fn reorder(rt: &Runtime, names: &[String], d: usize, cfg: &BenchConfig) -> Result<Json> {
    compare_backends(
        rt,
        names,
        d,
        cfg,
        &[Backend::Fused3S, Backend::Fused3SNoReorder],
        "ablation: row-window reordering (paper §3.2 / F3S_reorderRW)",
    )
}

/// Column-compaction ablation — isolates the BSB format's FLOP savings
/// (paper §3.1; the layout half of F3S_permuteQKV's memory story).
pub fn compaction(rt: &Runtime, names: &[String], d: usize, cfg: &BenchConfig) -> Result<Json> {
    let mut out = Vec::new();
    let mut table = Table::new(&[
        "dataset", "TCBs (BSB)", "TCBs (BCSR-like)", "BSB ms", "BCSR ms",
        "speedup",
    ]);
    for name in names {
        let ds = datasets::by_name(name)?;
        let compacted = bsb::build(&ds.graph);
        let bcsr = bsb::build_bcsr_like(&ds.graph);
        let n = ds.graph.n;
        let mut rng = Rng::new(7);
        let q = rng.normal_vec(n * d, 1.0);
        let k = rng.normal_vec(n * d, 1.0);
        let v = rng.normal_vec(n * d, 1.0);
        let x = AttentionProblem::new(n, d, &q, &k, &v, 1.0 / (d as f32).sqrt());
        let batch = AttentionBatch::single(&x);
        let engine = Engine::serial();
        let time_with = |compact: bool| -> Result<f64> {
            use crate::kernels::fused::{FusedDriver, FusedOpts};
            let driver = FusedDriver::new(
                rt.manifest(),
                &ds.graph,
                FusedOpts { compact, ..FusedOpts::default() },
            )?;
            driver.execute(&mut ExecCtx::pjrt(rt, &engine), &batch)?; // warmup
            Ok(bench("", cfg, || {
                driver
                    .execute(&mut ExecCtx::pjrt(rt, &engine), &batch)
                    .expect("run");
            })
            .median_ms())
        };
        let ms_bsb = time_with(true)?;
        let ms_bcsr = time_with(false)?;
        table.row(vec![
            ds.name.to_string(),
            compacted.total_tcbs().to_string(),
            bcsr.total_tcbs().to_string(),
            report::f(ms_bsb, 2),
            report::f(ms_bcsr, 2),
            format!("{:.2}x", ms_bcsr / ms_bsb),
        ]);
        out.push(obj(vec![
            ("dataset", s(ds.name)),
            ("tcbs_bsb", num(compacted.total_tcbs() as f64)),
            ("tcbs_bcsr", num(bcsr.total_tcbs() as f64)),
            ("ms_bsb", num(ms_bsb)),
            ("ms_bcsr", num(ms_bcsr)),
        ]));
    }
    println!("\nablation: column compaction (BSB vs BCSR-like blocks):");
    table.print();
    Ok(arr(out))
}

/// Bucket-granularity ablation: padding waste vs dispatch count as the
/// bucket set coarsens (AOT-specific design choice, DESIGN.md §1).
pub fn buckets(names: &[String]) -> Result<Json> {
    let fine: Vec<usize> = vec![4, 8, 16, 32, 64, 128];
    let medium: Vec<usize> = vec![8, 32, 128];
    let coarse: Vec<usize> = vec![128];
    let mut out = Vec::new();
    let mut table = Table::new(&[
        "dataset", "buckets", "calls", "padding%", "chunked RWs",
    ]);
    for name in names {
        let ds = datasets::by_name(name)?;
        let b = bsb::build(&ds.graph);
        for (label, set) in
            [("fine", &fine), ("medium", &medium), ("coarse", &coarse)]
        {
            let plan = bucket::plan(&b, set, 32, Order::ByTcbDesc, 128);
            table.row(vec![
                ds.name.to_string(),
                label.to_string(),
                plan.stats.n_calls.to_string(),
                format!("{:.1}%", plan.stats.padding_ratio() * 100.0),
                plan.stats.n_chunked_rws.to_string(),
            ]);
            out.push(obj(vec![
                ("dataset", s(ds.name)),
                ("buckets", s(label)),
                ("calls", num(plan.stats.n_calls as f64)),
                ("padding_ratio", num(plan.stats.padding_ratio())),
            ]));
        }
    }
    println!("\nablation: bucket granularity (padding vs dispatch count):");
    table.print();
    Ok(arr(out))
}

fn compare_backends(
    rt: &Runtime,
    names: &[String],
    d: usize,
    cfg: &BenchConfig,
    backends: &[Backend],
    title: &str,
) -> Result<Json> {
    let mut out = Vec::new();
    let mut headers = vec!["dataset".to_string()];
    headers.extend(backends.iter().map(|b| format!("{} (ms)", b.name())));
    headers.push("speedup".into());
    let mut table =
        Table::new(&headers.iter().map(|h| h.as_str()).collect::<Vec<_>>());
    for name in names {
        let ds = datasets::by_name(name)?;
        let n = ds.graph.n;
        let mut rng = Rng::new(11);
        let q = rng.normal_vec(n * d, 1.0);
        let k = rng.normal_vec(n * d, 1.0);
        let v = rng.normal_vec(n * d, 1.0);
        let x = AttentionProblem::new(n, d, &q, &k, &v, 1.0 / (d as f32).sqrt());
        let batch = AttentionBatch::single(&x);
        let engine = Engine::serial();
        let mut times = Vec::new();
        for &b in backends {
            let plan = Plan::new(rt.manifest(), &ds.graph, b, &engine)?;
            plan.execute(&mut ExecCtx::pjrt(rt, &engine), &batch)?;
            times.push(
                bench(b.name(), cfg, || {
                    plan.execute(&mut ExecCtx::pjrt(rt, &engine), &batch)
                        .expect("run");
                })
                .median_ms(),
            );
        }
        let mut row = vec![ds.name.to_string()];
        row.extend(times.iter().map(|&t| report::f(t, 2)));
        row.push(format!("{:.2}x", times[1] / times[0]));
        table.row(row);
        out.push(obj(vec![
            ("dataset", s(ds.name)),
            ("base", s(backends[0].name())),
            ("base_ms", num(times[0])),
            ("variant", s(backends[1].name())),
            ("variant_ms", num(times[1])),
        ]));
    }
    println!("\n{title}:");
    table.print();
    Ok(arr(out))
}
