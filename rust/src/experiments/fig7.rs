//! Figure 7: per-SM active time with and without row-window reordering,
//! via the scheduling simulator (DESIGN.md §1 substitution 4), plus the
//! *measured* wall-clock effect of reordering on the real dispatch path.

use anyhow::Result;

use crate::bsb;
use crate::bsb::reorder::Order;
use crate::graph::datasets;
use crate::simulator::{simulate, SimConfig};
use crate::util::json::{arr, num, obj, s, Json};

use super::report::{self, Table};

pub const DEFAULT_DATASETS: &[&str] = &["reddit-sim", "pubmed-sim"];

pub fn run(names: &[String], num_sms: usize) -> Result<Json> {
    let cfg = SimConfig { num_sms, ..SimConfig::default() };
    let mut results = Vec::new();
    for name in names {
        let d = datasets::by_name(name)?;
        let b = bsb::build(&d.graph);
        let nat = simulate(&b, Order::Natural, &cfg);
        let reo = simulate(&b, Order::ByTcbDesc, &cfg);

        println!("\nFigure 7 — {name} on {num_sms} simulated SMs");
        let mut t = Table::new(&[
            "schedule", "makespan", "balance", "tail-overhead", "min SM",
            "max SM",
        ]);
        for (label, r) in [("natural", &nat), ("reordered", &reo)] {
            let min = r.active.iter().cloned().fold(f64::INFINITY, f64::min);
            let max = r.active.iter().cloned().fold(0.0, f64::max);
            t.row(vec![
                label.to_string(),
                report::f(r.makespan, 0),
                report::f(r.balance(), 3),
                report::f(r.tail_overhead(), 3),
                report::f(min, 0),
                report::f(max, 0),
            ]);
        }
        t.print();
        println!("speedup from reordering: {:.3}x", nat.makespan / reo.makespan);
        render_histogram("natural  ", &nat.active, nat.makespan);
        render_histogram("reordered", &reo.active, nat.makespan);

        results.push(obj(vec![
            ("dataset", s(&d.name.to_string())),
            ("num_sms", num(num_sms as f64)),
            ("makespan_natural", num(nat.makespan)),
            ("makespan_reordered", num(reo.makespan)),
            ("balance_natural", num(nat.balance())),
            ("balance_reordered", num(reo.balance())),
            (
                "active_natural",
                Json::Arr(nat.active.iter().map(|&a| num(a)).collect()),
            ),
            (
                "active_reordered",
                Json::Arr(reo.active.iter().map(|&a| num(a)).collect()),
            ),
        ]));
    }
    Ok(arr(results))
}

/// ASCII version of the paper's per-SM bar chart.
fn render_histogram(label: &str, active: &[f64], scale_max: f64) {
    const WIDTH: usize = 60;
    println!("  {label} per-SM active time (each row = 8 SMs, ▏→ {scale_max:.0}):");
    for chunk in active.chunks(8) {
        let avg = chunk.iter().sum::<f64>() / chunk.len() as f64;
        let w = ((avg / scale_max) * WIDTH as f64).round() as usize;
        println!("    {}{}", "█".repeat(w.min(WIDTH)), " ".repeat(WIDTH - w.min(WIDTH)));
    }
}
