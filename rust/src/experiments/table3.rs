//! Table 3: memory footprint of eight sparse formats, measured on real
//! builds of the benchmark graphs (not density assumptions).

use anyhow::Result;

use crate::bsb::footprint;
use crate::graph::datasets;
use crate::util::json::{arr, num, obj, s, Json};

use super::report::{self, Table};

pub fn run(dataset_filter: Option<&str>) -> Result<Json> {
    let suite: Vec<_> = datasets::suite_single()
        .into_iter()
        .filter(|d| dataset_filter.map(|f| d.name == f).unwrap_or(true))
        .collect();
    let mut table = Table::new(&[
        "dataset", "nodes", "edges", "CSR", "SR-BCSR", "ME-BCRS", "BCSR",
        "TCF", "ME-TCF", "BitTCF", "BSB", "BSB/best-other",
    ]);
    let mut results = Vec::new();
    for d in &suite {
        let inputs = footprint::measure(&d.graph);
        let rows = footprint::table3_rows(&inputs);
        let mib = |bits: u64| bits as f64 / 8.0 / 1024.0 / 1024.0;
        let bsb = rows.iter().find(|(n, _)| *n == "BSB").unwrap().1;
        let best_other = rows
            .iter()
            .filter(|(n, _)| *n != "BSB")
            .map(|&(_, b)| b)
            .min()
            .unwrap();
        let mut cells = vec![
            d.name.to_string(),
            d.graph.n.to_string(),
            d.graph.nnz().to_string(),
        ];
        cells.extend(rows.iter().map(|&(_, b)| report::f(mib(b), 2)));
        cells.push(format!("{:.2}", bsb as f64 / best_other as f64));
        table.row(cells);
        results.push(obj(vec![
            ("dataset", s(d.name)),
            ("paper_dataset", s(d.paper_name)),
            (
                "footprints_bits",
                Json::Obj(
                    rows.iter()
                        .map(|&(n, b)| (n.to_string(), num(b as f64)))
                        .collect(),
                ),
            ),
        ]));
    }
    println!("Table 3 — sparse format memory footprint (MiB):");
    table.print();
    println!(
        "\n(BSB/best-other < 1.0 means BSB is the smallest format; the\n\
         crossover to ME-TCF appears only on hypersparse blocks, see\n\
         bsb::footprint tests.)"
    );
    Ok(arr(results))
}
