//! Aligned-table printing + JSON result files for the experiment binaries.

use std::path::Path;

use anyhow::Result;

use crate::util::json::{self, Json};

/// A simple fixed-column table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(
            &widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  "),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float cell compactly.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

/// "1.53x" speedup cell; "FAIL" for unavailable baselines (the paper's
/// missing OOM bars).
pub fn speedup(baseline_ms: Option<f64>, ours_ms: f64) -> String {
    match baseline_ms {
        Some(b) => format!("{:.2}x", b / ours_ms),
        None => "FAIL".to_string(),
    }
}

/// Write a JSON report under `results/` (created on demand).
pub fn write_json(name: &str, value: &Json) -> Result<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, json::to_string(value))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["name", "value"]);
        t.row(vec!["a".into(), "1.0".into()]);
        t.row(vec!["longer".into(), "2".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[2].ends_with("1.0"));
    }

    #[test]
    fn speedup_cells() {
        assert_eq!(speedup(Some(30.0), 10.0), "3.00x");
        assert_eq!(speedup(None, 10.0), "FAIL");
    }
}
