//! §3.5 numerical stability: demonstrate on the real executables that the
//! naive softmax overflows once scores exceed exp()'s f32 range while the
//! online (fused) and stable variants survive — the paper's justification
//! for paying the row-max reduction.

use anyhow::Result;

use crate::exec::Engine;
use crate::graph::generators;
use crate::kernels::{
    reference, AttentionBatch, AttentionProblem, Backend, ExecCtx, Plan,
};
use crate::runtime::Runtime;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::prng::Rng;

use super::report::Table;

pub fn run(rt: &Runtime) -> Result<Json> {
    let g = generators::erdos_renyi(256, 6.0, 3).with_self_loops();
    let n = g.n;
    let d = 64;
    let mut out = Vec::new();
    let mut table = Table::new(&[
        "value scale", "max |score|", "backend", "NaN rows", "max err vs ref",
    ]);
    // Sweep the feature magnitude: scores grow ~ scale² · d.
    for value_scale in [0.5f32, 2.0, 6.0] {
        let mut rng = Rng::new(17);
        let q: Vec<f32> =
            rng.normal_vec(n * d, 1.0).iter().map(|x| x * value_scale).collect();
        let k: Vec<f32> =
            rng.normal_vec(n * d, 1.0).iter().map(|x| x * value_scale).collect();
        let v = rng.normal_vec(n * d, 1.0);
        let x = AttentionProblem::new(n, d, &q, &k, &v, 1.0);
        // max score (for the table; computed over edges only)
        let mut max_score = 0.0f32;
        for i in 0..n {
            for &j in g.row(i) {
                let s: f32 = (0..d)
                    .map(|c| q[i * d + c] * k[j as usize * d + c])
                    .sum();
                max_score = max_score.max(s.abs());
            }
        }
        let want = reference::dense_attention_host(&g, &x);
        let engine = Engine::serial();
        for b in [Backend::Fused3S, Backend::UnfusedStable, Backend::UnfusedNaive] {
            let plan = Plan::new(rt.manifest(), &g, b, &engine)?;
            let got = plan
                .execute(&mut ExecCtx::pjrt(rt, &engine), &AttentionBatch::single(&x))?;
            let nan_rows = (0..n)
                .filter(|&i| got[i * d..(i + 1) * d].iter().any(|v| v.is_nan()))
                .count();
            let err = if nan_rows > 0 {
                f32::NAN
            } else {
                reference::max_abs_diff(&got, &want)
            };
            table.row(vec![
                format!("{value_scale}"),
                format!("{max_score:.0}"),
                b.name().to_string(),
                nan_rows.to_string(),
                if err.is_nan() {
                    "NaN".into()
                } else {
                    format!("{err:.3}")
                },
            ]);
            out.push(obj(vec![
                ("value_scale", num(value_scale as f64)),
                ("max_score", num(max_score as f64)),
                ("backend", s(b.name())),
                ("nan_rows", num(nan_rows as f64)),
            ]));
        }
    }
    println!(
        "\n§3.5 stability — naive softmax must break past |score| ≈ 88\n\
         (exp() overflow in f32) while online/stable variants stay exact:"
    );
    table.print();
    Ok(arr(out))
}
