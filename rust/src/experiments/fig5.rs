//! Figures 5 & 6: 3S kernel time across the dataset suites, one series per
//! backend.  Fig. 5 = single graphs, Fig. 6 = batched graphs; both share
//! this harness (they differ only in the dataset list).
//!
//! Reproduction semantics (DESIGN.md §1): absolute times are CPU-substrate
//! times; the comparisons the paper makes — fused vs unfused, compacted vs
//! not, kernel vs framework scalar, OOM-analog failures on oversize
//! problems — are what must hold.

use anyhow::Result;

use crate::exec::Engine;
use crate::graph::datasets::Dataset;
use crate::kernels::{AttentionBatch, AttentionProblem, Backend, ExecCtx, Plan};
use crate::runtime::Runtime;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::prng::Rng;
use crate::util::stats;
use crate::util::timing::{bench, BenchConfig};

use super::report::{self, Table};

/// One (dataset × backend) measurement.
pub struct Cell {
    pub dataset: String,
    pub backend: Backend,
    /// Median ms, or None with a failure reason (the paper's OOM bars).
    pub median_ms: Option<f64>,
    pub fail_reason: Option<String>,
}

/// Run the kernel comparison over `suite`.
pub fn run(
    rt: &Runtime,
    suite: &[Dataset],
    backends: &[Backend],
    d: usize,
    cfg: &BenchConfig,
    label: &str,
) -> Result<Json> {
    let mut cells: Vec<Cell> = Vec::new();
    let engine = Engine::serial();
    for ds in suite {
        let n = ds.graph.n;
        let mut rng = Rng::new(0xF16 + n as u64);
        let q = rng.normal_vec(n * d, 1.0);
        let k = rng.normal_vec(n * d, 1.0);
        let v = rng.normal_vec(n * d, 1.0);
        // 1/sqrt(d) keeps the naive-softmax baseline in exp() range on most
        // datasets, matching how frameworks actually run attention.
        let x = AttentionProblem::new(n, d, &q, &k, &v, 1.0 / (d as f32).sqrt());
        let batch = AttentionBatch::single(&x);
        for &b in backends {
            let cell = match Plan::new(rt.manifest(), &ds.graph, b, &engine) {
                Err(e) => Cell {
                    dataset: ds.name.to_string(),
                    backend: b,
                    median_ms: None,
                    fail_reason: Some(format!("{e}")),
                },
                Ok(plan) => {
                    // One untimed run warms executable compilation.
                    match plan.execute(&mut ExecCtx::pjrt(rt, &engine), &batch) {
                        Err(e) => Cell {
                            dataset: ds.name.to_string(),
                            backend: b,
                            median_ms: None,
                            fail_reason: Some(format!("{e}")),
                        },
                        Ok(_) => {
                            let r = bench(b.name(), cfg, || {
                                plan.execute(
                                    &mut ExecCtx::pjrt(rt, &engine),
                                    &batch,
                                )
                                .expect("benched run");
                            });
                            Cell {
                                dataset: ds.name.to_string(),
                                backend: b,
                                median_ms: Some(r.median_ms()),
                                fail_reason: None,
                            }
                        }
                    }
                }
            };
            eprintln!(
                "  [{label}] {} / {}: {}",
                cell.dataset,
                cell.backend.name(),
                cell.median_ms
                    .map(|m| format!("{m:.2} ms"))
                    .unwrap_or_else(|| "FAIL".into())
            );
            cells.push(cell);
        }
    }
    print_tables(&cells, backends, label);
    Ok(to_json(&cells, label, d))
}

fn cell_ms(cells: &[Cell], ds: &str, b: Backend) -> Option<f64> {
    cells
        .iter()
        .find(|c| c.dataset == ds && c.backend == b)
        .and_then(|c| c.median_ms)
}

fn print_tables(cells: &[Cell], backends: &[Backend], label: &str) {
    let datasets: Vec<String> = {
        let mut v: Vec<String> = Vec::new();
        for c in cells {
            if !v.contains(&c.dataset) {
                v.push(c.dataset.clone());
            }
        }
        v
    };
    let mut headers = vec!["dataset"];
    let names: Vec<String> =
        backends.iter().map(|b| format!("{} (ms)", b.name())).collect();
    headers.extend(names.iter().map(|s| s.as_str()));
    let spd: Vec<String> = backends
        .iter()
        .filter(|&&b| b != Backend::Fused3S)
        .map(|b| format!("vs {}", b.name()))
        .collect();
    headers.extend(spd.iter().map(|s| s.as_str()));
    let mut table = Table::new(&headers);

    let mut speedups: Vec<Vec<f64>> =
        vec![Vec::new(); backends.len().saturating_sub(1)];
    for ds in &datasets {
        let fused = cell_ms(cells, ds, Backend::Fused3S);
        let mut row = vec![ds.clone()];
        for &b in backends {
            row.push(
                cell_ms(cells, ds, b)
                    .map(|m| report::f(m, 2))
                    .unwrap_or_else(|| "FAIL".into()),
            );
        }
        let mut si = 0;
        for &b in backends.iter().filter(|&&b| b != Backend::Fused3S) {
            let base = cell_ms(cells, ds, b);
            match (base, fused) {
                (Some(base), Some(f)) => {
                    row.push(format!("{:.2}x", base / f));
                    speedups[si].push(base / f);
                }
                _ => row.push("-".into()),
            }
            si += 1;
        }
        table.row(row);
    }
    println!("\n{label} — 3S kernel comparison (median ms; lower is better):");
    table.print();
    print!("geomean speedup of fused3s:");
    let mut si = 0;
    for &b in backends.iter().filter(|&&b| b != Backend::Fused3S) {
        if !speedups[si].is_empty() {
            print!("  {:.2}x vs {}", stats::geomean(&speedups[si]), b.name());
        }
        si += 1;
    }
    println!();
}

fn to_json(cells: &[Cell], label: &str, d: usize) -> Json {
    arr(cells
        .iter()
        .map(|c| {
            obj(vec![
                ("figure", s(label)),
                ("dataset", s(&c.dataset)),
                ("backend", s(c.backend.name())),
                ("d", num(d as f64)),
                (
                    "median_ms",
                    c.median_ms.map(num).unwrap_or(Json::Null),
                ),
                (
                    "fail",
                    c.fail_reason
                        .as_deref()
                        .map(s)
                        .unwrap_or(Json::Null),
                ),
            ])
        })
        .collect())
}
