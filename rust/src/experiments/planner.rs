//! Planner decision audit — `repro plan`: what the adaptive backend
//! planner would choose for each dataset, and why.
//!
//! Not a paper artifact: this is the introspection table for the
//! [`crate::planner`] subsystem (EXPERIMENTS.md §Planner).  For each
//! dataset it prints the extracted [`GraphProfile`] features next to every
//! candidate backend's predicted latency under the factory cost model, and
//! marks the winner.  `benches/planner.rs` is the measuring counterpart
//! (predicted vs measured, auto vs fixed).

use anyhow::Result;

use crate::graph::datasets;
use crate::planner::{CostModel, GraphProfile, Planner, COST_FAMILIES};
use crate::util::json::{arr, num, obj, s, Json};

use super::report::{f, Table};

/// Audit the factory planner's decision for each named dataset.
pub fn run(names: &[String]) -> Result<Json> {
    let planner = Planner::new(CostModel::default());
    let mut table = Table::new(&[
        "dataset", "n", "nnz", "tcb/rw cv", "hub skew", "oversize",
        "fused3s ms", "unfused ms", "dense ms", "cpu ms", "choice",
    ]);
    let mut results = Vec::new();
    for name in names {
        let d = datasets::by_name(name)?;
        let profile = GraphProfile::from_csr(&d.graph);
        let decision = planner.decide(&profile);
        let ms = |b| {
            decision
                .scores
                .iter()
                .find(|sc| sc.backend == b)
                .and_then(|sc| sc.predicted_s)
                .map(|sec| f(sec * 1e3, 3))
                .unwrap_or_else(|| "infeasible".into())
        };
        let mut cells = vec![
            d.name.to_string(),
            profile.n.to_string(),
            profile.nnz.to_string(),
            f(profile.tcb_per_rw_cv, 2),
            f(profile.hub_skew, 1),
            profile.oversize_rws.to_string(),
        ];
        for b in COST_FAMILIES {
            cells.push(ms(b));
        }
        let mut choice = decision.backend.name().to_string();
        if decision.chunked {
            choice.push_str(" (chunked)");
        }
        cells.push(choice);
        table.row(cells);
        results.push(obj(vec![
            ("dataset", s(d.name)),
            ("n", num(profile.n as f64)),
            ("nnz", num(profile.nnz as f64)),
            ("tcb_per_rw_cv", num(profile.tcb_per_rw_cv)),
            ("hub_skew", num(profile.hub_skew)),
            ("oversize_rws", num(profile.oversize_rws as f64)),
            ("choice", s(decision.backend.name())),
            ("chunked", Json::Bool(decision.chunked)),
            ("predicted_ms", num(decision.predicted_s * 1e3)),
            (
                "scores",
                Json::Arr(
                    decision
                        .scores
                        .iter()
                        .map(|sc| {
                            obj(vec![
                                ("backend", s(sc.backend.name())),
                                (
                                    "predicted_ms",
                                    sc.predicted_s
                                        .map(|sec| num(sec * 1e3))
                                        .unwrap_or(Json::Null),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));
    }
    println!(
        "Planner audit — factory cost model, per-dataset decision\n\
         (predictions are device-regime estimates; the serving loop\n\
         refines the constants from measured latencies):"
    );
    table.print();
    Ok(arr(results))
}
