//! Table 6: per-dataset sparsity metrics after BSB compaction — the
//! calibration audit for the synthetic suite (TCB/RW and nnz/TCB, avg + CV).

use anyhow::Result;

use crate::bsb::{self, stats};
use crate::graph::datasets;
use crate::util::json::{arr, num, obj, s, Json};

use super::report::{self, Table};

/// The paper's Table 6 values, used to print the calibration target next to
/// the measured value (name, tcb/rw avg, tcb/rw cv, nnz/tcb avg, nnz/tcb cv).
const PAPER: &[(&str, f64, f64, f64, f64)] = &[
    ("IGB-small", 24.4, 0.25, 7.9, 0.11),
    ("IGB-medium", 24.4, 0.58, 7.9, 0.11),
    ("Amazon0505", 12.3, 0.20, 10.6, 0.46),
    ("Com-Amazon", 6.0, 0.61, 7.5, 0.22),
    ("Musae-github", 29.4, 1.34, 8.3, 0.15),
    ("Artist", 32.0, 0.73, 8.0, 0.11),
    ("Pubmed", 9.3, 0.45, 7.7, 0.18),
    ("Cora", 7.5, 0.38, 8.3, 0.29),
    ("Citeseer", 5.8, 0.31, 7.7, 0.24),
    ("AmazonProducts", 330.5, 1.22, 8.2, 0.07),
    ("Yelp", 39.0, 1.28, 8.0, 0.09),
    ("Reddit", 477.2, 1.35, 16.5, 0.95),
    ("Blog", 69.0, 2.47, 11.0, 0.44),
    ("Elliptic", 2.5, 0.57, 7.5, 0.45),
    ("Ogbn-products", 101.4, 0.84, 8.0, 0.05),
];

pub fn run(include_batched: bool) -> Result<Json> {
    let mut suite = datasets::suite_single();
    if include_batched {
        suite.extend(datasets::suite_batched());
    }
    let mut table = Table::new(&[
        "dataset", "paper", "nodes", "edges", "TCB/RW", "cv", "paperTCB/RW",
        "papercv", "nnz/TCB", "cv", "papernnz", "papercv",
    ]);
    let mut results = Vec::new();
    for d in &suite {
        let b = bsb::build(&d.graph);
        let st = stats::compaction_stats(&b);
        let paper = PAPER.iter().find(|p| p.0 == d.paper_name);
        let pf = |x: Option<f64>| {
            x.map(|v| report::f(v, 2)).unwrap_or_else(|| "-".into())
        };
        table.row(vec![
            d.name.to_string(),
            d.paper_name.to_string(),
            st.nodes.to_string(),
            st.edges.to_string(),
            report::f(st.tcb_per_rw_avg, 1),
            report::f(st.tcb_per_rw_cv, 2),
            pf(paper.map(|p| p.1)),
            pf(paper.map(|p| p.2)),
            report::f(st.nnz_per_tcb_avg, 1),
            report::f(st.nnz_per_tcb_cv, 2),
            pf(paper.map(|p| p.3)),
            pf(paper.map(|p| p.4)),
        ]);
        results.push(obj(vec![
            ("dataset", s(d.name)),
            ("paper_dataset", s(d.paper_name)),
            ("nodes", num(st.nodes as f64)),
            ("edges", num(st.edges as f64)),
            ("tcb_per_rw_avg", num(st.tcb_per_rw_avg)),
            ("tcb_per_rw_cv", num(st.tcb_per_rw_cv)),
            ("nnz_per_tcb_avg", num(st.nnz_per_tcb_avg)),
            ("nnz_per_tcb_cv", num(st.nnz_per_tcb_cv)),
            ("total_tcbs", num(st.total_tcbs as f64)),
        ]));
    }
    println!(
        "Table 6 — dataset stats after compaction (TCB 16x8); paper columns\n\
         show the original datasets' values (node counts are scaled down,\n\
         so TCB/RW magnitudes differ; the CV regime is the calibration target):"
    );
    table.print();
    Ok(arr(results))
}
