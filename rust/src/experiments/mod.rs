//! Experiment harness: one regenerator per table/figure of the paper's
//! evaluation (§4).  Each submodule prints the same rows/series the paper
//! reports and returns structured results for the JSON reports.
//!
//! | paper artifact | module | `repro` subcommand |
//! |---|---|---|
//! | Table 3 (format footprints)        | [`table3`]    | `table3` |
//! | Table 6 (dataset compaction stats) | [`table6`]    | `table6` |
//! | Table 7 (TCB/RW deciles)           | [`table7`]    | `table7` |
//! | Fig. 5 (3S kernel, single graphs)  | [`fig5`]      | `fig5` |
//! | Fig. 6 (3S kernel, batched graphs) | [`fig5`]      | `fig6` |
//! | Fig. 7 (SM active time ± reorder)  | [`fig7`]      | `fig7` |
//! | Fig. 8 (end-to-end GT inference)   | [`fig8`]      | `fig8` |
//! | §4.3 ablations                     | [`ablations`] | `ablate-*` |
//! | §3.5 stability                     | [`stability`] | `stability` |
//!
//! Beyond the paper, [`planner`] (`repro plan`) audits the adaptive
//! backend planner's per-dataset decisions (EXPERIMENTS.md §Planner),
//! [`shard`] (`repro shard`) audits the partition-parallel layer's cuts
//! (EXPERIMENTS.md §Sharding), [`serve_load`] (`repro serve`) drives
//! the TCP serving layer with a multi-connection loadgen
//! (EXPERIMENTS.md §Serving), [`streaming`] (`repro stream`) drives
//! the incremental-update path — wire deltas, dirty-window BSB rebuilds,
//! atomic plan swaps (EXPERIMENTS.md §Streaming), and [`trace_capture`]
//! (`repro trace`) records a served workload as Chrome `trace_event`
//! JSON (EXPERIMENTS.md §Tracing).

pub mod ablations;
pub mod fig5;
pub mod fig7;
pub mod fig8;
pub mod planner;
pub mod report;
pub mod serve_load;
pub mod shard;
pub mod stability;
pub mod streaming;
pub mod table3;
pub mod table6;
pub mod table7;
pub mod trace_capture;
