//! Sharding audit — `repro shard`: how the partition-parallel layer would
//! cut each dataset, and what the cut costs.
//!
//! Not a paper artifact: this is the introspection table for the
//! [`crate::shard`] subsystem (EXPERIMENTS.md §Sharding).  For each
//! dataset × shard count it prints, for both partition strategies, the
//! TCB-work imbalance (max/mean shard work) and the halo fraction
//! (replicated K/V rows ÷ n), plus the planner's sharded decision —
//! which backend the shards would run and the predicted latency under the
//! factory cost model.  `benches/shard.rs` is the measuring counterpart.

use anyhow::Result;

use crate::bsb::stats::halo_fraction;
use crate::graph::datasets;
use crate::planner::{CostModel, Planner};
use crate::shard::partition::{self, Strategy};
use crate::util::json::{arr, num, obj, s, Json};

use super::report::{f, Table};

/// Audit the sharding layer's partitions for each named dataset.
pub fn run(names: &[String], shard_counts: &[usize]) -> Result<Json> {
    let planner = Planner::new(CostModel::default());
    let mut table = Table::new(&[
        "dataset", "n", "shards", "strategy", "halo frac", "work max/mean",
        "backend", "predicted ms",
    ]);
    let mut results = Vec::new();
    for name in names {
        let d = datasets::by_name(name)?;
        let weights = partition::rw_tcb_counts(&d.graph);
        for &shards in shard_counts {
            for strategy in [Strategy::BalancedTcb, Strategy::Contiguous] {
                let part = partition::partition(&d.graph, shards, strategy);
                let halo = halo_fraction(&d.graph, &part.row_ranges(d.graph.n));
                let work = partition::shard_work(&weights, &part);
                let max = work.iter().copied().max().unwrap_or(0) as f64;
                let mean = work.iter().sum::<usize>() as f64
                    / work.len().max(1) as f64;
                let imbalance = if mean > 0.0 { max / mean } else { 1.0 };
                // The per-shard node cap this shard count implies; the
                // planner prices the balanced cut (its routing input).
                let strat = match strategy {
                    Strategy::BalancedTcb => "balanced",
                    Strategy::Contiguous => "contiguous",
                };
                let (backend, predicted_ms) = if strategy
                    == Strategy::BalancedTcb
                {
                    let cap = d.graph.n.div_ceil(part.shards().max(1)).max(1);
                    let dec = planner.resolve_sharded(&d.graph, cap);
                    (dec.backend.name(), dec.predicted_s * 1e3)
                } else {
                    ("-", 0.0)
                };
                table.row(vec![
                    d.name.to_string(),
                    d.graph.n.to_string(),
                    part.shards().to_string(),
                    strat.to_string(),
                    f(halo, 3),
                    f(imbalance, 2),
                    backend.to_string(),
                    if predicted_ms > 0.0 {
                        f(predicted_ms, 3)
                    } else {
                        "-".into()
                    },
                ]);
                results.push(obj(vec![
                    ("dataset", s(d.name)),
                    ("n", num(d.graph.n as f64)),
                    ("shards", num(part.shards() as f64)),
                    ("strategy", s(strat)),
                    ("halo_fraction", num(halo)),
                    ("work_imbalance", num(imbalance)),
                    ("backend", s(backend)),
                    ("predicted_ms", num(predicted_ms)),
                ]));
            }
        }
    }
    println!(
        "Sharding audit — TCB-balanced vs contiguous row-window cuts\n\
         (halo frac = replicated K/V rows / n; work max/mean = shard TCB\n\
         imbalance; the planner prices the balanced cut):"
    );
    table.print();
    Ok(arr(results))
}
