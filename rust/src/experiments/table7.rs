//! Table 7: decile distribution of TCB counts per row window — the
//! long-tail evidence behind the reordering optimisation.

use anyhow::Result;

use crate::bsb::{self, stats};
use crate::graph::datasets;
use crate::util::json::{arr, num, obj, s, Json};

use super::report::Table;

/// The paper's four representative graphs → our calibrated stand-ins.
pub const DEFAULT_DATASETS: &[&str] =
    &["reddit-sim", "yelp-sim", "pubmed-sim", "github-sim"];

pub fn run(names: &[String]) -> Result<Json> {
    let mut table = Table::new(&[
        "dataset", "decile sz", "10%", "20%", "30%", "40%", "50%", "60%",
        "70%", "80%", "90%", "100%",
    ]);
    let mut results = Vec::new();
    for name in names {
        let d = datasets::by_name(name)?;
        let b = bsb::build(&d.graph);
        let deciles = stats::tcb_deciles(&b);
        let mut cells =
            vec![d.name.to_string(), stats::decile_size(&b).to_string()];
        for &(lo, hi) in &deciles {
            cells.push(format!("{lo}-{hi}"));
        }
        while cells.len() < 12 {
            cells.push("-".into());
        }
        table.row(cells);
        results.push(obj(vec![
            ("dataset", s(&d.name.to_string())),
            (
                "deciles",
                Json::Arr(
                    deciles
                        .iter()
                        .map(|&(lo, hi)| {
                            Json::Arr(vec![num(lo as f64), num(hi as f64)])
                        })
                        .collect(),
                ),
            ),
        ]));
    }
    println!(
        "Table 7 — min-max TCB count per decile of row windows (sorted\n\
         ascending).  Long tails (last decile >> first) are the load-\n\
         imbalance cases that reordering targets:"
    );
    table.print();
    Ok(arr(results))
}
