//! Figure 8: end-to-end Graph Transformer inference with the 3S kernel
//! swapped between backends, sweeping the embedding dim d ∈ {64, 128, 256},
//! plus the attention-time fraction (Fig. 8b/8d).

use anyhow::Result;

use crate::graph::datasets::Dataset;
use crate::kernels::Backend;
use crate::model::weights::random_features;
use crate::model::{GraphTransformer, GtConfig};
use crate::runtime::Runtime;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::stats;
use crate::util::timing::BenchConfig;

use super::report::{self, Table};

/// The Fig. 8 backend series (DGL's role is taken by the scalar CSR path).
pub fn series() -> Vec<Backend> {
    vec![
        Backend::Fused3S,
        Backend::DfGnnLike,
        Backend::UnfusedStable,
        Backend::CpuCsr,
    ]
}

#[allow(clippy::too_many_arguments)]
pub fn run(
    rt: &Runtime,
    suite: &[Dataset],
    dims: &[usize],
    backends: &[Backend],
    n_blocks: usize,
    cfg: &BenchConfig,
) -> Result<Json> {
    let mut results = Vec::new();
    for d in dims {
        println!("\nFigure 8 — GT inference, d={d}, {n_blocks} blocks:");
        let mut headers: Vec<String> = vec!["dataset".into()];
        headers.extend(backends.iter().map(|b| format!("{} (ms)", b.name())));
        headers.extend(
            backends
                .iter()
                .map(|b| format!("{} attn%", b.name())),
        );
        let mut table =
            Table::new(&headers.iter().map(|h| h.as_str()).collect::<Vec<_>>());
        let mut speedups: Vec<Vec<f64>> = vec![Vec::new(); backends.len()];
        for ds in suite {
            let h = random_features(0xF18, ds.graph.n, *d);
            let mut times: Vec<Option<(f64, f64)>> = Vec::new();
            for &b in backends {
                let gt_cfg =
                    GtConfig { d: *d, n_blocks, backend: b, seed: 0x5EED };
                let r = (|| -> Result<(f64, f64)> {
                    let model = GraphTransformer::prepare(rt, &ds.graph, gt_cfg)?;
                    let (_, warm) = model.infer(rt, &h)?; // compile warmup
                    let mut samples = Vec::new();
                    let mut frac = warm.attention_fraction();
                    for _ in 0..cfg.min_iters.max(2) {
                        let (_, t) = model.infer(rt, &h)?;
                        samples.push(t.total_s);
                        frac = t.attention_fraction();
                    }
                    Ok((stats::median(&samples) * 1e3, frac))
                })();
                match &r {
                    Ok((ms, frac)) => eprintln!(
                        "  [fig8 d={d}] {} / {}: {ms:.1} ms (attn {:.0}%)",
                        ds.name,
                        b.name(),
                        frac * 100.0
                    ),
                    Err(e) => eprintln!(
                        "  [fig8 d={d}] {} / {}: FAIL ({e:#})",
                        ds.name,
                        b.name()
                    ),
                }
                times.push(r.ok());
            }
            let fused_ms = times[0].map(|t| t.0);
            let mut row = vec![ds.name.to_string()];
            for t in &times {
                row.push(
                    t.map(|(ms, _)| report::f(ms, 1))
                        .unwrap_or_else(|| "FAIL".into()),
                );
            }
            for t in &times {
                row.push(
                    t.map(|(_, f)| format!("{:.0}%", f * 100.0))
                        .unwrap_or_else(|| "-".into()),
                );
            }
            table.row(row);
            for (i, t) in times.iter().enumerate() {
                if let (Some((ms, _)), Some(f)) = (t, fused_ms) {
                    speedups[i].push(ms / f);
                }
            }
            for (bi, t) in times.iter().enumerate() {
                results.push(obj(vec![
                    ("figure", s("fig8")),
                    ("dataset", s(ds.name)),
                    ("d", num(*d as f64)),
                    ("backend", s(backends[bi].name())),
                    (
                        "median_ms",
                        t.map(|(ms, _)| num(ms)).unwrap_or(Json::Null),
                    ),
                    (
                        "attention_fraction",
                        t.map(|(_, f)| num(f)).unwrap_or(Json::Null),
                    ),
                ]));
            }
        }
        table.print();
        print!("geomean speedup of fused3s (d={d}):");
        for (i, &b) in backends.iter().enumerate() {
            if b != Backend::Fused3S && !speedups[i].is_empty() {
                print!("  {:.2}x vs {}", stats::geomean(&speedups[i]), b.name());
            }
        }
        println!();
    }
    Ok(arr(results))
}
