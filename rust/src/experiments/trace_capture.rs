//! `repro trace` — capture a Chrome `trace_event` timeline of a loopback
//! serving workload (EXPERIMENTS.md §Tracing, DESIGN.md §15).
//!
//! Arms the process-global [`Tracer`](crate::trace::Tracer), drives the
//! same multi-client TCP loadgen as `repro serve`, then snapshots the
//! event ring as `results/trace.json` — loadable directly in
//! `chrome://tracing` or <https://ui.perfetto.dev>.  Each traced request
//! renders as one horizontal track (`tid` = span id) with its
//! admission / coalesce / prepare / execute / respond children nested
//! inside the request span.

use anyhow::Result;

use crate::coordinator::CoordinatorConfig;
use crate::net::NetConfig;
use crate::trace::{self, TraceConfig, TraceKind};
use crate::util::json::Json;

use super::report::Table;
use super::serve_load::{self, LoadSpec};

/// Drive the loadgen under an armed tracer and return the Chrome export.
///
/// The returned JSON is the `{"traceEvents": [...]}` object itself (not a
/// wrapper), so the written file loads in the viewer unmodified.
pub fn run(
    coord_cfg: CoordinatorConfig,
    net_cfg: NetConfig,
    spec: &LoadSpec,
    trace_cfg: TraceConfig,
) -> Result<Json> {
    let guard = trace::install(trace_cfg);
    let _workload = serve_load::run(coord_cfg, net_cfg, spec)?;

    // Snapshot after the server has drained: every span has closed, so
    // the export is complete (see the quiescence note on `snapshot`).
    let events = guard.snapshot();
    let mut t = Table::new(&["site", "begin", "end", "instant"]);
    let mut sites: Vec<(&'static str, [u64; 3])> = Vec::new();
    for e in &events {
        let k = match e.kind {
            TraceKind::Begin => 0,
            TraceKind::End => 1,
            TraceKind::Instant => 2,
        };
        match sites.iter_mut().find(|(n, _)| *n == e.site.name()) {
            Some((_, counts)) => counts[k] += 1,
            None => {
                let mut counts = [0u64; 3];
                counts[k] += 1;
                sites.push((e.site.name(), counts));
            }
        }
    }
    for (name, [b, e, i]) in &sites {
        t.row(vec![
            (*name).to_string(),
            b.to_string(),
            e.to_string(),
            i.to_string(),
        ]);
    }
    t.print();
    println!(
        "trace: {} events captured ({} recorded, {} dropped by the ring), \
         sample_rate={}",
        events.len(),
        guard.recorded(),
        guard.dropped(),
        trace_cfg.sample_rate,
    );

    Ok(guard.chrome_json())
}
