//! Streaming-update audit (EXPERIMENTS.md §Streaming): drive the full
//! delta path — wire `GraphUpdate` → coordinator `update_graph` →
//! incremental BSB rebuild → atomic plan swap — over a loopback
//! [`NetServer`](crate::net::NetServer) and report what churn costs.
//!
//! One client owns one evolving graph.  Each step it ships a batched
//! edge delta (never the CSR), mirrors the patch locally, verifies the
//! server's `new_fp` matches its own recompute (the versioned-
//! fingerprint contract end to end), then submits attention requests
//! against the patched topology by bare fingerprint reference — which
//! must hit the swapped-in plan cache, never rebuild, and never serve
//! the retired version.  The report ties together the client's byte
//! savings (delta vs. naive re-upload), the server's streaming counters
//! (dirtied vs. spliced row windows, full-rebuild fallbacks), and the
//! plan-cache hit evidence for the swap.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::{Coordinator, CoordinatorConfig};
use crate::graph::{generators, CsrGraph, GraphDelta};
use crate::kernels::Backend;
use crate::net::{NetClient, NetConfig, NetServer, WireRequest};
use crate::util::json::{self, Json};
use crate::util::prng::Rng;

use super::report::Table;

/// Workload shape for one streaming run.
#[derive(Clone)]
pub struct StreamSpec {
    /// Nodes in the evolving graph.
    pub n: usize,
    /// Delta batches applied in sequence.
    pub steps: usize,
    /// Edge edits (inserts + removes) per batch.
    pub edits_per_step: usize,
    /// Attention requests submitted against each patched version.
    pub requests_per_step: usize,
    /// Feature dim (single-head, dv = d).
    pub d: usize,
    pub backend: Backend,
    pub seed: u64,
}

impl Default for StreamSpec {
    fn default() -> StreamSpec {
        StreamSpec {
            n: 512,
            steps: 8,
            edits_per_step: 24,
            requests_per_step: 4,
            d: 32,
            backend: Backend::Fused3S,
            seed: 0x57AE_A119,
        }
    }
}

/// Run the streaming audit against a coordinator started from
/// `coord_cfg` and a loopback listener from `net_cfg`, print the tables,
/// and return the JSON report.
pub fn run(
    coord_cfg: CoordinatorConfig,
    net_cfg: NetConfig,
    spec: &StreamSpec,
) -> Result<Json> {
    let coord = Arc::new(Coordinator::start(coord_cfg)?);
    let server = NetServer::serve(coord.clone(), net_cfg)
        .context("starting loopback listener")?;
    let addr = server.local_addr();

    let mut rng = Rng::new(spec.seed);
    let mut g = generators::erdos_renyi(spec.n.max(32), 5.0, spec.seed)
        .with_self_loops();
    println!(
        "streaming on {addr}: {} steps x {} edits over n={} (d={}, backend={})",
        spec.steps,
        spec.edits_per_step,
        g.n,
        spec.d,
        spec.backend.name()
    );

    let mut client = NetClient::connect(addr, "")
        .map_err(|e| anyhow::anyhow!("connect: {e}"))?;
    let t0 = Instant::now();

    // Warm the base version: uploads the CSR once and caches its plan.
    let mut ok = 0u64;
    let mut failed = 0u64;
    submit_burst(&mut client, &g, spec, &mut rng, &mut ok, &mut failed)?;

    let mut deltas_ok = 0u64;
    let mut full_rebuilds = 0u64;
    let mut dirty_total = 0u64;
    let mut spliced_total = 0u64;
    for step in 0..spec.steps {
        let (ins, rem) = random_edits(&g, spec.edits_per_step, &mut rng);
        // Mirror the patch locally — the client-side recompute the
        // server's answer must agree with.
        let delta = GraphDelta::against(&g, ins.clone(), rem.clone());
        let (patched, report) = delta
            .applied(&g)
            .context("local mirror of the delta failed")?;
        let summary = client
            .update_graph(&g, &ins, &rem)
            .map_err(|e| anyhow::anyhow!("update_graph transport: {e}"))?
            .map_err(|e| anyhow::anyhow!("server rejected delta: {e:?}"))?;
        if summary.new_fp != patched.fingerprint() {
            bail!(
                "step {step}: server fp {:#x} != local recompute {:#x}",
                summary.new_fp,
                patched.fingerprint()
            );
        }
        if summary.dirty_rws != report.dirty_rws.len() {
            bail!(
                "step {step}: server dirtied {} RWs, local delta says {}",
                summary.dirty_rws,
                report.dirty_rws.len()
            );
        }
        deltas_ok += 1;
        full_rebuilds += u64::from(summary.full_rebuild);
        dirty_total += summary.dirty_rws as u64;
        spliced_total += summary.spliced_rws as u64;
        g = patched;
        // Replay burst against the patched version: bare fingerprint
        // references into the freshly swapped plan cache.
        submit_burst(&mut client, &g, spec, &mut rng, &mut ok, &mut failed)?;
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let stats = client.stats();
    client.close();
    let m = coord.metrics();
    let st = &m.streaming;
    let naive = stats.graph_bytes_naive;
    let saved = if naive > 0 {
        1.0 - stats.graph_bytes_uploaded as f64 / naive as f64
    } else {
        0.0
    };
    let splice_frac = if dirty_total + spliced_total > 0 {
        spliced_total as f64 / (dirty_total + spliced_total) as f64
    } else {
        0.0
    };

    let mut t = Table::new(&["metric", "value"]);
    t.row(vec!["requests ok".into(), format!("{ok} ({failed} failed)")]);
    t.row(vec!["deltas applied".into(), format!("{deltas_ok}")]);
    t.row(vec![
        "rw dirtied / spliced".into(),
        format!("{dirty_total} / {spliced_total} ({:.0}% spliced)", splice_frac * 100.0),
    ]);
    t.row(vec!["full rebuilds".into(), format!("{full_rebuilds}")]);
    t.row(vec![
        "graph bytes shipped".into(),
        format!("{} (naive {}, saved {:.0}%)", stats.graph_bytes_uploaded, naive, saved * 100.0),
    ]);
    t.row(vec![
        "server bsb-cache hit / miss".into(),
        format!("{} / {}", m.batching.cache_hits(), m.batching.cache_misses()),
    ]);
    t.row(vec!["wall".into(), format!("{wall_s:.2}s")]);
    t.print();
    println!("{}", m.report());

    let j = json::obj(vec![
        ("n", json::num(g.n as f64)),
        ("steps", json::num(spec.steps as f64)),
        ("edits_per_step", json::num(spec.edits_per_step as f64)),
        ("requests_per_step", json::num(spec.requests_per_step as f64)),
        ("d", json::num(spec.d as f64)),
        ("backend", json::s(spec.backend.name())),
        ("ok", json::num(ok as f64)),
        ("failed", json::num(failed as f64)),
        ("deltas_applied", json::num(st.deltas_applied() as f64)),
        ("rws_dirtied", json::num(st.rws_dirtied() as f64)),
        ("rws_spliced", json::num(st.rws_spliced() as f64)),
        ("full_rebuilds", json::num(st.full_rebuilds() as f64)),
        ("spliced_fraction", json::num(splice_frac)),
        ("graph_bytes_uploaded", json::num(stats.graph_bytes_uploaded as f64)),
        ("graph_bytes_naive", json::num(naive as f64)),
        ("delta_savings_ratio", json::num(saved)),
        ("cache_hits", json::num(m.batching.cache_hits() as f64)),
        ("cache_misses", json::num(m.batching.cache_misses() as f64)),
        ("wall_s", json::num(wall_s)),
    ]);

    server.shutdown();
    coord.shutdown();
    Ok(j)
}

/// Submit `requests_per_step` single-head requests against `g`, tallying
/// outcomes.  Transport failure aborts the run (loopback should never).
fn submit_burst(
    client: &mut NetClient,
    g: &CsrGraph,
    spec: &StreamSpec,
    rng: &mut Rng,
    ok: &mut u64,
    failed: &mut u64,
) -> Result<()> {
    for r in 0..spec.requests_per_step.max(1) {
        let nd = g.n * spec.d;
        let q = rng.normal_vec(nd, 1.0);
        let k = rng.normal_vec(nd, 1.0);
        let v = rng.normal_vec(nd, 1.0);
        let req = WireRequest::single_head(
            (*ok + *failed) ^ ((r as u64) << 48),
            g,
            spec.d,
            &q,
            &k,
            &v,
            1.0 / (spec.d as f32).sqrt(),
            spec.backend,
        );
        match client.submit(&req) {
            Ok(resp) if resp.result.is_ok() => *ok += 1,
            Ok(_) => *failed += 1,
            Err(e) => bail!("loopback submit transport failure: {e}"),
        }
    }
    Ok(())
}

/// Random edit batch against `g`: removes sampled from resident edges
/// (so they take effect), inserts from fresh pairs, never overlapping.
fn random_edits(
    g: &CsrGraph,
    edits: usize,
    rng: &mut Rng,
) -> (Vec<(u32, u32)>, Vec<(u32, u32)>) {
    let mut ins = Vec::new();
    let mut rem = Vec::new();
    for _ in 0..edits.max(1) {
        if rng.coin(0.5) {
            let u = rng.below(g.n);
            let row = g.row(u);
            if !row.is_empty() {
                rem.push((u as u32, row[rng.below(row.len())]));
                continue;
            }
        }
        let u = rng.below(g.n) as u32;
        let v = rng.below(g.n) as u32;
        ins.push((u, v));
    }
    // An edge in both lists is rejected as ambiguous server-side; keep
    // the batch well-formed.
    ins.retain(|e| !rem.contains(e));
    (ins, rem)
}
