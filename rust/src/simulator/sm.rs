//! Discrete-event simulation of row windows scheduled onto streaming
//! multiprocessors — the substrate substitution for Figure 7's Nsight
//! SM-active-time traces (DESIGN.md §1, substitution 4).
//!
//! Model: the GPU dispatches thread blocks (= row windows, node-parallel
//! fusion) to SMs greedily — each SM picks the next RW from the work queue
//! as soon as it finishes its current one.  An RW's execution cost is its
//! TCB count (each TCB is one SDDMM-MMA + softmax step + SpMM-MMA of fixed
//! shape) plus a fixed launch overhead.  This first-order model is exactly
//! what the paper's reordering argument relies on: long-running RWs
//! scheduled late leave SMs idle at the kernel tail.

use crate::bsb::reorder::{schedule, Order};
use crate::bsb::Bsb;
use crate::util::stats;

/// Simulation parameters.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Number of SMs (A30: 56, H100: 132).
    pub num_sms: usize,
    /// Cost per TCB (arbitrary time units).
    pub cost_per_tcb: f64,
    /// Fixed per-RW scheduling/launch overhead.
    pub launch_overhead: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        // A30 of the paper's Figure 7.
        SimConfig { num_sms: 56, cost_per_tcb: 1.0, launch_overhead: 2.0 }
    }
}

/// Per-SM active times and derived balance metrics.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Active (busy) time of each SM.
    pub active: Vec<f64>,
    /// Total wall-clock (max over SMs of finish time).
    pub makespan: f64,
    /// Sum of all RW costs (the work-conserving lower bound is
    /// `total_work / num_sms`).
    pub total_work: f64,
}

impl SimResult {
    /// Load balance in [0, 1]: mean(active) / max(active). 1.0 = perfect.
    pub fn balance(&self) -> f64 {
        let max = self.active.iter().cloned().fold(0.0, f64::max);
        if max == 0.0 {
            1.0
        } else {
            stats::mean(&self.active) / max
        }
    }

    /// Tail latency: makespan minus the ideal work-conserving bound,
    /// normalised by the bound (0 = perfect packing).
    pub fn tail_overhead(&self) -> f64 {
        let ideal = self.total_work / self.active.len() as f64;
        if ideal == 0.0 {
            0.0
        } else {
            (self.makespan - ideal) / ideal
        }
    }
}

/// Greedy list scheduling of the BSB's row windows in the given order.
pub fn simulate(bsb: &Bsb, order: Order, cfg: &SimConfig) -> SimResult {
    let sched = schedule(bsb, order);
    let costs: Vec<f64> = sched
        .iter()
        .map(|&rw| {
            let t = bsb.rw_tcbs(rw as usize);
            if t == 0 {
                0.0
            } else {
                cfg.launch_overhead + cfg.cost_per_tcb * t as f64
            }
        })
        .filter(|&c| c > 0.0)
        .collect();
    simulate_costs(&costs, cfg.num_sms)
}

/// Core list scheduler over explicit per-RW costs (exposed for tests and
/// for the coordinator's what-if planning).
pub fn simulate_costs(costs: &[f64], num_sms: usize) -> SimResult {
    assert!(num_sms > 0);
    // Greedy: next work item goes to the SM that frees up first.  A binary
    // heap keyed on finish time would be O(n log s); with s <= a few hundred
    // a linear scan is fine and allocation-free.
    let mut finish = vec![0.0f64; num_sms];
    let mut active = vec![0.0f64; num_sms];
    for &c in costs {
        let (idx, _) = finish
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        finish[idx] += c;
        active[idx] += c;
    }
    let makespan = finish.iter().cloned().fold(0.0, f64::max);
    SimResult { active, makespan, total_work: costs.iter().sum() }
}

#[cfg(test)]
mod tests {
    use crate::bsb::build;
    use crate::graph::generators;

    use super::*;

    #[test]
    fn uniform_work_is_balanced() {
        let costs = vec![1.0; 560];
        let r = simulate_costs(&costs, 56);
        assert!((r.balance() - 1.0).abs() < 1e-9);
        assert_eq!(r.makespan, 10.0);
    }

    #[test]
    fn one_giant_task_dominates() {
        let mut costs = vec![1.0; 55];
        costs.push(100.0);
        let r = simulate_costs(&costs, 56);
        assert_eq!(r.makespan, 100.0);
        assert!(r.balance() < 0.05);
    }

    #[test]
    fn lpt_order_helps_skewed_work() {
        // Longest-processing-time-first (the paper's reordering) beats
        // natural order when a heavy task sits at the end of the queue.
        let mut costs = vec![1.0f64; 300];
        costs.extend([80.0, 70.0, 60.0]);
        let natural = simulate_costs(&costs, 8);
        let mut sorted = costs.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let lpt = simulate_costs(&sorted, 8);
        assert!(
            lpt.makespan < natural.makespan,
            "lpt {} vs natural {}",
            lpt.makespan,
            natural.makespan
        );
    }

    #[test]
    fn reordering_improves_power_law_graph() {
        // The Figure 7 experiment in miniature.
        let g = generators::barabasi_albert(8192, 6, 11).with_self_loops();
        let bsb = build(&g);
        let cfg = SimConfig::default();
        let nat = simulate(&bsb, Order::Natural, &cfg);
        let reo = simulate(&bsb, Order::ByTcbDesc, &cfg);
        assert!(reo.makespan <= nat.makespan);
        assert!(reo.balance() >= nat.balance());
        // Work conserved: reordering changes schedule, not total work.
        assert!((reo.total_work - nat.total_work).abs() < 1e-6);
    }

    #[test]
    fn uniform_graph_insensitive_to_order() {
        // Pubmed-like: reordering should barely matter (paper §4.3).
        let g = generators::erdos_renyi(8192, 4.5, 12).with_self_loops();
        let bsb = build(&g);
        let cfg = SimConfig::default();
        let nat = simulate(&bsb, Order::Natural, &cfg);
        let reo = simulate(&bsb, Order::ByTcbDesc, &cfg);
        let gain = nat.makespan / reo.makespan;
        assert!(gain < 1.1, "uniform graph gained {gain}");
    }

    #[test]
    fn empty_windows_cost_nothing() {
        let g = crate::graph::CsrGraph::from_edges(160, &[(0, 1)]).unwrap();
        let bsb = build(&g);
        let r = simulate(&bsb, Order::Natural, &SimConfig::default());
        // only one non-empty RW
        assert_eq!(r.active.iter().filter(|&&a| a > 0.0).count(), 1);
    }
}
