//! SM scheduling simulator — reproduces Figure 7 (per-SM active time with
//! and without row-window reordering).

pub mod sm;

pub use sm::{simulate, SimConfig, SimResult};
