//! Scalar CSR 3S on the CPU — the PyG/DGL framework-kernel analog: per-edge
//! gather-scatter with no blocking, no tensor-core-shaped tiles, f32
//! throughout.  Also doubles as an independent reference implementation for
//! driver verification (it shares no code with the Pallas path).
//!
//! Multi-threaded variant splits rows across `std::thread::scope` workers
//! (rayon is unavailable offline).

use crate::graph::CsrGraph;

use super::op::{AttnError, ExecCtx, SparseAttentionOp};
use super::{AttentionBatch, AttentionProblem};

/// The prepared CPU-CSR baseline: no format conversion at all — the plan
/// is the graph itself plus a thread count (inherited from the planning
/// engine's pool width).
pub struct CpuCsrDriver {
    pub graph: CsrGraph,
    pub threads: usize,
}

impl CpuCsrDriver {
    pub fn new(graph: CsrGraph, threads: usize) -> CpuCsrDriver {
        CpuCsrDriver { graph, threads }
    }
}

impl SparseAttentionOp for CpuCsrDriver {
    fn execute(
        &self,
        _ctx: &mut ExecCtx<'_>,
        x: &AttentionBatch<'_>,
    ) -> Result<Vec<f32>, AttnError> {
        x.validate()?;
        if self.graph.n != x.n {
            return Err(AttnError::BadShape(format!(
                "problem n={} != prepared n={}",
                x.n, self.graph.n
            )));
        }
        // Heads run back to back (each head's row loop already shards
        // across threads); per-head results are the single-head runs
        // verbatim, so a multi-head call bit-matches a per-head loop.
        let per_head = x.n * x.dv;
        let mut out = vec![0.0f32; x.out_len()];
        for h in 0..x.heads {
            let oh = run(&self.graph, &x.head(h), self.threads);
            out[h * per_head..(h + 1) * per_head].copy_from_slice(&oh);
        }
        Ok(out)
    }
}

/// Run the full 3S over CSR.  `threads` = 1 gives the deterministic
/// reference; more threads shard rows.
pub fn run(g: &CsrGraph, x: &AttentionProblem, threads: usize) -> Vec<f32> {
    assert_eq!(g.n, x.n);
    let mut out = vec![0.0f32; x.n * x.dv];
    if threads <= 1 {
        run_rows(g, x, 0..x.n, &mut out);
        return out;
    }
    let chunk = x.n.div_ceil(threads);
    std::thread::scope(|s| {
        for (ti, slice) in out.chunks_mut(chunk * x.dv).enumerate() {
            let lo = ti * chunk;
            let hi = ((ti + 1) * chunk).min(x.n);
            // Each worker owns its pre-split output chunk and writes rows
            // in place — no per-worker staging Vec, no final copy.
            s.spawn(move || run_rows_offset(g, x, lo..hi, slice, lo));
        }
    });
    out
}

fn run_rows(
    g: &CsrGraph,
    x: &AttentionProblem,
    rows: std::ops::Range<usize>,
    out: &mut [f32],
) {
    run_rows_offset(g, x, rows, out, 0)
}

/// Row loop with the output buffer starting at row `base`.
fn run_rows_offset(
    g: &CsrGraph,
    x: &AttentionProblem,
    rows: std::ops::Range<usize>,
    out: &mut [f32],
    base: usize,
) {
    let (d, dv) = (x.d, x.dv);
    let mut scores: Vec<f32> = Vec::new();
    for i in rows {
        let nbrs = g.row(i);
        if nbrs.is_empty() {
            continue;
        }
        // SDDMM row: s_j = scale * q_i · k_j
        scores.clear();
        let qi = &x.q[i * d..(i + 1) * d];
        let mut m = f32::NEG_INFINITY;
        for &j in nbrs {
            let kj = &x.k[j as usize * d..(j as usize + 1) * d];
            let mut s = 0.0f32;
            for c in 0..d {
                s += qi[c] * kj[c];
            }
            s *= x.scale;
            m = m.max(s);
            scores.push(s);
        }
        // Stable softmax + SpMM accumulate.
        let mut l = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - m).exp();
            l += *s;
        }
        let orow = &mut out[(i - base) * dv..(i - base + 1) * dv];
        for (e, &j) in scores.iter().zip(nbrs) {
            let w = e / l;
            let vj = &x.v[j as usize * dv..(j as usize + 1) * dv];
            for c in 0..dv {
                orow[c] += w * vj[c];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::generators;
    use crate::util::prng::Rng;

    use super::super::reference;
    use super::*;

    fn mk_problem(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (
            rng.normal_vec(n * d, 1.0),
            rng.normal_vec(n * d, 1.0),
            rng.normal_vec(n * d, 1.0),
        )
    }

    #[test]
    fn matches_dense_reference() {
        let g = generators::erdos_renyi(128, 5.0, 3).with_self_loops();
        let (q, k, v) = mk_problem(128, 16, 4);
        let x = AttentionProblem::new(128, 16, &q, &k, &v, 0.25);
        let got = run(&g, &x, 1);
        let want = reference::dense_attention_host(&g, &x);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn threads_match_single() {
        let g = generators::barabasi_albert(500, 4, 5).with_self_loops();
        let (q, k, v) = mk_problem(500, 8, 6);
        let x = AttentionProblem::new(500, 8, &q, &k, &v, 1.0);
        let a = run(&g, &x, 1);
        let b = run(&g, &x, 4);
        assert_eq!(a, b);
    }

    #[test]
    fn isolated_rows_zero() {
        let g = CsrGraph::from_edges(32, &[(0, 1), (1, 0)]).unwrap();
        let (q, k, v) = mk_problem(32, 4, 7);
        let x = AttentionProblem::new(32, 4, &q, &k, &v, 1.0);
        let out = run(&g, &x, 1);
        assert!(out[2 * 4..].iter().all(|&z| z == 0.0));
        assert!(out[..4].iter().any(|&z| z != 0.0));
    }

    #[test]
    fn self_loop_only_copies_value() {
        let g = CsrGraph::from_edges(16, &[(3, 3)]).unwrap();
        let (q, k, v) = mk_problem(16, 4, 8);
        let x = AttentionProblem::new(16, 4, &q, &k, &v, 1.0);
        let out = run(&g, &x, 1);
        for c in 0..4 {
            assert!((out[3 * 4 + c] - v[3 * 4 + c]).abs() < 1e-6);
        }
    }

    use crate::graph::CsrGraph;
}
