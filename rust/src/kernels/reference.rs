//! Dense host reference for verification: the textbook
//! `O = softmax(scale·QKᵀ ⊙ A) V` in plain f64-accumulated loops.
//! O(N²·d) — tests only.

use crate::graph::CsrGraph;

use super::AttentionProblem;

/// Compute the exact masked attention output (f32 output, f64 accumulate).
pub fn dense_attention_host(g: &CsrGraph, x: &AttentionProblem) -> Vec<f32> {
    let (n, d, dv) = (x.n, x.d, x.dv);
    let mut out = vec![0.0f32; n * dv];
    for i in 0..n {
        let nbrs = g.row(i);
        if nbrs.is_empty() {
            continue;
        }
        let qi = &x.q[i * d..(i + 1) * d];
        let mut s: Vec<f64> = nbrs
            .iter()
            .map(|&j| {
                let kj = &x.k[j as usize * d..(j as usize + 1) * d];
                qi.iter()
                    .zip(kj)
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum::<f64>()
                    * x.scale as f64
            })
            .collect();
        let m = s.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut l = 0.0f64;
        for v in s.iter_mut() {
            *v = (*v - m).exp();
            l += *v;
        }
        for (e, &j) in s.iter().zip(nbrs) {
            let w = (e / l) as f32;
            let vj = &x.v[j as usize * dv..(j as usize + 1) * dv];
            for c in 0..dv {
                out[i * dv + c] += w * vj[c];
            }
        }
    }
    out
}

/// Max |a-b| between two equally-shaped outputs.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

/// Relative L2 error ||a-b|| / ||b||.
pub fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    let num: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    let den: f64 = b.iter().map(|y| (*y as f64).powi(2)).sum::<f64>().sqrt();
    if den == 0.0 {
        num
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use crate::graph::generators;
    use crate::util::prng::Rng;

    use super::*;

    #[test]
    fn softmax_weights_sum_to_one_implicitly() {
        // With V = all-ones, output rows with neighbours must be exactly 1.
        let g = generators::erdos_renyi(64, 4.0, 1).with_self_loops();
        let mut rng = Rng::new(2);
        let d = 8;
        let q = rng.normal_vec(64 * d, 1.0);
        let k = rng.normal_vec(64 * d, 1.0);
        let v = vec![1.0f32; 64 * d];
        let x = AttentionProblem::new(64, d, &q, &k, &v, 1.0);
        let out = dense_attention_host(&g, &x);
        for i in 0..64 {
            assert!((out[i * d] - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn error_metrics() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 2.0]), 0.5);
        assert!(rel_l2(&[1.0, 0.0], &[1.0, 0.0]) == 0.0);
        assert!(rel_l2(&[2.0], &[1.0]) == 1.0);
    }
}
