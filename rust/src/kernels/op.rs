//! The unified plan-based op API: [`AttnError`], [`ExecCtx`],
//! [`SparseAttentionOp`] and [`Plan`].
//!
//! Callers no longer pick among per-driver entry points: a [`Backend`]
//! plans a graph into a [`Plan`] (the per-graph preprocessing — BSB build,
//! reordering, bucket plan), and the plan executes head-batched
//! [`AttentionBatch`] problems through an [`ExecCtx`] — one seam over the
//! PJRT runtime, the offline host emulation and the pipelined
//! [`Engine`].  The coordinator caches `Arc<Plan>`s by graph fingerprint;
//! the models hold one plan per graph and issue one multi-head call per
//! layer.

use crate::bsb::reorder::Order;
use crate::bsb::Bsb;
use crate::exec::Engine;
use crate::graph::CsrGraph;
use crate::runtime::{Manifest, Runtime};

use super::backend::{Backend, Driver};
use super::fused::FusedDriver;
use super::unfused::{UnfusedDriver, UnfusedError};
use super::AttentionBatch;

/// Structured failure of the attention op API — what
/// [`AttnResponse.result`](crate::coordinator::AttnResponse) and
/// [`Plan::execute`] carry instead of stringly-typed errors.
///
/// Display renders the carried message verbatim, so response/log lines are
/// byte-identical to the previous `Result<_, String>` shape.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AttnError {
    /// Input buffers inconsistent with the declared (n, d, dv, heads).
    BadShape(String),
    /// Per-graph preprocessing (plan construction) failed — e.g. the
    /// unfused baseline's oversize-row-window refusal (the OOM analog).
    Prepare(String),
    /// Kernel execution failed (missing artifact, dispatch error, …).
    Execute(String),
    /// The op cannot run under the requested context (e.g. the dense
    /// fallback has no offline host emulation).
    Unsupported(String),
    /// The serving queue shut down before the request could complete.
    QueueClosed,
    /// The request's deadline elapsed before execution started, so the
    /// coordinator shed it instead of spending kernel time on an answer
    /// the caller has already given up on (DESIGN.md §11).
    DeadlineExceeded,
}

impl std::fmt::Display for AttnError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AttnError::BadShape(m)
            | AttnError::Prepare(m)
            | AttnError::Execute(m)
            | AttnError::Unsupported(m) => f.write_str(m),
            AttnError::QueueClosed => f.write_str("coordinator is shut down"),
            AttnError::DeadlineExceeded => {
                f.write_str("deadline exceeded before execution")
            }
        }
    }
}

impl std::error::Error for AttnError {}

impl From<UnfusedError> for AttnError {
    fn from(e: UnfusedError) -> AttnError {
        AttnError::Prepare(e.to_string())
    }
}

// The vendored `anyhow::Error` deliberately does not implement
// `std::error::Error`, so this conversion is coherent; driver internals stay
// anyhow-based and surface here as execution failures with the full `{:#}`
// context chain (the string the coordinator used to ship).
impl From<anyhow::Error> for AttnError {
    fn from(e: anyhow::Error) -> AttnError {
        AttnError::Execute(format!("{e:#}"))
    }
}

/// The execution context a [`Plan`] dispatches through — the single seam
/// unifying the PJRT runtime, the offline host-kernel emulation, and the
/// pipelined host [`Engine`] (which both modes run their gathers,
/// double-buffering and scatters on).
#[derive(Clone, Copy)]
pub enum ExecCtx<'a> {
    /// Dispatch AOT artifacts through a live PJRT runtime.
    Pjrt { rt: &'a Runtime, engine: &'a Engine },
    /// Offline host-kernel emulation (tests, benches, cold CI).
    Host { engine: &'a Engine },
}

impl<'a> ExecCtx<'a> {
    /// Production context: PJRT dispatch, host pipeline on `engine`.
    pub fn pjrt(rt: &'a Runtime, engine: &'a Engine) -> ExecCtx<'a> {
        ExecCtx::Pjrt { rt, engine }
    }

    /// Offline context: host-kernel emulation on `engine` (no artifacts).
    pub fn host(engine: &'a Engine) -> ExecCtx<'a> {
        ExecCtx::Host { engine }
    }
}

/// A graph-specialised sparse-attention op: executes every head of an
/// [`AttentionBatch`] through an [`ExecCtx`], returning head-major output
/// (`heads × n × dv`).  Implemented by the fused, unfused, dense and
/// CPU-CSR drivers (and by [`Driver`], dispatching to whichever it wraps).
pub trait SparseAttentionOp {
    /// Run the 3S computation over every head of `x`.
    fn execute(
        &self,
        ctx: &mut ExecCtx<'_>,
        x: &AttentionBatch<'_>,
    ) -> Result<Vec<f32>, AttnError>;

    /// Artifact names this op dispatches at feature dim `d` (for warmup
    /// outside the timed region).  Ops with no artifacts return nothing.
    fn executables(&self, _d: usize) -> Vec<String> {
        Vec::new()
    }
}

/// A prepared (graph-specialised) attention plan for any backend — the
/// handle the serving layer caches and the models hold per graph.
///
/// Construction *is* the paper's per-graph preprocessing (BSB build +
/// row-window reordering + bucket plan), done once and amortized over
/// every subsequent [`Plan::execute`] call — and, via [`AttentionBatch`],
/// over every head of every layer.
pub struct Plan {
    driver: Driver,
    backend: Backend,
}

impl Plan {
    /// Plan `g` for `backend`, sharding the BSB build across `engine`'s
    /// worker pool (bit-identical to the serial build).
    ///
    /// [`Backend::Auto`] is resolved here (see [`Backend::resolve_for`]):
    /// the stored plan always carries the concrete backend the planner
    /// chose, so [`Plan::backend`] tells the caller what actually ran.
    pub fn new(
        man: &Manifest,
        g: &CsrGraph,
        backend: Backend,
        engine: &Engine,
    ) -> Result<Plan, AttnError> {
        let backend = backend.resolve_for(g, man);
        let driver = Driver::prepare_on(man, g, backend, engine)
            .map_err(|e| AttnError::Prepare(format!("{e:#}")))?;
        Ok(Plan { driver, backend })
    }

    /// Plan from an already-built (compacted) BSB — the entry point for
    /// callers that cache or share preprocessing: only the cheap bucket
    /// plan is rebuilt.  Backends that plan from the graph itself (dense,
    /// CPU CSR) are unsupported here, so [`Backend::Auto`] resolves over
    /// the BSB-plannable candidates only, profiled from the BSB itself
    /// ([`GraphProfile::from_bsb`](crate::planner::GraphProfile::from_bsb)).
    pub fn from_bsb(
        man: &Manifest,
        bsb: Bsb,
        backend: Backend,
    ) -> Result<Plan, AttnError> {
        let backend = if backend == Backend::Auto {
            let profile = crate::planner::GraphProfile::from_bsb(&bsb);
            crate::planner::Planner::with_candidates(
                crate::planner::CostModel::default(),
                vec![Backend::Fused3S, Backend::Hybrid, Backend::UnfusedStable],
            )
            .decide(&profile)
            .backend
        } else {
            backend
        };
        // One backend→options mapping, shared with `Driver::prepare_on`.
        let driver = if backend == Backend::Hybrid {
            super::hybrid::HybridDriver::from_bsb(man, bsb).map(Driver::Hybrid)
        } else if let Some(opts) = backend.fused_opts() {
            FusedDriver::from_bsb(man, bsb, opts).map(Driver::Fused)
        } else if let Some(stable) = backend.unfused_stable() {
            UnfusedDriver::from_bsb(man, bsb, stable, Order::ByTcbDesc)
                .map(Driver::Unfused)
        } else {
            return Err(AttnError::Unsupported(format!(
                "backend {} plans from the graph, not a BSB",
                backend.name()
            )));
        };
        let driver = driver.map_err(|e| AttnError::Prepare(format!("{e:#}")))?;
        Ok(Plan { driver, backend })
    }

    /// Plan `g` as a partition-parallel [`ShardedPlan`] wrapped in the
    /// ordinary [`Plan`] handle: row-window shards under `policy`, one
    /// inner plan per shard, halo K/V gathers at execute time (see
    /// [`crate::shard`]).  The result is cache- and executor-compatible
    /// with single-shard plans; [`Plan::shard_stats`] reports the shape.
    ///
    /// [`ShardedPlan`]: crate::shard::ShardedPlan
    pub fn new_sharded(
        man: &Manifest,
        g: &CsrGraph,
        backend: Backend,
        engine: &Engine,
        policy: crate::shard::ShardPolicy,
    ) -> Result<Plan, AttnError> {
        let sharded =
            crate::shard::ShardedPlan::new(man, g, backend, engine, policy)?;
        Ok(Plan::from_sharded(sharded))
    }

    /// Wrap an externally built [`ShardedPlan`] (e.g. one whose per-shard
    /// plans came from the coordinator's cache via
    /// [`ShardedPlan::build`](crate::shard::ShardedPlan::build)).
    ///
    /// [`ShardedPlan`]: crate::shard::ShardedPlan
    pub fn from_sharded(sharded: crate::shard::ShardedPlan) -> Plan {
        Plan { backend: sharded.backend(), driver: Driver::Sharded(sharded) }
    }

    /// Partition shape when this plan is sharded (`None` for single-shard
    /// plans) — what the coordinator's sharding metrics record.
    pub fn shard_stats(&self) -> Option<crate::shard::ShardStats> {
        match &self.driver {
            Driver::Sharded(s) => Some(s.stats()),
            _ => None,
        }
    }

    /// The backend this plan was prepared for.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// The underlying prepared driver (for introspection: BSB stats,
    /// bucket-plan stats, chunked row windows).
    pub fn driver(&self) -> &Driver {
        &self.driver
    }

    /// Execute every head of `x` through `ctx`; head-major output.
    pub fn execute(
        &self,
        ctx: &mut ExecCtx<'_>,
        x: &AttentionBatch<'_>,
    ) -> Result<Vec<f32>, AttnError> {
        self.driver.execute(ctx, x)
    }

    /// Artifact names this plan dispatches at feature dim `d` (warmup).
    pub fn executables(&self, d: usize) -> Vec<String> {
        self.driver.executables(d)
    }
}

impl Backend {
    /// Plan a graph for this backend — the unified preprocessing entry
    /// point (`Backend::plan` + [`Plan::execute`] replace the old
    /// `Driver::run/run_with/run_offline/run_exec` family).
    pub fn plan(
        self,
        man: &Manifest,
        g: &CsrGraph,
        engine: &Engine,
    ) -> Result<Plan, AttnError> {
        Plan::new(man, g, self, engine)
    }

    /// Plan from a prebuilt BSB (see [`Plan::from_bsb`]).
    pub fn plan_from_bsb(self, man: &Manifest, bsb: Bsb) -> Result<Plan, AttnError> {
        Plan::from_bsb(man, bsb, self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attn_error_display_is_the_raw_message() {
        let e = AttnError::BadShape("q: expected 12 elements".into());
        assert_eq!(format!("{e}"), "q: expected 12 elements");
        let e = AttnError::QueueClosed;
        assert_eq!(format!("{e}"), "coordinator is shut down");
    }

    #[test]
    fn anyhow_round_trip_keeps_context_chain() {
        let inner: anyhow::Error = anyhow::anyhow!("root cause");
        let chained = inner.context("outer");
        let e = AttnError::from(chained);
        assert_eq!(format!("{e}"), "outer: root cause");
        // And back into anyhow (via the std::error::Error blanket).
        let back: anyhow::Error = e.into();
        assert_eq!(format!("{back}"), "outer: root cause");
    }

    #[test]
    fn unfused_oversize_maps_to_prepare() {
        let e = AttnError::from(UnfusedError::Oversize { rw: 3, tcbs: 999 });
        assert!(matches!(e, AttnError::Prepare(_)));
        assert!(format!("{e}").contains("OOM"));
    }
}
