//! The unfused 3S driver — the FlashSparse / framework execution model:
//! three separate executables with S and E materialised in host memory
//! between stages.  Same BSB layout and bucketing as the fused driver, so
//! benchmarking fused-vs-unfused isolates *fusion* (the paper's headline
//! comparison).
//!
//! Limitation reproduced on purpose: there is no partial/chunked path —
//! materialising S for a mega-hub row window is exactly what OOMs
//! FlashSparse/PyG on AmazonProducts in the paper (§4.2).  Oversize row
//! windows therefore return [`UnfusedError::Oversize`], which the bench
//! harness reports as the paper reports OOM (missing bars).

use anyhow::{Context, Result};

use crate::bsb::bucket::{self, Plan};
use crate::bsb::reorder::Order;
use crate::bsb::{self, Bsb};
use crate::exec::{CallExecutor, Engine, HostExecutor};
use crate::graph::CsrGraph;
use crate::runtime::buffers::Arg;
use crate::runtime::{Manifest, Runtime};
use crate::{BITMAP_WORDS, TCB_C, TCB_R};

use super::gather::CallBuffers;
use super::op::{AttnError, ExecCtx, SparseAttentionOp};
use super::{AttentionBatch, AttentionProblem};

/// Why the unfused baseline refused to run (the "OOM analog").
#[derive(Debug)]
pub enum UnfusedError {
    /// A row window's TCB count exceeds every compiled bucket: materialising
    /// its score matrix is the FlashSparse OOM case.
    Oversize { rw: u32, tcbs: usize },
}

impl std::fmt::Display for UnfusedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            UnfusedError::Oversize { rw, tcbs } => write!(
                f,
                "row window {rw} has {tcbs} TCBs > max bucket: unfused \
                 baseline would materialise an oversize S (paper: OOM)"
            ),
        }
    }
}

impl std::error::Error for UnfusedError {}

pub struct UnfusedDriver {
    pub bsb: Bsb,
    pub plan: Plan,
    pub stable_softmax: bool,
    batch: usize,
}

impl UnfusedDriver {
    pub fn new(
        man: &Manifest,
        g: &CsrGraph,
        stable_softmax: bool,
        order: Order,
    ) -> Result<UnfusedDriver> {
        UnfusedDriver::new_with(man, g, stable_softmax, order, &Engine::serial())
    }

    /// Preprocess with the BSB build sharded across the engine's pool.
    pub fn new_with(
        man: &Manifest,
        g: &CsrGraph,
        stable_softmax: bool,
        order: Order,
        engine: &Engine,
    ) -> Result<UnfusedDriver> {
        let bsb = bsb::build_with(g, &engine.pool);
        UnfusedDriver::from_bsb(man, bsb, stable_softmax, order)
    }

    /// Build a driver from an already-constructed (compacted) BSB — the
    /// pre-built-preprocessing entry point mirroring
    /// [`FusedDriver::from_bsb`](super::fused::FusedDriver::from_bsb).
    pub fn from_bsb(
        man: &Manifest,
        bsb: Bsb,
        stable_softmax: bool,
        order: Order,
    ) -> Result<UnfusedDriver> {
        let plan =
            bucket::plan(&bsb, &man.t_buckets, man.rw_batch, order, man.chunk_t);
        if let Some(c) = plan.chunked.first() {
            return Err(UnfusedError::Oversize {
                rw: c.rw,
                tcbs: bsb.rw_tcbs(c.rw as usize),
            }
            .into());
        }
        Ok(UnfusedDriver { bsb, plan, stable_softmax, batch: man.rw_batch })
    }

    /// Artifact names this driver will dispatch (for warmup).
    pub fn artifact_names(&self, d: usize) -> Vec<String> {
        let mut names = Vec::new();
        for c in &self.plan.calls {
            names.push(Manifest::sddmm_name(c.t_bucket, d));
            names.push(Manifest::softmax_name(c.t_bucket, self.stable_softmax));
            names.push(Manifest::spmm_name(c.t_bucket, d));
        }
        names.sort();
        names.dedup();
        names
    }

    /// Engine-driven execution of every head against any [`CallExecutor`]:
    /// the three PJRT stages stay back-to-back on the calling thread (the
    /// intermediates S and E still cross the host boundary — the data
    /// movement fusion removes), while gathers and scatters of
    /// neighbouring calls — and neighbouring *heads* — overlap them.
    pub fn execute_with<E: CallExecutor>(
        &self,
        x: &AttentionBatch,
        engine: &Engine,
        exec: &mut E,
    ) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; x.out_len()];
        engine.run_bucketed(
            &self.plan.calls,
            &self.bsb,
            x,
            self.batch,
            &mut out,
            |call, h, bufs| {
                let xh = x.head(h);
                exec.bucket(call.t_bucket, bufs, &xh, self.batch)
            },
        )?;
        Ok(out)
    }
}

impl SparseAttentionOp for UnfusedDriver {
    fn execute(
        &self,
        ctx: &mut ExecCtx<'_>,
        x: &AttentionBatch<'_>,
    ) -> Result<Vec<f32>, AttnError> {
        x.validate()?;
        match *ctx {
            ExecCtx::Pjrt { rt, engine } => {
                let mut exec =
                    PjrtUnfused { rt, stable_softmax: self.stable_softmax };
                self.execute_with(x, engine, &mut exec).map_err(AttnError::from)
            }
            ExecCtx::Host { engine } => {
                let mut exec = HostExecutor::new(&engine.pool);
                self.execute_with(x, engine, &mut exec).map_err(AttnError::from)
            }
        }
    }

    fn executables(&self, d: usize) -> Vec<String> {
        self.artifact_names(d)
    }
}

/// The production unfused [`CallExecutor`]: SDDMM → softmax → SpMM, each a
/// separate PJRT dispatch with host-materialised intermediates.
struct PjrtUnfused<'a> {
    rt: &'a Runtime,
    stable_softmax: bool,
}

impl CallExecutor for PjrtUnfused<'_> {
    fn bucket(
        &mut self,
        t: usize,
        bufs: &CallBuffers,
        x: &AttentionProblem,
        batch: usize,
    ) -> Result<Vec<f32>> {
        // Stage 1: SDDMM -> S materialised on host.
        let sddmm = self
            .rt
            .executable(&Manifest::sddmm_name(t, x.d))
            .with_context(|| format!("sddmm t={t} d={}", x.d))?;
        let sq = [batch, TCB_R, x.d];
        let sk = [batch, t * TCB_C, x.d];
        let sv = [batch, t * TCB_C, x.dv];
        let sbm = [batch, t, BITMAP_WORDS];
        let s = self.rt.run_exe_raw(
            &sddmm,
            &[
                Arg::F32(&bufs.q, &sq),
                Arg::F32(&bufs.k, &sk),
                Arg::I32(&bufs.bm, &sbm),
            ],
        )?;

        // Stage 2: softmax -> E materialised on host.
        let softmax = self
            .rt
            .executable(&Manifest::softmax_name(t, self.stable_softmax))
            .with_context(|| format!("softmax t={t}"))?;
        let e = self.rt.run_exe(&softmax, &[s.into_iter().next().unwrap()])?;

        // Stage 3: SpMM.
        let spmm = self
            .rt
            .executable(&Manifest::spmm_name(t, x.dv))
            .with_context(|| format!("spmm t={t} d={}", x.dv))?;
        let e0 = e.into_iter().next().unwrap();
        let o = self
            .rt
            .run_exe_raw(&spmm, &[e0.as_arg(), Arg::F32(&bufs.v, &sv)])?;
        o.into_iter()
            .next()
            .expect("spmm executable returns one output")
            .into_f32()
    }

    fn partial(
        &mut self,
        _chunk_t: usize,
        _bufs: &CallBuffers,
        _x: &AttentionProblem,
        _batch: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        // Unreachable by construction: `new` rejects plans with chunked RWs
        // (the FlashSparse OOM analog), so the engine never dispatches a
        // partial call for this driver.
        Err(UnfusedError::Oversize { rw: 0, tcbs: 0 }.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use std::path::Path;

    fn manifest() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok()
    }

    #[test]
    fn oversize_graph_rejected_like_oom() {
        let Some(man) = manifest() else { return };
        let g = generators::star(50_000); // hub RW: 6250 TCBs
        let err = UnfusedDriver::new(&man, &g, true, Order::Natural)
            .err()
            .expect("must refuse");
        let msg = format!("{err:#}");
        assert!(msg.contains("OOM"), "{msg}");
    }

    #[test]
    fn normal_graph_accepted() {
        let Some(man) = manifest() else { return };
        let g = generators::erdos_renyi(256, 4.0, 1);
        assert!(UnfusedDriver::new(&man, &g, true, Order::Natural).is_ok());
    }
}
