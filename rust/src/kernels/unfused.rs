//! The unfused 3S driver — the FlashSparse / framework execution model:
//! three separate executables with S and E materialised in host memory
//! between stages.  Same BSB layout and bucketing as the fused driver, so
//! benchmarking fused-vs-unfused isolates *fusion* (the paper's headline
//! comparison).
//!
//! Limitation reproduced on purpose: there is no partial/chunked path —
//! materialising S for a mega-hub row window is exactly what OOMs
//! FlashSparse/PyG on AmazonProducts in the paper (§4.2).  Oversize row
//! windows therefore return [`UnfusedError::Oversize`], which the bench
//! harness reports as the paper reports OOM (missing bars).

use anyhow::{Context, Result};

use crate::bsb::bucket::{self, Plan};
use crate::bsb::reorder::Order;
use crate::bsb::{self, Bsb};
use crate::graph::CsrGraph;
use crate::runtime::buffers::Arg;
use crate::runtime::{Manifest, Runtime};
use crate::{BITMAP_WORDS, TCB_C, TCB_R};

use super::gather::{self, CallBuffers};
use super::AttentionProblem;

/// Why the unfused baseline refused to run (the "OOM analog").
#[derive(Debug)]
pub enum UnfusedError {
    /// A row window's TCB count exceeds every compiled bucket: materialising
    /// its score matrix is the FlashSparse OOM case.
    Oversize { rw: u32, tcbs: usize },
}

impl std::fmt::Display for UnfusedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            UnfusedError::Oversize { rw, tcbs } => write!(
                f,
                "row window {rw} has {tcbs} TCBs > max bucket: unfused \
                 baseline would materialise an oversize S (paper: OOM)"
            ),
        }
    }
}

impl std::error::Error for UnfusedError {}

pub struct UnfusedDriver {
    pub bsb: Bsb,
    pub plan: Plan,
    pub stable_softmax: bool,
    batch: usize,
}

impl UnfusedDriver {
    pub fn new(
        man: &Manifest,
        g: &CsrGraph,
        stable_softmax: bool,
        order: Order,
    ) -> Result<UnfusedDriver> {
        let bsb = bsb::build(g);
        let plan =
            bucket::plan(&bsb, &man.t_buckets, man.rw_batch, order, man.chunk_t);
        if let Some(c) = plan.chunked.first() {
            return Err(UnfusedError::Oversize {
                rw: c.rw,
                tcbs: bsb.rw_tcbs(c.rw as usize),
            }
            .into());
        }
        Ok(UnfusedDriver { bsb, plan, stable_softmax, batch: man.rw_batch })
    }

    pub fn executables(&self, d: usize) -> Vec<String> {
        let mut names = Vec::new();
        for c in &self.plan.calls {
            names.push(Manifest::sddmm_name(c.t_bucket, d));
            names.push(Manifest::softmax_name(c.t_bucket, self.stable_softmax));
            names.push(Manifest::spmm_name(c.t_bucket, d));
        }
        names.sort();
        names.dedup();
        names
    }

    /// Run the three-stage pipeline.  Between stages the intermediates
    /// S and E cross the host boundary — the data movement fusion removes.
    pub fn run(&self, rt: &Runtime, x: &AttentionProblem) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; x.n * x.dv];
        let mut bufs = CallBuffers::default();
        for call in &self.plan.calls {
            let t = call.t_bucket;
            gather::gather_call(&mut bufs, &call.rws, t, &self.bsb, x, self.batch);

            // Stage 1: SDDMM -> S materialised on host.
            let sddmm = rt
                .executable(&Manifest::sddmm_name(t, x.d))
                .with_context(|| format!("sddmm t={t} d={}", x.d))?;
            let sq = [self.batch, TCB_R, x.d];
            let sk = [self.batch, t * TCB_C, x.d];
            let sv = [self.batch, t * TCB_C, x.dv];
            let sbm = [self.batch, t, BITMAP_WORDS];
            let s = rt.run_exe_raw(
                &sddmm,
                &[
                    Arg::F32(&bufs.q, &sq),
                    Arg::F32(&bufs.k, &sk),
                    Arg::I32(&bufs.bm, &sbm),
                ],
            )?;

            // Stage 2: softmax -> E materialised on host.
            let softmax = rt
                .executable(&Manifest::softmax_name(t, self.stable_softmax))
                .with_context(|| format!("softmax t={t}"))?;
            let e = rt.run_exe(&softmax, &[s.into_iter().next().unwrap()])?;

            // Stage 3: SpMM.
            let spmm = rt
                .executable(&Manifest::spmm_name(t, x.dv))
                .with_context(|| format!("spmm t={t} d={}", x.dv))?;
            let e0 = e.into_iter().next().unwrap();
            let o = rt.run_exe_raw(
                &spmm,
                &[e0.as_arg(), Arg::F32(&bufs.v, &sv)],
            )?;
            gather::scatter_call(&mut out, o[0].as_f32()?, &call.rws, x.n, x.dv);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use std::path::Path;

    fn manifest() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok()
    }

    #[test]
    fn oversize_graph_rejected_like_oom() {
        let Some(man) = manifest() else { return };
        let g = generators::star(50_000); // hub RW: 6250 TCBs
        let err = UnfusedDriver::new(&man, &g, true, Order::Natural)
            .err()
            .expect("must refuse");
        let msg = format!("{err:#}");
        assert!(msg.contains("OOM"), "{msg}");
    }

    #[test]
    fn normal_graph_accepted() {
        let Some(man) = manifest() else { return };
        let g = generators::erdos_renyi(256, 4.0, 1);
        assert!(UnfusedDriver::new(&man, &g, true, Order::Natural).is_ok());
    }
}
