//! The Fused3S driver — the paper's system, end to end:
//! BSB build → row-window reordering → bucketed batching → fused kernel
//! dispatches → chunk merges → scatter.

use anyhow::{bail, Context, Result};

use crate::bsb::bucket::{self, Plan};
use crate::bsb::reorder::Order;
use crate::bsb::{self, Bsb};
use crate::graph::CsrGraph;
use crate::runtime::buffers::Arg;
use crate::runtime::{Manifest, Runtime};
use crate::{BITMAP_WORDS, TCB_C, TCB_R};

use super::gather::{self, CallBuffers};
use super::AttentionProblem;

/// Driver configuration (the ablation axes of §4.3).
#[derive(Clone, Copy, Debug)]
pub struct FusedOpts {
    /// "bf16" (the paper's mixed precision) or "f32" (DF-GNN analog).
    pub precision: &'static str,
    /// "splitc" (default) or "splitr" (warp-partition ablation).
    pub variant: &'static str,
    /// Column compaction on (BSB) or off (BCSR-like blocks).
    pub compact: bool,
    /// Row-window schedule.
    pub order: Order,
}

impl Default for FusedOpts {
    fn default() -> Self {
        FusedOpts {
            precision: "bf16",
            variant: "splitc",
            compact: true,
            order: Order::ByTcbDesc,
        }
    }
}

/// Preprocessed state for one graph (the paper's "preprocessing, alongside
/// sparse matrix compaction" — done once, reused across inference calls).
pub struct FusedDriver {
    pub bsb: Bsb,
    pub plan: Plan,
    pub opts: FusedOpts,
    batch: usize,
    chunk_t: usize,
}

impl FusedDriver {
    pub fn new(man: &Manifest, g: &CsrGraph, opts: FusedOpts) -> Result<FusedDriver> {
        let bsb = if opts.compact {
            bsb::build(g)
        } else {
            bsb::build_bcsr_like(g)
        };
        let plan = bucket::plan(
            &bsb,
            &man.t_buckets,
            man.rw_batch,
            opts.order,
            man.chunk_t,
        );
        Ok(FusedDriver {
            bsb,
            plan,
            opts,
            batch: man.rw_batch,
            chunk_t: man.chunk_t,
        })
    }

    /// Artifact names this driver will dispatch (for warmup).
    pub fn executables(&self, d: usize) -> Vec<String> {
        let mut names: Vec<String> = self
            .plan
            .calls
            .iter()
            .map(|c| {
                Manifest::fused3s_name(
                    c.t_bucket,
                    d,
                    self.opts.precision,
                    self.opts.variant,
                )
            })
            .collect();
        if !self.plan.chunked.is_empty() {
            names.push(Manifest::partial_name(self.chunk_t, d));
        }
        names.sort();
        names.dedup();
        names
    }

    /// Run the fused 3S over the prepared graph.
    pub fn run(&self, rt: &Runtime, x: &AttentionProblem) -> Result<Vec<f32>> {
        if x.d != x.dv {
            bail!("fused driver requires d == dv (GAT path uses model::gat)");
        }
        let mut out = vec![0.0f32; x.n * x.dv];
        let mut bufs = CallBuffers::default();

        // Regular bucketed dispatches, in schedule order.
        for call in &self.plan.calls {
            let name = Manifest::fused3s_name(
                call.t_bucket,
                x.d,
                self.opts.precision,
                self.opts.variant,
            );
            let exe = rt.executable(&name).with_context(|| {
                format!(
                    "bucket t={} d={} ({}/{}): artifact missing",
                    call.t_bucket, x.d, self.opts.precision, self.opts.variant
                )
            })?;
            gather::gather_call(
                &mut bufs, &call.rws, call.t_bucket, &self.bsb, x, self.batch,
            );
            let (sq, sk, sv, sbm) = shapes(self.batch, call.t_bucket, x.d, x.dv);
            let outs = rt.run_exe_raw(
                &exe,
                &[
                    Arg::F32(&bufs.q, &sq),
                    Arg::F32(&bufs.k, &sk),
                    Arg::F32(&bufs.v, &sv),
                    Arg::I32(&bufs.bm, &sbm),
                ],
            )?;
            let o = outs[0].as_f32()?;
            gather::scatter_call(&mut out, o, &call.rws, x.n, x.dv);
        }

        // Oversize row windows: chunked through the partial executable.
        if !self.plan.chunked.is_empty() {
            self.run_chunked(rt, x, &mut out, &mut bufs)?;
        }
        Ok(out)
    }

    fn run_chunked(
        &self,
        rt: &Runtime,
        x: &AttentionProblem,
        out: &mut [f32],
        bufs: &mut CallBuffers,
    ) -> Result<()> {
        let name = Manifest::partial_name(self.chunk_t, x.d);
        let exe = rt
            .executable(&name)
            .with_context(|| format!("partial artifact {name} missing"))?;
        // Work items: (rw, chunk index).
        let items: Vec<(u32, usize)> = self
            .plan
            .chunked
            .iter()
            .flat_map(|c| (0..c.n_chunks).map(move |i| (c.rw, i)))
            .collect();
        // Per-RW merge state, keyed by rw id.
        let mut merge: std::collections::HashMap<u32, MergeState> =
            std::collections::HashMap::new();
        for batch_items in items.chunks(self.batch) {
            bufs.reset(self.batch, self.chunk_t, x.d, x.dv);
            for (slot, &(rw, ci)) in batch_items.iter().enumerate() {
                let rw_us = rw as usize;
                gather::gather_q(&mut bufs.q, slot, rw_us, x);
                let t = self.bsb.rw_tcbs(rw_us);
                let t_lo = ci * self.chunk_t;
                let t_hi = ((ci + 1) * self.chunk_t).min(t);
                gather::gather_kv_range(
                    bufs, slot, &self.bsb, rw_us, t_lo, t_hi, self.chunk_t, x,
                );
            }
            let (sq, sk, sv, sbm) = shapes(self.batch, self.chunk_t, x.d, x.dv);
            let outs = rt.run_exe_raw(
                &exe,
                &[
                    Arg::F32(&bufs.q, &sq),
                    Arg::F32(&bufs.k, &sk),
                    Arg::F32(&bufs.v, &sv),
                    Arg::I32(&bufs.bm, &sbm),
                ],
            )?;
            let (o, m, l) = (outs[0].as_f32()?, outs[1].as_f32()?, outs[2].as_f32()?);
            for (slot, &(rw, _)) in batch_items.iter().enumerate() {
                let st = merge
                    .entry(rw)
                    .or_insert_with(|| MergeState::new(x.dv));
                st.merge(
                    &o[slot * TCB_R * x.dv..(slot + 1) * TCB_R * x.dv],
                    &m[slot * TCB_R..(slot + 1) * TCB_R],
                    &l[slot * TCB_R..(slot + 1) * TCB_R],
                );
            }
        }
        for (rw, st) in merge {
            gather::scatter_slot(out, &st.o, 0, rw as usize, x.n, x.dv);
        }
        Ok(())
    }
}

/// Input shapes of a fused3s-style call at (batch, t, d, dv).
fn shapes(
    b: usize,
    t: usize,
    d: usize,
    dv: usize,
) -> ([usize; 3], [usize; 3], [usize; 3], [usize; 3]) {
    (
        [b, TCB_R, d],
        [b, t * TCB_C, d],
        [b, t * TCB_C, dv],
        [b, t, BITMAP_WORDS],
    )
}

/// Online-softmax merge across row-window chunks (the host half of the
/// flash-decoding-style combine; see `fused3s.merge_partials` in Python —
/// `rust/tests/` pins the two against each other through the kernel).
pub struct MergeState {
    pub o: Vec<f32>,
    pub m: [f32; TCB_R],
    pub l: [f32; TCB_R],
    dv: usize,
}

impl MergeState {
    pub fn new(dv: usize) -> MergeState {
        MergeState {
            o: vec![0.0; TCB_R * dv],
            m: [f32::NEG_INFINITY; TCB_R],
            l: [0.0; TCB_R],
            dv,
        }
    }

    /// Fold one normalised chunk (o2, m2, l2) into the state.
    pub fn merge(&mut self, o2: &[f32], m2: &[f32], l2: &[f32]) {
        for r in 0..TCB_R {
            let m_new = self.m[r].max(m2[r]);
            if m_new == f32::NEG_INFINITY {
                continue; // both sides empty
            }
            let w1 = self.l[r] * safe_exp(self.m[r] - m_new);
            let w2 = l2[r] * safe_exp(m2[r] - m_new);
            let denom = w1 + w2;
            let row = &mut self.o[r * self.dv..(r + 1) * self.dv];
            if denom > 0.0 {
                let (a, b) = (w1 / denom, w2 / denom);
                for (c, slot) in row.iter_mut().enumerate() {
                    *slot = a * *slot + b * o2[r * self.dv + c];
                }
            }
            self.m[r] = m_new;
            self.l[r] = denom;
        }
    }
}

#[inline]
fn safe_exp(x: f32) -> f32 {
    // exp(-inf - -inf) would be NaN; callers guarantee x <= 0 or -inf.
    if x == f32::NEG_INFINITY {
        0.0
    } else {
        x.exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_two_chunks_matches_manual_softmax() {
        // Row attends to 2 values in chunk A (logits 1, 2) and 1 value in
        // chunk B (logit 3).  Chunk states mimic the kernel's outputs.
        let dv = 1;
        let mut st = MergeState::new(dv);
        // Chunk A: m=2, l=e^{-1}+1, o = (e^{-1}*10 + 1*20)/(e^{-1}+1)
        let la = (-1.0f32).exp() + 1.0;
        let oa = ((-1.0f32).exp() * 10.0 + 20.0) / la;
        st.merge(&[oa; 16], &[2.0; 16], &[la; 16]);
        // Chunk B: m=3, l=1, o=30
        st.merge(&[30.0; 16], &[3.0; 16], &[1.0; 16]);
        // Exact softmax over logits (1,2,3) with values (10,20,30):
        let z: f32 = (1f32).exp() + (2f32).exp() + (3f32).exp();
        let expect =
            ((1f32).exp() * 10.0 + (2f32).exp() * 20.0 + (3f32).exp() * 30.0) / z;
        assert!((st.o[0] - expect).abs() < 1e-4, "{} vs {expect}", st.o[0]);
    }

    #[test]
    fn merge_with_empty_side_is_identity() {
        let dv = 2;
        let mut st = MergeState::new(dv);
        st.merge(
            &[5.0; 32],
            &[1.0; 16],
            &[2.0; 16],
        );
        let before = st.o.clone();
        // Empty chunk: m=-inf, l=0.
        st.merge(&[0.0; 32], &[f32::NEG_INFINITY; 16], &[0.0; 16]);
        assert_eq!(st.o, before);
        // Merging into an empty state adopts the chunk.
        let mut st2 = MergeState::new(dv);
        st2.merge(&[0.0; 32], &[f32::NEG_INFINITY; 16], &[0.0; 16]);
        assert!(st2.o.iter().all(|&v| v == 0.0));
        st2.merge(&[7.0; 32], &[0.5; 16], &[1.5; 16]);
        assert!((st2.o[0] - 7.0).abs() < 1e-6);
    }

    #[test]
    fn merge_is_order_invariant() {
        let dv = 1;
        let chunks: Vec<([f32; 16], [f32; 16], [f32; 16])> = vec![
            ([1.0; 16], [0.0; 16], [1.0; 16]),
            ([2.0; 16], [5.0; 16], [0.5; 16]),
            ([3.0; 16], [-2.0; 16], [2.0; 16]),
        ];
        let run = |order: &[usize]| {
            let mut st = MergeState::new(dv);
            for &i in order {
                let (o, m, l) = &chunks[i];
                st.merge(o, m, l);
            }
            st.o[0]
        };
        let a = run(&[0, 1, 2]);
        let b = run(&[2, 0, 1]);
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
}
