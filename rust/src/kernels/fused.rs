//! The Fused3S driver — the paper's system, end to end:
//! BSB build → row-window reordering → bucketed batching → fused kernel
//! dispatches → chunk merges → scatter.

use anyhow::{bail, Context, Result};

use crate::bsb::bucket::{self, Plan};
use crate::bsb::reorder::Order;
use crate::bsb::{self, Bsb};
use crate::exec::{CallExecutor, Engine, HostExecutor};
use crate::graph::CsrGraph;
use crate::runtime::buffers::Arg;
use crate::runtime::{Manifest, Runtime};
use crate::{BITMAP_WORDS, TCB_C, TCB_R};

use super::gather::{self, CallBuffers};
use super::op::{AttnError, ExecCtx, SparseAttentionOp};
use super::{AttentionBatch, AttentionProblem};

/// Driver configuration (the ablation axes of §4.3).
#[derive(Clone, Copy, Debug)]
pub struct FusedOpts {
    /// "bf16" (the paper's mixed precision) or "f32" (DF-GNN analog).
    pub precision: &'static str,
    /// "splitc" (default) or "splitr" (warp-partition ablation).
    pub variant: &'static str,
    /// Column compaction on (BSB) or off (BCSR-like blocks).
    pub compact: bool,
    /// Row-window schedule.
    pub order: Order,
}

impl Default for FusedOpts {
    fn default() -> Self {
        FusedOpts {
            precision: "bf16",
            variant: "splitc",
            compact: true,
            order: Order::ByTcbDesc,
        }
    }
}

/// Preprocessed state for one graph (the paper's "preprocessing, alongside
/// sparse matrix compaction" — done once, reused across inference calls).
pub struct FusedDriver {
    pub bsb: Bsb,
    pub plan: Plan,
    pub opts: FusedOpts,
    batch: usize,
    chunk_t: usize,
}

impl FusedDriver {
    pub fn new(man: &Manifest, g: &CsrGraph, opts: FusedOpts) -> Result<FusedDriver> {
        FusedDriver::new_with(man, g, opts, &Engine::serial())
    }

    /// Preprocess with the BSB build sharded across the engine's pool
    /// (bit-identical to the serial build; see `bsb::build_with`).
    pub fn new_with(
        man: &Manifest,
        g: &CsrGraph,
        opts: FusedOpts,
        engine: &Engine,
    ) -> Result<FusedDriver> {
        let bsb = if opts.compact {
            bsb::build_with(g, &engine.pool)
        } else {
            bsb::build_bcsr_like_with(g, &engine.pool)
        };
        FusedDriver::from_bsb(man, bsb, opts)
    }

    /// Build a driver from an already-constructed BSB — the entry point for
    /// callers that cache or share preprocessing (the coordinator's
    /// fingerprint cache): only the cheap bucket plan is rebuilt.  The BSB
    /// must have been built with the same `opts.compact` mode.
    pub fn from_bsb(man: &Manifest, bsb: Bsb, opts: FusedOpts) -> Result<FusedDriver> {
        let plan = bucket::plan(
            &bsb,
            &man.t_buckets,
            man.rw_batch,
            opts.order,
            man.chunk_t,
        );
        Ok(FusedDriver {
            bsb,
            plan,
            opts,
            batch: man.rw_batch,
            chunk_t: man.chunk_t,
        })
    }

    /// Artifact names this driver will dispatch (for warmup).
    pub fn artifact_names(&self, d: usize) -> Vec<String> {
        let mut names: Vec<String> = self
            .plan
            .calls
            .iter()
            .map(|c| {
                Manifest::fused3s_name(
                    c.t_bucket,
                    d,
                    self.opts.precision,
                    self.opts.variant,
                )
            })
            .collect();
        if !self.plan.chunked.is_empty() {
            names.push(Manifest::partial_name(self.chunk_t, d));
        }
        names.sort();
        names.dedup();
        names
    }

    /// Engine-driven execution of every head against any [`CallExecutor`]
    /// — the PJRT runtime online, or `exec::HostExecutor` offline
    /// (benches/tests).  Head-major output; bit-identical across engine
    /// policies, and bit-identical to a per-head loop.
    pub fn execute_with<E: CallExecutor>(
        &self,
        x: &AttentionBatch,
        engine: &Engine,
        exec: &mut E,
    ) -> Result<Vec<f32>> {
        if x.d != x.dv {
            bail!("fused driver requires d == dv (GAT path uses model::gat)");
        }
        let mut out = vec![0.0f32; x.out_len()];

        // Regular bucketed dispatches, pipelined in schedule order with
        // heads inner (bitmaps staged once per call, not once per head).
        engine.run_bucketed(
            &self.plan.calls,
            &self.bsb,
            x,
            self.batch,
            &mut out,
            |call, h, bufs| {
                let xh = x.head(h);
                exec.bucket(call.t_bucket, bufs, &xh, self.batch)
            },
        )?;

        // Oversize row windows: chunked through the partial executable.
        if !self.plan.chunked.is_empty() {
            run_chunked(
                &self.bsb,
                &self.plan.chunked,
                self.chunk_t,
                self.batch,
                x,
                engine,
                exec,
                &mut out,
            )?;
        }
        Ok(out)
    }
}

/// Execute oversize (chunked) row windows through the partial executable and
/// fold the per-chunk softmax states on the host.  Shared by the fused
/// driver and the hybrid driver's wide path — chunked RWs always run this
/// wide-geometry code regardless of how the rest of the plan is routed, so
/// chunk boundaries and merge order (and hence f32 results) are identical
/// across backends.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_chunked<E: CallExecutor>(
    bsb: &Bsb,
    chunked: &[bucket::ChunkedRw],
    chunk_t: usize,
    batch: usize,
    x: &AttentionBatch,
    engine: &Engine,
    exec: &mut E,
    out: &mut [f32],
) -> Result<()> {
    // Work items: (rw, chunk index), batched to the call width, then
    // swept per head (chunk-batch major, heads inner).
    let items: Vec<(u32, usize)> = chunked
        .iter()
        .flat_map(|c| (0..c.n_chunks).map(move |i| (c.rw, i)))
        .collect();
    let batches: Vec<&[(u32, usize)]> = items.chunks(batch).collect();
    let heads = x.heads;
    // Per-(head, RW) merge state.  The pipeline commits scatter in item
    // order, so each head's merge sequence — and hence its f32 result —
    // is identical to a single-head run under every policy.
    let mut merge: std::collections::HashMap<(usize, u32), MergeState> =
        std::collections::HashMap::new();
    engine.run_pipeline(
        batches.len() * heads,
        |i, bufs| {
            let (bi, h) = (i / heads, i % heads);
            let xh = x.head(h);
            gather::gather_partial_call_with(
                &engine.pool,
                bufs,
                batches[bi],
                chunk_t,
                bsb,
                &xh,
                batch,
            );
        },
        |i, bufs| {
            let h = i % heads;
            let xh = x.head(h);
            let (o, m, l) = exec.partial(chunk_t, bufs, &xh, batch)?;
            Ok(vec![o, m, l])
        },
        |i, outs| {
            let (bi, h) = (i / heads, i % heads);
            let (o, m, l) = (&outs[0], &outs[1], &outs[2]);
            for (slot, &(rw, _)) in batches[bi].iter().enumerate() {
                let st = merge
                    .entry((h, rw))
                    .or_insert_with(|| MergeState::new(x.dv));
                st.merge(
                    &o[slot * TCB_R * x.dv..(slot + 1) * TCB_R * x.dv],
                    &m[slot * TCB_R..(slot + 1) * TCB_R],
                    &l[slot * TCB_R..(slot + 1) * TCB_R],
                );
            }
        },
    )?;
    let per_head = x.n * x.dv;
    for ((h, rw), st) in merge {
        let out_h = &mut out[h * per_head..(h + 1) * per_head];
        gather::scatter_slot(out_h, &st.o, 0, rw as usize, x.n, x.dv);
    }
    Ok(())
}

impl SparseAttentionOp for FusedDriver {
    fn execute(
        &self,
        ctx: &mut ExecCtx<'_>,
        x: &AttentionBatch<'_>,
    ) -> Result<Vec<f32>, AttnError> {
        x.validate()?;
        if x.d != x.dv {
            return Err(AttnError::BadShape(
                "fused driver requires d == dv (GAT path uses model::gat)".into(),
            ));
        }
        match *ctx {
            ExecCtx::Pjrt { rt, engine } => {
                let mut exec = PjrtFused { rt, opts: self.opts };
                self.execute_with(x, engine, &mut exec).map_err(AttnError::from)
            }
            ExecCtx::Host { engine } => {
                let mut exec = HostExecutor::new(&engine.pool);
                self.execute_with(x, engine, &mut exec).map_err(AttnError::from)
            }
        }
    }

    fn executables(&self, d: usize) -> Vec<String> {
        self.artifact_names(d)
    }
}

/// The production [`CallExecutor`]: dispatches staged buffers to the AOT
/// fused3s executables through PJRT.
struct PjrtFused<'a> {
    rt: &'a Runtime,
    opts: FusedOpts,
}

impl CallExecutor for PjrtFused<'_> {
    fn bucket(
        &mut self,
        t_bucket: usize,
        bufs: &CallBuffers,
        x: &AttentionProblem,
        batch: usize,
    ) -> Result<Vec<f32>> {
        let name = Manifest::fused3s_name(
            t_bucket,
            x.d,
            self.opts.precision,
            self.opts.variant,
        );
        let exe = self.rt.executable(&name).with_context(|| {
            format!(
                "bucket t={} d={} ({}/{}): artifact missing",
                t_bucket, x.d, self.opts.precision, self.opts.variant
            )
        })?;
        let (sq, sk, sv, sbm) = shapes(batch, t_bucket, x.d, x.dv);
        let outs = self.rt.run_exe_raw(
            &exe,
            &[
                Arg::F32(&bufs.q, &sq),
                Arg::F32(&bufs.k, &sk),
                Arg::F32(&bufs.v, &sv),
                Arg::I32(&bufs.bm, &sbm),
            ],
        )?;
        outs.into_iter()
            .next()
            .expect("fused3s executable returns one output")
            .into_f32()
    }

    fn partial(
        &mut self,
        chunk_t: usize,
        bufs: &CallBuffers,
        x: &AttentionProblem,
        batch: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let name = Manifest::partial_name(chunk_t, x.d);
        let exe = self
            .rt
            .executable(&name)
            .with_context(|| format!("partial artifact {name} missing"))?;
        let (sq, sk, sv, sbm) = shapes(batch, chunk_t, x.d, x.dv);
        let outs = self.rt.run_exe_raw(
            &exe,
            &[
                Arg::F32(&bufs.q, &sq),
                Arg::F32(&bufs.k, &sk),
                Arg::F32(&bufs.v, &sv),
                Arg::I32(&bufs.bm, &sbm),
            ],
        )?;
        let mut it = outs.into_iter();
        let (Some(o), Some(m), Some(l)) = (it.next(), it.next(), it.next())
        else {
            bail!("partial executable must return (o, m, l)");
        };
        Ok((o.into_f32()?, m.into_f32()?, l.into_f32()?))
    }
}

/// Input shapes of a fused3s-style call at (batch, t, d, dv).
fn shapes(
    b: usize,
    t: usize,
    d: usize,
    dv: usize,
) -> ([usize; 3], [usize; 3], [usize; 3], [usize; 3]) {
    (
        [b, TCB_R, d],
        [b, t * TCB_C, d],
        [b, t * TCB_C, dv],
        [b, t, BITMAP_WORDS],
    )
}

/// Online-softmax merge across row-window chunks (the host half of the
/// flash-decoding-style combine; see `fused3s.merge_partials` in Python —
/// `rust/tests/` pins the two against each other through the kernel).
pub struct MergeState {
    pub o: Vec<f32>,
    pub m: [f32; TCB_R],
    pub l: [f32; TCB_R],
    dv: usize,
}

impl MergeState {
    pub fn new(dv: usize) -> MergeState {
        MergeState {
            o: vec![0.0; TCB_R * dv],
            m: [f32::NEG_INFINITY; TCB_R],
            l: [0.0; TCB_R],
            dv,
        }
    }

    /// Fold one normalised chunk (o2, m2, l2) into the state.
    pub fn merge(&mut self, o2: &[f32], m2: &[f32], l2: &[f32]) {
        for r in 0..TCB_R {
            let m_new = self.m[r].max(m2[r]);
            if m_new == f32::NEG_INFINITY {
                continue; // both sides empty
            }
            let w1 = self.l[r] * safe_exp(self.m[r] - m_new);
            let w2 = l2[r] * safe_exp(m2[r] - m_new);
            let denom = w1 + w2;
            let row = &mut self.o[r * self.dv..(r + 1) * self.dv];
            if denom > 0.0 {
                let (a, b) = (w1 / denom, w2 / denom);
                for (c, slot) in row.iter_mut().enumerate() {
                    *slot = a * *slot + b * o2[r * self.dv + c];
                }
            }
            self.m[r] = m_new;
            self.l[r] = denom;
        }
    }
}

#[inline]
fn safe_exp(x: f32) -> f32 {
    // exp(-inf - -inf) would be NaN; callers guarantee x <= 0 or -inf.
    if x == f32::NEG_INFINITY {
        0.0
    } else {
        x.exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_two_chunks_matches_manual_softmax() {
        // Row attends to 2 values in chunk A (logits 1, 2) and 1 value in
        // chunk B (logit 3).  Chunk states mimic the kernel's outputs.
        let dv = 1;
        let mut st = MergeState::new(dv);
        // Chunk A: m=2, l=e^{-1}+1, o = (e^{-1}*10 + 1*20)/(e^{-1}+1)
        let la = (-1.0f32).exp() + 1.0;
        let oa = ((-1.0f32).exp() * 10.0 + 20.0) / la;
        st.merge(&[oa; 16], &[2.0; 16], &[la; 16]);
        // Chunk B: m=3, l=1, o=30
        st.merge(&[30.0; 16], &[3.0; 16], &[1.0; 16]);
        // Exact softmax over logits (1,2,3) with values (10,20,30):
        let z: f32 = (1f32).exp() + (2f32).exp() + (3f32).exp();
        let expect =
            ((1f32).exp() * 10.0 + (2f32).exp() * 20.0 + (3f32).exp() * 30.0) / z;
        assert!((st.o[0] - expect).abs() < 1e-4, "{} vs {expect}", st.o[0]);
    }

    #[test]
    fn merge_with_empty_side_is_identity() {
        let dv = 2;
        let mut st = MergeState::new(dv);
        st.merge(
            &[5.0; 32],
            &[1.0; 16],
            &[2.0; 16],
        );
        let before = st.o.clone();
        // Empty chunk: m=-inf, l=0.
        st.merge(&[0.0; 32], &[f32::NEG_INFINITY; 16], &[0.0; 16]);
        assert_eq!(st.o, before);
        // Merging into an empty state adopts the chunk.
        let mut st2 = MergeState::new(dv);
        st2.merge(&[0.0; 32], &[f32::NEG_INFINITY; 16], &[0.0; 16]);
        assert!(st2.o.iter().all(|&v| v == 0.0));
        st2.merge(&[7.0; 32], &[0.5; 16], &[1.5; 16]);
        assert!((st2.o[0] - 7.0).abs() < 1e-6);
    }

    #[test]
    fn merge_is_order_invariant() {
        let dv = 1;
        let chunks: Vec<([f32; 16], [f32; 16], [f32; 16])> = vec![
            ([1.0; 16], [0.0; 16], [1.0; 16]),
            ([2.0; 16], [5.0; 16], [0.5; 16]),
            ([3.0; 16], [-2.0; 16], [2.0; 16]),
        ];
        let run = |order: &[usize]| {
            let mut st = MergeState::new(dv);
            for &i in order {
                let (o, m, l) = &chunks[i];
                st.merge(o, m, l);
            }
            st.o[0]
        };
        let a = run(&[0, 1, 2]);
        let b = run(&[2, 0, 1]);
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
}
