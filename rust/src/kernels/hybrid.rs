//! The hybrid dense/sparse driver — per-row-window geometry dispatch
//! (DESIGN.md §12): wide 16×8 TCB calls for the windows that fill them,
//! narrow 8×1 tiles for scattered windows, dense 16×1 lanes for near-dense
//! ones, all inside one plan with one output buffer.
//!
//! The three paths partition the row windows
//! ([`geometry::hybrid_covers`]), so their scatters touch disjoint output
//! rows and no cross-path merge exists — the only merge seam is the wide
//! path's existing oversize-chunk fold ([`fused::run_chunked`]), shared
//! verbatim with the fused driver.  Outputs are bit-identical to the fused
//! driver (and to the all-wide hybrid reference) because every path visits
//! a row's nonzero columns in ascending original-column order with the same
//! scalar op sequence; `rust/tests/packing_equivalence.rs` pins this.
//!
//! No PJRT lane artifacts exist yet, so the hybrid backend executes only
//! under [`ExecCtx::Host`]; the planner's cost model knows it as a
//! host-feasible family and the PJRT candidate set excludes it.

use anyhow::Result;

use crate::bsb::geometry::{self, HybridPlan};
use crate::bsb::{self, Bsb};
use crate::exec::{CallExecutor, Engine, HostExecutor};
use crate::graph::CsrGraph;
use crate::runtime::Manifest;

use super::fused;
use super::op::{AttnError, ExecCtx, SparseAttentionOp};
use super::AttentionBatch;

/// Preprocessed state for one graph: the shared BSB plus the routed
/// mixed-geometry plan.  Unlike the fused driver, the hybrid driver
/// accepts `d != dv` — its kernels are the general host lane/slot kernels,
/// not the square AOT artifacts.
pub struct HybridDriver {
    pub bsb: Bsb,
    pub hplan: HybridPlan,
    batch: usize,
    chunk_t: usize,
}

impl HybridDriver {
    /// Preprocess `g`: BSB build sharded across the engine's pool
    /// (bit-identical to the serial build), then the hybrid routing plan.
    pub fn new_with(
        man: &Manifest,
        g: &CsrGraph,
        engine: &Engine,
    ) -> Result<HybridDriver> {
        let bsb = bsb::build_with(g, &engine.pool);
        HybridDriver::from_bsb(man, bsb)
    }

    /// Build from an already-constructed (compacted) BSB — the cache entry
    /// point; only the routing + lane extraction is rebuilt.
    pub fn from_bsb(man: &Manifest, bsb: Bsb) -> Result<HybridDriver> {
        HybridDriver::from_bsb_with(man, bsb, &geometry::RouteParams::default())
    }

    /// [`HybridDriver::from_bsb`] with explicit router knobs.  The
    /// differential suite forces every window wide
    /// (`RouteParams { narrow: false, dense: false, .. }`) to obtain the
    /// 16-row all-wide reference that the routed plan must bit-match.
    pub fn from_bsb_with(
        man: &Manifest,
        bsb: Bsb,
        params: &geometry::RouteParams,
    ) -> Result<HybridDriver> {
        let hplan = geometry::plan_hybrid_with(
            &bsb,
            &man.t_buckets,
            man.rw_batch,
            crate::bsb::reorder::Order::ByTcbDesc,
            man.chunk_t,
            params,
        );
        Ok(HybridDriver {
            bsb,
            hplan,
            batch: man.rw_batch,
            chunk_t: man.chunk_t,
        })
    }

    /// Engine-driven execution of every head against any [`CallExecutor`]
    /// with lane support.  Head-major output; bit-identical across engine
    /// policies and to the fused driver on the same problem.
    pub fn execute_with<E: CallExecutor>(
        &self,
        x: &AttentionBatch,
        engine: &Engine,
        exec: &mut E,
    ) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; x.out_len()];

        // Wide-routed windows: the unchanged bucketed path.
        engine.run_bucketed(
            &self.hplan.wide.calls,
            &self.bsb,
            x,
            self.batch,
            &mut out,
            |call, h, bufs| {
                let xh = x.head(h);
                exec.bucket(call.t_bucket, bufs, &xh, self.batch)
            },
        )?;

        // Oversize row windows: always wide, chunked through the shared
        // partial path so chunk boundaries and merge order match the fused
        // driver exactly.
        if !self.hplan.wide.chunked.is_empty() {
            fused::run_chunked(
                &self.bsb,
                &self.hplan.wide.chunked,
                self.chunk_t,
                self.batch,
                x,
                engine,
                exec,
                &mut out,
            )?;
        }

        // Narrow-routed windows: 8-row × 1-col tiles.
        engine.run_lane_calls(
            &self.hplan.narrow,
            &self.hplan.narrow_calls,
            x,
            self.batch,
            &mut out,
            |call, h, bufs| {
                let xh = x.head(h);
                exec.lanes(
                    self.hplan.narrow.rows,
                    call.t_lanes,
                    bufs,
                    &xh,
                    self.batch,
                )
            },
        )?;

        // Dense-routed windows: 16-row × 1-col lanes.
        engine.run_lane_calls(
            &self.hplan.dense,
            &self.hplan.dense_calls,
            x,
            self.batch,
            &mut out,
            |call, h, bufs| {
                let xh = x.head(h);
                exec.lanes(
                    self.hplan.dense.rows,
                    call.t_lanes,
                    bufs,
                    &xh,
                    self.batch,
                )
            },
        )?;

        Ok(out)
    }
}

impl SparseAttentionOp for HybridDriver {
    fn execute(
        &self,
        ctx: &mut ExecCtx<'_>,
        x: &AttentionBatch<'_>,
    ) -> Result<Vec<f32>, AttnError> {
        x.validate()?;
        match *ctx {
            ExecCtx::Pjrt { .. } => Err(AttnError::Unsupported(
                "hybrid backend has no PJRT lane artifacts; it executes \
                 under the host context only"
                    .into(),
            )),
            ExecCtx::Host { engine } => {
                let mut exec = HostExecutor::new(&engine.pool);
                self.execute_with(x, engine, &mut exec).map_err(AttnError::from)
            }
        }
    }

    fn executables(&self, _d: usize) -> Vec<String> {
        Vec::new()
    }
}
