//! Host-side kernel drivers: each one runs the 3S pattern
//! `O = softmax(QKᵀ·scale ⊙ A) V` end-to-end over a graph, through a
//! different execution strategy.  These are the series of the paper's
//! Figures 5/6:
//!
//! * [`fused::FusedDriver`] — **Fused3S** (the paper's system): BSB
//!   compaction + bucketed batching + the fused Pallas kernel; bf16 mixed
//!   precision; oversize row windows chunked and merged on host.
//! * [`fused::FusedDriver`] with f32/no-compaction — the **DF-GNN** analog
//!   (fused but fp32, generic block format).
//! * [`unfused::UnfusedDriver`] — the **FlashSparse** analog: separate
//!   SDDMM / softmax / SpMM executables with intermediates materialised in
//!   host memory; naive- and stable-softmax variants.
//! * [`dense::DenseDriver`] — whole-graph dense masked attention (the
//!   framework dense fallback; also the graph-scale oracle).
//! * [`cpu_csr`] — scalar CSR gather-scatter on the CPU (the PyG/DGL
//!   framework-kernel analog), single- or multi-threaded.
//! * [`reference`] — O(N²d) dense host reference used only for verification.

pub mod backend;
pub mod backward;
pub mod cpu_csr;
pub mod dense;
pub mod fused;
pub mod gather;
pub mod reference;
pub mod unfused;

pub use backend::{Backend, Driver};

/// A 3S attention problem over a graph's node features (row-major slices).
#[derive(Clone, Copy, Debug)]
pub struct AttentionProblem<'a> {
    pub n: usize,
    /// Q/K feature dim.
    pub d: usize,
    /// V / output feature dim (= d except for GAT-style rank-2 scores).
    pub dv: usize,
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    /// Score scale (1/sqrt(d) for transformer heads, 1 for raw 3S).
    pub scale: f32,
}

impl<'a> AttentionProblem<'a> {
    pub fn new(
        n: usize,
        d: usize,
        q: &'a [f32],
        k: &'a [f32],
        v: &'a [f32],
        scale: f32,
    ) -> Self {
        assert_eq!(q.len(), n * d);
        assert_eq!(k.len(), n * d);
        assert_eq!(v.len(), n * d);
        AttentionProblem { n, d, dv: d, q, k, v, scale }
    }
}
