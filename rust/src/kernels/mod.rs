//! Host-side kernels: the plan/batch API over the 3S pattern
//! `O = softmax(QKᵀ·scale ⊙ A) V`.
//!
//! The public surface has two halves:
//!
//! * **Problems** — [`AttentionBatch`]: `heads` independent Q/K/V problems
//!   sharing one graph (head-major layout), the unit every kernel entry
//!   point consumes.  [`AttentionProblem`] is the single-head view
//!   ([`AttentionBatch::single`] adapts one into a one-head batch with zero
//!   copies; [`AttentionBatch::head`] slices one head back out).
//! * **Plans** — [`Plan`] is a graph-specialised, ready-to-execute op
//!   produced by [`Backend::plan`] (or [`Plan::from_bsb`] when the BSB is
//!   already built).  [`Plan::execute`] runs every head of a batch through
//!   an [`ExecCtx`] — the PJRT runtime online or the host emulation
//!   offline — amortizing the BSB structure across all heads.  The
//!   [`SparseAttentionOp`] trait is the seam each driver implements.
//!
//! The drivers behind the trait are the series of the paper's Figures
//! 5/6:
//!
//! * [`fused::FusedDriver`] — **Fused3S** (the paper's system): BSB
//!   compaction + bucketed batching + the fused Pallas kernel; bf16 mixed
//!   precision; oversize row windows chunked and merged on host.
//! * [`fused::FusedDriver`] with f32/no-compaction — the **DF-GNN** analog
//!   (fused but fp32, generic block format).
//! * [`hybrid::HybridDriver`] — **Fused3S + per-window geometry routing**
//!   (DESIGN.md §12): wide 16×8 TCBs, narrow 8×1 tiles and dense 16×1
//!   lanes mixed per row window; bit-identical to Fused3S, host-only.
//! * [`unfused::UnfusedDriver`] — the **FlashSparse** analog: separate
//!   SDDMM / softmax / SpMM executables with intermediates materialised in
//!   host memory; naive- and stable-softmax variants.
//! * [`dense::DenseDriver`] — whole-graph dense masked attention (the
//!   framework dense fallback; also the graph-scale oracle).
//! * [`cpu_csr::CpuCsrDriver`] — scalar CSR gather-scatter on the CPU (the
//!   PyG/DGL framework-kernel analog), single- or multi-threaded.
//! * [`reference`] — O(N²d) dense host reference used only for verification.

pub mod backend;
pub mod backward;
pub mod cpu_csr;
pub mod dense;
pub mod fused;
pub mod gather;
pub mod hybrid;
pub mod op;
pub mod reference;
pub mod unfused;

pub use backend::{Backend, Driver};
pub use cpu_csr::CpuCsrDriver;
pub use op::{AttnError, ExecCtx, Plan, SparseAttentionOp};

/// A 3S attention problem over a graph's node features (row-major slices).
///
/// This is the **single-head view**: the kernel entry points consume
/// [`AttentionBatch`]; drivers slice per-head problems back out of a batch
/// with [`AttentionBatch::head`] when staging each head's buffers.
#[derive(Clone, Copy, Debug)]
pub struct AttentionProblem<'a> {
    pub n: usize,
    /// Q/K feature dim.
    pub d: usize,
    /// V / output feature dim (= d except for GAT-style rank-2 scores).
    pub dv: usize,
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    /// Score scale (1/sqrt(d) for transformer heads, 1 for raw 3S).
    pub scale: f32,
}

impl<'a> AttentionProblem<'a> {
    pub fn new(
        n: usize,
        d: usize,
        q: &'a [f32],
        k: &'a [f32],
        v: &'a [f32],
        scale: f32,
    ) -> Self {
        assert_eq!(q.len(), n * d);
        assert_eq!(k.len(), n * d);
        assert_eq!(v.len(), n * d);
        AttentionProblem { n, d, dv: d, q, k, v, scale }
    }
}

/// A head-batched 3S attention problem: `heads` independent Q/K/V problems
/// over the **same graph**, head-major layout (head `h`'s rows occupy
/// `q[h*n*d .. (h+1)*n*d]`, and likewise for `k`/`v` at their dims).
///
/// This is the unit [`Plan::execute`] consumes.  Batching heads is the
/// lever behind the paper's §4.5 end-to-end result: one BSB build, one
/// bucket plan and one set of staged TCB bitmaps are amortized over every
/// head, and the host pipeline overlaps head *h+1*'s gather with head
/// *h*'s dispatch instead of idling between per-head calls.
///
/// Output layout is head-major to match: `heads × n × dv`, head `h`'s
/// rows at `out[h*n*dv .. (h+1)*n*dv]`.
#[derive(Clone, Copy, Debug)]
pub struct AttentionBatch<'a> {
    pub n: usize,
    /// Q/K feature dim (per head).
    pub d: usize,
    /// V / output feature dim (= d except for GAT-style rank-2 scores).
    pub dv: usize,
    /// Number of heads sharing the graph (≥ 1).
    pub heads: usize,
    /// Head-major Q: `heads × n × d`.
    pub q: &'a [f32],
    /// Head-major K: `heads × n × d`.
    pub k: &'a [f32],
    /// Head-major V: `heads × n × dv`.
    pub v: &'a [f32],
    /// Score scale shared by every head (1/sqrt(d) for transformer heads).
    pub scale: f32,
}

impl<'a> AttentionBatch<'a> {
    /// Build a head-batched problem, asserting buffer sizes.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        n: usize,
        d: usize,
        dv: usize,
        heads: usize,
        q: &'a [f32],
        k: &'a [f32],
        v: &'a [f32],
        scale: f32,
    ) -> Self {
        assert!(heads > 0, "a batch needs at least one head");
        assert_eq!(q.len(), heads * n * d);
        assert_eq!(k.len(), heads * n * d);
        assert_eq!(v.len(), heads * n * dv);
        AttentionBatch { n, d, dv, heads, q, k, v, scale }
    }

    /// Zero-copy adapter: a single-head problem *is* a one-head batch.
    pub fn single(x: &AttentionProblem<'a>) -> AttentionBatch<'a> {
        AttentionBatch {
            n: x.n,
            d: x.d,
            dv: x.dv,
            heads: 1,
            q: x.q,
            k: x.k,
            v: x.v,
            scale: x.scale,
        }
    }

    /// Zero-copy view of head `h` as a single-head problem.
    pub fn head(&self, h: usize) -> AttentionProblem<'a> {
        debug_assert!(h < self.heads);
        let qk = self.n * self.d;
        let vl = self.n * self.dv;
        AttentionProblem {
            n: self.n,
            d: self.d,
            dv: self.dv,
            q: &self.q[h * qk..(h + 1) * qk],
            k: &self.k[h * qk..(h + 1) * qk],
            v: &self.v[h * vl..(h + 1) * vl],
            scale: self.scale,
        }
    }

    /// Length of the head-major output this batch produces.
    pub fn out_len(&self) -> usize {
        self.heads * self.n * self.dv
    }

    /// Structured shape validation (the non-panicking sibling of
    /// [`AttentionBatch::new`]'s asserts).
    pub fn validate(&self) -> Result<(), AttnError> {
        if self.heads == 0 {
            return Err(AttnError::BadShape("heads must be ≥ 1".into()));
        }
        let want_qk = self.heads * self.n * self.d;
        let want_v = self.heads * self.n * self.dv;
        for (name, len, want) in [
            ("q", self.q.len(), want_qk),
            ("k", self.k.len(), want_qk),
            ("v", self.v.len(), want_v),
        ] {
            if len != want {
                return Err(AttnError::BadShape(format!(
                    "{name}: expected {want} elements (heads={} × n={} × dim), got {len}",
                    self.heads, self.n
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_is_one_head_zero_copy() {
        let q = vec![1.0f32; 8];
        let k = vec![2.0f32; 8];
        let v = vec![3.0f32; 8];
        let x = AttentionProblem::new(4, 2, &q, &k, &v, 0.5);
        let b = AttentionBatch::single(&x);
        assert_eq!(b.heads, 1);
        assert_eq!(b.out_len(), 8);
        assert!(std::ptr::eq(b.q, x.q));
        let h0 = b.head(0);
        assert!(std::ptr::eq(h0.q, x.q));
        assert_eq!(h0.scale, 0.5);
    }

    #[test]
    fn head_slices_are_disjoint_and_ordered() {
        let n = 3;
        let d = 2;
        let dv = 4;
        let heads = 2;
        let q: Vec<f32> = (0..heads * n * d).map(|i| i as f32).collect();
        let k = q.clone();
        let v: Vec<f32> = (0..heads * n * dv).map(|i| i as f32).collect();
        let b = AttentionBatch::new(n, d, dv, heads, &q, &k, &v, 1.0);
        assert_eq!(b.head(0).q, &q[..n * d]);
        assert_eq!(b.head(1).q, &q[n * d..]);
        assert_eq!(b.head(1).v, &v[n * dv..]);
        assert_eq!(b.head(1).dv, dv);
    }

    #[test]
    fn validate_reports_bad_shapes() {
        let q = vec![0.0f32; 8];
        let k = vec![0.0f32; 8];
        let v = vec![0.0f32; 7];
        let b = AttentionBatch { n: 4, d: 2, dv: 2, heads: 1, q: &q, k: &k, v: &v, scale: 1.0 };
        assert!(matches!(b.validate(), Err(AttnError::BadShape(_))));
        let v = vec![0.0f32; 8];
        let b = AttentionBatch { n: 4, d: 2, dv: 2, heads: 1, q: &q, k: &k, v: &v, scale: 1.0 };
        assert!(b.validate().is_ok());
    }
}
