//! Dense masked attention over the whole (padded) graph — the framework
//! dense fallback of PyG-style implementations, and the executable-level
//! oracle for small graphs.  O(N²d): only sensible for the smallest
//! datasets, which is exactly the paper's observation about dense baselines.

use anyhow::{bail, Result};

use crate::graph::CsrGraph;
use crate::runtime::{Manifest, Runtime, Tensor};

use super::op::{AttnError, ExecCtx, SparseAttentionOp};
use super::{AttentionBatch, AttentionProblem};

pub struct DenseDriver {
    /// Padded size (a compiled dense_n bucket).
    pub n_pad: usize,
    mask: Vec<i32>,
    n: usize,
}

/// The compiled dense problem sizes (must match aot.py's DENSE_N): a graph
/// pads up to the smallest entry ≥ its n, and anything beyond the largest
/// is infeasible for this backend.  Public because the adaptive planner's
/// cost model gates the dense candidate on the same ladder.
pub const DENSE_N: &[usize] = &[256, 1024];

impl DenseDriver {
    pub fn new(man: &Manifest, g: &CsrGraph) -> Result<DenseDriver> {
        let Some(&n_pad) = DENSE_N.iter().find(|&&c| c >= g.n) else {
            bail!(
                "graph n={} exceeds the largest dense bucket ({}): dense \
                 baseline infeasible (the paper's dense-fallback OOM case)",
                g.n,
                DENSE_N.last().unwrap()
            );
        };
        // Touch the manifest so a missing artifact fails at prepare time.
        let _ = man;
        let mut mask = vec![0i32; n_pad * n_pad];
        for u in 0..g.n {
            for &v in g.row(u) {
                mask[u * n_pad + v as usize] = 1;
            }
        }
        Ok(DenseDriver { n_pad, mask, n: g.n })
    }

    pub fn artifact_names(&self, d: usize) -> Vec<String> {
        vec![Manifest::dense_name(self.n_pad, d)]
    }

    /// One whole-graph dense dispatch for a single head (the compiled
    /// executable is per-head; [`SparseAttentionOp::execute`] loops heads).
    pub fn run(&self, rt: &Runtime, x: &AttentionProblem) -> Result<Vec<f32>> {
        if x.n != self.n {
            bail!("problem n={} != prepared n={}", x.n, self.n);
        }
        let np = self.n_pad;
        let pad = |src: &[f32], d: usize, scale: f32| {
            let mut v = vec![0.0f32; np * d];
            for row in 0..x.n {
                let dst = &mut v[row * d..(row + 1) * d];
                dst.copy_from_slice(&src[row * d..(row + 1) * d]);
                if scale != 1.0 {
                    for s in dst.iter_mut() {
                        *s *= scale;
                    }
                }
            }
            v
        };
        let name = Manifest::dense_name(np, x.d);
        let outs = rt.run(
            &name,
            &[
                Tensor::f32(pad(x.q, x.d, x.scale), vec![np, x.d]),
                Tensor::f32(pad(x.k, x.d, 1.0), vec![np, x.d]),
                Tensor::f32(pad(x.v, x.dv, 1.0), vec![np, x.dv]),
                Tensor::i32(self.mask.clone(), vec![np, np]),
            ],
        )?;
        let o = outs[0].as_f32()?;
        Ok(o[..x.n * x.dv].to_vec())
    }
}

impl SparseAttentionOp for DenseDriver {
    fn execute(
        &self,
        ctx: &mut ExecCtx<'_>,
        x: &AttentionBatch<'_>,
    ) -> Result<Vec<f32>, AttnError> {
        x.validate()?;
        let rt = match *ctx {
            ExecCtx::Pjrt { rt, .. } => rt,
            ExecCtx::Host { .. } => {
                return Err(AttnError::Unsupported(
                    "dense backend has no offline host emulation (needs artifacts)"
                        .into(),
                ));
            }
        };
        let per_head = x.n * x.dv;
        let mut out = vec![0.0f32; x.out_len()];
        for h in 0..x.heads {
            let oh = self.run(rt, &x.head(h)).map_err(AttnError::from)?;
            out[h * per_head..(h + 1) * per_head].copy_from_slice(&oh);
        }
        Ok(out)
    }

    fn executables(&self, d: usize) -> Vec<String> {
        self.artifact_names(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use std::path::Path;

    #[test]
    fn oversized_graph_rejected() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let Ok(man) = Manifest::load(&dir) else { return };
        let g = generators::erdos_renyi(5000, 2.0, 1);
        assert!(DenseDriver::new(&man, &g).is_err());
    }

    #[test]
    fn bucket_padding_choice() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let Ok(man) = Manifest::load(&dir) else { return };
        let g = generators::erdos_renyi(100, 2.0, 1);
        let d = DenseDriver::new(&man, &g).unwrap();
        assert_eq!(d.n_pad, 256);
        let g = generators::erdos_renyi(300, 2.0, 1);
        let d = DenseDriver::new(&man, &g).unwrap();
        assert_eq!(d.n_pad, 1024);
    }
}
