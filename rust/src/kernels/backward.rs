//! Backward-pass driver — the paper's §6 extension at the system level.
//!
//! Runs the fused backward kernel (`fused3s_bwd_*` artifacts: dV/dP/dS/dQ/dK̂
//! in one program, E recomputed in-kernel) over the same BSB bucketing as
//! the forward driver, then **scatter-adds** the per-gathered-row dK̂/dV̂
//! gradients back to dK/dV: a column appears in every row window that
//! attends to it, so the host reduction mirrors the forward gather — the
//! reverse of the paper's "SpMM and SDDMM in reverse order" observation at
//! the memory-movement level.

use anyhow::{bail, Context, Result};

use crate::bsb::bucket::{self, Plan};
use crate::bsb::builder::PAD_COL;
use crate::bsb::reorder::Order;
use crate::bsb::{self, Bsb};
use crate::graph::CsrGraph;
use crate::runtime::buffers::Arg;
use crate::runtime::{Manifest, Runtime};
use crate::{BITMAP_WORDS, TCB_C, TCB_R};

use super::gather::{self, CallBuffers};
use super::AttentionProblem;

/// Buckets with compiled backward artifacts (aot.py: t ∈ {8, 32}).
pub const BWD_BUCKETS: &[usize] = &[8, 32];

/// Executes one staged backward kernel call — the dispatch seam mirroring
/// [`CallExecutor`](crate::exec::CallExecutor): PJRT against the
/// `fused3s_bwd_*` artifacts online, or the host emulation offline
/// (`exec::HostExecutor` implements this trait too, which is what the
/// finite-difference gradcheck in `rust/tests/backward_gradcheck.rs` runs).
pub trait BackwardExecutor {
    /// One bucketed backward call: staged Q̂ (pre-scaled) / K̂ / V̂ / bitmaps
    /// plus the gathered upstream-gradient blocks `d_out` (same layout as
    /// Q, unscaled).  Returns `(gq, gk, gv)`:
    /// `gq` is `batch * 16 * d` (gradients w.r.t. the *pre-scaled* Q
    /// blocks), `gk`/`gv` are `batch * t * TCB_C * d` per gathered lane.
    fn backward(
        &mut self,
        t_bucket: usize,
        bufs: &CallBuffers,
        d_out: &[f32],
        x: &AttentionProblem,
        batch: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)>;
}

/// Gradients of the 3S attention w.r.t. its inputs.
pub struct Gradients {
    pub dq: Vec<f32>,
    pub dk: Vec<f32>,
    pub dv: Vec<f32>,
}

pub struct BackwardDriver {
    bsb: Bsb,
    plan: Plan,
    batch: usize,
}

impl BackwardDriver {
    pub fn new(man: &Manifest, g: &CsrGraph) -> Result<BackwardDriver> {
        let bsb = bsb::build(g);
        let plan = bucket::plan(
            &bsb,
            BWD_BUCKETS,
            man.rw_batch,
            Order::ByTcbDesc,
            man.chunk_t,
        );
        if let Some(c) = plan.chunked.first() {
            bail!(
                "row window {} has {} TCBs > backward bucket max {}: \
                 chunked backward is future work (needs dS cross-chunk \
                 reduction state)",
                c.rw,
                bsb.rw_tcbs(c.rw as usize),
                BWD_BUCKETS.last().unwrap()
            );
        }
        Ok(BackwardDriver { bsb, plan, batch: man.rw_batch })
    }

    /// Buckets the plan actually dispatches (sorted, deduplicated) — lets
    /// tests assert a graph exercises the intended `BWD_BUCKETS` entries.
    pub fn buckets_used(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self.plan.calls.iter().map(|c| c.t_bucket).collect();
        b.sort_unstable();
        b.dedup();
        b
    }

    /// Compute (dQ, dK, dV) for upstream gradients `d_out` (n × d) through
    /// the PJRT runtime (`fused3s_bwd_*` artifacts).
    pub fn run(
        &self,
        rt: &Runtime,
        x: &AttentionProblem,
        d_out: &[f32],
    ) -> Result<Gradients> {
        self.run_exec(x, d_out, &mut PjrtBackward { rt })
    }

    /// Compute (dQ, dK, dV) against any [`BackwardExecutor`] — the seam the
    /// offline gradcheck drives with the host emulation.
    pub fn run_exec<E: BackwardExecutor>(
        &self,
        x: &AttentionProblem,
        d_out: &[f32],
        exec: &mut E,
    ) -> Result<Gradients> {
        if x.d != x.dv {
            bail!("backward driver requires d == dv");
        }
        if d_out.len() != x.n * x.dv {
            bail!("d_out: expected {} elements", x.n * x.dv);
        }
        let d = x.d;
        let mut dq = vec![0.0f32; x.n * d];
        let mut dk = vec![0.0f32; x.n * d];
        let mut dv = vec![0.0f32; x.n * d];
        let mut bufs = CallBuffers::default();
        let mut do_buf: Vec<f32> = Vec::new();

        for call in &self.plan.calls {
            let t = call.t_bucket;
            gather::gather_call(&mut bufs, &call.rws, t, &self.bsb, x, self.batch);
            // Gather dO row-window blocks (same layout as Q, unscaled).
            do_buf.clear();
            do_buf.resize(self.batch * TCB_R * d, 0.0);
            let xo = AttentionProblem { scale: 1.0, q: d_out, ..*x };
            for (slot, &rw) in call.rws.iter().enumerate() {
                gather::gather_q(&mut do_buf, slot, rw as usize, &xo);
            }
            let (gq, gk, gv) = exec.backward(t, &bufs, &do_buf, x, self.batch)?;

            // dQ: one owner per row — plain scatter (note: the artifact bakes
            // scale=1; the forward pre-scales Q by `scale`, so by the chain
            // rule dQ_original = scale * dQ_prescaled).
            for (slot, &rw) in call.rws.iter().enumerate() {
                let base = slot * TCB_R * d;
                for r in 0..TCB_R {
                    let row = rw as usize * TCB_R + r;
                    if row >= x.n {
                        break;
                    }
                    for c in 0..d {
                        dq[row * d + c] += x.scale * gq[base + r * d + c];
                    }
                }
            }
            // dK̂/dV̂: scatter-ADD per gathered column (columns repeat across
            // row windows).  No extra scale on dK: the kernel saw the
            // pre-scaled Q, so its dK̂ = dSᵀ·(scale·Q) already carries it.
            for (slot, &rw) in call.rws.iter().enumerate() {
                let rw = rw as usize;
                let t_rw = self.bsb.rw_tcbs(rw);
                for j in 0..t_rw {
                    let cols = self.bsb.tcb_cols(rw, j);
                    for (ci, &col) in cols.iter().enumerate() {
                        if col == PAD_COL {
                            continue;
                        }
                        let col = col as usize;
                        let src = (slot * t * TCB_C + j * TCB_C + ci) * d;
                        for c in 0..d {
                            dk[col * d + c] += gk[src + c];
                            dv[col * d + c] += gv[src + c];
                        }
                    }
                }
            }
        }
        Ok(Gradients { dq, dk, dv })
    }
}

/// The production [`BackwardExecutor`]: dispatches staged buffers to the
/// AOT `fused3s_bwd_*` executables through PJRT.
struct PjrtBackward<'a> {
    rt: &'a Runtime,
}

impl BackwardExecutor for PjrtBackward<'_> {
    fn backward(
        &mut self,
        t: usize,
        bufs: &CallBuffers,
        d_out: &[f32],
        x: &AttentionProblem,
        batch: usize,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let d = x.d;
        let name = format!("fused3s_bwd_t{t}_d{d}");
        let exe = self
            .rt
            .executable(&name)
            .with_context(|| format!("backward artifact {name}"))?;
        let sq = [batch, TCB_R, d];
        let skv = [batch, t * TCB_C, d];
        let sbm = [batch, t, BITMAP_WORDS];
        let outs = self.rt.run_exe_raw(
            &exe,
            &[
                Arg::F32(&bufs.q, &sq),
                Arg::F32(&bufs.k, &skv),
                Arg::F32(&bufs.v, &skv),
                Arg::I32(&bufs.bm, &sbm),
                Arg::F32(d_out, &sq),
            ],
        )?;
        let mut it = outs.into_iter();
        let (Some(gq), Some(gk), Some(gv)) = (it.next(), it.next(), it.next())
        else {
            bail!("{name} must return (dQ, dK̂, dV̂)");
        };
        Ok((gq.into_f32()?, gk.into_f32()?, gv.into_f32()?))
    }
}

/// Exact host reference for the gradients (dense, f64 accumulation):
/// analytic backward of `O = softmax(scale·QKᵀ ⊙ A) V` row by row.
pub fn backward_reference(
    g: &CsrGraph,
    x: &AttentionProblem,
    d_out: &[f32],
) -> Gradients {
    let (n, d) = (x.n, x.d);
    let mut dq = vec![0.0f32; n * d];
    let mut dk = vec![0.0f32; n * d];
    let mut dv = vec![0.0f32; n * d];
    for i in 0..n {
        let nbrs = g.row(i);
        if nbrs.is_empty() {
            continue;
        }
        let qi = &x.q[i * d..(i + 1) * d];
        let doi = &d_out[i * d..(i + 1) * d];
        // forward softmax weights
        let mut s: Vec<f64> = nbrs
            .iter()
            .map(|&j| {
                let kj = &x.k[j as usize * d..(j as usize + 1) * d];
                qi.iter()
                    .zip(kj)
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum::<f64>()
                    * x.scale as f64
            })
            .collect();
        let m = s.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut l = 0.0;
        for v in s.iter_mut() {
            *v = (*v - m).exp();
            l += *v;
        }
        let e: Vec<f64> = s.iter().map(|v| v / l).collect();
        // dP_j = dO · V_j ; row = Σ_j dP_j E_j ; dS_j = E_j (dP_j − row)
        let dp: Vec<f64> = nbrs
            .iter()
            .map(|&j| {
                let vj = &x.v[j as usize * d..(j as usize + 1) * d];
                doi.iter()
                    .zip(vj)
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum::<f64>()
            })
            .collect();
        let row: f64 = dp.iter().zip(&e).map(|(a, b)| a * b).sum();
        for ((&j, &ej), &dpj) in nbrs.iter().zip(&e).zip(&dp) {
            let ds = ej * (dpj - row) * x.scale as f64;
            let kj = &x.k[j as usize * d..(j as usize + 1) * d];
            for c in 0..d {
                dq[i * d + c] += (ds * kj[c] as f64) as f32;
                dk[j as usize * d + c] += (ds * qi[c] as f64) as f32;
                dv[j as usize * d + c] += (ej * doi[c] as f64) as f32;
            }
        }
    }
    Gradients { dq, dk, dv }
}
