//! Backward-pass driver — the paper's §6 extension at the system level.
//!
//! Runs the fused backward kernel (`fused3s_bwd_*` artifacts: dV/dP/dS/dQ/dK̂
//! in one program, E recomputed in-kernel) over the same BSB bucketing as
//! the forward driver, then **scatter-adds** the per-gathered-row dK̂/dV̂
//! gradients back to dK/dV: a column appears in every row window that
//! attends to it, so the host reduction mirrors the forward gather — the
//! reverse of the paper's "SpMM and SDDMM in reverse order" observation at
//! the memory-movement level.

use anyhow::{bail, Context, Result};

use crate::bsb::bucket::{self, Plan};
use crate::bsb::builder::PAD_COL;
use crate::bsb::reorder::Order;
use crate::bsb::{self, Bsb};
use crate::graph::CsrGraph;
use crate::runtime::buffers::Arg;
use crate::runtime::{Manifest, Runtime};
use crate::{BITMAP_WORDS, TCB_C, TCB_R};

use super::gather::{self, CallBuffers};
use super::AttentionProblem;

/// Buckets with compiled backward artifacts (aot.py: t ∈ {8, 32}).
const BWD_BUCKETS: &[usize] = &[8, 32];

/// Gradients of the 3S attention w.r.t. its inputs.
pub struct Gradients {
    pub dq: Vec<f32>,
    pub dk: Vec<f32>,
    pub dv: Vec<f32>,
}

pub struct BackwardDriver {
    bsb: Bsb,
    plan: Plan,
    batch: usize,
}

impl BackwardDriver {
    pub fn new(man: &Manifest, g: &CsrGraph) -> Result<BackwardDriver> {
        let bsb = bsb::build(g);
        let plan = bucket::plan(
            &bsb,
            BWD_BUCKETS,
            man.rw_batch,
            Order::ByTcbDesc,
            man.chunk_t,
        );
        if let Some(c) = plan.chunked.first() {
            bail!(
                "row window {} has {} TCBs > backward bucket max {}: \
                 chunked backward is future work (needs dS cross-chunk \
                 reduction state)",
                c.rw,
                bsb.rw_tcbs(c.rw as usize),
                BWD_BUCKETS.last().unwrap()
            );
        }
        Ok(BackwardDriver { bsb, plan, batch: man.rw_batch })
    }

    /// Compute (dQ, dK, dV) for upstream gradients `d_out` (n × d).
    pub fn run(
        &self,
        rt: &Runtime,
        x: &AttentionProblem,
        d_out: &[f32],
    ) -> Result<Gradients> {
        if x.d != x.dv {
            bail!("backward driver requires d == dv");
        }
        if d_out.len() != x.n * x.dv {
            bail!("d_out: expected {} elements", x.n * x.dv);
        }
        let d = x.d;
        let mut dq = vec![0.0f32; x.n * d];
        let mut dk = vec![0.0f32; x.n * d];
        let mut dv = vec![0.0f32; x.n * d];
        let mut bufs = CallBuffers::default();
        let mut do_buf: Vec<f32> = Vec::new();

        for call in &self.plan.calls {
            let t = call.t_bucket;
            let name = format!("fused3s_bwd_t{t}_d{d}");
            let exe = rt
                .executable(&name)
                .with_context(|| format!("backward artifact {name}"))?;
            gather::gather_call(&mut bufs, &call.rws, t, &self.bsb, x, self.batch);
            // Gather dO row-window blocks (same layout as Q, unscaled).
            do_buf.clear();
            do_buf.resize(self.batch * TCB_R * d, 0.0);
            let xo = AttentionProblem { scale: 1.0, q: d_out, ..*x };
            for (slot, &rw) in call.rws.iter().enumerate() {
                gather::gather_q(&mut do_buf, slot, rw as usize, &xo);
            }
            let sq = [self.batch, TCB_R, d];
            let skv = [self.batch, t * TCB_C, d];
            let sbm = [self.batch, t, BITMAP_WORDS];
            let outs = rt.run_exe_raw(
                &exe,
                &[
                    Arg::F32(&bufs.q, &sq),
                    Arg::F32(&bufs.k, &skv),
                    Arg::F32(&bufs.v, &skv),
                    Arg::I32(&bufs.bm, &sbm),
                    Arg::F32(&do_buf, &sq),
                ],
            )?;
            let (gq, gk, gv) = (outs[0].as_f32()?, outs[1].as_f32()?, outs[2].as_f32()?);

            // dQ: one owner per row — plain scatter (note: the artifact bakes
            // scale=1; the forward pre-scales Q by `scale`, so by the chain
            // rule dQ_original = scale * dQ_prescaled).
            for (slot, &rw) in call.rws.iter().enumerate() {
                let base = slot * TCB_R * d;
                for r in 0..TCB_R {
                    let row = rw as usize * TCB_R + r;
                    if row >= x.n {
                        break;
                    }
                    for c in 0..d {
                        dq[row * d + c] += x.scale * gq[base + r * d + c];
                    }
                }
            }
            // dK̂/dV̂: scatter-ADD per gathered column (columns repeat across
            // row windows).  No extra scale on dK: the kernel saw the
            // pre-scaled Q, so its dK̂ = dSᵀ·(scale·Q) already carries it.
            for (slot, &rw) in call.rws.iter().enumerate() {
                let rw = rw as usize;
                let t_rw = self.bsb.rw_tcbs(rw);
                for j in 0..t_rw {
                    let cols = self.bsb.tcb_cols(rw, j);
                    for (ci, &col) in cols.iter().enumerate() {
                        if col == PAD_COL {
                            continue;
                        }
                        let col = col as usize;
                        let src = (slot * t * TCB_C + j * TCB_C + ci) * d;
                        for c in 0..d {
                            dk[col * d + c] += gk[src + c];
                            dv[col * d + c] += gv[src + c];
                        }
                    }
                }
            }
        }
        Ok(Gradients { dq, dk, dv })
    }
}

/// Exact host reference for the gradients (dense, f64 accumulation):
/// analytic backward of `O = softmax(scale·QKᵀ ⊙ A) V` row by row.
pub fn backward_reference(
    g: &CsrGraph,
    x: &AttentionProblem,
    d_out: &[f32],
) -> Gradients {
    let (n, d) = (x.n, x.d);
    let mut dq = vec![0.0f32; n * d];
    let mut dk = vec![0.0f32; n * d];
    let mut dv = vec![0.0f32; n * d];
    for i in 0..n {
        let nbrs = g.row(i);
        if nbrs.is_empty() {
            continue;
        }
        let qi = &x.q[i * d..(i + 1) * d];
        let doi = &d_out[i * d..(i + 1) * d];
        // forward softmax weights
        let mut s: Vec<f64> = nbrs
            .iter()
            .map(|&j| {
                let kj = &x.k[j as usize * d..(j as usize + 1) * d];
                qi.iter()
                    .zip(kj)
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum::<f64>()
                    * x.scale as f64
            })
            .collect();
        let m = s.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let mut l = 0.0;
        for v in s.iter_mut() {
            *v = (*v - m).exp();
            l += *v;
        }
        let e: Vec<f64> = s.iter().map(|v| v / l).collect();
        // dP_j = dO · V_j ; row = Σ_j dP_j E_j ; dS_j = E_j (dP_j − row)
        let dp: Vec<f64> = nbrs
            .iter()
            .map(|&j| {
                let vj = &x.v[j as usize * d..(j as usize + 1) * d];
                doi.iter()
                    .zip(vj)
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum::<f64>()
            })
            .collect();
        let row: f64 = dp.iter().zip(&e).map(|(a, b)| a * b).sum();
        for ((&j, &ej), &dpj) in nbrs.iter().zip(&e).zip(&dp) {
            let ds = ej * (dpj - row) * x.scale as f64;
            let kj = &x.k[j as usize * d..(j as usize + 1) * d];
            for c in 0..d {
                dq[i * d + c] += (ds * kj[c] as f64) as f32;
                dk[j as usize * d + c] += (ds * qi[c] as f64) as f32;
                dv[j as usize * d + c] += (ej * doi[c] as f64) as f32;
            }
        }
    }
    Gradients { dq, dk, dv }
}
