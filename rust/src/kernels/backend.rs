//! Backend enumeration + prepared-driver storage — how the experiment
//! harness instantiates the Figure-5/6/8 comparison series by name.
//!
//! Execution happens through the [`SparseAttentionOp`] trait (one
//! multi-head [`AttentionBatch`](super::AttentionBatch) call through an
//! [`ExecCtx`]); callers usually hold a [`Plan`](super::Plan) rather than
//! a raw [`Driver`].

use anyhow::Result;

use crate::bsb::reorder::Order;
use crate::exec::Engine;
use crate::graph::CsrGraph;
use crate::runtime::Manifest;

use super::cpu_csr::CpuCsrDriver;
use super::dense::DenseDriver;
use super::fused::{FusedDriver, FusedOpts};
use super::hybrid::HybridDriver;
use super::op::{AttnError, ExecCtx, SparseAttentionOp};
use super::unfused::UnfusedDriver;
use super::AttentionBatch;

/// The comparison series (paper Figures 5/6/8 legends → our analogs).
/// `Hash` because the coordinator's preprocessing cache keys on
/// (graph fingerprint, backend).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Fused3S (ours): bf16, compacted, reordered.
    Fused3S,
    /// Fused3S with per-row-window geometry routing (DESIGN.md §12): wide
    /// 16×8 TCBs, narrow 8×1 tiles and dense 16×1 lanes mixed in one plan.
    /// Bit-identical output to `Fused3S`; host-execution only (no PJRT
    /// lane artifacts yet), so the PJRT planner never selects it.
    Hybrid,
    /// F3S_splitC without reordering (ablation stage 1).
    Fused3SNoReorder,
    /// Split-row warp partition (ablation).
    Fused3SSplitR,
    /// DF-GNN analog: fused but fp32 end-to-end (DF-GNN runs CUDA cores in
    /// fp32; it processes each nonzero once, so it does NOT pay the
    /// no-compaction block penalty — that lives in `ablate-compaction`).
    DfGnnLike,
    /// FlashSparse analog, naive softmax.
    UnfusedNaive,
    /// FlashSparse analog, stable softmax.
    UnfusedStable,
    /// Dense framework fallback (small graphs only).
    Dense,
    /// PyG/DGL analog: scalar CSR on CPU.
    CpuCsr,
    /// Let the adaptive planner choose (see [`crate::planner`]): the graph
    /// is profiled and the cheapest feasible backend under the current
    /// cost-model calibration is substituted.  `Auto` is resolved *before*
    /// preparation — a built [`Plan`](super::Plan) always reports the
    /// concrete backend, the coordinator resolves at admission so
    /// auto-routed requests coalesce and cache under the resolved key, and
    /// `Auto` itself never reaches a driver.
    Auto,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Fused3S => "fused3s",
            Backend::Hybrid => "hybrid",
            Backend::Fused3SNoReorder => "fused3s_noreorder",
            Backend::Fused3SSplitR => "fused3s_splitr",
            Backend::DfGnnLike => "dfgnn_like",
            Backend::UnfusedNaive => "unfused_naive",
            Backend::UnfusedStable => "unfused_stable",
            Backend::Dense => "dense",
            Backend::CpuCsr => "cpu_csr",
            Backend::Auto => "auto",
        }
    }

    pub fn parse(s: &str) -> Result<Backend> {
        Ok(match s {
            "fused3s" => Backend::Fused3S,
            "hybrid" => Backend::Hybrid,
            "fused3s_noreorder" => Backend::Fused3SNoReorder,
            "fused3s_splitr" => Backend::Fused3SSplitR,
            "dfgnn_like" => Backend::DfGnnLike,
            "unfused_naive" => Backend::UnfusedNaive,
            "unfused_stable" => Backend::UnfusedStable,
            "dense" => Backend::Dense,
            "cpu_csr" => Backend::CpuCsr,
            "auto" => Backend::Auto,
            _ => anyhow::bail!("unknown backend '{s}'"),
        })
    }

    /// The Figure-5/6 kernel comparison set.
    pub fn kernel_series() -> Vec<Backend> {
        vec![
            Backend::Fused3S,
            Backend::DfGnnLike,
            Backend::UnfusedNaive,
            Backend::UnfusedStable,
            Backend::CpuCsr,
        ]
    }

    /// The fused-driver configuration for fused-family backends — the ONE
    /// backend→options mapping, shared by graph planning (`prepare_on`)
    /// and prebuilt-BSB planning (`Plan::from_bsb`).
    pub(crate) fn fused_opts(self) -> Option<FusedOpts> {
        Some(match self {
            Backend::Fused3S => FusedOpts::default(),
            Backend::Fused3SNoReorder => {
                FusedOpts { order: Order::Natural, ..FusedOpts::default() }
            }
            Backend::Fused3SSplitR => {
                FusedOpts { variant: "splitr", ..FusedOpts::default() }
            }
            Backend::DfGnnLike => {
                FusedOpts { precision: "f32", ..FusedOpts::default() }
            }
            _ => return None,
        })
    }

    /// The softmax variant for unfused-family backends.
    pub(crate) fn unfused_stable(self) -> Option<bool> {
        match self {
            Backend::UnfusedNaive => Some(false),
            Backend::UnfusedStable => Some(true),
            _ => None,
        }
    }

    /// Resolve [`Backend::Auto`] to a concrete backend for `g` via the
    /// factory-calibrated planner; any concrete backend resolves to
    /// itself.  This is the resolution seam of [`Backend::plan`]: every
    /// preparation path funnels through it, so `Auto` never reaches a
    /// driver constructor.
    ///
    /// The candidate set honours what `man` can actually dispatch: the
    /// dense fallback is only considered when the manifest carries
    /// compiled dense executables — offline/host-emulation manifests
    /// don't, so an auto plan built against one is always executable
    /// through [`ExecCtx::host`](super::ExecCtx::host).  Serving callers
    /// with a *tuned* planner (the coordinator) resolve earlier, at
    /// admission, and hand a concrete backend down.
    ///
    /// [`Backend::plan`]: Backend::plan
    pub fn resolve_for(self, g: &CsrGraph, man: &Manifest) -> Backend {
        use crate::planner::{CostModel, Planner};
        if self != Backend::Auto {
            return self;
        }
        let model = CostModel::default();
        let planner = if man.entries.keys().any(|k| k.starts_with("dense_n")) {
            Planner::new(model)
        } else {
            Planner::offline(model)
        };
        planner.resolve(g).backend
    }
}

/// A prepared (graph-specialised) driver for any backend.  The variants
/// are the [`SparseAttentionOp`] implementations; `Driver` itself
/// implements the trait by dispatching to whichever it wraps.
pub enum Driver {
    Fused(FusedDriver),
    Hybrid(HybridDriver),
    Unfused(UnfusedDriver),
    Dense(DenseDriver),
    CpuCsr(CpuCsrDriver),
    /// Partition-parallel execution over row-window shards, one inner plan
    /// per shard (built by [`Plan::new_sharded`](super::Plan::new_sharded),
    /// never by backend name).
    Sharded(crate::shard::ShardedPlan),
}

impl Driver {
    /// Preprocess `g` for `backend` (the paper's per-graph preprocessing),
    /// sharding the BSB build across the engine's worker pool
    /// (bit-identical to the serial build).  The CPU-CSR baseline inherits
    /// the engine's thread count.  This is the single driver constructor —
    /// callers go through [`Plan::new`](super::Plan::new), which wraps it.
    pub fn prepare_on(
        man: &Manifest,
        g: &CsrGraph,
        backend: Backend,
        engine: &Engine,
    ) -> Result<Driver> {
        let backend = backend.resolve_for(g, man);
        if backend == Backend::Hybrid {
            return Ok(Driver::Hybrid(HybridDriver::new_with(man, g, engine)?));
        }
        if let Some(opts) = backend.fused_opts() {
            return Ok(Driver::Fused(FusedDriver::new_with(man, g, opts, engine)?));
        }
        if let Some(stable) = backend.unfused_stable() {
            return Ok(Driver::Unfused(UnfusedDriver::new_with(
                man,
                g,
                stable,
                Order::ByTcbDesc,
                engine,
            )?));
        }
        Ok(match backend {
            Backend::Dense => Driver::Dense(DenseDriver::new(man, g)?),
            Backend::CpuCsr => Driver::CpuCsr(CpuCsrDriver::new(
                g.clone(),
                engine.policy.threads,
            )),
            // Fused/unfused families are handled above.
            _ => unreachable!("backend family not covered"),
        })
    }
}

impl SparseAttentionOp for Driver {
    fn execute(
        &self,
        ctx: &mut ExecCtx<'_>,
        x: &AttentionBatch<'_>,
    ) -> Result<Vec<f32>, AttnError> {
        match self {
            Driver::Fused(d) => d.execute(ctx, x),
            Driver::Hybrid(d) => d.execute(ctx, x),
            Driver::Unfused(d) => d.execute(ctx, x),
            Driver::Dense(d) => d.execute(ctx, x),
            Driver::CpuCsr(d) => d.execute(ctx, x),
            Driver::Sharded(d) => d.execute(ctx, x),
        }
    }

    fn executables(&self, d: usize) -> Vec<String> {
        match self {
            Driver::Fused(dr) => dr.artifact_names(d),
            Driver::Hybrid(dr) => dr.executables(d),
            Driver::Unfused(dr) => dr.artifact_names(d),
            Driver::Dense(dr) => dr.artifact_names(d),
            Driver::CpuCsr(_) => vec![],
            Driver::Sharded(dr) => dr.executables(d),
        }
    }
}
