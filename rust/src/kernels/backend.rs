//! Backend enumeration + unified driver facade — how the experiment harness
//! instantiates the Figure-5/6/8 comparison series by name.

use anyhow::Result;

use crate::bsb::reorder::Order;
use crate::exec::Engine;
use crate::graph::CsrGraph;
use crate::runtime::{Manifest, Runtime};

use super::cpu_csr;
use super::dense::DenseDriver;
use super::fused::{FusedDriver, FusedOpts};
use super::unfused::UnfusedDriver;
use super::AttentionProblem;

/// The comparison series (paper Figures 5/6/8 legends → our analogs).
/// `Hash` because the coordinator's preprocessing cache keys on
/// (graph fingerprint, backend).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Backend {
    /// Fused3S (ours): bf16, compacted, reordered.
    Fused3S,
    /// F3S_splitC without reordering (ablation stage 1).
    Fused3SNoReorder,
    /// Split-row warp partition (ablation).
    Fused3SSplitR,
    /// DF-GNN analog: fused but fp32 end-to-end (DF-GNN runs CUDA cores in
    /// fp32; it processes each nonzero once, so it does NOT pay the
    /// no-compaction block penalty — that lives in `ablate-compaction`).
    DfGnnLike,
    /// FlashSparse analog, naive softmax.
    UnfusedNaive,
    /// FlashSparse analog, stable softmax.
    UnfusedStable,
    /// Dense framework fallback (small graphs only).
    Dense,
    /// PyG/DGL analog: scalar CSR on CPU.
    CpuCsr,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Fused3S => "fused3s",
            Backend::Fused3SNoReorder => "fused3s_noreorder",
            Backend::Fused3SSplitR => "fused3s_splitr",
            Backend::DfGnnLike => "dfgnn_like",
            Backend::UnfusedNaive => "unfused_naive",
            Backend::UnfusedStable => "unfused_stable",
            Backend::Dense => "dense",
            Backend::CpuCsr => "cpu_csr",
        }
    }

    pub fn parse(s: &str) -> Result<Backend> {
        Ok(match s {
            "fused3s" => Backend::Fused3S,
            "fused3s_noreorder" => Backend::Fused3SNoReorder,
            "fused3s_splitr" => Backend::Fused3SSplitR,
            "dfgnn_like" => Backend::DfGnnLike,
            "unfused_naive" => Backend::UnfusedNaive,
            "unfused_stable" => Backend::UnfusedStable,
            "dense" => Backend::Dense,
            "cpu_csr" => Backend::CpuCsr,
            _ => anyhow::bail!("unknown backend '{s}'"),
        })
    }

    /// The Figure-5/6 kernel comparison set.
    pub fn kernel_series() -> Vec<Backend> {
        vec![
            Backend::Fused3S,
            Backend::DfGnnLike,
            Backend::UnfusedNaive,
            Backend::UnfusedStable,
            Backend::CpuCsr,
        ]
    }
}

/// A prepared (graph-specialised) driver for any backend.
pub enum Driver {
    Fused(FusedDriver),
    Unfused(UnfusedDriver),
    Dense(DenseDriver),
    CpuCsr { graph: CsrGraph, threads: usize },
}

impl Driver {
    /// Preprocess `g` for `backend` (the paper's per-graph preprocessing).
    pub fn prepare(rt: &Runtime, g: &CsrGraph, backend: Backend) -> Result<Driver> {
        Self::prepare_with(rt.manifest(), g, backend)
    }

    /// Preprocess without a live PJRT runtime (used by the coordinator's
    /// worker pool, which only needs the manifest's bucket configuration).
    pub fn prepare_with(
        man: &Manifest,
        g: &CsrGraph,
        backend: Backend,
    ) -> Result<Driver> {
        Self::prepare_on(man, g, backend, &Engine::serial())
    }

    /// Preprocess with BSB construction sharded across the engine's worker
    /// pool (bit-identical to the serial build).  The CPU-CSR baseline
    /// inherits the engine's thread count.
    pub fn prepare_on(
        man: &Manifest,
        g: &CsrGraph,
        backend: Backend,
        engine: &Engine,
    ) -> Result<Driver> {
        Ok(match backend {
            Backend::Fused3S => Driver::Fused(FusedDriver::new_with(
                man,
                g,
                FusedOpts::default(),
                engine,
            )?),
            Backend::Fused3SNoReorder => Driver::Fused(FusedDriver::new_with(
                man,
                g,
                FusedOpts { order: Order::Natural, ..FusedOpts::default() },
                engine,
            )?),
            Backend::Fused3SSplitR => Driver::Fused(FusedDriver::new_with(
                man,
                g,
                FusedOpts { variant: "splitr", ..FusedOpts::default() },
                engine,
            )?),
            Backend::DfGnnLike => Driver::Fused(FusedDriver::new_with(
                man,
                g,
                FusedOpts { precision: "f32", ..FusedOpts::default() },
                engine,
            )?),
            Backend::UnfusedNaive => Driver::Unfused(UnfusedDriver::new_with(
                man,
                g,
                false,
                Order::ByTcbDesc,
                engine,
            )?),
            Backend::UnfusedStable => Driver::Unfused(UnfusedDriver::new_with(
                man,
                g,
                true,
                Order::ByTcbDesc,
                engine,
            )?),
            Backend::Dense => Driver::Dense(DenseDriver::new(man, g)?),
            Backend::CpuCsr => Driver::CpuCsr {
                graph: g.clone(),
                threads: engine.policy.threads,
            },
        })
    }

    /// Execute the 3S computation (serial reference policy).
    pub fn run(&self, rt: &Runtime, x: &AttentionProblem) -> Result<Vec<f32>> {
        self.run_with(rt, x, &Engine::serial())
    }

    /// Execute through the host execution engine (bit-identical to
    /// [`Driver::run`] for every policy).
    pub fn run_with(
        &self,
        rt: &Runtime,
        x: &AttentionProblem,
        engine: &Engine,
    ) -> Result<Vec<f32>> {
        match self {
            Driver::Fused(d) => d.run_with(rt, x, engine),
            Driver::Unfused(d) => d.run_with(rt, x, engine),
            Driver::Dense(d) => d.run(rt, x),
            Driver::CpuCsr { graph, threads } => Ok(cpu_csr::run(graph, x, *threads)),
        }
    }

    /// Execute with **no PJRT runtime**: fused/unfused dispatch through the
    /// offline host-kernel emulation, CPU-CSR runs natively.  This is the
    /// coordinator's `HostEmulation` executor (tests, benches, cold CI);
    /// the dense fallback has no host emulation and reports so.
    pub fn run_offline(
        &self,
        x: &AttentionProblem,
        engine: &Engine,
    ) -> Result<Vec<f32>> {
        use crate::exec::HostExecutor;
        match self {
            Driver::Fused(d) => {
                d.run_exec(x, engine, &mut HostExecutor::new(&engine.pool))
            }
            Driver::Unfused(d) => {
                d.run_exec(x, engine, &mut HostExecutor::new(&engine.pool))
            }
            Driver::Dense(_) => anyhow::bail!(
                "dense backend has no offline host emulation (needs artifacts)"
            ),
            Driver::CpuCsr { graph, threads } => Ok(cpu_csr::run(graph, x, *threads)),
        }
    }

    /// Names of executables this driver dispatches (for warmup outside the
    /// timed region).
    pub fn executables(&self, d: usize) -> Vec<String> {
        match self {
            Driver::Fused(dr) => dr.executables(d),
            Driver::Unfused(dr) => dr.executables(d),
            Driver::Dense(dr) => dr.executables(d),
            Driver::CpuCsr { .. } => vec![],
        }
    }
}
