//! Gathering K̂/V̂ row stacks and scattering output blocks — the L3 "memory
//! engine" of the reproduction (DESIGN.md §1: the paper's PTX-level
//! HBM→register gather becomes an explicit host gather into contiguous
//! per-call buffers that the kernel streams once).
//!
//! All functions write into caller-provided buffers so the hot path can
//! reuse allocations across calls (see EXPERIMENTS.md §Perf).

use crate::bsb::builder::{Bsb, PAD_COL};
use crate::bsb::bitmap;
use crate::bsb::geometry::LaneSet;
use crate::exec::WorkerPool;
use crate::{BITMAP_WORDS, TCB_C, TCB_R};

use super::AttentionProblem;

/// Reusable per-call staging buffers.
#[derive(Default)]
pub struct CallBuffers {
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub bm: Vec<i32>,
}

impl CallBuffers {
    /// Resize for a call of `batch` row windows at bucket `t`.
    ///
    /// Only the **bitmap** buffer is zeroed.  Stale f32 values left in
    /// q/k/v slots from earlier calls are sound: every lane not covered by
    /// a fresh gather has a zero bitmap bit, the kernel masks its score to
    /// -inf before exp (p = 0 exactly), and `0 × finite = 0` in the SpMM —
    /// so stale-but-finite values never reach the output.  (The gather only
    /// ever writes finite feature data, preserving the invariant.)  Skipping
    /// the q/k/v memset removes the dominant per-call host cost on large
    /// buckets (up to ~16 MB/call at t=128; EXPERIMENTS.md §Perf).
    pub fn reset(&mut self, batch: usize, t: usize, d: usize, dv: usize) {
        self.reset_features(batch, t, d, dv);
        // Bitmaps must be exact: a stale 1-bit would unmask a stale lane.
        self.bm.clear();
        self.bm.resize(batch * t * BITMAP_WORDS, 0);
    }

    /// Resize only the q/k/v feature buffers (same stale-value soundness
    /// argument as [`CallBuffers::reset`]); the caller supplies the exact
    /// bitmap words separately — the multi-head path stages them once per
    /// call per batch and memcpys them in per head.
    pub fn reset_features(&mut self, batch: usize, t: usize, d: usize, dv: usize) {
        resize_only(&mut self.q, batch * TCB_R * d);
        resize_only(&mut self.k, batch * t * TCB_C * d);
        resize_only(&mut self.v, batch * t * TCB_C * dv);
    }

    /// Resize for a *lane* call (narrow/dense geometry): `batch` windows of
    /// `rows` rows and `t_lanes` column lanes each.  `bm` holds one i32 row
    /// mask per lane (low `rows` bits).  Only the masks are zeroed — the
    /// stale-f32 soundness argument of [`CallBuffers::reset`] applies
    /// unchanged (a zero mask fully masks its lane).
    pub fn reset_lanes(
        &mut self,
        batch: usize,
        rows: usize,
        t_lanes: usize,
        d: usize,
        dv: usize,
    ) {
        self.reset_lane_features(batch, rows, t_lanes, d, dv);
        self.bm.clear();
        self.bm.resize(batch * t_lanes, 0);
    }

    /// Lane-call analogue of [`CallBuffers::reset_features`]: resize q/k/v
    /// only; the caller installs pre-staged lane masks.
    pub fn reset_lane_features(
        &mut self,
        batch: usize,
        rows: usize,
        t_lanes: usize,
        d: usize,
        dv: usize,
    ) {
        resize_only(&mut self.q, batch * rows * d);
        resize_only(&mut self.k, batch * t_lanes * d);
        resize_only(&mut self.v, batch * t_lanes * dv);
    }
}

fn resize_only<T: Copy + Default>(v: &mut Vec<T>, len: usize) {
    if v.len() != len {
        v.resize(len, T::default());
    }
}

/// Fill one slot-local Q block (`16 × d`): rows `rw*16 .. rw*16+16` of `q`,
/// scaled.  Rows beyond n stay zero.
pub fn gather_q_into(dst: &mut [f32], rw: usize, x: &AttentionProblem) {
    gather_rows_q_into(dst, rw * TCB_R, TCB_R, x)
}

/// Fill a slot-local Q block of `rows` rows starting at `base_row`, scaled.
/// Rows beyond n stay zero.  The wide path uses 16-row windows
/// ([`gather_q_into`]); the narrow lane path uses 8-row half-windows.
pub fn gather_rows_q_into(
    dst: &mut [f32],
    base_row: usize,
    rows: usize,
    x: &AttentionProblem,
) {
    let d = x.d;
    for r in 0..rows {
        let row = base_row + r;
        if row >= x.n {
            break;
        }
        let dst = &mut dst[r * d..(r + 1) * d];
        let src = &x.q[row * d..(row + 1) * d];
        if x.scale == 1.0 {
            dst.copy_from_slice(src);
        } else {
            // Pre-scaling Q folds the score scale into the gather pass, so
            // one artifact (scale=1) serves every head configuration.
            for (o, s) in dst.iter_mut().zip(src) {
                *o = s * x.scale;
            }
        }
    }
}

/// Fill one batch slot's Q block inside a packed multi-slot buffer.
pub fn gather_q(buf: &mut [f32], slot: usize, rw: usize, x: &AttentionProblem) {
    let len = TCB_R * x.d;
    gather_q_into(&mut buf[slot * len..(slot + 1) * len], rw, x);
}

/// Fill slot-local K̂/V̂ stacks + bitmaps for TCBs `[t_lo, t_hi)` of `rw`.
/// The slices cover the slot's full capacity; lanes past `t_hi - t_lo` stay
/// untouched (zero bitmap = fully masked).  `t_lo > 0` is the chunked case.
#[allow(clippy::too_many_arguments)]
pub fn gather_kv_into(
    k: &mut [f32],
    v: &mut [f32],
    bm: &mut [i32],
    bsb: &Bsb,
    rw: usize,
    t_lo: usize,
    t_hi: usize,
    x: &AttentionProblem,
) {
    let (d, dv) = (x.d, x.dv);
    for (jj, j) in (t_lo..t_hi).enumerate() {
        let cols = bsb.tcb_cols(rw, j);
        for (ci, &col) in cols.iter().enumerate() {
            if col == PAD_COL {
                continue;
            }
            let col = col as usize;
            let krow = (jj * TCB_C + ci) * d;
            k[krow..krow + d].copy_from_slice(&x.k[col * d..(col + 1) * d]);
            let vrow = (jj * TCB_C + ci) * dv;
            v[vrow..vrow + dv].copy_from_slice(&x.v[col * dv..(col + 1) * dv]);
        }
        let words = bitmap::as_i32(bsb.tcb_bitmap(rw, j));
        bm[jj * BITMAP_WORDS..(jj + 1) * BITMAP_WORDS].copy_from_slice(&words);
    }
}

/// Fill slot-local K̂/V̂ feature stacks only (no bitmap writes) for TCBs
/// `[t_lo, t_hi)` of `rw` — the per-head half of a gather whose
/// head-invariant bitmaps were staged by [`stage_call_bitmaps`].
pub fn gather_kv_features_into(
    k: &mut [f32],
    v: &mut [f32],
    bsb: &Bsb,
    rw: usize,
    t_lo: usize,
    t_hi: usize,
    x: &AttentionProblem,
) {
    let (d, dv) = (x.d, x.dv);
    for (jj, j) in (t_lo..t_hi).enumerate() {
        let cols = bsb.tcb_cols(rw, j);
        for (ci, &col) in cols.iter().enumerate() {
            if col == PAD_COL {
                continue;
            }
            let col = col as usize;
            let krow = (jj * TCB_C + ci) * d;
            k[krow..krow + d].copy_from_slice(&x.k[col * d..(col + 1) * d]);
            let vrow = (jj * TCB_C + ci) * dv;
            v[vrow..vrow + dv].copy_from_slice(&x.v[col * dv..(col + 1) * dv]);
        }
    }
}

/// Fill one slot's K̂/V̂ stacks + bitmaps for TCBs `[t_lo, t_hi)` of `rw`,
/// padded to `t_cap` TCBs, inside packed multi-slot buffers.
#[allow(clippy::too_many_arguments)]
pub fn gather_kv_range(
    bufs: &mut CallBuffers,
    slot: usize,
    bsb: &Bsb,
    rw: usize,
    t_lo: usize,
    t_hi: usize,
    t_cap: usize,
    x: &AttentionProblem,
) {
    let (d, dv) = (x.d, x.dv);
    let k_len = t_cap * TCB_C * d;
    let v_len = t_cap * TCB_C * dv;
    let bm_len = t_cap * BITMAP_WORDS;
    gather_kv_into(
        &mut bufs.k[slot * k_len..(slot + 1) * k_len],
        &mut bufs.v[slot * v_len..(slot + 1) * v_len],
        &mut bufs.bm[slot * bm_len..(slot + 1) * bm_len],
        bsb,
        rw,
        t_lo,
        t_hi,
        x,
    );
}

/// Gather a whole regular call (all slots), serially.
pub fn gather_call(
    bufs: &mut CallBuffers,
    rws: &[u32],
    t_bucket: usize,
    bsb: &Bsb,
    x: &AttentionProblem,
    batch: usize,
) {
    gather_call_with(&WorkerPool::new(1), bufs, rws, t_bucket, bsb, x, batch)
}

/// Gather a whole regular call, sharding slots across the pool.  Each slot
/// owns disjoint sub-slices of the call buffers, so any pool width produces
/// bit-identical buffers.
pub fn gather_call_with(
    pool: &WorkerPool,
    bufs: &mut CallBuffers,
    rws: &[u32],
    t_bucket: usize,
    bsb: &Bsb,
    x: &AttentionProblem,
    batch: usize,
) {
    bufs.reset(batch, t_bucket, x.d, x.dv);
    let slots = split_slots(bufs, rws.len(), t_bucket, x);
    pool.run_items(slots, |(slot, q, k, v, bm)| {
        let rw = rws[slot] as usize;
        gather_q_into(q, rw, x);
        gather_kv_into(k, v, bm, bsb, rw, 0, bsb.rw_tcbs(rw), x);
    });
}

/// Stage a regular call's TCB bitmaps: a contiguous `batch * t_cap *
/// BITMAP_WORDS` i32 buffer laid out exactly like `CallBuffers::bm`
/// (unoccupied slots and padding TCBs zero).  The bitmaps depend only on
/// the BSB structure — never on Q/K/V — so a multi-head batch computes
/// this **once per call per batch** and memcpys it into each head's
/// buffers instead of re-walking the BSB per head.
pub fn stage_call_bitmaps(
    bsb: &Bsb,
    rws: &[u32],
    t_cap: usize,
    batch: usize,
) -> Vec<i32> {
    let mut bm = vec![0i32; batch * t_cap * BITMAP_WORDS];
    for (slot, &rw) in rws.iter().enumerate() {
        let rw = rw as usize;
        for j in 0..bsb.rw_tcbs(rw) {
            let words = bitmap::as_i32(bsb.tcb_bitmap(rw, j));
            let base = (slot * t_cap + j) * BITMAP_WORDS;
            bm[base..base + BITMAP_WORDS].copy_from_slice(&words);
        }
    }
    bm
}

/// Gather a whole regular call for one head with pre-staged bitmaps:
/// the head-invariant bitmap buffer is copied wholesale; the per-head
/// Q/K̂/V̂ feature gathers shard across the pool.  Produces buffers
/// bit-identical to [`gather_call_with`].
#[allow(clippy::too_many_arguments)]
pub fn gather_call_staged(
    pool: &WorkerPool,
    bufs: &mut CallBuffers,
    rws: &[u32],
    t_bucket: usize,
    staged_bm: &[i32],
    bsb: &Bsb,
    x: &AttentionProblem,
    batch: usize,
) {
    bufs.reset_features(batch, t_bucket, x.d, x.dv);
    debug_assert_eq!(staged_bm.len(), batch * t_bucket * BITMAP_WORDS);
    bufs.bm.clear();
    bufs.bm.extend_from_slice(staged_bm);
    let slots = split_feature_slots(bufs, rws.len(), t_bucket, x);
    pool.run_items(slots, |(slot, q, k, v)| {
        let rw = rws[slot] as usize;
        gather_q_into(q, rw, x);
        gather_kv_features_into(k, v, bsb, rw, 0, bsb.rw_tcbs(rw), x);
    });
}

/// Gather one batch of chunked-RW work items `(rw, chunk index)` at chunk
/// capacity `chunk_t`, sharding slots across the pool.
pub fn gather_partial_call_with(
    pool: &WorkerPool,
    bufs: &mut CallBuffers,
    items: &[(u32, usize)],
    chunk_t: usize,
    bsb: &Bsb,
    x: &AttentionProblem,
    batch: usize,
) {
    bufs.reset(batch, chunk_t, x.d, x.dv);
    let slots = split_slots(bufs, items.len(), chunk_t, x);
    pool.run_items(slots, |(slot, q, k, v, bm)| {
        let (rw, ci) = items[slot];
        let rw = rw as usize;
        gather_q_into(q, rw, x);
        let t = bsb.rw_tcbs(rw);
        let t_lo = ci * chunk_t;
        let t_hi = ((ci + 1) * chunk_t).min(t);
        gather_kv_into(k, v, bm, bsb, rw, t_lo, t_hi, x);
    });
}

/// Fill one lane slot: Q rows of window `wid`, plus K̂/V̂ rows and the i32
/// row mask for each of the window's lanes.  Lanes past the window's count
/// stay untouched (zero mask = fully masked).
fn gather_lane_slot_into(
    q: &mut [f32],
    k: &mut [f32],
    v: &mut [f32],
    bm: &mut [i32],
    set: &LaneSet,
    wid: usize,
    x: &AttentionProblem,
) {
    let (d, dv) = (x.d, x.dv);
    gather_rows_q_into(q, wid * set.rows, set.rows, x);
    for (li, lane) in set.lanes(wid).enumerate() {
        let col = set.cols[lane] as usize;
        k[li * d..(li + 1) * d].copy_from_slice(&x.k[col * d..(col + 1) * d]);
        v[li * dv..(li + 1) * dv].copy_from_slice(&x.v[col * dv..(col + 1) * dv]);
        bm[li] = set.masks[lane] as i32;
    }
}

/// Per-head half of [`gather_lane_slot_into`] when masks were pre-staged.
fn gather_lane_features_into(
    q: &mut [f32],
    k: &mut [f32],
    v: &mut [f32],
    set: &LaneSet,
    wid: usize,
    x: &AttentionProblem,
) {
    let (d, dv) = (x.d, x.dv);
    gather_rows_q_into(q, wid * set.rows, set.rows, x);
    for (li, lane) in set.lanes(wid).enumerate() {
        let col = set.cols[lane] as usize;
        k[li * d..(li + 1) * d].copy_from_slice(&x.k[col * d..(col + 1) * d]);
        v[li * dv..(li + 1) * dv].copy_from_slice(&x.v[col * dv..(col + 1) * dv]);
    }
}

/// Stage a lane call's row masks: a `batch * t_lanes` i32 buffer laid out
/// like `CallBuffers::bm` under [`CallBuffers::reset_lanes`].  Masks depend
/// only on structure, so the multi-head path stages them once per call.
pub fn stage_lane_masks(
    set: &LaneSet,
    windows: &[u32],
    t_lanes: usize,
    batch: usize,
) -> Vec<i32> {
    let mut bm = vec![0i32; batch * t_lanes];
    for (slot, &wid) in windows.iter().enumerate() {
        for (li, lane) in set.lanes(wid as usize).enumerate() {
            bm[slot * t_lanes + li] = set.masks[lane] as i32;
        }
    }
    bm
}

/// Gather a whole lane call (narrow or dense geometry), sharding slots
/// across the pool.  Bit-identical for any pool width (disjoint slots).
pub fn gather_lane_call_with(
    pool: &WorkerPool,
    bufs: &mut CallBuffers,
    set: &LaneSet,
    windows: &[u32],
    t_lanes: usize,
    x: &AttentionProblem,
    batch: usize,
) {
    bufs.reset_lanes(batch, set.rows, t_lanes, x.d, x.dv);
    let slots = split_lane_slots(bufs, windows.len(), set.rows, t_lanes, x);
    pool.run_items(slots, |(slot, q, k, v, bm)| {
        gather_lane_slot_into(q, k, v, bm, set, windows[slot] as usize, x);
    });
}

/// Gather a lane call for one head with pre-staged masks (multi-head path).
/// Produces buffers bit-identical to [`gather_lane_call_with`].
#[allow(clippy::too_many_arguments)]
pub fn gather_lane_call_staged(
    pool: &WorkerPool,
    bufs: &mut CallBuffers,
    set: &LaneSet,
    windows: &[u32],
    t_lanes: usize,
    staged_bm: &[i32],
    x: &AttentionProblem,
    batch: usize,
) {
    bufs.reset_lane_features(batch, set.rows, t_lanes, x.d, x.dv);
    debug_assert_eq!(staged_bm.len(), batch * t_lanes);
    bufs.bm.clear();
    bufs.bm.extend_from_slice(staged_bm);
    let slots = split_lane_feature_slots(bufs, windows.len(), set.rows, t_lanes, x);
    pool.run_items(slots, |(slot, q, k, v)| {
        gather_lane_features_into(q, k, v, set, windows[slot] as usize, x);
    });
}

fn split_lane_slots<'b>(
    bufs: &'b mut CallBuffers,
    n_slots: usize,
    rows: usize,
    t_lanes: usize,
    x: &AttentionProblem,
) -> SlotViews<'b> {
    let CallBuffers { q, k, v, bm } = bufs;
    let views: SlotViews<'b> = q
        .chunks_mut(rows * x.d)
        .zip(k.chunks_mut(t_lanes * x.d))
        .zip(v.chunks_mut(t_lanes * x.dv))
        .zip(bm.chunks_mut(t_lanes))
        .take(n_slots)
        .enumerate()
        .map(|(slot, (((q, k), v), bm))| (slot, q, k, v, bm))
        .collect();
    assert_eq!(views.len(), n_slots, "call has more slots than batch capacity");
    views
}

fn split_lane_feature_slots<'b>(
    bufs: &'b mut CallBuffers,
    n_slots: usize,
    rows: usize,
    t_lanes: usize,
    x: &AttentionProblem,
) -> FeatureSlotViews<'b> {
    let CallBuffers { q, k, v, .. } = bufs;
    let views: FeatureSlotViews<'b> = q
        .chunks_mut(rows * x.d)
        .zip(k.chunks_mut(t_lanes * x.d))
        .zip(v.chunks_mut(t_lanes * x.dv))
        .take(n_slots)
        .enumerate()
        .map(|(slot, ((q, k), v))| (slot, q, k, v))
        .collect();
    assert_eq!(views.len(), n_slots, "call has more slots than batch capacity");
    views
}

/// Scatter a lane call's output blocks (`rows × dv` per slot) back into the
/// n×dv output matrix.
pub fn scatter_lane_call(
    out: &mut [f32],
    o: &[f32],
    rows: usize,
    windows: &[u32],
    n: usize,
    dv: usize,
) {
    for (slot, &wid) in windows.iter().enumerate() {
        scatter_rows_slot(out, o, slot, wid as usize * rows, rows, n, dv);
    }
}

/// Scatter one slot's `rows × dv` block to rows `base_row..` of `out`.
pub fn scatter_rows_slot(
    out: &mut [f32],
    o: &[f32],
    slot: usize,
    base_row: usize,
    rows: usize,
    n: usize,
    dv: usize,
) {
    let base = slot * rows * dv;
    for r in 0..rows {
        let row = base_row + r;
        if row >= n {
            break;
        }
        out[row * dv..(row + 1) * dv]
            .copy_from_slice(&o[base + r * dv..base + (r + 1) * dv]);
    }
}

/// Per-slot disjoint views over the call buffers for `n_slots` occupied
/// slots at TCB capacity `t_cap`.
type SlotViews<'b> =
    Vec<(usize, &'b mut [f32], &'b mut [f32], &'b mut [f32], &'b mut [i32])>;

/// Per-slot disjoint q/k/v views (no bitmap) for staged-bitmap gathers.
type FeatureSlotViews<'b> =
    Vec<(usize, &'b mut [f32], &'b mut [f32], &'b mut [f32])>;

fn split_feature_slots<'b>(
    bufs: &'b mut CallBuffers,
    n_slots: usize,
    t_cap: usize,
    x: &AttentionProblem,
) -> FeatureSlotViews<'b> {
    let CallBuffers { q, k, v, .. } = bufs;
    let views: FeatureSlotViews<'b> = q
        .chunks_mut(TCB_R * x.d)
        .zip(k.chunks_mut(t_cap * TCB_C * x.d))
        .zip(v.chunks_mut(t_cap * TCB_C * x.dv))
        .take(n_slots)
        .enumerate()
        .map(|(slot, ((q, k), v))| (slot, q, k, v))
        .collect();
    assert_eq!(views.len(), n_slots, "call has more slots than batch capacity");
    views
}

fn split_slots<'b>(
    bufs: &'b mut CallBuffers,
    n_slots: usize,
    t_cap: usize,
    x: &AttentionProblem,
) -> SlotViews<'b> {
    let CallBuffers { q, k, v, bm } = bufs;
    let views: SlotViews<'b> = q
        .chunks_mut(TCB_R * x.d)
        .zip(k.chunks_mut(t_cap * TCB_C * x.d))
        .zip(v.chunks_mut(t_cap * TCB_C * x.dv))
        .zip(bm.chunks_mut(t_cap * BITMAP_WORDS))
        .take(n_slots)
        .enumerate()
        .map(|(slot, (((q, k), v), bm))| (slot, q, k, v, bm))
        .collect();
    // A call with more occupied slots than the buffers' batch capacity is a
    // planner bug; fail loudly instead of silently dropping row windows.
    assert_eq!(views.len(), n_slots, "call has more slots than batch capacity");
    views
}

/// Scatter a call's output blocks back into the n×dv output matrix.
pub fn scatter_call(out: &mut [f32], o: &[f32], rws: &[u32], n: usize, dv: usize) {
    for (slot, &rw) in rws.iter().enumerate() {
        scatter_slot(out, o, slot, rw as usize, n, dv);
    }
}

/// Scatter one slot's 16×dv block to rows rw*16.. of `out`.
pub fn scatter_slot(
    out: &mut [f32],
    o: &[f32],
    slot: usize,
    rw: usize,
    n: usize,
    dv: usize,
) {
    let base = slot * TCB_R * dv;
    for r in 0..TCB_R {
        let row = rw * TCB_R + r;
        if row >= n {
            break;
        }
        out[row * dv..(row + 1) * dv]
            .copy_from_slice(&o[base + r * dv..base + (r + 1) * dv]);
    }
}

#[cfg(test)]
mod tests {
    use crate::bsb::build;
    use crate::graph::generators;

    use super::*;

    fn problem_data(n: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = crate::util::prng::Rng::new(3);
        (
            rng.normal_vec(n * d, 1.0),
            rng.normal_vec(n * d, 1.0),
            rng.normal_vec(n * d, 1.0),
        )
    }

    #[test]
    fn gather_q_scales_and_pads() {
        let n = 20; // last window ragged
        let d = 4;
        let (q, k, v) = problem_data(n, d);
        let x = AttentionProblem { n, d, dv: d, q: &q, k: &k, v: &v, scale: 2.0 };
        let mut buf = vec![0.0f32; 2 * TCB_R * d];
        gather_q(&mut buf, 1, 1, &x); // rw 1 covers rows 16..20
        for r in 0..4 {
            for c in 0..d {
                assert_eq!(
                    buf[TCB_R * d + r * d + c],
                    q[(16 + r) * d + c] * 2.0
                );
            }
        }
        // Rows 20.. padded with zeros.
        assert!(buf[TCB_R * d + 4 * d..].iter().all(|&z| z == 0.0));
        // Slot 0 untouched.
        assert!(buf[..TCB_R * d].iter().all(|&z| z == 0.0));
    }

    #[test]
    fn gather_kv_places_columns() {
        let g = generators::erdos_renyi(64, 4.0, 9).with_self_loops();
        let bsb = build(&g);
        let d = 8;
        let (q, k, v) = problem_data(64, d);
        let x = AttentionProblem { n: 64, d, dv: d, q: &q, k: &k, v: &v, scale: 1.0 };
        let t_cap = 8;
        let mut bufs = CallBuffers::default();
        bufs.reset(1, t_cap, d, d);
        let t = bsb.rw_tcbs(0);
        assert!(t > 0 && t <= t_cap);
        gather_kv_range(&mut bufs, 0, &bsb, 0, 0, t, t_cap, &x);
        // Verify each gathered K row matches its source column.
        for j in 0..t {
            let cols = bsb.tcb_cols(0, j);
            for (ci, &col) in cols.iter().enumerate() {
                let krow = &bufs.k[(j * TCB_C + ci) * d..(j * TCB_C + ci + 1) * d];
                if col == PAD_COL {
                    assert!(krow.iter().all(|&z| z == 0.0));
                } else {
                    assert_eq!(krow, &k[col as usize * d..(col as usize + 1) * d]);
                }
            }
        }
        // Padding TCBs beyond t: all zero including bitmaps.
        assert!(bufs.bm[t * BITMAP_WORDS..].iter().all(|&w| w == 0));
    }

    #[test]
    fn scatter_respects_n_boundary() {
        let n = 18;
        let dv = 4;
        let mut out = vec![0.0f32; n * dv];
        let o: Vec<f32> = (0..TCB_R * dv).map(|i| i as f32).collect();
        scatter_slot(&mut out, &o, 0, 1, n, dv); // rows 16, 17 only
        assert_eq!(out[16 * dv], 0.0 * 1.0); // o[0]
        assert_eq!(out[17 * dv + 3], o[dv + 3]);
        // rows 0..16 untouched
        assert!(out[..16 * dv].iter().all(|&z| z == 0.0));
    }

    #[test]
    fn staged_gather_bit_matches_plain_gather() {
        let g = generators::barabasi_albert(200, 4, 13).with_self_loops();
        let bsb = build(&g);
        let d = 8;
        let (q, k, v) = problem_data(200, d);
        let x = AttentionProblem { n: 200, d, dv: d, q: &q, k: &k, v: &v, scale: 0.5 };
        let t_cap = (0..bsb.num_rw).map(|i| bsb.rw_tcbs(i)).max().unwrap();
        let rws: Vec<u32> = (0..bsb.num_rw as u32).collect();
        let pool = WorkerPool::new(2);
        let mut plain = CallBuffers::default();
        gather_call_with(&pool, &mut plain, &rws, t_cap, &bsb, &x, rws.len());
        let staged_bm = stage_call_bitmaps(&bsb, &rws, t_cap, rws.len());
        assert_eq!(staged_bm, plain.bm, "staged bitmaps must match gathered");
        let mut staged = CallBuffers::default();
        gather_call_staged(
            &pool, &mut staged, &rws, t_cap, &staged_bm, &bsb, &x, rws.len(),
        );
        assert_eq!(staged.q, plain.q);
        assert_eq!(staged.k, plain.k);
        assert_eq!(staged.v, plain.v);
        assert_eq!(staged.bm, plain.bm);
    }

    #[test]
    fn gather_scatter_roundtrip_identity_window() {
        // With one full window, gather_q + scatter of the same data is id.
        let n = 16;
        let d = 4;
        let (q, k, v) = problem_data(n, d);
        let x = AttentionProblem { n, d, dv: d, q: &q, k: &k, v: &v, scale: 1.0 };
        let mut buf = vec![0.0f32; TCB_R * d];
        gather_q(&mut buf, 0, 0, &x);
        let mut out = vec![0.0f32; n * d];
        scatter_slot(&mut out, &buf, 0, 0, n, d);
        assert_eq!(out, q);
    }
}
