//! Gathering K̂/V̂ row stacks and scattering output blocks — the L3 "memory
//! engine" of the reproduction (DESIGN.md §1: the paper's PTX-level
//! HBM→register gather becomes an explicit host gather into contiguous
//! per-call buffers that the kernel streams once).
//!
//! All functions write into caller-provided buffers so the hot path can
//! reuse allocations across calls (see EXPERIMENTS.md §Perf).

use crate::bsb::builder::{Bsb, PAD_COL};
use crate::bsb::bitmap;
use crate::{BITMAP_WORDS, TCB_C, TCB_R};

use super::AttentionProblem;

/// Reusable per-call staging buffers.
#[derive(Default)]
pub struct CallBuffers {
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub bm: Vec<i32>,
}

impl CallBuffers {
    /// Resize for a call of `batch` row windows at bucket `t`.
    ///
    /// Only the **bitmap** buffer is zeroed.  Stale f32 values left in
    /// q/k/v slots from earlier calls are sound: every lane not covered by
    /// a fresh gather has a zero bitmap bit, the kernel masks its score to
    /// -inf before exp (p = 0 exactly), and `0 × finite = 0` in the SpMM —
    /// so stale-but-finite values never reach the output.  (The gather only
    /// ever writes finite feature data, preserving the invariant.)  Skipping
    /// the q/k/v memset removes the dominant per-call host cost on large
    /// buckets (up to ~16 MB/call at t=128; EXPERIMENTS.md §Perf).
    pub fn reset(&mut self, batch: usize, t: usize, d: usize, dv: usize) {
        resize_only(&mut self.q, batch * TCB_R * d);
        resize_only(&mut self.k, batch * t * TCB_C * d);
        resize_only(&mut self.v, batch * t * TCB_C * dv);
        // Bitmaps must be exact: a stale 1-bit would unmask a stale lane.
        self.bm.clear();
        self.bm.resize(batch * t * BITMAP_WORDS, 0);
    }
}

fn resize_only<T: Copy + Default>(v: &mut Vec<T>, len: usize) {
    if v.len() != len {
        v.resize(len, T::default());
    }
}

/// Fill one batch slot's Q block: rows `rw*16 .. rw*16+16` of `q`, scaled.
/// Rows beyond n stay zero.
pub fn gather_q(
    buf: &mut [f32],
    slot: usize,
    rw: usize,
    x: &AttentionProblem,
) {
    let d = x.d;
    let base = slot * TCB_R * d;
    for r in 0..TCB_R {
        let row = rw * TCB_R + r;
        if row >= x.n {
            break;
        }
        let dst = &mut buf[base + r * d..base + (r + 1) * d];
        let src = &x.q[row * d..(row + 1) * d];
        if x.scale == 1.0 {
            dst.copy_from_slice(src);
        } else {
            // Pre-scaling Q folds the score scale into the gather pass, so
            // one artifact (scale=1) serves every head configuration.
            for (o, s) in dst.iter_mut().zip(src) {
                *o = s * x.scale;
            }
        }
    }
}

/// Fill one slot's K̂/V̂ stacks + bitmaps for TCBs `[t_lo, t_hi)` of `rw`,
/// padded to `t_cap` TCBs.  `t_lo > 0` is the chunked-RW case.
#[allow(clippy::too_many_arguments)]
pub fn gather_kv_range(
    bufs: &mut CallBuffers,
    slot: usize,
    bsb: &Bsb,
    rw: usize,
    t_lo: usize,
    t_hi: usize,
    t_cap: usize,
    x: &AttentionProblem,
) {
    let (d, dv) = (x.d, x.dv);
    let k_base = slot * t_cap * TCB_C * d;
    let v_base = slot * t_cap * TCB_C * dv;
    let bm_base = slot * t_cap * BITMAP_WORDS;
    for (jj, j) in (t_lo..t_hi).enumerate() {
        let cols = bsb.tcb_cols(rw, j);
        for (ci, &col) in cols.iter().enumerate() {
            if col == PAD_COL {
                continue;
            }
            let col = col as usize;
            let krow = k_base + (jj * TCB_C + ci) * d;
            bufs.k[krow..krow + d]
                .copy_from_slice(&x.k[col * d..(col + 1) * d]);
            let vrow = v_base + (jj * TCB_C + ci) * dv;
            bufs.v[vrow..vrow + dv]
                .copy_from_slice(&x.v[col * dv..(col + 1) * dv]);
        }
        let bm = bitmap::as_i32(bsb.tcb_bitmap(rw, j));
        bufs.bm[bm_base + jj * BITMAP_WORDS..bm_base + (jj + 1) * BITMAP_WORDS]
            .copy_from_slice(&bm);
    }
    // Slots jj in [t_hi-t_lo, t_cap) stay zero (zero bitmap = fully masked).
}

/// Gather a whole regular call (all slots).
pub fn gather_call(
    bufs: &mut CallBuffers,
    rws: &[u32],
    t_bucket: usize,
    bsb: &Bsb,
    x: &AttentionProblem,
    batch: usize,
) {
    bufs.reset(batch, t_bucket, x.d, x.dv);
    for (slot, &rw) in rws.iter().enumerate() {
        let rw = rw as usize;
        gather_q(&mut bufs.q, slot, rw, x);
        let t = bsb.rw_tcbs(rw);
        gather_kv_range(bufs, slot, bsb, rw, 0, t, t_bucket, x);
    }
}

/// Scatter a call's output blocks back into the n×dv output matrix.
pub fn scatter_call(out: &mut [f32], o: &[f32], rws: &[u32], n: usize, dv: usize) {
    for (slot, &rw) in rws.iter().enumerate() {
        scatter_slot(out, o, slot, rw as usize, n, dv);
    }
}

/// Scatter one slot's 16×dv block to rows rw*16.. of `out`.
pub fn scatter_slot(
    out: &mut [f32],
    o: &[f32],
    slot: usize,
    rw: usize,
    n: usize,
    dv: usize,
) {
    let base = slot * TCB_R * dv;
    for r in 0..TCB_R {
        let row = rw * TCB_R + r;
        if row >= n {
            break;
        }
        out[row * dv..(row + 1) * dv]
            .copy_from_slice(&o[base + r * dv..base + (r + 1) * dv]);
    }
}

#[cfg(test)]
mod tests {
    use crate::bsb::build;
    use crate::graph::generators;

    use super::*;

    fn problem_data(n: usize, d: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = crate::util::prng::Rng::new(3);
        (
            rng.normal_vec(n * d, 1.0),
            rng.normal_vec(n * d, 1.0),
            rng.normal_vec(n * d, 1.0),
        )
    }

    #[test]
    fn gather_q_scales_and_pads() {
        let n = 20; // last window ragged
        let d = 4;
        let (q, k, v) = problem_data(n, d);
        let x = AttentionProblem { n, d, dv: d, q: &q, k: &k, v: &v, scale: 2.0 };
        let mut buf = vec![0.0f32; 2 * TCB_R * d];
        gather_q(&mut buf, 1, 1, &x); // rw 1 covers rows 16..20
        for r in 0..4 {
            for c in 0..d {
                assert_eq!(
                    buf[TCB_R * d + r * d + c],
                    q[(16 + r) * d + c] * 2.0
                );
            }
        }
        // Rows 20.. padded with zeros.
        assert!(buf[TCB_R * d + 4 * d..].iter().all(|&z| z == 0.0));
        // Slot 0 untouched.
        assert!(buf[..TCB_R * d].iter().all(|&z| z == 0.0));
    }

    #[test]
    fn gather_kv_places_columns() {
        let g = generators::erdos_renyi(64, 4.0, 9).with_self_loops();
        let bsb = build(&g);
        let d = 8;
        let (q, k, v) = problem_data(64, d);
        let x = AttentionProblem { n: 64, d, dv: d, q: &q, k: &k, v: &v, scale: 1.0 };
        let t_cap = 8;
        let mut bufs = CallBuffers::default();
        bufs.reset(1, t_cap, d, d);
        let t = bsb.rw_tcbs(0);
        assert!(t > 0 && t <= t_cap);
        gather_kv_range(&mut bufs, 0, &bsb, 0, 0, t, t_cap, &x);
        // Verify each gathered K row matches its source column.
        for j in 0..t {
            let cols = bsb.tcb_cols(0, j);
            for (ci, &col) in cols.iter().enumerate() {
                let krow = &bufs.k[(j * TCB_C + ci) * d..(j * TCB_C + ci + 1) * d];
                if col == PAD_COL {
                    assert!(krow.iter().all(|&z| z == 0.0));
                } else {
                    assert_eq!(krow, &k[col as usize * d..(col as usize + 1) * d]);
                }
            }
        }
        // Padding TCBs beyond t: all zero including bitmaps.
        assert!(bufs.bm[t * BITMAP_WORDS..].iter().all(|&w| w == 0));
    }

    #[test]
    fn scatter_respects_n_boundary() {
        let n = 18;
        let dv = 4;
        let mut out = vec![0.0f32; n * dv];
        let o: Vec<f32> = (0..TCB_R * dv).map(|i| i as f32).collect();
        scatter_slot(&mut out, &o, 0, 1, n, dv); // rows 16, 17 only
        assert_eq!(out[16 * dv], 0.0 * 1.0); // o[0]
        assert_eq!(out[17 * dv + 3], o[dv + 3]);
        // rows 0..16 untouched
        assert!(out[..16 * dv].iter().all(|&z| z == 0.0));
    }

    #[test]
    fn gather_scatter_roundtrip_identity_window() {
        // With one full window, gather_q + scatter of the same data is id.
        let n = 16;
        let d = 4;
        let (q, k, v) = problem_data(n, d);
        let x = AttentionProblem { n, d, dv: d, q: &q, k: &k, v: &v, scale: 1.0 };
        let mut buf = vec![0.0f32; TCB_R * d];
        gather_q(&mut buf, 0, 0, &x);
        let mut out = vec![0.0f32; n * d];
        scatter_slot(&mut out, &buf, 0, 0, n, d);
        assert_eq!(out, q);
    }
}
