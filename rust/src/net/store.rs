//! Server-side graph store behind the fingerprint handshake.
//!
//! The serving steady state replays the same topologies (the same reason
//! the coordinator's `DriverCache` exists), so the listener keeps an LRU
//! map `fingerprint → Arc<CsrGraph>` shared by every session: a client
//! that has uploaded a graph once — on *any* connection — can afterwards
//! submit by bare fingerprint and skip the CSR bytes entirely.
//!
//! Collision safety mirrors [`DriverCache`](crate::coordinator::DriverCache):
//! a fingerprint hit is cross-checked against the submit's declared
//! `(n, nnz)`, so a 2⁻⁶⁴ collision degrades to a
//! [`CODE_GRAPH_UNKNOWN`](super::proto::CODE_GRAPH_UNKNOWN) reply (the
//! client re-uploads inline) rather than attention over the wrong graph.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::graph::CsrGraph;
use crate::util::sync::lock_unpoisoned;

struct Slot {
    graph: Arc<CsrGraph>,
    last_used: u64,
}

struct Inner {
    map: HashMap<u64, Slot>,
    tick: u64,
}

/// LRU store of uploaded graphs, keyed by content fingerprint.
pub struct GraphStore {
    capacity: usize,
    inner: Mutex<Inner>,
}

impl GraphStore {
    /// `capacity == 0` disables the store (every submit must inline its
    /// graph; `GraphQuery` always answers unknown).
    pub fn new(capacity: usize) -> GraphStore {
        GraphStore {
            capacity,
            inner: Mutex::new(Inner { map: HashMap::new(), tick: 0 }),
        }
    }

    /// Whether `fp` is resident (refreshes LRU recency — a client asking
    /// about a graph is about to use it).
    pub fn contains(&self, fp: u64) -> bool {
        if self.capacity == 0 {
            return false;
        }
        let mut inner = lock_unpoisoned(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(&fp) {
            Some(slot) => {
                slot.last_used = tick;
                true
            }
            None => false,
        }
    }

    /// Resolve a submit-by-fingerprint; `n`/`nnz` are the submit's
    /// declared counts (collision cross-check).  A mismatch is a miss.
    pub fn get(&self, fp: u64, n: usize, nnz: usize) -> Option<Arc<CsrGraph>> {
        if self.capacity == 0 {
            return None;
        }
        let mut inner = lock_unpoisoned(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        let slot = inner.map.get_mut(&fp)?;
        if slot.graph.n != n || slot.graph.nnz() != nnz {
            return None;
        }
        slot.last_used = tick;
        Some(slot.graph.clone())
    }

    /// Register an uploaded graph under its own content fingerprint,
    /// evicting least-recently-used entries to stay within capacity.
    /// Returns how many were evicted.
    pub fn insert(&self, graph: Arc<CsrGraph>) -> u64 {
        if self.capacity == 0 {
            return 0;
        }
        let fp = graph.fingerprint();
        let mut inner = lock_unpoisoned(&self.inner);
        let mut evicted = 0u64;
        while inner.map.len() >= self.capacity && !inner.map.contains_key(&fp)
        {
            let oldest = inner
                .map
                .iter()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| *k)
                // invariant: the loop condition guarantees len >= capacity
                // >= 1, so the map cannot be empty here.
                .expect("non-empty map");
            inner.map.remove(&oldest);
            evicted += 1;
        }
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.insert(fp, Slot { graph, last_used: tick });
        evicted
    }

    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn insert_then_resolve_with_cross_check() {
        let store = GraphStore::new(4);
        let g = Arc::new(generators::ring(32)); // n=32, nnz=64
        let fp = g.fingerprint();
        assert!(!store.contains(fp));
        store.insert(g.clone());
        assert!(store.contains(fp));
        assert!(store.get(fp, 32, 64).is_some());
        // Declared counts disagreeing with the stored graph: miss.
        assert!(store.get(fp, 33, 64).is_none());
        assert!(store.get(fp, 32, 63).is_none());
    }

    #[test]
    fn lru_eviction_order() {
        let store = GraphStore::new(2);
        let gs: Vec<Arc<CsrGraph>> =
            (0..3).map(|i| Arc::new(generators::ring(16 + i))).collect();
        store.insert(gs[0].clone());
        store.insert(gs[1].clone());
        // Touch 0 so 1 becomes the LRU entry.
        assert!(store.contains(gs[0].fingerprint()));
        let evicted = store.insert(gs[2].clone());
        assert_eq!(evicted, 1);
        assert!(store.contains(gs[0].fingerprint()));
        assert!(!store.contains(gs[1].fingerprint()));
        assert!(store.contains(gs[2].fingerprint()));
    }

    #[test]
    fn zero_capacity_disables() {
        let store = GraphStore::new(0);
        let g = Arc::new(generators::ring(8));
        assert_eq!(store.insert(g.clone()), 0);
        assert!(!store.contains(g.fingerprint()));
        assert!(store.get(g.fingerprint(), 8, 16).is_none());
        assert!(store.is_empty());
    }
}
