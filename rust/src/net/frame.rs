//! Length-prefixed framing over a byte stream.
//!
//! Every message travels as one frame:
//!
//! ```text
//! ┌────────────┬────────────┬───────────────────────┐
//! │ magic u32  │ len u32    │ payload (len bytes)   │   all little-endian
//! └────────────┴────────────┴───────────────────────┘
//! ```
//!
//! The magic word rejects non-protocol peers (a browser, a port scanner)
//! on the first 4 bytes; the length is validated against the session's
//! `max_frame_bytes` *before* the payload buffer is allocated, so an
//! absurd or hostile length prefix costs a structured
//! [`FrameError::Oversize`], never memory.  A clean EOF exactly at a
//! frame boundary is the normal end-of-stream ([`FrameError::Closed`]);
//! EOF anywhere inside a frame is [`FrameError::Truncated`].

use std::io::{ErrorKind, Read, Write};

/// Frame magic: ASCII `F3SN` (Fused3S Net), little-endian.
pub const MAGIC: u32 = u32::from_le_bytes(*b"F3SN");

/// Default per-frame payload cap (256 MiB — a 1M-node graph with d=64
/// three-tensor features fits comfortably; sessions can lower it).
pub const DEFAULT_MAX_FRAME_BYTES: usize = 256 << 20;

/// Transport-level failure while reading or writing one frame.
#[derive(Debug)]
pub enum FrameError {
    /// Clean EOF at a frame boundary — the peer hung up between messages.
    Closed,
    /// EOF inside a header or payload — the peer died mid-frame.
    Truncated,
    /// The first 4 bytes were not the protocol magic.
    BadMagic(u32),
    /// Declared payload length exceeds the session's cap.
    Oversize { len: usize, max: usize },
    /// Underlying socket error.
    Io(std::io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Closed => f.write_str("connection closed"),
            FrameError::Truncated => f.write_str("EOF inside a frame"),
            FrameError::BadMagic(m) => {
                write!(f, "bad frame magic {m:#010x} (expected {MAGIC:#010x})")
            }
            FrameError::Oversize { len, max } => {
                write!(f, "frame payload {len} bytes exceeds cap {max}")
            }
            FrameError::Io(e) => write!(f, "socket error: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// Write one frame (header + payload) and flush it.
pub fn write_frame(
    w: &mut impl Write,
    payload: &[u8],
    max: usize,
) -> Result<(), FrameError> {
    if payload.len() > max {
        return Err(FrameError::Oversize { len: payload.len(), max });
    }
    let mut header = [0u8; 8];
    header[..4].copy_from_slice(&MAGIC.to_le_bytes());
    header[4..].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    w.write_all(&header).map_err(FrameError::Io)?;
    w.write_all(payload).map_err(FrameError::Io)?;
    w.flush().map_err(FrameError::Io)
}

/// Read one frame's payload.  Distinguishes a clean close (EOF before any
/// header byte) from a mid-frame disconnect.
pub fn read_frame(
    r: &mut impl Read,
    max: usize,
) -> Result<Vec<u8>, FrameError> {
    let mut header = [0u8; 8];
    let mut got = 0usize;
    while got < header.len() {
        match r.read(&mut header[got..]) {
            Ok(0) => {
                return Err(if got == 0 {
                    FrameError::Closed
                } else {
                    FrameError::Truncated
                });
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    let magic = u32::from_le_bytes([header[0], header[1], header[2], header[3]]);
    if magic != MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    let len =
        u32::from_le_bytes([header[4], header[5], header[6], header[7]]) as usize;
    // Cap check BEFORE allocation: a hostile length prefix must not cost
    // memory.
    if len > max {
        return Err(FrameError::Oversize { len, max });
    }
    let mut payload = vec![0u8; len];
    match r.read_exact(&mut payload) {
        Ok(()) => Ok(payload),
        Err(e) if e.kind() == ErrorKind::UnexpectedEof => {
            Err(FrameError::Truncated)
        }
        Err(e) => Err(FrameError::Io(e)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello", 1024).unwrap();
        write_frame(&mut buf, b"", 1024).unwrap();
        let mut c = Cursor::new(buf);
        assert_eq!(read_frame(&mut c, 1024).unwrap(), b"hello");
        assert_eq!(read_frame(&mut c, 1024).unwrap(), b"");
        assert!(matches!(read_frame(&mut c, 1024), Err(FrameError::Closed)));
    }

    #[test]
    fn bad_magic() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&0xDEADBEEFu32.to_le_bytes());
        buf.extend_from_slice(&4u32.to_le_bytes());
        buf.extend_from_slice(b"oops");
        let mut c = Cursor::new(buf);
        assert!(matches!(
            read_frame(&mut c, 1024),
            Err(FrameError::BadMagic(0xDEADBEEF))
        ));
    }

    #[test]
    fn oversize_rejected_before_alloc() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC.to_le_bytes());
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut c = Cursor::new(buf);
        match read_frame(&mut c, 1024) {
            Err(FrameError::Oversize { len, max }) => {
                assert_eq!(len, u32::MAX as usize);
                assert_eq!(max, 1024);
            }
            other => panic!("expected Oversize, got {other:?}"),
        }
    }

    #[test]
    fn writer_respects_cap() {
        let mut buf = Vec::new();
        assert!(matches!(
            write_frame(&mut buf, &[0u8; 64], 16),
            Err(FrameError::Oversize { .. })
        ));
        assert!(buf.is_empty(), "nothing written after a cap refusal");
    }

    #[test]
    fn truncated_header_and_payload() {
        // 3 header bytes then EOF.
        let mut c = Cursor::new(MAGIC.to_le_bytes()[..3].to_vec());
        assert!(matches!(read_frame(&mut c, 64), Err(FrameError::Truncated)));
        // Full header declaring 100 bytes, only 10 present.
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC.to_le_bytes());
        buf.extend_from_slice(&100u32.to_le_bytes());
        buf.extend_from_slice(&[7u8; 10]);
        let mut c = Cursor::new(buf);
        assert!(matches!(read_frame(&mut c, 1024), Err(FrameError::Truncated)));
    }
}
