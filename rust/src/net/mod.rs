//! Network serving layer: a binary wire protocol + session handling in
//! front of the coordinator.
//!
//! ```text
//!   client process                      server process
//!   ──────────────                      ──────────────────────────────
//!   NetClient ──TCP── accept loop ──► session (reader thread)
//!     │ frame.rs        listener.rs      │  handshake (auth/version)
//!     │ proto.rs                         │  fingerprint → GraphStore
//!     │ wire.rs                          │  quota slot (Mutex+Condvar)
//!     │                                  ▼
//!     │                           Coordinator::submit  (bounded queue)
//!     │                                  │  batcher → workers → executor
//!     │                                  ▼
//!     ◄──────────────────────────── forwarder thread (per session)
//!                                     flushes AttnResponse frames
//! ```
//!
//! Layering, bottom-up:
//!
//! * [`wire`] — primitive little-endian encode/decode with
//!   allocation-safe length validation.
//! * [`frame`] — `[MAGIC][len][payload]` framing over any
//!   `Read`/`Write`, with the length cap enforced *before* allocation.
//! * [`proto`] — the message vocabulary ([`proto::Msg`]): hello/ack,
//!   graph query/status, submit, response, goodbye; CSR graphs are
//!   structurally validated on decode.
//! * [`store`] — the shared LRU of uploaded graphs that makes the
//!   fingerprint handshake work across connections.
//! * [`session`] (private) + [`listener`] — per-connection reader and
//!   forwarder threads, auth, per-session in-flight quota, graceful
//!   drain.
//! * [`client`] — the blocking library used by `repro serve`, the
//!   loadgen, and the differential tests.
//!
//! **Flow control** composes three bounded layers with zero additional
//! buffering: a session that has `max_inflight` unanswered submits stops
//! granting quota slots, which parks its reader; a parked reader stops
//! draining the socket, so the kernel TCP window fills and the *client's*
//! writer blocks.  Independently, `Coordinator::submit` blocks when the
//! coordinator's ingress queue is full, with the same reader-parking
//! effect.  The in-process backpressure contract becomes end-to-end
//! connection-level flow control for free.
//!
//! **Fingerprint handshake.**  Graph topology dominates request bytes
//! for small feature dims, and serving steady states replay the same
//! graphs (the premise of the coordinator's `DriverCache`).  A client
//! therefore asks `GraphQuery{fp}` before first use, uploads the CSR
//! inline only on `known: false`, and afterwards submits by bare
//! `(fp, n, nnz)` reference.  The server cross-checks `(n, nnz)` against
//! the stored graph (collision guard) and answers
//! [`proto::CODE_GRAPH_UNKNOWN`] on eviction or mismatch, which the
//! client handles by re-uploading inline exactly once.  Combined with
//! the fingerprint-keyed `DriverCache` behind the batcher, a repeat
//! graph costs neither wire bytes nor preprocessing.
//!
//! Everything is std-only (threads + blocking sockets), matching the
//! coordinator's no-async design; see DESIGN.md §13 for the frame
//! grammar and the session state machine.

pub mod client;
pub mod frame;
pub mod listener;
pub mod proto;
pub mod store;
pub mod wire;

mod session;

pub use client::{
    ClientStats, NetClient, NetError, UpdateSummary, WireRequest, WireResponse,
};
pub use listener::{NetConfig, NetServer};
pub use store::GraphStore;
