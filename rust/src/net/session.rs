//! Per-connection session: handshake → auth → request pump.
//!
//! Thread layout per connection (std threads, matching the coordinator):
//!
//! ```text
//! session (reader) thread          forwarder thread
//!   read_frame / decode              rx.recv()  ◄─ coordinator replies
//!   auth + quota acquire             encode Response frame
//!   resolve GraphRef via store       write (socket mutex)
//!   Coordinator::submit  ──────►     quota release + notify
//! ```
//!
//! Flow control composes three layers: the per-session in-flight quota
//! (acquired before submit, released as each response is written), the
//! coordinator's bounded ingress (a blocked `submit` blocks this reader),
//! and TCP's own window (a blocked reader stops draining the socket).
//!
//! Failure policy: anything the coordinator can answer structurally
//! (shape errors, prepare/execute failures, deadline sheds) flows back as
//! a [`Msg::Response`] with the mapped error code and the session lives
//! on.  Frame-level garbage (bad magic, truncation, unknown tags,
//! malformed CSR) is session-fatal: the server best-effort sends a
//! `Response{id: 0, CODE_PROTOCOL}` and closes.  Either way the reader
//! drops its reply sender on exit, the forwarder drains every response
//! still in flight, and no quota slot or batcher stage is left wedged.

use std::net::{Shutdown, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::coordinator::{AttnRequest, AttnResponse};
use crate::graph::GraphDelta;
use crate::kernels::Backend;
use crate::trace::{self, TraceSite};
use crate::util::json;
use crate::util::sync::lock_unpoisoned;

use super::frame::{read_frame, write_frame, FrameError};
use super::listener::Shared;
use super::proto::{
    self, GraphRef, GraphUpdateMsg, GraphUpdatedMsg, Msg, OkPayload,
    ResponseMsg, SubmitMsg, UpdateSummaryMsg, CODE_GRAPH_UNKNOWN,
    CODE_PROTOCOL, VERSION,
};

/// In-flight slot counter + wakeup for one session.
struct Quota {
    slots: Mutex<usize>,
    freed: Condvar,
}

/// Serve one connection to completion.  Never panics outward: every exit
/// path drains the forwarder and closes the socket.
pub(crate) fn run(shared: &Arc<Shared>, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    if !handshake(shared, &stream) {
        let _ = stream.shutdown(Shutdown::Both);
        return;
    }
    // All writes (forwarder responses + reader-side error/status frames)
    // serialize through one cloned handle behind a mutex, so frames never
    // interleave.
    let Ok(write_half) = stream.try_clone() else {
        let _ = stream.shutdown(Shutdown::Both);
        return;
    };
    let writer = Arc::new(Mutex::new(write_half));
    let quota =
        Arc::new(Quota { slots: Mutex::new(0), freed: Condvar::new() });
    let (tx, rx) = channel::<AttnResponse>();

    let forwarder = {
        let writer = writer.clone();
        let quota = quota.clone();
        let shared = shared.clone();
        std::thread::spawn(move || {
            while let Ok(resp) = rx.recv() {
                let span = resp.span;
                let msg = Msg::Response(to_wire_response(resp));
                // A write failure means the client is gone; keep draining
                // so every reply sender disconnects and quota stays sane.
                let encode = trace::span(TraceSite::NetEncode, span, 0);
                let _ = send(&shared, &writer, &msg);
                drop(encode);
                let mut slots = lock_unpoisoned(&quota.slots);
                *slots = slots.saturating_sub(1);
                drop(slots);
                quota.freed.notify_all();
            }
        })
    };

    reader_loop(shared, &stream, &writer, &quota, &tx);

    // Dropping the master sender lets the forwarder's recv() disconnect
    // once every in-flight request has been answered — the drain path.
    drop(tx);
    let _ = forwarder.join();
    let _ = stream.shutdown(Shutdown::Both);
}

/// Read frames until the peer closes, a protocol violation occurs, or
/// shutdown cuts the read side.
fn reader_loop(
    shared: &Arc<Shared>,
    stream: &TcpStream,
    writer: &Mutex<TcpStream>,
    quota: &Arc<Quota>,
    tx: &Sender<AttnResponse>,
) {
    let max = shared.cfg.max_frame_bytes;
    loop {
        let payload = match read_frame(&mut &*stream, max) {
            Ok(p) => p,
            Err(FrameError::Closed) => return,
            Err(e) => {
                // Mid-frame disconnects surface as Truncated/Io; hostile
                // prefixes as BadMagic/Oversize.  All are session-fatal.
                protocol_fatal(shared, writer, &e.to_string());
                return;
            }
        };
        shared.metrics.net.read(8 + payload.len() as u64);
        let msg = match Msg::decode(&payload) {
            Ok(m) => m,
            Err(e) => {
                protocol_fatal(shared, writer, &e.to_string());
                return;
            }
        };
        match msg {
            Msg::GraphQuery { fp } => {
                let known = shared.store.contains(fp);
                if !send(shared, writer, &Msg::GraphStatus { fp, known }) {
                    return;
                }
            }
            Msg::Submit(sub) => {
                if !handle_submit(shared, writer, quota, tx, sub) {
                    return;
                }
            }
            Msg::GraphUpdate(up) => {
                if !handle_graph_update(shared, writer, up) {
                    return;
                }
            }
            Msg::MetricsQuery => {
                let report = Msg::MetricsReport {
                    json: json::to_string(&shared.metrics.to_json()),
                };
                if !send(shared, writer, &report) {
                    return;
                }
            }
            Msg::Goodbye => return,
            // Server-to-client messages (or a second hello) arriving here
            // mark a confused peer.
            Msg::ClientHello { .. }
            | Msg::ServerHello { .. }
            | Msg::GraphStatus { .. }
            | Msg::Response(_)
            | Msg::MetricsReport { .. }
            | Msg::GraphUpdated(_) => {
                protocol_fatal(shared, writer, "unexpected message for server");
                return;
            }
        }
    }
}

/// Admit one submit.  Returns false when the session must close (socket
/// dead or server shutting down); structured per-request failures return
/// true and keep the session alive.
fn handle_submit(
    shared: &Arc<Shared>,
    writer: &Mutex<TcpStream>,
    quota: &Arc<Quota>,
    tx: &Sender<AttnResponse>,
    sub: SubmitMsg,
) -> bool {
    // Resolve the graph reference first — a fingerprint miss must be
    // answered without consuming a quota slot (the client immediately
    // retries inline, and a blocked slot would deadlock a full pipeline).
    let graph = match sub.graph {
        GraphRef::Inline(g) => {
            let arc = Arc::new(g);
            shared.store.insert(arc.clone());
            shared.metrics.net.graph_upload();
            arc
        }
        GraphRef::Fingerprint { fp, n, nnz } => {
            match shared.store.get(fp, n as usize, nnz as usize) {
                Some(g) => {
                    shared.metrics.net.graph_reuse();
                    g
                }
                None => {
                    return send_error(
                        shared,
                        writer,
                        sub.id,
                        CODE_GRAPH_UNKNOWN,
                        "graph not resident; re-send inline",
                    );
                }
            }
        }
    };
    let backend = match Backend::parse(&sub.backend) {
        Ok(b) => b,
        Err(e) => {
            return send_error(
                shared,
                writer,
                sub.id,
                proto::CODE_UNSUPPORTED,
                &format!("{e:#}"),
            );
        }
    };
    // Connection-level flow control: block until a slot frees (responses
    // written) or the server starts draining.
    if !acquire_slot(shared, quota) {
        return false;
    }
    shared.metrics.net.request();
    let span = trace::sample_request(sub.id);
    trace::instant(
        TraceSite::NetDecode,
        span,
        sub.id,
        (sub.q.len() + sub.k.len() + sub.v.len()) as u64,
    );
    let req = AttnRequest {
        id: sub.id,
        // The coordinator owns its request's graph by value; the store
        // keeps sharing the Arc, so this clone is the one topology copy
        // per request (features already arrived owned).
        graph: (*graph).clone(),
        d: sub.d as usize,
        dv: sub.dv as usize,
        heads: sub.heads as usize,
        q: sub.q,
        k: sub.k,
        v: sub.v,
        scale: sub.scale,
        backend,
        deadline: (sub.deadline_micros > 0)
            .then(|| Duration::from_micros(sub.deadline_micros)),
        // The session rolls the sampling decision here (rather than
        // leaving it to Coordinator::submit) so the decode seam can be
        // attributed to the same span the serving core will carry.
        span,
        reply: tx.clone(),
    };
    if let Err(e) = shared.coord.submit(req) {
        // The request never entered the pipeline: give the slot back and
        // answer structurally.
        release_slot(quota);
        let (code, msg) = proto::encode_attn_error(&e);
        return send_error(shared, writer, sub.id, code, &msg);
    }
    true
}

/// Apply one streaming delta (DESIGN.md §14).  The base resolves through
/// the same [`GraphRef`] path submits use; the patched graph is inserted
/// into the store under its new fingerprint so subsequent submits (and
/// further deltas) ride bare references.  All outcomes — including a
/// rejected delta — answer with [`Msg::GraphUpdated`] and keep the
/// session alive; only a dead socket returns false.
fn handle_graph_update(
    shared: &Arc<Shared>,
    writer: &Mutex<TcpStream>,
    up: GraphUpdateMsg,
) -> bool {
    let base = match up.base {
        GraphRef::Inline(g) => {
            let arc = Arc::new(g);
            shared.store.insert(arc.clone());
            shared.metrics.net.graph_upload();
            arc
        }
        GraphRef::Fingerprint { fp, n, nnz } => {
            match shared.store.get(fp, n as usize, nnz as usize) {
                Some(g) => {
                    shared.metrics.net.graph_reuse();
                    g
                }
                None => {
                    return send(
                        shared,
                        writer,
                        &Msg::GraphUpdated(GraphUpdatedMsg {
                            payload: Err((
                                CODE_GRAPH_UNKNOWN,
                                "base graph not resident; re-send inline"
                                    .to_string(),
                            )),
                        }),
                    );
                }
            }
        }
    };
    let delta = GraphDelta {
        base_fp: base.fingerprint(),
        inserts: up.inserts,
        removes: up.removes,
    };
    let payload = match shared.coord.update_graph(&base, &delta) {
        Ok(r) => {
            shared.store.insert(r.patched.clone());
            Ok(UpdateSummaryMsg {
                old_fp: r.old_fp,
                new_fp: r.new_fp,
                inserted: r.inserted as u32,
                removed: r.removed as u32,
                dirty_rws: r.dirty_rws as u32,
                spliced_rws: r.spliced_rws as u32,
                full_rebuild: r.full_rebuild,
            })
        }
        Err(e) => Err(proto::encode_attn_error(&e)),
    };
    send(shared, writer, &Msg::GraphUpdated(GraphUpdatedMsg { payload }))
}

/// Block for an in-flight slot.  False once the server is draining.
fn acquire_slot(shared: &Shared, quota: &Quota) -> bool {
    let mut slots = lock_unpoisoned(&quota.slots);
    loop {
        if shared.closed.load(Ordering::SeqCst) {
            return false;
        }
        if *slots < shared.cfg.max_inflight {
            *slots += 1;
            return true;
        }
        // Bounded wait so a shutdown during a full pipeline still gets
        // observed (the forwarder also notifies on every release).
        let (guard, _) = match quota
            .freed
            .wait_timeout(slots, Duration::from_millis(50))
        {
            Ok(x) => x,
            Err(poisoned) => poisoned.into_inner(),
        };
        slots = guard;
    }
}

fn release_slot(quota: &Quota) {
    let mut slots = lock_unpoisoned(&quota.slots);
    *slots = slots.saturating_sub(1);
    drop(slots);
    quota.freed.notify_all();
}

/// Hello exchange under the handshake read-timeout.  False = reject/close.
fn handshake(shared: &Arc<Shared>, stream: &TcpStream) -> bool {
    let _ = stream.set_read_timeout(Some(shared.cfg.handshake_timeout));
    let max = shared.cfg.max_frame_bytes;
    let payload = match read_frame(&mut &*stream, max) {
        Ok(p) => p,
        Err(FrameError::Closed) => return false, // probe connected + left
        Err(_) => {
            shared.metrics.net.protocol_error();
            return false;
        }
    };
    shared.metrics.net.read(8 + payload.len() as u64);
    let (version, token) = match Msg::decode(&payload) {
        Ok(Msg::ClientHello { version, token }) => (version, token),
        _ => {
            shared.metrics.net.protocol_error();
            reject(shared, stream, "expected client hello");
            return false;
        }
    };
    if version != VERSION {
        shared.metrics.net.protocol_error();
        reject(
            shared,
            stream,
            &format!("protocol version {version} unsupported (server: {VERSION})"),
        );
        return false;
    }
    if !shared.cfg.auth_tokens.is_empty()
        && !shared.cfg.auth_tokens.iter().any(|t| t == &token)
    {
        shared.metrics.net.auth_failure();
        reject(shared, stream, "invalid auth token");
        return false;
    }
    let hello = Msg::ServerHello {
        version: VERSION,
        ok: true,
        detail: String::new(),
        max_inflight: shared.cfg.max_inflight as u32,
    };
    let bytes = hello.encode();
    if write_frame(&mut &*stream, &bytes, max).is_err() {
        return false;
    }
    shared.metrics.net.wrote(8 + bytes.len() as u64);
    let _ = stream.set_read_timeout(None);
    true
}

/// Best-effort rejection hello (the peer may already be gone).
fn reject(shared: &Arc<Shared>, stream: &TcpStream, detail: &str) {
    let msg = Msg::ServerHello {
        version: VERSION,
        ok: false,
        detail: detail.to_string(),
        max_inflight: 0,
    };
    let bytes = msg.encode();
    if write_frame(&mut &*stream, &bytes, shared.cfg.max_frame_bytes).is_ok() {
        shared.metrics.net.wrote(8 + bytes.len() as u64);
    }
}

/// Count + best-effort-report a session-fatal protocol violation.
fn protocol_fatal(shared: &Arc<Shared>, writer: &Mutex<TcpStream>, msg: &str) {
    shared.metrics.net.protocol_error();
    let _ = send(
        shared,
        writer,
        &Msg::Response(ResponseMsg {
            id: 0,
            payload: Err((CODE_PROTOCOL, msg.to_string())),
        }),
    );
}

/// Send one per-request error response.  True while the socket still
/// accepts writes.
fn send_error(
    shared: &Arc<Shared>,
    writer: &Mutex<TcpStream>,
    id: u64,
    code: u8,
    msg: &str,
) -> bool {
    send(
        shared,
        writer,
        &Msg::Response(ResponseMsg {
            id,
            payload: Err((code, msg.to_string())),
        }),
    )
}

/// Encode + write one frame through the shared write half.
fn send(shared: &Shared, writer: &Mutex<TcpStream>, msg: &Msg) -> bool {
    let bytes = msg.encode();
    let mut sock = lock_unpoisoned(writer);
    match write_frame(&mut *sock, &bytes, shared.cfg.max_frame_bytes) {
        Ok(()) => {
            shared.metrics.net.wrote(8 + bytes.len() as u64);
            true
        }
        Err(_) => false,
    }
}

/// Lower an [`AttnResponse`] onto the wire shape.
fn to_wire_response(resp: AttnResponse) -> ResponseMsg {
    let id = resp.id;
    match resp.result {
        Ok(out) => ResponseMsg {
            id,
            payload: Ok(OkPayload {
                out,
                latency_s: resp.latency_s,
                preprocess_s: resp.preprocess_s,
                execute_s: resp.execute_s,
                batch_size: resp.batch_size as u32,
                backend: resp
                    .backend
                    .map(|b| b.name().to_string())
                    .unwrap_or_default(),
            }),
        },
        Err(e) => {
            let (code, msg) = proto::encode_attn_error(&e);
            ResponseMsg { id, payload: Err((code, msg)) }
        }
    }
}
