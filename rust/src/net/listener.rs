//! The threaded TCP listener: accept loop + session lifecycle + graceful
//! drain.
//!
//! [`NetServer::serve`] binds, spawns one accept thread, and hands each
//! connection to a session thread ([`super::session`]).  Sessions feed
//! the coordinator's bounded ingress directly — a blocked
//! `Coordinator::submit` (backpressure) blocks that session's reader,
//! which stops reading from its socket, which fills the kernel's TCP
//! window, which blocks the client's writer: the in-process bounded-queue
//! contract becomes end-to-end connection-level flow control with no
//! extra buffering anywhere.
//!
//! [`NetServer::shutdown`] drains rather than drops: it stops accepting,
//! then half-closes each session's *read* side only — in-flight requests
//! keep their reply channels, the coordinator answers them through the
//! batcher's normal drain path, and each session's forwarder flushes
//! those responses to the socket before the connection closes.  The
//! coordinator itself is NOT shut down here (it may be shared); callers
//! stop it after the listener.

use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::{Coordinator, Metrics};
use crate::util::sync::lock_unpoisoned;

use super::frame::DEFAULT_MAX_FRAME_BYTES;
use super::session;
use super::store::GraphStore;

/// Listener + session policy.
#[derive(Clone)]
pub struct NetConfig {
    /// Bind address; port 0 picks a free port (see
    /// [`NetServer::local_addr`]).
    pub addr: String,
    /// Accepted auth tokens.  Empty = open server (no auth) — the
    /// loopback/test default; production deployments list their tenants.
    pub auth_tokens: Vec<String>,
    /// Per-session in-flight request quota: a client with this many
    /// unanswered submits blocks (connection-level flow control layered
    /// on top of the coordinator's global backpressure).
    pub max_inflight: usize,
    /// Per-frame payload cap, enforced before allocation.
    pub max_frame_bytes: usize,
    /// LRU capacity of the shared uploaded-graph store (entries).
    pub graph_capacity: usize,
    /// How long a fresh connection may take to send its `ClientHello`
    /// before the session gives up (slowloris guard).
    pub handshake_timeout: Duration,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            addr: "127.0.0.1:0".to_string(),
            auth_tokens: Vec::new(),
            max_inflight: 8,
            max_frame_bytes: DEFAULT_MAX_FRAME_BYTES,
            graph_capacity: 256,
            handshake_timeout: Duration::from_secs(5),
        }
    }
}

/// State shared by the accept loop and every session.
pub(crate) struct Shared {
    pub coord: Arc<Coordinator>,
    pub store: GraphStore,
    pub cfg: NetConfig,
    pub metrics: Arc<Metrics>,
    /// Set once by [`NetServer::shutdown`]; sessions poll it so quota
    /// waiters and accept races unblock promptly.
    pub closed: AtomicBool,
}

struct SessionHandle {
    /// A clone of the session's stream, kept so shutdown can half-close
    /// the read side from outside the session thread.
    stream: TcpStream,
    thread: JoinHandle<()>,
}

/// A running TCP front end over an `Arc<Coordinator>`.
pub struct NetServer {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    accept: Mutex<Option<JoinHandle<()>>>,
    sessions: Arc<Mutex<Vec<SessionHandle>>>,
}

impl NetServer {
    /// Bind `cfg.addr` and start serving `coord` over it.
    pub fn serve(coord: Arc<Coordinator>, cfg: NetConfig) -> Result<NetServer> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let local_addr =
            listener.local_addr().context("resolving bound address")?;
        let metrics = coord.metrics_arc();
        let shared = Arc::new(Shared {
            store: GraphStore::new(cfg.graph_capacity),
            coord,
            cfg,
            metrics,
            closed: AtomicBool::new(false),
        });
        let sessions: Arc<Mutex<Vec<SessionHandle>>> =
            Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let shared = shared.clone();
            let sessions = sessions.clone();
            std::thread::spawn(move || accept_loop(&listener, shared, sessions))
        };
        Ok(NetServer {
            shared,
            local_addr,
            accept: Mutex::new(Some(accept)),
            sessions,
        })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Stop accepting, drain every live session, join every thread.
    /// In-flight requests are answered before their connections close
    /// (the forwarder flushes coordinator responses after the read side
    /// is cut); idempotent.
    pub fn shutdown(&self) {
        if self.shared.closed.swap(true, Ordering::SeqCst) {
            return;
        }
        // Wake the accept loop: a throwaway connection makes the blocking
        // accept() return, after which it observes `closed` and exits.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = lock_unpoisoned(&self.accept).take() {
            let _ = h.join();
        }
        let handles: Vec<SessionHandle> =
            lock_unpoisoned(&self.sessions).drain(..).collect();
        for h in handles {
            // Half-close: the session's reader sees EOF and stops taking
            // new requests; its write side stays open so the forwarder
            // can still deliver every in-flight response.
            let _ = h.stream.shutdown(Shutdown::Read);
            let _ = h.thread.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: &TcpListener,
    shared: Arc<Shared>,
    sessions: Arc<Mutex<Vec<SessionHandle>>>,
) {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if shared.closed.load(Ordering::SeqCst) {
                    // The shutdown wake-up (or a straggler racing it):
                    // drop it and stop accepting.
                    break;
                }
                // The handle clone lets shutdown cut the read side from
                // outside; a clone failure means the socket is already
                // dead, so the connection is refused.
                let Ok(handle) = stream.try_clone() else {
                    continue;
                };
                shared.metrics.net.connection();
                let s = shared.clone();
                let thread =
                    std::thread::spawn(move || session::run(&s, stream));
                let mut list = lock_unpoisoned(&sessions);
                // Reap naturally finished sessions so a long-lived server
                // doesn't accumulate dead handles.
                let mut i = 0;
                while i < list.len() {
                    if list[i].thread.is_finished() {
                        let done = list.remove(i);
                        let _ = done.thread.join();
                    } else {
                        i += 1;
                    }
                }
                list.push(SessionHandle { stream: handle, thread });
            }
            Err(_) => {
                if shared.closed.load(Ordering::SeqCst) {
                    break;
                }
                // Transient accept error (EMFILE, aborted handshake):
                // keep serving.
            }
        }
    }
}
