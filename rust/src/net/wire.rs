//! Byte-level wire primitives: a little-endian bump writer and a
//! bounds-checked reader.
//!
//! Everything on the wire is little-endian.  Variable-length fields carry
//! an explicit count prefix (u32 for strings, u64 for numeric arrays) and
//! the reader checks the declared count against the bytes *actually
//! remaining* before allocating — a frame that lies about its own length
//! costs a [`WireError::Truncated`], never an absurd allocation (the frame
//! layer has already capped the total payload size, so `remaining()` is a
//! trusted bound).

/// Decode failure inside a frame payload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// The payload ended before a declared field — a truncated or lying
    /// message body.
    Truncated {
        /// Bytes the field needed.
        needed: usize,
        /// Bytes left in the payload.
        available: usize,
    },
    /// Structurally invalid content (unknown tag, bad UTF-8, inconsistent
    /// CSR arrays, ...).
    Malformed(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { needed, available } => write!(
                f,
                "truncated payload: field needs {needed} bytes, {available} \
                 remain"
            ),
            WireError::Malformed(m) => write!(f, "malformed payload: {m}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Append-only encoder over a growable byte buffer.
#[derive(Default)]
pub struct WireWriter {
    buf: Vec<u8>,
}

impl WireWriter {
    pub fn new() -> WireWriter {
        WireWriter::default()
    }

    /// Encoded bytes so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    /// UTF-8 string with a u32 byte-length prefix.
    pub fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// u32 array with a u64 element-count prefix.
    pub fn put_u32s(&mut self, vs: &[u32]) {
        self.put_u64(vs.len() as u64);
        self.buf.reserve(vs.len() * 4);
        for &v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// f32 array (bit patterns) with a u64 element-count prefix — the
    /// encoding is exact, so a round-trip preserves every payload bit
    /// (including NaN payloads and signed zeros).
    pub fn put_f32s(&mut self, vs: &[f32]) {
        self.put_u64(vs.len() as u64);
        self.buf.reserve(vs.len() * 4);
        for &v in vs {
            self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
        }
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Bounds-checked cursor over a received payload.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> WireReader<'a> {
        WireReader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take_bytes(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated {
                needed: n,
                available: self.remaining(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn take_u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take_bytes(1)?[0])
    }

    pub fn take_u16(&mut self) -> Result<u16, WireError> {
        let b = self.take_bytes(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn take_u32(&mut self) -> Result<u32, WireError> {
        let b = self.take_bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn take_u64(&mut self) -> Result<u64, WireError> {
        let b = self.take_bytes(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn take_f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_bits(self.take_u32()?))
    }

    pub fn take_f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.take_u64()?))
    }

    /// String with a u32 length prefix; the bytes must be valid UTF-8.
    pub fn take_str(&mut self) -> Result<String, WireError> {
        let len = self.take_u32()? as usize;
        let bytes = self.take_bytes(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| WireError::Malformed("string is not UTF-8".into()))
    }

    /// Declared element count of an array field, validated against the
    /// bytes actually remaining *before* any allocation happens.
    fn take_count(&mut self, elem_bytes: usize) -> Result<usize, WireError> {
        let count = self.take_u64()?;
        let needed = count.checked_mul(elem_bytes as u64).ok_or_else(|| {
            WireError::Malformed(format!("array count {count} overflows"))
        })?;
        if needed > self.remaining() as u64 {
            return Err(WireError::Truncated {
                needed: needed.min(usize::MAX as u64) as usize,
                available: self.remaining(),
            });
        }
        Ok(count as usize)
    }

    pub fn take_u32s(&mut self) -> Result<Vec<u32>, WireError> {
        let count = self.take_count(4)?;
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            out.push(self.take_u32()?);
        }
        Ok(out)
    }

    pub fn take_f32s(&mut self) -> Result<Vec<f32>, WireError> {
        let count = self.take_count(4)?;
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            out.push(self.take_f32()?);
        }
        Ok(out)
    }

    /// Assert the payload is fully consumed — trailing garbage marks a
    /// version-skewed or corrupted sender.
    pub fn expect_end(&self) -> Result<(), WireError> {
        if self.remaining() != 0 {
            return Err(WireError::Malformed(format!(
                "{} trailing bytes after message",
                self.remaining()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrip() {
        let mut w = WireWriter::new();
        w.put_u8(7);
        w.put_u16(513);
        w.put_u32(70_000);
        w.put_u64(1 << 40);
        w.put_f32(-0.0);
        w.put_f64(std::f64::consts::PI);
        w.put_str("héllo");
        let bytes = w.finish();
        let mut r = WireReader::new(&bytes);
        assert_eq!(r.take_u8().unwrap(), 7);
        assert_eq!(r.take_u16().unwrap(), 513);
        assert_eq!(r.take_u32().unwrap(), 70_000);
        assert_eq!(r.take_u64().unwrap(), 1 << 40);
        assert_eq!(r.take_f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.take_f64().unwrap(), std::f64::consts::PI);
        assert_eq!(r.take_str().unwrap(), "héllo");
        r.expect_end().unwrap();
    }

    #[test]
    fn arrays_roundtrip_bit_exact() {
        let f = vec![1.5f32, f32::NAN, -0.0, f32::INFINITY, 1e-40];
        let u = vec![0u32, 1, u32::MAX];
        let mut w = WireWriter::new();
        w.put_f32s(&f);
        w.put_u32s(&u);
        let bytes = w.finish();
        let mut r = WireReader::new(&bytes);
        let f2 = r.take_f32s().unwrap();
        assert_eq!(
            f.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            f2.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(r.take_u32s().unwrap(), u);
        r.expect_end().unwrap();
    }

    #[test]
    fn lying_count_is_truncated_not_alloc() {
        // Declares 2^61 floats in an 8-byte payload: the reader must
        // refuse before allocating.
        let mut w = WireWriter::new();
        w.put_u64(1 << 61);
        let bytes = w.finish();
        let mut r = WireReader::new(&bytes);
        assert!(matches!(
            r.take_f32s(),
            Err(WireError::Truncated { .. })
        ));
    }

    #[test]
    fn overflowing_count_is_malformed() {
        let mut w = WireWriter::new();
        w.put_u64(u64::MAX);
        let bytes = w.finish();
        let mut r = WireReader::new(&bytes);
        assert!(matches!(r.take_f32s(), Err(WireError::Malformed(_))));
    }

    #[test]
    fn truncated_scalar_and_string() {
        let mut r = WireReader::new(&[1, 2]);
        assert!(matches!(r.take_u32(), Err(WireError::Truncated { .. })));
        let mut w = WireWriter::new();
        w.put_u32(10); // 10-byte string, no bytes follow
        let bytes = w.finish();
        let mut r = WireReader::new(&bytes);
        assert!(matches!(r.take_str(), Err(WireError::Truncated { .. })));
    }

    #[test]
    fn bad_utf8_is_malformed() {
        let mut w = WireWriter::new();
        w.put_u32(2);
        let mut bytes = w.finish();
        bytes.extend_from_slice(&[0xFF, 0xFE]);
        let mut r = WireReader::new(&bytes);
        assert!(matches!(r.take_str(), Err(WireError::Malformed(_))));
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut w = WireWriter::new();
        w.put_u8(1);
        w.put_u8(2);
        let bytes = w.finish();
        let mut r = WireReader::new(&bytes);
        r.take_u8().unwrap();
        assert!(matches!(r.expect_end(), Err(WireError::Malformed(_))));
    }
}
