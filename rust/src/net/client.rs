//! Blocking client for the wire protocol — the library behind
//! `repro serve`'s loadgen, `examples/serve.rs`, and the loopback
//! differential suite.
//!
//! One [`NetClient`] owns one connection and submits one request at a
//! time ([`NetClient::submit`] blocks until the response arrives); run
//! several clients on threads for concurrency, exactly like in-process
//! submitters.  The client drives the fingerprint handshake
//! transparently: before the first submit of a graph it asks the server
//! ([`Msg::GraphQuery`]) whether the fingerprint is resident, uploads the
//! CSR inline only on a miss, and remembers server-known fingerprints so
//! repeat graphs travel as 16 bytes of reference instead of the full
//! topology.  [`ClientStats`] counts both sides of that bargain
//! (uploads vs. skips, bytes up vs. down) — the loadgen's
//! upload-savings evidence.

use std::collections::HashSet;
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::graph::CsrGraph;
use crate::kernels::{AttnError, Backend};
use crate::util::json::Json;

use super::frame::{
    read_frame, write_frame, FrameError, DEFAULT_MAX_FRAME_BYTES,
};
use super::proto::{
    self, csr_wire_bytes, delta_wire_bytes, GraphRef, GraphUpdateMsg, Msg,
    ResponseMsg, SubmitMsg, CODE_GRAPH_UNKNOWN, CODE_PROTOCOL, VERSION,
};

/// Client-side transport failure (errors the *request* itself produced
/// come back inside [`WireResponse::result`] instead).
#[derive(Debug)]
pub enum NetError {
    /// Socket-level failure (connect, read, write, mid-stream close).
    Io(String),
    /// The server sent something outside the protocol, or flagged our
    /// traffic as a protocol violation.
    Protocol(String),
    /// The server refused the handshake (auth or version).
    Rejected(String),
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::Io(m) => write!(f, "transport error: {m}"),
            NetError::Protocol(m) => write!(f, "protocol error: {m}"),
            NetError::Rejected(m) => write!(f, "handshake rejected: {m}"),
        }
    }
}

impl std::error::Error for NetError {}

impl From<FrameError> for NetError {
    fn from(e: FrameError) -> NetError {
        match e {
            FrameError::Io(io) => NetError::Io(io.to_string()),
            other => NetError::Io(other.to_string()),
        }
    }
}

/// Counters over one connection's lifetime.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClientStats {
    /// `submit` calls completed (any outcome).
    pub requests: u64,
    /// Submits that carried the CSR inline.
    pub graph_uploads: u64,
    /// Submits that rode a fingerprint reference instead of re-uploading.
    pub upload_skips: u64,
    /// CSR bytes actually uploaded (inline submits only).
    pub graph_bytes_uploaded: u64,
    /// CSR bytes a handshake-less protocol would have uploaded (every
    /// submit inline) — the denominator of the savings ratio.
    pub graph_bytes_naive: u64,
    /// Total frame bytes written / read (headers included).
    pub bytes_sent: u64,
    pub bytes_received: u64,
}

/// One attention request, borrowed from the caller's buffers (the wire
/// image of [`AttnRequest`](crate::coordinator::AttnRequest)).
pub struct WireRequest<'a> {
    pub id: u64,
    pub graph: &'a CsrGraph,
    pub d: usize,
    pub dv: usize,
    pub heads: usize,
    pub q: &'a [f32],
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub scale: f32,
    pub backend: Backend,
    /// Server-side deadline measured from admission (micros resolution on
    /// the wire; sub-microsecond values round to none).
    pub deadline: Option<Duration>,
}

impl<'a> WireRequest<'a> {
    /// Single-head `dv = d` request — the common shape, mirroring
    /// [`AttnRequest::single_head`](crate::coordinator::AttnRequest::single_head).
    #[allow(clippy::too_many_arguments)]
    pub fn single_head(
        id: u64,
        graph: &'a CsrGraph,
        d: usize,
        q: &'a [f32],
        k: &'a [f32],
        v: &'a [f32],
        scale: f32,
        backend: Backend,
    ) -> WireRequest<'a> {
        WireRequest {
            id,
            graph,
            d,
            dv: d,
            heads: 1,
            q,
            k,
            v,
            scale,
            backend,
            deadline: None,
        }
    }
}

/// A served response, lifted back to in-process types.
pub struct WireResponse {
    pub id: u64,
    pub result: Result<Vec<f32>, AttnError>,
    pub latency_s: f64,
    pub preprocess_s: f64,
    pub execute_s: f64,
    pub batch_size: usize,
    /// Backend that served the request (parsed from the wire name; `None`
    /// when the request failed before execution or the name is unknown).
    pub backend: Option<Backend>,
}

/// Server-side outcome of one [`NetClient::update_graph`] call, lifted
/// back to in-process counts (the wire image of
/// [`UpdateReport`](crate::coordinator::UpdateReport)).
#[derive(Clone, Copy, Debug)]
pub struct UpdateSummary {
    pub old_fp: u64,
    pub new_fp: u64,
    pub inserted: usize,
    pub removed: usize,
    pub dirty_rws: usize,
    pub spliced_rws: usize,
    pub full_rebuild: bool,
}

/// One blocking connection to a [`NetServer`](super::NetServer).
pub struct NetClient {
    stream: TcpStream,
    /// Fingerprints the server is known to hold (populated by
    /// `GraphStatus` answers and our own inline uploads).
    known: HashSet<u64>,
    stats: ClientStats,
    max_frame: usize,
    /// The per-session in-flight quota the server granted at handshake.
    pub server_max_inflight: usize,
}

impl NetClient {
    /// Connect + handshake.  `token` is ignored by open servers; pass
    /// `""` there.
    pub fn connect(
        addr: impl ToSocketAddrs,
        token: &str,
    ) -> Result<NetClient, NetError> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| NetError::Io(format!("connect: {e}")))?;
        let _ = stream.set_nodelay(true);
        let mut client = NetClient {
            stream,
            known: HashSet::new(),
            stats: ClientStats::default(),
            max_frame: DEFAULT_MAX_FRAME_BYTES,
            server_max_inflight: 0,
        };
        client.send(&Msg::ClientHello {
            version: VERSION,
            token: token.to_string(),
        })?;
        match client.recv()? {
            Msg::ServerHello { ok: true, max_inflight, .. } => {
                client.server_max_inflight = max_inflight as usize;
                Ok(client)
            }
            Msg::ServerHello { ok: false, detail, .. } => {
                Err(NetError::Rejected(detail))
            }
            _ => Err(NetError::Protocol("expected server hello".into())),
        }
    }

    /// Counters so far.
    pub fn stats(&self) -> ClientStats {
        self.stats
    }

    /// Submit one request and block for its response.  Drives the
    /// fingerprint handshake: query-once per new graph, upload inline
    /// only on a miss, retry inline exactly once if the server evicted
    /// the graph between our query and the submit.
    pub fn submit(
        &mut self,
        req: &WireRequest<'_>,
    ) -> Result<WireResponse, NetError> {
        let fp = req.graph.fingerprint();
        if !self.known.contains(&fp) {
            self.send(&Msg::GraphQuery { fp })?;
            match self.recv()? {
                Msg::GraphStatus { fp: rfp, known } if rfp == fp => {
                    if known {
                        self.known.insert(fp);
                    }
                }
                _ => {
                    return Err(NetError::Protocol(
                        "expected graph status".into(),
                    ))
                }
            }
        }
        let inline = !self.known.contains(&fp);
        match self.submit_once(req, fp, inline)? {
            Outcome::Done(resp) => Ok(resp),
            Outcome::GraphUnknown => {
                // The store evicted the graph after our query (or a
                // collision cross-check fired): re-upload inline, once.
                self.known.remove(&fp);
                match self.submit_once(req, fp, true)? {
                    Outcome::Done(resp) => Ok(resp),
                    Outcome::GraphUnknown => Err(NetError::Protocol(
                        "server rejected an inline graph as unknown".into(),
                    )),
                }
            }
        }
    }

    /// Ship a batched edge delta for `base` and block for the server's
    /// swap summary — the streaming analog of [`NetClient::submit`]: the
    /// base rides a bare fingerprint reference in the steady state
    /// (deltas, not CSRs, cross the wire), falls back to inline exactly
    /// once if the server evicted it, and the patched fingerprint is
    /// remembered so follow-up submits skip their `GraphQuery`.
    ///
    /// The outer `Err` is transport/protocol failure; the inner `Err` is
    /// the server structurally rejecting the delta (stale base,
    /// out-of-range endpoint, conflicting edit) with the base version
    /// still served.
    pub fn update_graph(
        &mut self,
        base: &CsrGraph,
        inserts: &[(u32, u32)],
        removes: &[(u32, u32)],
    ) -> Result<Result<UpdateSummary, AttnError>, NetError> {
        let fp = base.fingerprint();
        if !self.known.contains(&fp) {
            self.send(&Msg::GraphQuery { fp })?;
            match self.recv()? {
                Msg::GraphStatus { fp: rfp, known } if rfp == fp => {
                    if known {
                        self.known.insert(fp);
                    }
                }
                _ => {
                    return Err(NetError::Protocol(
                        "expected graph status".into(),
                    ))
                }
            }
        }
        let inline = !self.known.contains(&fp);
        match self.update_once(base, fp, inline, inserts, removes)? {
            UpdateOutcome::Done(r) => Ok(r),
            UpdateOutcome::BaseUnknown => {
                self.known.remove(&fp);
                match self.update_once(base, fp, true, inserts, removes)? {
                    UpdateOutcome::Done(r) => Ok(r),
                    UpdateOutcome::BaseUnknown => Err(NetError::Protocol(
                        "server rejected an inline base as unknown".into(),
                    )),
                }
            }
        }
    }

    /// Scrape the server's live metrics snapshot
    /// ([`Metrics::to_json`](crate::coordinator::Metrics::to_json)):
    /// send [`Msg::MetricsQuery`], block for the [`Msg::MetricsReport`],
    /// and parse its JSON payload.
    pub fn metrics(&mut self) -> Result<Json, NetError> {
        self.send(&Msg::MetricsQuery)?;
        match self.recv()? {
            Msg::MetricsReport { json } => Json::parse(&json).map_err(|e| {
                NetError::Protocol(format!("malformed metrics report: {e:#}"))
            }),
            _ => Err(NetError::Protocol("expected metrics report".into())),
        }
    }

    /// Clean close: best-effort goodbye, then both halves down.
    pub fn close(self) {
        let bytes = Msg::Goodbye.encode();
        let mut sock = &self.stream;
        let _ = write_frame(&mut sock, &bytes, self.max_frame);
        let _ = self.stream.shutdown(Shutdown::Both);
    }

    fn submit_once(
        &mut self,
        req: &WireRequest<'_>,
        fp: u64,
        inline: bool,
    ) -> Result<Outcome, NetError> {
        let graph = if inline {
            GraphRef::Inline(req.graph.clone())
        } else {
            GraphRef::Fingerprint {
                fp,
                n: req.graph.n as u32,
                nnz: req.graph.nnz() as u32,
            }
        };
        let msg = Msg::Submit(SubmitMsg {
            id: req.id,
            graph,
            d: req.d as u32,
            dv: req.dv as u32,
            heads: req.heads as u32,
            scale: req.scale,
            backend: req.backend.name().to_string(),
            deadline_micros: req
                .deadline
                .map(|d| d.as_micros().min(u64::MAX as u128) as u64)
                .unwrap_or(0),
            q: req.q.to_vec(),
            k: req.k.to_vec(),
            v: req.v.to_vec(),
        });
        self.send(&msg)?;
        let graph_bytes = csr_wire_bytes(req.graph);
        self.stats.graph_bytes_naive += graph_bytes;
        if inline {
            self.stats.graph_uploads += 1;
            self.stats.graph_bytes_uploaded += graph_bytes;
        } else {
            self.stats.upload_skips += 1;
        }
        let resp = match self.recv()? {
            Msg::Response(r) => r,
            _ => return Err(NetError::Protocol("expected response".into())),
        };
        if let Err((code, _)) = &resp.payload {
            if *code == CODE_GRAPH_UNKNOWN {
                return Ok(Outcome::GraphUnknown);
            }
        }
        if resp.id != req.id {
            return Err(NetError::Protocol(format!(
                "response id {} for request {}",
                resp.id, req.id
            )));
        }
        if inline {
            // The server now holds the graph under its fingerprint.
            self.known.insert(fp);
        }
        self.stats.requests += 1;
        Ok(Outcome::Done(from_wire_response(resp)?))
    }

    fn update_once(
        &mut self,
        base: &CsrGraph,
        fp: u64,
        inline: bool,
        inserts: &[(u32, u32)],
        removes: &[(u32, u32)],
    ) -> Result<UpdateOutcome, NetError> {
        let base_ref = if inline {
            GraphRef::Inline(base.clone())
        } else {
            GraphRef::Fingerprint {
                fp,
                n: base.n as u32,
                nnz: base.nnz() as u32,
            }
        };
        self.send(&Msg::GraphUpdate(GraphUpdateMsg {
            base: base_ref,
            inserts: inserts.to_vec(),
            removes: removes.to_vec(),
        }))?;
        // The naive protocol re-ships the whole patched CSR; the delta
        // path ships edge edits (plus the base, once, when inline).
        let base_bytes = csr_wire_bytes(base);
        self.stats.graph_bytes_naive += base_bytes;
        if inline {
            self.stats.graph_uploads += 1;
            self.stats.graph_bytes_uploaded += base_bytes;
        } else {
            self.stats.upload_skips += 1;
        }
        self.stats.graph_bytes_uploaded +=
            delta_wire_bytes(inserts.len(), removes.len());
        let upd = match self.recv()? {
            Msg::GraphUpdated(u) => u,
            _ => {
                return Err(NetError::Protocol("expected update summary".into()))
            }
        };
        match upd.payload {
            Ok(s) => {
                if inline {
                    self.known.insert(fp);
                }
                // The server now holds (and serves) the patched version.
                self.known.insert(s.new_fp);
                Ok(UpdateOutcome::Done(Ok(UpdateSummary {
                    old_fp: s.old_fp,
                    new_fp: s.new_fp,
                    inserted: s.inserted as usize,
                    removed: s.removed as usize,
                    dirty_rws: s.dirty_rws as usize,
                    spliced_rws: s.spliced_rws as usize,
                    full_rebuild: s.full_rebuild,
                })))
            }
            Err((code, msg)) => {
                if code == CODE_GRAPH_UNKNOWN {
                    return Ok(UpdateOutcome::BaseUnknown);
                }
                if code == CODE_PROTOCOL {
                    return Err(NetError::Protocol(msg));
                }
                match proto::decode_attn_error(code, msg) {
                    Some(e) => Ok(UpdateOutcome::Done(Err(e))),
                    None => Err(NetError::Protocol(format!(
                        "unknown error code {code}"
                    ))),
                }
            }
        }
    }

    fn send(&mut self, msg: &Msg) -> Result<(), NetError> {
        let bytes = msg.encode();
        let mut sock = &self.stream;
        write_frame(&mut sock, &bytes, self.max_frame)?;
        self.stats.bytes_sent += 8 + bytes.len() as u64;
        Ok(())
    }

    fn recv(&mut self) -> Result<Msg, NetError> {
        let mut sock = &self.stream;
        let payload = read_frame(&mut sock, self.max_frame)?;
        self.stats.bytes_received += 8 + payload.len() as u64;
        Msg::decode(&payload)
            .map_err(|e| NetError::Protocol(e.to_string()))
    }
}

enum Outcome {
    Done(WireResponse),
    GraphUnknown,
}

enum UpdateOutcome {
    Done(Result<UpdateSummary, AttnError>),
    BaseUnknown,
}

fn from_wire_response(r: ResponseMsg) -> Result<WireResponse, NetError> {
    match r.payload {
        Ok(ok) => Ok(WireResponse {
            id: r.id,
            backend: if ok.backend.is_empty() {
                None
            } else {
                Backend::parse(&ok.backend).ok()
            },
            result: Ok(ok.out),
            latency_s: ok.latency_s,
            preprocess_s: ok.preprocess_s,
            execute_s: ok.execute_s,
            batch_size: ok.batch_size as usize,
        }),
        Err((code, msg)) => {
            if code == CODE_PROTOCOL {
                return Err(NetError::Protocol(msg));
            }
            match proto::decode_attn_error(code, msg) {
                Some(e) => Ok(WireResponse {
                    id: r.id,
                    result: Err(e),
                    latency_s: 0.0,
                    preprocess_s: 0.0,
                    execute_s: 0.0,
                    batch_size: 0,
                    backend: None,
                }),
                None => Err(NetError::Protocol(format!(
                    "unknown error code {code}"
                ))),
            }
        }
    }
}
