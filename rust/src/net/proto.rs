//! The versioned message vocabulary (DESIGN.md §13).
//!
//! Each frame payload is `tag u8` + a tag-specific body encoded with
//! [`wire`](super::wire).  The conversation:
//!
//! ```text
//! client                                server
//!   ── ClientHello{version, token} ──►
//!   ◄── ServerHello{version, ok, …} ──     (reject ⇒ close)
//!   ── GraphQuery{fp} ──►                  (optional, any time)
//!   ◄── GraphStatus{fp, known} ──
//!   ── Submit{…, GraphRef} ──►             (by fingerprint or inline CSR)
//!   ◄── Response{id, output | error} ──    (order = coordinator completion)
//!   ── MetricsQuery ──►                    (optional, any time)
//!   ◄── MetricsReport{json} ──             (Metrics::to_json snapshot)
//!   ── Goodbye ──►                         (clean close)
//! ```
//!
//! A [`Submit`](Msg::Submit) referencing an unknown fingerprint is
//! answered with error code [`CODE_GRAPH_UNKNOWN`]; the client retries
//! once with the CSR inline.  [`CODE_PROTOCOL`] marks a session-fatal
//! protocol violation (the server answers best-effort with `id = 0` and
//! closes).
//!
//! Inline CSR payloads are structurally validated at decode time — the
//! full [`CsrGraph`] invariant (monotone `indptr`, in-range, strictly
//! ascending row indices) — so no malformed topology can reach the BSB
//! builder from the network.

use crate::graph::CsrGraph;
use crate::kernels::AttnError;

use super::wire::{WireError, WireReader, WireWriter};

/// Protocol version carried in the hello exchange.  The server rejects
/// mismatches in [`ServerHello`](Msg::ServerHello) (carrying its own
/// version so the client can report the skew precisely).
pub const VERSION: u16 = 1;

const TAG_CLIENT_HELLO: u8 = 1;
const TAG_SERVER_HELLO: u8 = 2;
const TAG_GRAPH_QUERY: u8 = 3;
const TAG_GRAPH_STATUS: u8 = 4;
const TAG_SUBMIT: u8 = 5;
const TAG_RESPONSE: u8 = 6;
const TAG_GOODBYE: u8 = 7;
const TAG_GRAPH_UPDATE: u8 = 8;
const TAG_GRAPH_UPDATED: u8 = 9;
const TAG_METRICS_QUERY: u8 = 10;
const TAG_METRICS_REPORT: u8 = 11;

/// Error codes for the `Response` error arm.  1–6 mirror
/// [`AttnError`]'s variants; 16+ are protocol-level conditions with no
/// in-process analog.
pub const CODE_BAD_SHAPE: u8 = 1;
pub const CODE_PREPARE: u8 = 2;
pub const CODE_EXECUTE: u8 = 3;
pub const CODE_UNSUPPORTED: u8 = 4;
pub const CODE_QUEUE_CLOSED: u8 = 5;
pub const CODE_DEADLINE: u8 = 6;
/// Submit-by-fingerprint missed the server's graph store: re-send inline.
pub const CODE_GRAPH_UNKNOWN: u8 = 16;
/// Session-fatal protocol violation (bad frame, unknown tag, malformed
/// body); the server closes after sending this.
pub const CODE_PROTOCOL: u8 = 19;

/// How a [`Msg::Submit`] names its graph: a bare fingerprint (the repeat
/// path — `n`/`nnz` ride along as the store's collision cross-check,
/// mirroring the `DriverCache` contract) or the full CSR (first sight).
pub enum GraphRef {
    Fingerprint { fp: u64, n: u32, nnz: u32 },
    Inline(CsrGraph),
}

/// Body of [`Msg::Submit`] — the wire image of
/// [`AttnRequest`](crate::coordinator::AttnRequest).  Q/K/V are head-major
/// (`heads × n × d` / `heads × n × dv`), exactly the in-process layout.
pub struct SubmitMsg {
    pub id: u64,
    pub graph: GraphRef,
    pub d: u32,
    pub dv: u32,
    pub heads: u32,
    pub scale: f32,
    /// Backend name (`Backend::name` vocabulary, including `"auto"`);
    /// parsed server-side so an unknown name degrades to a structured
    /// [`CODE_UNSUPPORTED`] response instead of a decode failure.
    pub backend: String,
    /// Deadline in microseconds from server admission; 0 = none.
    pub deadline_micros: u64,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// Success payload of [`Msg::Response`] — the wire image of a successful
/// [`AttnResponse`](crate::coordinator::AttnResponse).
pub struct OkPayload {
    /// Head-major output (`heads × n × dv`), bit-exact f32.
    pub out: Vec<f32>,
    pub latency_s: f64,
    pub preprocess_s: f64,
    pub execute_s: f64,
    pub batch_size: u32,
    /// Name of the backend that served the request (`""` = unknown — the
    /// request failed before any backend ran; unreachable on this arm but
    /// kept symmetric with `AttnResponse::backend`).
    pub backend: String,
}

/// Body of [`Msg::Response`].
pub struct ResponseMsg {
    pub id: u64,
    pub payload: Result<OkPayload, (u8, String)>,
}

/// Body of [`Msg::GraphUpdate`] — a batched edge delta against a graph
/// version the server (usually) already holds.  The base rides the same
/// [`GraphRef`] vocabulary as submits: by fingerprint in the steady
/// state (the whole point — clients ship deltas, not CSRs), inline on
/// first contact or after a [`CODE_GRAPH_UNKNOWN`] eviction bounce.
pub struct GraphUpdateMsg {
    pub base: GraphRef,
    /// Edges to add, as (row, col).
    pub inserts: Vec<(u32, u32)>,
    /// Edges to drop, as (row, col).
    pub removes: Vec<(u32, u32)>,
}

/// Success payload of [`Msg::GraphUpdated`] — the wire image of
/// [`UpdateReport`](crate::coordinator::UpdateReport) (minus the patched
/// graph itself, which stays server-side under `new_fp`).
pub struct UpdateSummaryMsg {
    pub old_fp: u64,
    pub new_fp: u64,
    pub inserted: u32,
    pub removed: u32,
    pub dirty_rws: u32,
    pub spliced_rws: u32,
    pub full_rebuild: bool,
}

/// Body of [`Msg::GraphUpdated`].  The error arm reuses the response
/// code vocabulary: [`CODE_GRAPH_UNKNOWN`] (base not resident — re-send
/// inline) or a mapped [`AttnError`] (delta rejected; base still served).
pub struct GraphUpdatedMsg {
    pub payload: Result<UpdateSummaryMsg, (u8, String)>,
}

/// One protocol message (= one frame payload).
pub enum Msg {
    ClientHello { version: u16, token: String },
    ServerHello { version: u16, ok: bool, detail: String, max_inflight: u32 },
    GraphQuery { fp: u64 },
    GraphStatus { fp: u64, known: bool },
    Submit(SubmitMsg),
    Response(ResponseMsg),
    Goodbye,
    GraphUpdate(GraphUpdateMsg),
    GraphUpdated(GraphUpdatedMsg),
    /// Ask the server for its full metrics snapshot (DESIGN.md §15).
    /// Empty body; answered with [`Msg::MetricsReport`].
    MetricsQuery,
    /// The server's [`Metrics::to_json`] snapshot, serialised with
    /// `util::json::to_string`.  Carried as a string rather than a wire
    /// struct so the schema can grow (new counter groups, new histogram
    /// shapes) without a protocol version bump.
    ///
    /// [`Metrics::to_json`]: crate::coordinator::Metrics::to_json
    MetricsReport { json: String },
}

impl Msg {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = WireWriter::new();
        match self {
            Msg::ClientHello { version, token } => {
                w.put_u8(TAG_CLIENT_HELLO);
                w.put_u16(*version);
                w.put_str(token);
            }
            Msg::ServerHello { version, ok, detail, max_inflight } => {
                w.put_u8(TAG_SERVER_HELLO);
                w.put_u16(*version);
                w.put_u8(u8::from(*ok));
                w.put_str(detail);
                w.put_u32(*max_inflight);
            }
            Msg::GraphQuery { fp } => {
                w.put_u8(TAG_GRAPH_QUERY);
                w.put_u64(*fp);
            }
            Msg::GraphStatus { fp, known } => {
                w.put_u8(TAG_GRAPH_STATUS);
                w.put_u64(*fp);
                w.put_u8(u8::from(*known));
            }
            Msg::Submit(s) => {
                w.put_u8(TAG_SUBMIT);
                w.put_u64(s.id);
                encode_graph_ref(&mut w, &s.graph);
                w.put_u32(s.d);
                w.put_u32(s.dv);
                w.put_u32(s.heads);
                w.put_f32(s.scale);
                w.put_str(&s.backend);
                w.put_u64(s.deadline_micros);
                w.put_f32s(&s.q);
                w.put_f32s(&s.k);
                w.put_f32s(&s.v);
            }
            Msg::Response(r) => {
                w.put_u8(TAG_RESPONSE);
                w.put_u64(r.id);
                match &r.payload {
                    Ok(ok) => {
                        w.put_u8(1);
                        w.put_f64(ok.latency_s);
                        w.put_f64(ok.preprocess_s);
                        w.put_f64(ok.execute_s);
                        w.put_u32(ok.batch_size);
                        w.put_str(&ok.backend);
                        w.put_f32s(&ok.out);
                    }
                    Err((code, msg)) => {
                        w.put_u8(0);
                        w.put_u8(*code);
                        w.put_str(msg);
                    }
                }
            }
            Msg::Goodbye => w.put_u8(TAG_GOODBYE),
            Msg::MetricsQuery => w.put_u8(TAG_METRICS_QUERY),
            Msg::MetricsReport { json } => {
                w.put_u8(TAG_METRICS_REPORT);
                w.put_str(json);
            }
            Msg::GraphUpdate(u) => {
                w.put_u8(TAG_GRAPH_UPDATE);
                encode_graph_ref(&mut w, &u.base);
                encode_edges(&mut w, &u.inserts);
                encode_edges(&mut w, &u.removes);
            }
            Msg::GraphUpdated(u) => {
                w.put_u8(TAG_GRAPH_UPDATED);
                match &u.payload {
                    Ok(s) => {
                        w.put_u8(1);
                        w.put_u64(s.old_fp);
                        w.put_u64(s.new_fp);
                        w.put_u32(s.inserted);
                        w.put_u32(s.removed);
                        w.put_u32(s.dirty_rws);
                        w.put_u32(s.spliced_rws);
                        w.put_u8(u8::from(s.full_rebuild));
                    }
                    Err((code, msg)) => {
                        w.put_u8(0);
                        w.put_u8(*code);
                        w.put_str(msg);
                    }
                }
            }
        }
        w.finish()
    }

    pub fn decode(payload: &[u8]) -> Result<Msg, WireError> {
        let mut r = WireReader::new(payload);
        let tag = r.take_u8()?;
        let msg = match tag {
            TAG_CLIENT_HELLO => Msg::ClientHello {
                version: r.take_u16()?,
                token: r.take_str()?,
            },
            TAG_SERVER_HELLO => Msg::ServerHello {
                version: r.take_u16()?,
                ok: r.take_u8()? != 0,
                detail: r.take_str()?,
                max_inflight: r.take_u32()?,
            },
            TAG_GRAPH_QUERY => Msg::GraphQuery { fp: r.take_u64()? },
            TAG_GRAPH_STATUS => Msg::GraphStatus {
                fp: r.take_u64()?,
                known: r.take_u8()? != 0,
            },
            TAG_SUBMIT => {
                let id = r.take_u64()?;
                let graph = decode_graph_ref(&mut r)?;
                Msg::Submit(SubmitMsg {
                    id,
                    graph,
                    d: r.take_u32()?,
                    dv: r.take_u32()?,
                    heads: r.take_u32()?,
                    scale: r.take_f32()?,
                    backend: r.take_str()?,
                    deadline_micros: r.take_u64()?,
                    q: r.take_f32s()?,
                    k: r.take_f32s()?,
                    v: r.take_f32s()?,
                })
            }
            TAG_RESPONSE => {
                let id = r.take_u64()?;
                let payload = if r.take_u8()? != 0 {
                    let latency_s = r.take_f64()?;
                    let preprocess_s = r.take_f64()?;
                    let execute_s = r.take_f64()?;
                    let batch_size = r.take_u32()?;
                    let backend = r.take_str()?;
                    let out = r.take_f32s()?;
                    Ok(OkPayload {
                        out,
                        latency_s,
                        preprocess_s,
                        execute_s,
                        batch_size,
                        backend,
                    })
                } else {
                    Err((r.take_u8()?, r.take_str()?))
                };
                Msg::Response(ResponseMsg { id, payload })
            }
            TAG_GOODBYE => Msg::Goodbye,
            TAG_METRICS_QUERY => Msg::MetricsQuery,
            TAG_METRICS_REPORT => {
                Msg::MetricsReport { json: r.take_str()? }
            }
            TAG_GRAPH_UPDATE => Msg::GraphUpdate(GraphUpdateMsg {
                base: decode_graph_ref(&mut r)?,
                inserts: decode_edges(&mut r)?,
                removes: decode_edges(&mut r)?,
            }),
            TAG_GRAPH_UPDATED => {
                let payload = if r.take_u8()? != 0 {
                    Ok(UpdateSummaryMsg {
                        old_fp: r.take_u64()?,
                        new_fp: r.take_u64()?,
                        inserted: r.take_u32()?,
                        removed: r.take_u32()?,
                        dirty_rws: r.take_u32()?,
                        spliced_rws: r.take_u32()?,
                        full_rebuild: r.take_u8()? != 0,
                    })
                } else {
                    Err((r.take_u8()?, r.take_str()?))
                };
                Msg::GraphUpdated(GraphUpdatedMsg { payload })
            }
            other => {
                return Err(WireError::Malformed(format!(
                    "unknown message tag {other}"
                )))
            }
        };
        r.expect_end()?;
        Ok(msg)
    }
}

/// CSR wire size in bytes (inside a `GraphRef::Inline`) — what a
/// fingerprint-hit submit saves.
pub fn csr_wire_bytes(g: &CsrGraph) -> u64 {
    // n u64 + (count u64 + 4 bytes/elem) for indptr and indices.
    8 + (8 + 4 * (g.indptr.len() as u64)) + (8 + 4 * (g.indices.len() as u64))
}

/// Delta wire size in bytes (edge lists only) — what a streaming update
/// costs against [`csr_wire_bytes`] for re-shipping the whole patched CSR.
pub fn delta_wire_bytes(inserts: usize, removes: usize) -> u64 {
    // Two (count u64 + 8 bytes/edge) flattened edge lists.
    (8 + 8 * inserts as u64) + (8 + 8 * removes as u64)
}

fn encode_graph(w: &mut WireWriter, g: &CsrGraph) {
    w.put_u64(g.n as u64);
    w.put_u32s(&g.indptr);
    w.put_u32s(&g.indices);
}

fn encode_graph_ref(w: &mut WireWriter, graph: &GraphRef) {
    match graph {
        GraphRef::Fingerprint { fp, n, nnz } => {
            w.put_u8(0);
            w.put_u64(*fp);
            w.put_u32(*n);
            w.put_u32(*nnz);
        }
        GraphRef::Inline(g) => {
            w.put_u8(1);
            encode_graph(w, g);
        }
    }
}

fn decode_graph_ref(r: &mut WireReader<'_>) -> Result<GraphRef, WireError> {
    match r.take_u8()? {
        0 => Ok(GraphRef::Fingerprint {
            fp: r.take_u64()?,
            n: r.take_u32()?,
            nnz: r.take_u32()?,
        }),
        1 => Ok(GraphRef::Inline(decode_graph(r)?)),
        other => {
            Err(WireError::Malformed(format!("unknown graph-ref tag {other}")))
        }
    }
}

/// Edge lists travel flattened (`row, col` interleaved); endpoints are
/// only range-checked against the *resolved base* server-side (the wire
/// layer can't know `n` for a fingerprint ref).
fn encode_edges(w: &mut WireWriter, edges: &[(u32, u32)]) {
    let mut flat = Vec::with_capacity(edges.len() * 2);
    for &(u, v) in edges {
        flat.push(u);
        flat.push(v);
    }
    w.put_u32s(&flat);
}

fn decode_edges(r: &mut WireReader<'_>) -> Result<Vec<(u32, u32)>, WireError> {
    let flat = r.take_u32s()?;
    if flat.len() % 2 != 0 {
        return Err(WireError::Malformed(format!(
            "edge list has odd element count {}",
            flat.len()
        )));
    }
    Ok(flat.chunks_exact(2).map(|p| (p[0], p[1])).collect())
}

/// Decode + fully validate a CSR graph.  Every invariant the in-process
/// constructors guarantee is re-checked here: the network is the one
/// place graphs arrive without having gone through `CsrGraph::from_edges`.
fn decode_graph(r: &mut WireReader<'_>) -> Result<CsrGraph, WireError> {
    let n64 = r.take_u64()?;
    if n64 > u32::MAX as u64 {
        return Err(WireError::Malformed(format!("graph n {n64} exceeds u32")));
    }
    let n = n64 as usize;
    let indptr = r.take_u32s()?;
    let indices = r.take_u32s()?;
    if indptr.len() != n + 1 {
        return Err(WireError::Malformed(format!(
            "indptr has {} entries, expected n+1 = {}",
            indptr.len(),
            n + 1
        )));
    }
    if indptr[0] != 0 {
        return Err(WireError::Malformed("indptr[0] != 0".into()));
    }
    if indptr.windows(2).any(|w| w[1] < w[0]) {
        return Err(WireError::Malformed("indptr not monotone".into()));
    }
    if indptr[n] as usize != indices.len() {
        return Err(WireError::Malformed(format!(
            "indptr[n] = {} but {} indices present",
            indptr[n],
            indices.len()
        )));
    }
    for i in 0..n {
        let row = &indices[indptr[i] as usize..indptr[i + 1] as usize];
        // Strictly ascending ⇒ sorted + deduplicated + (via the bound
        // check) in range: the CsrGraph invariant every kernel assumes.
        for pair in row.windows(2) {
            if pair[1] <= pair[0] {
                return Err(WireError::Malformed(format!(
                    "row {i} not strictly ascending"
                )));
            }
        }
        if let Some(&last) = row.last() {
            if last as usize >= n {
                return Err(WireError::Malformed(format!(
                    "row {i} column {last} out of range (n = {n})"
                )));
            }
        }
    }
    Ok(CsrGraph { n, indptr, indices })
}

/// Map an [`AttnError`] onto its wire code + message.
pub fn encode_attn_error(e: &AttnError) -> (u8, String) {
    match e {
        AttnError::BadShape(m) => (CODE_BAD_SHAPE, m.clone()),
        AttnError::Prepare(m) => (CODE_PREPARE, m.clone()),
        AttnError::Execute(m) => (CODE_EXECUTE, m.clone()),
        AttnError::Unsupported(m) => (CODE_UNSUPPORTED, m.clone()),
        AttnError::QueueClosed => (CODE_QUEUE_CLOSED, String::new()),
        AttnError::DeadlineExceeded => (CODE_DEADLINE, String::new()),
    }
}

/// Map a wire code back onto an [`AttnError`]; `None` for protocol-level
/// codes ([`CODE_GRAPH_UNKNOWN`], [`CODE_PROTOCOL`], unknown values) that
/// have no in-process analog.
pub fn decode_attn_error(code: u8, msg: String) -> Option<AttnError> {
    Some(match code {
        CODE_BAD_SHAPE => AttnError::BadShape(msg),
        CODE_PREPARE => AttnError::Prepare(msg),
        CODE_EXECUTE => AttnError::Execute(msg),
        CODE_UNSUPPORTED => AttnError::Unsupported(msg),
        CODE_QUEUE_CLOSED => AttnError::QueueClosed,
        CODE_DEADLINE => AttnError::DeadlineExceeded,
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn roundtrip(m: &Msg) -> Msg {
        Msg::decode(&m.encode()).expect("roundtrip decode")
    }

    #[test]
    fn hello_roundtrip() {
        match roundtrip(&Msg::ClientHello {
            version: VERSION,
            token: "tok".into(),
        }) {
            Msg::ClientHello { version, token } => {
                assert_eq!(version, VERSION);
                assert_eq!(token, "tok");
            }
            _ => panic!("wrong tag"),
        }
        match roundtrip(&Msg::ServerHello {
            version: 3,
            ok: false,
            detail: "nope".into(),
            max_inflight: 7,
        }) {
            Msg::ServerHello { version, ok, detail, max_inflight } => {
                assert_eq!((version, ok, max_inflight), (3, false, 7));
                assert_eq!(detail, "nope");
            }
            _ => panic!("wrong tag"),
        }
    }

    #[test]
    fn submit_inline_roundtrip_preserves_graph_and_features() {
        let g = generators::erdos_renyi(60, 4.0, 7).with_self_loops();
        let q: Vec<f32> = (0..g.n * 4).map(|i| (i as f32).sin()).collect();
        let m = Msg::Submit(SubmitMsg {
            id: 42,
            graph: GraphRef::Inline(g.clone()),
            d: 4,
            dv: 4,
            heads: 1,
            scale: 0.5,
            backend: "fused3s".into(),
            deadline_micros: 1500,
            q: q.clone(),
            k: q.clone(),
            v: q.clone(),
        });
        match roundtrip(&m) {
            Msg::Submit(s) => {
                assert_eq!(s.id, 42);
                assert_eq!(s.deadline_micros, 1500);
                assert_eq!(s.backend, "fused3s");
                match s.graph {
                    GraphRef::Inline(g2) => {
                        assert_eq!(g2, g);
                        assert_eq!(g2.fingerprint(), g.fingerprint());
                    }
                    _ => panic!("wrong graph ref"),
                }
                assert_eq!(s.q, q);
            }
            _ => panic!("wrong tag"),
        }
    }

    #[test]
    fn response_ok_and_err_roundtrip() {
        let m = Msg::Response(ResponseMsg {
            id: 9,
            payload: Ok(OkPayload {
                out: vec![1.0, f32::NAN, -0.0],
                latency_s: 0.25,
                preprocess_s: 0.0625,
                execute_s: 0.125,
                batch_size: 3,
                backend: "hybrid".into(),
            }),
        });
        match roundtrip(&m) {
            Msg::Response(r) => {
                let ok = r.payload.expect("ok arm");
                assert_eq!(ok.out.len(), 3);
                assert!(ok.out[1].is_nan());
                assert_eq!(ok.out[2].to_bits(), (-0.0f32).to_bits());
                assert_eq!(ok.batch_size, 3);
                assert_eq!(ok.backend, "hybrid");
            }
            _ => panic!("wrong tag"),
        }
        let m = Msg::Response(ResponseMsg {
            id: 1,
            payload: Err((CODE_PREPARE, "boom".into())),
        });
        match roundtrip(&m) {
            Msg::Response(r) => {
                let (code, msg) = r.payload.expect_err("err arm");
                assert_eq!(code, CODE_PREPARE);
                assert_eq!(msg, "boom");
            }
            _ => panic!("wrong tag"),
        }
    }

    #[test]
    fn attn_error_codes_roundtrip() {
        for e in [
            AttnError::BadShape("a".into()),
            AttnError::Prepare("b".into()),
            AttnError::Execute("c".into()),
            AttnError::Unsupported("d".into()),
            AttnError::QueueClosed,
            AttnError::DeadlineExceeded,
        ] {
            let (code, msg) = encode_attn_error(&e);
            assert_eq!(decode_attn_error(code, msg), Some(e));
        }
        assert_eq!(decode_attn_error(CODE_GRAPH_UNKNOWN, String::new()), None);
        assert_eq!(decode_attn_error(CODE_PROTOCOL, String::new()), None);
    }

    #[test]
    fn unknown_tag_and_trailing_bytes_rejected() {
        assert!(matches!(
            Msg::decode(&[0xEE]),
            Err(WireError::Malformed(_))
        ));
        let mut bytes = Msg::Goodbye.encode();
        bytes.push(0);
        assert!(matches!(Msg::decode(&bytes), Err(WireError::Malformed(_))));
        assert!(matches!(
            Msg::decode(&[]),
            Err(WireError::Truncated { .. })
        ));
    }

    #[test]
    fn malformed_graphs_rejected() {
        let encode_raw = |n: u64, indptr: &[u32], indices: &[u32]| {
            let mut w = WireWriter::new();
            w.put_u8(TAG_SUBMIT);
            w.put_u64(1); // id
            w.put_u8(1); // inline
            w.put_u64(n);
            w.put_u32s(indptr);
            w.put_u32s(indices);
            w.put_u32(4);
            w.put_u32(4);
            w.put_u32(1);
            w.put_f32(1.0);
            w.put_str("cpu_csr");
            w.put_u64(0);
            w.put_f32s(&[]);
            w.put_f32s(&[]);
            w.put_f32s(&[]);
            w.finish()
        };
        // Non-monotone indptr.
        assert!(Msg::decode(&encode_raw(2, &[0, 2, 1], &[0, 1])).is_err());
        // indptr[0] != 0.
        assert!(Msg::decode(&encode_raw(2, &[1, 1, 2], &[0, 1])).is_err());
        // Wrong indptr length.
        assert!(Msg::decode(&encode_raw(2, &[0, 1], &[0])).is_err());
        // indptr[n] disagrees with indices length.
        assert!(Msg::decode(&encode_raw(2, &[0, 1, 2], &[0, 1, 1])).is_err());
        // Column out of range.
        assert!(Msg::decode(&encode_raw(2, &[0, 1, 2], &[0, 5])).is_err());
        // Duplicate / unsorted row.
        assert!(Msg::decode(&encode_raw(2, &[0, 2, 2], &[1, 1])).is_err());
        // The well-formed version of the same shape decodes.
        assert!(Msg::decode(&encode_raw(2, &[0, 1, 2], &[1, 0])).is_ok());
    }

    #[test]
    fn graph_update_roundtrip_both_base_forms() {
        let g = generators::ring(32);
        let m = Msg::GraphUpdate(GraphUpdateMsg {
            base: GraphRef::Fingerprint {
                fp: g.fingerprint(),
                n: 32,
                nnz: 64,
            },
            inserts: vec![(0, 5), (17, 2)],
            removes: vec![(3, 4)],
        });
        match roundtrip(&m) {
            Msg::GraphUpdate(u) => {
                match u.base {
                    GraphRef::Fingerprint { fp, n, nnz } => {
                        assert_eq!((fp, n, nnz), (g.fingerprint(), 32, 64));
                    }
                    _ => panic!("wrong base form"),
                }
                assert_eq!(u.inserts, vec![(0, 5), (17, 2)]);
                assert_eq!(u.removes, vec![(3, 4)]);
            }
            _ => panic!("wrong tag"),
        }
        let m = Msg::GraphUpdate(GraphUpdateMsg {
            base: GraphRef::Inline(g.clone()),
            inserts: vec![],
            removes: vec![(0, 1)],
        });
        match roundtrip(&m) {
            Msg::GraphUpdate(u) => match u.base {
                GraphRef::Inline(g2) => assert_eq!(g2, g),
                _ => panic!("wrong base form"),
            },
            _ => panic!("wrong tag"),
        }
    }

    #[test]
    fn graph_updated_roundtrip_ok_and_err() {
        let m = Msg::GraphUpdated(GraphUpdatedMsg {
            payload: Ok(UpdateSummaryMsg {
                old_fp: 7,
                new_fp: 9,
                inserted: 3,
                removed: 1,
                dirty_rws: 2,
                spliced_rws: 14,
                full_rebuild: false,
            }),
        });
        match roundtrip(&m) {
            Msg::GraphUpdated(u) => {
                let s = u.payload.ok().expect("ok arm");
                assert_eq!((s.old_fp, s.new_fp), (7, 9));
                assert_eq!((s.inserted, s.removed), (3, 1));
                assert_eq!((s.dirty_rws, s.spliced_rws), (2, 14));
                assert!(!s.full_rebuild);
            }
            _ => panic!("wrong tag"),
        }
        let m = Msg::GraphUpdated(GraphUpdatedMsg {
            payload: Err((CODE_GRAPH_UNKNOWN, "resend".into())),
        });
        match roundtrip(&m) {
            Msg::GraphUpdated(u) => {
                let (code, msg) = u.payload.err().expect("err arm");
                assert_eq!(code, CODE_GRAPH_UNKNOWN);
                assert_eq!(msg, "resend");
            }
            _ => panic!("wrong tag"),
        }
    }

    #[test]
    fn metrics_query_and_report_roundtrip() {
        assert!(matches!(roundtrip(&Msg::MetricsQuery), Msg::MetricsQuery));
        let snapshot = r#"{"requests":{"completed":3,"failed":0}}"#;
        match roundtrip(&Msg::MetricsReport { json: snapshot.into() }) {
            Msg::MetricsReport { json } => assert_eq!(json, snapshot),
            _ => panic!("wrong tag"),
        }
    }

    #[test]
    fn odd_edge_list_rejected() {
        let mut w = WireWriter::new();
        w.put_u8(TAG_GRAPH_UPDATE);
        w.put_u8(0); // fingerprint base
        w.put_u64(1);
        w.put_u32(8);
        w.put_u32(16);
        w.put_u32s(&[0, 1, 2]); // 1.5 edges
        w.put_u32s(&[]);
        assert!(matches!(
            Msg::decode(&w.finish()),
            Err(WireError::Malformed(_))
        ));
    }

    #[test]
    fn delta_wire_bytes_matches_encoding() {
        let mut w = WireWriter::new();
        encode_edges(&mut w, &[(0, 1), (2, 3), (4, 5)]);
        encode_edges(&mut w, &[(6, 7)]);
        assert_eq!(w.len() as u64, delta_wire_bytes(3, 1));
    }

    #[test]
    fn csr_wire_bytes_matches_encoding() {
        let g = generators::ring(40);
        let mut w = WireWriter::new();
        encode_graph(&mut w, &g);
        assert_eq!(w.len() as u64, csr_wire_bytes(&g));
    }
}
