//! Sharded execution: a [`ShardedPlan`] is a [`SparseAttentionOp`] over a
//! partition of the graph, so it composes with [`AttentionBatch`], the
//! models and the coordinator exactly like a single-shard [`Plan`].
//!
//! Execution runs through the existing [`Engine`] pipeline seam — shards
//! are the work items: while shard *i* dispatches (its own plan's bucketed
//! pipeline, PJRT or host emulation), a scoped worker stages shard
//! *i+1*'s halo-gathered Q/K/V buffers and another commits shard *i−1*'s
//! own-row outputs into the global `heads × n × dv` buffer.  Dispatch
//! stays on the calling thread (the PJRT client is not `Sync`), and the
//! gather/dispatch/scatter sequence is the shard order under every
//! `ExecPolicy` — so sharded output is **bit-identical** across policies
//! and, by the halo layout contract ([`super::halo`]), bit-identical to
//! the unsharded plan.

use std::sync::Arc;

use crate::exec::Engine;
use crate::graph::CsrGraph;
use crate::kernels::op::{AttnError, ExecCtx, Plan, SparseAttentionOp};
use crate::kernels::{AttentionBatch, Backend};
use crate::runtime::Manifest;

use super::halo::{self, Halo};
use super::partition::{self, Strategy};

/// How to shard a plan: shard count (clamped to the row-window count) and
/// partition strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPolicy {
    pub shards: usize,
    pub strategy: Strategy,
}

impl ShardPolicy {
    /// `shards` TCB-work-balanced shards (the hub-robust default).
    pub fn balanced(shards: usize) -> ShardPolicy {
        ShardPolicy { shards, strategy: Strategy::BalancedTcb }
    }

    /// `shards` equal-row-window shards.
    pub fn contiguous(shards: usize) -> ShardPolicy {
        ShardPolicy { shards, strategy: Strategy::Contiguous }
    }
}

/// Aggregate shape of a sharded plan (for metrics and audits).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardStats {
    /// Shards in the partition.
    pub shards: usize,
    /// Total replicated K/V rows gathered across shards (Σ per-shard halo).
    pub halo_rows: usize,
    /// Total local nodes across shard-local graphs (own + halo + padding).
    pub local_nodes: usize,
}

/// One shard: its prepared (possibly cache-shared) plan plus the halo
/// gather/scatter map.
struct ShardExec {
    plan: Arc<Plan>,
    halo: Halo,
}

/// A partition-parallel sparse-attention plan: one BSB + plan per
/// row-window shard, halo K/V gathers in, own-row scatters out.
pub struct ShardedPlan {
    n: usize,
    backend: Backend,
    shards: Vec<ShardExec>,
}

/// The backend families a shard can run: dense is whole-graph by
/// construction (its padded-softmax column order changes under halo
/// remapping), and hybrid is whole-graph too — its per-window routing is
/// priced against the whole graph's packing profile, and the cost model
/// deliberately reports no sharded estimate for it
/// ([`sharded_cells`](crate::planner::sharded_cells) returns `None`).
/// Everything else is row-window-local.
fn shardable(backend: Backend) -> bool {
    !matches!(backend, Backend::Dense | Backend::Hybrid | Backend::Auto)
}

impl ShardedPlan {
    /// Partition `g` under `policy` and prepare one plan per shard on
    /// `engine`.  [`Backend::Auto`] resolves over the shardable candidates
    /// (fused / unfused / CPU-CSR — never dense); an explicit
    /// [`Backend::Dense`] is refused as [`AttnError::Unsupported`].
    pub fn new(
        man: &Manifest,
        g: &CsrGraph,
        backend: Backend,
        engine: &Engine,
        policy: ShardPolicy,
    ) -> Result<ShardedPlan, AttnError> {
        ShardedPlan::build(g, backend, policy, &mut |lg, b| {
            Plan::new(man, lg, b, engine).map(Arc::new)
        })
    }

    /// [`ShardedPlan::new`] with an external per-shard plan source — the
    /// coordinator passes a closure that consults its fingerprint-keyed
    /// [`DriverCache`](crate::coordinator::DriverCache) so repeated shard
    /// structures skip their BSB builds entirely.
    pub fn build(
        g: &CsrGraph,
        backend: Backend,
        policy: ShardPolicy,
        plan_source: &mut dyn FnMut(
            &CsrGraph,
            Backend,
        ) -> Result<Arc<Plan>, AttnError>,
    ) -> Result<ShardedPlan, AttnError> {
        let backend = if backend == Backend::Auto {
            crate::planner::Planner::with_candidates(
                crate::planner::CostModel::default(),
                vec![Backend::Fused3S, Backend::UnfusedStable, Backend::CpuCsr],
            )
            .resolve(g)
            .backend
        } else {
            backend
        };
        if !shardable(backend) {
            return Err(AttnError::Unsupported(format!(
                "backend {} cannot run sharded (whole-graph execution only)",
                backend.name()
            )));
        }
        let part = partition::partition(g, policy.shards, policy.strategy);
        let total = part.shards();
        let mut shards = Vec::with_capacity(total);
        for (i, range) in part.ranges.iter().enumerate() {
            let (local, h) = halo::build_shard(g, range.clone());
            // A failing (or panicking) shard build must surface as a
            // structured error naming the shard, so the coordinator's
            // ladder can fail or re-route *this* request alone instead of
            // the failure tearing through a preprocessing worker.
            let built = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || plan_source(&local, backend),
            ));
            let plan = match built {
                Ok(Ok(p)) => p,
                Ok(Err(e)) => {
                    return Err(AttnError::Prepare(format!(
                        "shard {i}/{total}: {e}"
                    )))
                }
                Err(payload) => {
                    return Err(AttnError::Prepare(format!(
                        "shard {i}/{total}: panic during shard prepare: {}",
                        crate::fault::panic_message(payload.as_ref())
                    )))
                }
            };
            shards.push(ShardExec { plan, halo: h });
        }
        Ok(ShardedPlan { n: g.n, backend, shards })
    }

    /// The concrete backend every shard plan runs.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Aggregate partition shape.
    pub fn stats(&self) -> ShardStats {
        ShardStats {
            shards: self.shards.len(),
            halo_rows: self.shards.iter().map(|s| s.halo.halo_rows).sum(),
            local_nodes: self.shards.iter().map(|s| s.halo.local_n()).sum(),
        }
    }

    /// Replicated K/V rows ÷ n — the realised halo fraction (matches
    /// [`bsb::stats::halo_fraction`](crate::bsb::stats::halo_fraction) on
    /// the same partition).
    pub fn halo_fraction(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.stats().halo_rows as f64 / self.n as f64
        }
    }
}

impl SparseAttentionOp for ShardedPlan {
    fn execute(
        &self,
        ctx: &mut ExecCtx<'_>,
        x: &AttentionBatch<'_>,
    ) -> Result<Vec<f32>, AttnError> {
        x.validate()?;
        if x.n != self.n {
            return Err(AttnError::BadShape(format!(
                "problem n={} != sharded plan n={}",
                x.n, self.n
            )));
        }
        let engine: &Engine = match *ctx {
            ExecCtx::Pjrt { engine, .. } => engine,
            ExecCtx::Host { engine } => engine,
        };
        let (heads, d, dv) = (x.heads, x.d, x.dv);
        let mut out = vec![0.0f32; x.out_len()];
        // Dispatch errors cross the pipeline as anyhow; keep the structured
        // AttnError of the failing shard so callers see the same class a
        // single-shard run would produce.
        let mut inner_err: Option<AttnError> = None;
        let mut shard_ctx = *ctx;
        let result = engine.run_pipeline(
            self.shards.len(),
            |i, bufs| {
                // Stage shard i's head-major local Q/K/V: own + halo rows
                // gathered from the global buffers, padding zero-filled.
                let h = &self.shards[i].halo;
                let n_loc = h.local_n();
                bufs.q.resize(heads * n_loc * d, 0.0);
                bufs.k.resize(heads * n_loc * d, 0.0);
                bufs.v.resize(heads * n_loc * dv, 0.0);
                for hh in 0..heads {
                    h.gather_rows(
                        &mut bufs.q[hh * n_loc * d..(hh + 1) * n_loc * d],
                        &x.q[hh * x.n * d..(hh + 1) * x.n * d],
                        d,
                    );
                    h.gather_rows(
                        &mut bufs.k[hh * n_loc * d..(hh + 1) * n_loc * d],
                        &x.k[hh * x.n * d..(hh + 1) * x.n * d],
                        d,
                    );
                    h.gather_rows(
                        &mut bufs.v[hh * n_loc * dv..(hh + 1) * n_loc * dv],
                        &x.v[hh * x.n * dv..(hh + 1) * x.n * dv],
                        dv,
                    );
                }
            },
            |i, bufs| {
                let sh = &self.shards[i];
                let n_loc = sh.halo.local_n();
                let lx = AttentionBatch {
                    n: n_loc,
                    d,
                    dv,
                    heads,
                    q: &bufs.q[..heads * n_loc * d],
                    k: &bufs.k[..heads * n_loc * d],
                    v: &bufs.v[..heads * n_loc * dv],
                    scale: x.scale,
                };
                match sh.plan.execute(&mut shard_ctx, &lx) {
                    Ok(o) => Ok(vec![o]),
                    Err(e) => {
                        inner_err = Some(e.clone());
                        Err(e.into())
                    }
                }
            },
            |i, outs| {
                let sh = &self.shards[i];
                let n_loc = sh.halo.local_n();
                let o = &outs[0];
                for hh in 0..heads {
                    sh.halo.scatter_own(
                        &mut out[hh * x.n * dv..(hh + 1) * x.n * dv],
                        &o[hh * n_loc * dv..(hh + 1) * n_loc * dv],
                        dv,
                    );
                }
            },
        );
        match result {
            Ok(()) => Ok(out),
            Err(e) => Err(inner_err
                .take()
                .unwrap_or_else(|| AttnError::Execute(format!("{e:#}")))),
        }
    }

    fn executables(&self, d: usize) -> Vec<String> {
        let mut names: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| s.plan.executables(d))
            .collect();
        names.sort();
        names.dedup();
        names
    }
}

#[cfg(test)]
mod tests {
    use crate::exec::offline_manifest;
    use crate::graph::generators;
    use crate::planner::DEFAULT_BUCKETS;
    use crate::util::prng::Rng;

    use super::*;

    fn manifest() -> Manifest {
        offline_manifest(8, DEFAULT_BUCKETS, 128)
    }

    #[test]
    fn single_shard_matches_plain_plan() {
        let man = manifest();
        let engine = Engine::serial();
        let g = generators::erdos_renyi(300, 5.0, 1).with_self_loops();
        let d = 8;
        let mut rng = Rng::new(2);
        let q = rng.normal_vec(g.n * d, 1.0);
        let k = rng.normal_vec(g.n * d, 1.0);
        let v = rng.normal_vec(g.n * d, 1.0);
        let x = AttentionBatch::new(g.n, d, d, 1, &q, &k, &v, 0.5);
        let plain = Plan::new(&man, &g, Backend::Fused3S, &engine).unwrap();
        let want = plain.execute(&mut ExecCtx::host(&engine), &x).unwrap();
        let sharded = ShardedPlan::new(
            &man,
            &g,
            Backend::Fused3S,
            &engine,
            ShardPolicy::balanced(1),
        )
        .unwrap();
        assert_eq!(sharded.stats().shards, 1);
        assert_eq!(sharded.stats().halo_rows, 0);
        let got = sharded.execute(&mut ExecCtx::host(&engine), &x).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn dense_and_auto_handling() {
        let man = manifest();
        let engine = Engine::serial();
        let g = generators::ring(64);
        let err = ShardedPlan::new(
            &man,
            &g,
            Backend::Dense,
            &engine,
            ShardPolicy::balanced(2),
        )
        .err()
        .expect("dense must refuse to shard");
        assert!(matches!(err, AttnError::Unsupported(_)));
        let auto = ShardedPlan::new(
            &man,
            &g,
            Backend::Auto,
            &engine,
            ShardPolicy::balanced(2),
        )
        .unwrap();
        assert!(shardable(auto.backend()));
    }

    #[test]
    fn shape_mismatch_is_bad_shape() {
        let man = manifest();
        let engine = Engine::serial();
        let g = generators::ring(64);
        let sp = ShardedPlan::new(
            &man,
            &g,
            Backend::CpuCsr,
            &engine,
            ShardPolicy::balanced(2),
        )
        .unwrap();
        let q = vec![0.0f32; 32 * 4];
        let x = AttentionBatch::new(32, 4, 4, 1, &q, &q, &q, 1.0);
        assert!(matches!(
            sp.execute(&mut ExecCtx::host(&engine), &x),
            Err(AttnError::BadShape(_))
        ));
    }
}
