//! Partition-parallel 3S execution — the sharding layer between the plan
//! API and the engine (DESIGN.md §10).
//!
//! The paper's decomposition is row-window-local: softmax normalises per
//! row, so a **row partition** of the BSB needs no cross-shard reduction —
//! only a gather of the K/V source rows each shard's compacted columns
//! reference (the *halo*).  This module exploits that to serve graphs
//! larger than one plan's working set and to stop a single mega-graph from
//! monopolising the engine:
//!
//! * [`partition`] — contiguous and TCB-work-balanced row-window
//!   partitioners (balance by per-RW TCB counts, not row counts, so
//!   hub-heavy graphs don't skew one shard — the Gale-et-al. 1D-tiling
//!   load-balance argument);
//! * [`halo`] — per-shard gather sets with the monotone, window-aligned
//!   global→local remap that makes sharded execution **bit-exact** against
//!   the unsharded plan;
//! * [`exec`] — [`ShardedPlan`]: one BSB + [`Plan`](crate::kernels::Plan)
//!   per shard, executed through the engine pipeline (shard *i+1*'s halo
//!   gather overlaps shard *i*'s dispatch) with own-row scatters into the
//!   global head-major output.  It implements
//!   [`SparseAttentionOp`](crate::kernels::SparseAttentionOp), so the
//!   models, `AttentionBatch` and the coordinator compose with it
//!   unchanged; the coordinator routes graphs above
//!   `CoordinatorConfig::max_plan_nodes` here instead of refusing them,
//!   caching per-shard plans by shard-local fingerprint.
//!
//! The planner prices a sharded candidate (per-shard fixed overhead +
//! halo-gather cells; [`CostModel::predict_sharded_s`]) and
//! [`bsb::stats::halo_fraction`] estimates the replication cost of a
//! partition without building it.  Equivalence is pinned by
//! `rust/tests/shard_equivalence.rs`; `benches/shard.rs` and
//! `repro shard` measure and audit (EXPERIMENTS.md §Sharding).
//!
//! [`CostModel::predict_sharded_s`]: crate::planner::CostModel::predict_sharded_s
//! [`bsb::stats::halo_fraction`]: crate::bsb::stats::halo_fraction

pub mod exec;
pub mod halo;
pub mod partition;

pub use exec::{ShardPolicy, ShardStats, ShardedPlan};
pub use halo::{build_shard, Halo, PAD_ROW};
pub use partition::{rw_tcb_counts, Partition, Strategy};
