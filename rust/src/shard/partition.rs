//! Row-window partitioning — how a graph is cut into shards.
//!
//! The 3S decomposition is row-window-local (softmax normalises per row),
//! so any partition of the row windows is computationally valid; the only
//! cross-shard traffic is the K/V halo gather ([`super::halo`]).  What the
//! partition *does* control is balance: equal-RW-count shards are badly
//! skewed on hub-heavy graphs (one shard inherits the mega-hub window and
//! its hundreds of TCBs), which is exactly the 1D-tiling load-balance
//! argument of *Sparse GPU Kernels for Deep Learning* (Gale et al.).  The
//! [`Strategy::BalancedTcb`] partitioner therefore balances by per-RW
//! **TCB work** — the same post-compaction distinct-column counts the
//! planner's [`GraphProfile`](crate::planner::GraphProfile) extracts — so
//! every shard carries ~1/S of the dispatched tensor-core blocks.
//!
//! Shards are always **contiguous RW ranges**: contiguity keeps each
//! shard's own rows a single global row interval, which the halo layout
//! relies on for its bit-exactness argument (see [`super::halo`]).

use crate::bsb::RW;
use crate::graph::CsrGraph;
use crate::TCB_C;

/// How to cut the row-window axis into shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Equal row-window counts per shard (ignores per-window work).
    Contiguous,
    /// Equal post-compaction TCB work per shard (hub-robust; default).
    BalancedTcb,
}

/// A partition of a graph's row windows into contiguous shard ranges.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    /// Contiguous, non-overlapping RW ranges covering `0..num_rw` in
    /// order.  Every range is non-empty (shard counts are clamped to the
    /// row-window count).
    pub ranges: Vec<std::ops::Range<usize>>,
    /// Total row windows partitioned (= `ceil(n / 16)`).
    pub num_rw: usize,
}

impl Partition {
    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.ranges.len()
    }

    /// The global **row** (node) ranges the RW ranges correspond to, the
    /// last one clamped to `n` for ragged graphs.
    pub fn row_ranges(&self, n: usize) -> Vec<std::ops::Range<usize>> {
        self.ranges
            .iter()
            .map(|r| (r.start * RW).min(n)..(r.end * RW).min(n))
            .collect()
    }

    /// Debug-check the partition invariants (contiguous cover, in order).
    pub fn validate(&self) -> bool {
        let mut lo = 0usize;
        for r in &self.ranges {
            if r.start != lo || r.end <= r.start {
                return false;
            }
            lo = r.end;
        }
        lo == self.num_rw
    }
}

/// Post-compaction TCB count of every row window, straight from the CSR
/// adjacency (no BSB build): the distinct neighbour columns across the
/// window's 16 rows are exactly what compaction keeps, so
/// `ceil(distinct / 8)` equals the post-build `Bsb::tcbs_per_rw` value
/// (the same pinned estimate [`GraphProfile::from_csr`] uses).
///
/// [`GraphProfile::from_csr`]: crate::planner::GraphProfile::from_csr
pub fn rw_tcb_counts(g: &CsrGraph) -> Vec<usize> {
    let num_rw = g.n.div_ceil(RW);
    let mut counts = Vec::with_capacity(num_rw);
    let mut cols: Vec<u32> = Vec::new();
    for w in 0..num_rw {
        let lo = w * RW;
        let hi = ((w + 1) * RW).min(g.n);
        cols.clear();
        for r in lo..hi {
            cols.extend_from_slice(g.row(r));
        }
        cols.sort_unstable();
        cols.dedup();
        counts.push(cols.len().div_ceil(TCB_C));
    }
    counts
}

/// Partition `g` into (at most) `shards` contiguous RW ranges under
/// `strategy`.  The shard count is clamped to `[1, num_rw]`; a graph with
/// no row windows yields a single empty-range partition.
pub fn partition(g: &CsrGraph, shards: usize, strategy: Strategy) -> Partition {
    let num_rw = g.n.div_ceil(RW);
    match strategy {
        Strategy::Contiguous => contiguous(num_rw, shards),
        Strategy::BalancedTcb => balanced_by_work(&rw_tcb_counts(g), shards),
    }
}

/// Equal-RW-count contiguous partition of `num_rw` row windows.
pub fn contiguous(num_rw: usize, shards: usize) -> Partition {
    if num_rw == 0 {
        return Partition { ranges: vec![0..0], num_rw };
    }
    let shards = shards.clamp(1, num_rw);
    let base = num_rw / shards;
    let extra = num_rw % shards;
    let mut ranges = Vec::with_capacity(shards);
    let mut lo = 0usize;
    for i in 0..shards {
        let hi = lo + base + usize::from(i < extra);
        ranges.push(lo..hi);
        lo = hi;
    }
    Partition { ranges, num_rw }
}

/// Work-balanced contiguous partition: greedy prefix sweep closing a shard
/// boundary once the cumulative weight reaches the next 1/S mark, while
/// guaranteeing every remaining shard at least one row window.  With
/// `weights = rw_tcb_counts(g)` this balances dispatched TCB work; an
/// all-zero weight vector degrades to the equal-count split.
pub fn balanced_by_work(weights: &[usize], shards: usize) -> Partition {
    let num_rw = weights.len();
    if num_rw == 0 {
        return Partition { ranges: vec![0..0], num_rw };
    }
    let shards = shards.clamp(1, num_rw);
    let total: usize = weights.iter().sum();
    if total == 0 {
        return contiguous(num_rw, shards);
    }
    let total = total as f64;
    let mut ranges = Vec::with_capacity(shards);
    let mut lo = 0usize;
    let mut acc = 0.0f64;
    for s in 0..shards {
        let remaining = shards - s - 1;
        // Leave at least one RW for every shard after this one.
        let hi_max = num_rw - remaining;
        let target = total * (s + 1) as f64 / shards as f64;
        let mut hi = lo;
        while hi < hi_max && (hi == lo || acc < target) {
            acc += weights[hi] as f64;
            hi += 1;
        }
        // Last shard swallows whatever the sweep left behind.
        if remaining == 0 {
            while hi < num_rw {
                acc += weights[hi] as f64;
                hi += 1;
            }
        }
        ranges.push(lo..hi);
        lo = hi;
    }
    let p = Partition { ranges, num_rw };
    debug_assert!(p.validate(), "balanced partition must cover 0..num_rw");
    p
}

/// Per-shard total weight (for balance metrics: max/mean work ratio).
pub fn shard_work(weights: &[usize], p: &Partition) -> Vec<usize> {
    p.ranges
        .iter()
        .map(|r| weights[r.clone()].iter().sum())
        .collect()
}

#[cfg(test)]
mod tests {
    use crate::bsb::build;
    use crate::graph::generators;

    use super::*;

    #[test]
    fn rw_tcb_counts_match_built_bsb() {
        for g in [
            generators::erdos_renyi(1500, 6.0, 1).with_self_loops(),
            generators::star(2000).with_self_loops(),
            generators::ring(277),
        ] {
            let counts = rw_tcb_counts(&g);
            let bsb = build(&g);
            let built: Vec<usize> =
                bsb.tcbs_per_rw().iter().map(|&t| t as usize).collect();
            assert_eq!(counts, built, "n={}", g.n);
        }
    }

    #[test]
    fn contiguous_covers_and_balances_counts() {
        let p = contiguous(10, 4);
        assert!(p.validate());
        let sizes: Vec<usize> = p.ranges.iter().map(|r| r.len()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
        // Clamped above num_rw.
        let p = contiguous(3, 16);
        assert_eq!(p.shards(), 3);
        assert!(p.validate());
        // Zero windows: one empty range.
        let p = contiguous(0, 4);
        assert_eq!(p.ranges, vec![0..0]);
    }

    #[test]
    fn balanced_isolates_the_hub_window() {
        // star(4096): the hub lives in RW 0 with ~512 TCBs of work while
        // every other window has 1; a 4-way balanced cut must give RW 0 a
        // (nearly) private shard where the contiguous cut spreads 1024
        // windows per shard regardless.
        let g = generators::star(4096).with_self_loops();
        let w = rw_tcb_counts(&g);
        let bal = balanced_by_work(&w, 4);
        assert!(bal.validate());
        assert_eq!(bal.shards(), 4);
        let work = shard_work(&w, &bal);
        let contig = contiguous(w.len(), 4);
        let cwork = shard_work(&w, &contig);
        let imbalance = |v: &[usize]| {
            let max = *v.iter().max().unwrap() as f64;
            let mean = v.iter().sum::<usize>() as f64 / v.len() as f64;
            max / mean
        };
        assert!(
            imbalance(&work) < imbalance(&cwork),
            "balanced {work:?} must beat contiguous {cwork:?}"
        );
        // The hub shard is small in window count.
        assert!(bal.ranges[0].len() < contig.ranges[0].len());
    }

    #[test]
    fn balanced_every_shard_nonempty() {
        for shards in [1, 2, 3, 7, 16] {
            let g = generators::barabasi_albert(1000, 4, 5).with_self_loops();
            let w = rw_tcb_counts(&g);
            let p = balanced_by_work(&w, shards);
            assert!(p.validate(), "shards={shards}");
            assert!(p.ranges.iter().all(|r| !r.is_empty()));
            assert_eq!(p.shards(), shards.min(w.len()));
        }
    }

    #[test]
    fn all_zero_weights_fall_back_to_contiguous() {
        let p = balanced_by_work(&[0, 0, 0, 0, 0, 0], 3);
        assert_eq!(p, contiguous(6, 3));
    }

    #[test]
    fn row_ranges_clamp_ragged_tail() {
        // n = 37 -> 3 RWs; rows 32..37 in the last window.
        let p = contiguous(3, 2);
        let rows = p.row_ranges(37);
        assert_eq!(rows, vec![0..32, 32..37]);
    }
}
