//! Halo computation — the K/V rows a shard must gather, and the
//! global→local remap that keeps sharded execution **bit-exact**.
//!
//! A shard owns a contiguous RW range (global rows `rows_lo..rows_hi`).
//! Its rows' compacted columns reference source rows inside the range
//! (intra-shard) and outside it — the **halo**.  The shard executes over a
//! *local* graph whose node space is laid out as:
//!
//! ```text
//! [ halo-below (global id < rows_lo, ascending) ]
//! [ alignment padding (isolated, never referenced) ]
//! [ own rows rows_lo..rows_hi ]
//! [ halo-above (global id >= rows_hi, ascending) ]
//! ```
//!
//! Two properties of this layout carry the bit-exactness proof:
//!
//! 1. **Monotone remap** — every *referenced* local id orders exactly as
//!    its global id (padding slots are never referenced), so each row
//!    window's compacted column list sorts into the same sequence as the
//!    unsharded build.  TCB packing, bitmaps, bucket choice and chunk
//!    boundaries are therefore structurally identical, and every per-row
//!    float reduction (score max, softmax denominator, SpMM accumulate,
//!    chunk merges) runs in the identical order.
//! 2. **Window alignment** — the padding block sizes halo-below to a
//!    multiple of 16, so local row window `own_start/16 + w` contains
//!    exactly the 16 rows of global window `rw_lo + w`.  Shards are
//!    RW-aligned and (except the global tail) own a multiple of 16 rows,
//!    so halo-above also starts on a window boundary; halo rows have no
//!    out-edges, their windows build zero TCBs and are never dispatched.
//!
//! Together: each shard's rows produce bitwise the same output values as
//! the unsharded plan (pinned by `rust/tests/shard_equivalence.rs`).

use std::collections::HashMap;

use crate::bsb::RW;
use crate::graph::CsrGraph;

/// Sentinel in [`Halo::gather`] for alignment padding slots: gather zeros,
/// never referenced by any edge.
pub const PAD_ROW: u32 = u32::MAX;

/// One shard's gather set and layout (see the module docs for the local
/// node-space contract).
#[derive(Clone, Debug)]
pub struct Halo {
    /// Global source row of every local slot, in local order ([`PAD_ROW`]
    /// for alignment padding).  `gather.len()` is the local node count.
    pub gather: Vec<u32>,
    /// Local index of the first own row (a multiple of 16).
    pub own_start: usize,
    /// Own rows (= `rows_hi - rows_lo`).
    pub own_rows: usize,
    /// First owned global row.
    pub own_global_start: usize,
    /// Replicated K/V rows gathered from outside the own range
    /// (halo-below + halo-above; padding not counted).
    pub halo_rows: usize,
}

impl Halo {
    /// Local node count (rows of the shard-local graph).
    pub fn local_n(&self) -> usize {
        self.gather.len()
    }

    /// Gather one head's features into `dst` (local row-major, `width`
    /// floats per row) from the global `src`: own + halo rows copy their
    /// global rows, padding slots zero-fill.  `dst` must hold
    /// `local_n() * width` floats.
    pub fn gather_rows(&self, dst: &mut [f32], src: &[f32], width: usize) {
        debug_assert_eq!(dst.len(), self.local_n() * width);
        for (i, &g) in self.gather.iter().enumerate() {
            let row = &mut dst[i * width..(i + 1) * width];
            if g == PAD_ROW {
                row.fill(0.0);
            } else {
                let s = g as usize * width;
                row.copy_from_slice(&src[s..s + width]);
            }
        }
    }

    /// Scatter one head's own-row outputs from the shard-local `src` back
    /// into the global `dst` (`width` floats per row).
    pub fn scatter_own(&self, dst: &mut [f32], src: &[f32], width: usize) {
        let lo = self.own_start * width;
        let glo = self.own_global_start * width;
        let len = self.own_rows * width;
        dst[glo..glo + len].copy_from_slice(&src[lo..lo + len]);
    }
}

/// Build one shard's halo and local graph for the RW range
/// `rw_range` of `g`.  Returns `(local graph, halo)`; the local graph
/// carries only the own rows' edges, remapped into the local node space.
pub fn build_shard(
    g: &CsrGraph,
    rw_range: std::ops::Range<usize>,
) -> (CsrGraph, Halo) {
    let rows_lo = (rw_range.start * RW).min(g.n);
    let rows_hi = (rw_range.end * RW).min(g.n);
    let own_rows = rows_hi - rows_lo;

    // Distinct referenced columns, split at the own-range boundaries.
    let mut cols: Vec<u32> = Vec::new();
    for r in rows_lo..rows_hi {
        cols.extend_from_slice(g.row(r));
    }
    cols.sort_unstable();
    cols.dedup();
    let below: Vec<u32> =
        cols.iter().copied().filter(|&c| (c as usize) < rows_lo).collect();
    let above: Vec<u32> =
        cols.iter().copied().filter(|&c| (c as usize) >= rows_hi).collect();
    let halo_rows = below.len() + above.len();

    // Local layout: below ++ pad-to-16 ++ own ++ above.
    let pad = (RW - below.len() % RW) % RW;
    let own_start = below.len() + pad;
    let mut gather = Vec::with_capacity(own_start + own_rows + above.len());
    gather.extend_from_slice(&below);
    gather.extend(std::iter::repeat(PAD_ROW).take(pad));
    gather.extend((rows_lo as u32)..(rows_hi as u32));
    gather.extend_from_slice(&above);

    // Global → local id map over every gatherable (non-pad) slot.
    let mut remap: HashMap<u32, u32> = HashMap::with_capacity(gather.len());
    for (i, &src) in gather.iter().enumerate() {
        if src != PAD_ROW {
            remap.insert(src, i as u32);
        }
    }

    // The shard-local graph: own rows' edges only.
    let local_n = gather.len();
    let mut edges: Vec<(u32, u32)> = Vec::new();
    for r in rows_lo..rows_hi {
        let lr = (own_start + (r - rows_lo)) as u32;
        for &c in g.row(r) {
            edges.push((lr, remap[&c]));
        }
    }
    let local =
        CsrGraph::from_edges(local_n, &edges).expect("remapped ids in range");

    let halo = Halo {
        gather,
        own_start,
        own_rows,
        own_global_start: rows_lo,
        halo_rows,
    };
    (local, halo)
}

#[cfg(test)]
mod tests {
    use crate::graph::generators;
    use crate::shard::partition::{partition, Strategy};
    use crate::util::prng::Rng;

    use super::*;

    #[test]
    fn full_range_shard_reproduces_the_graph() {
        let g = generators::erdos_renyi(300, 5.0, 1).with_self_loops();
        let num_rw = g.n.div_ceil(RW);
        let (local, halo) = build_shard(&g, 0..num_rw);
        assert_eq!(halo.halo_rows, 0);
        assert_eq!(halo.own_start, 0);
        assert_eq!(halo.own_rows, g.n);
        assert_eq!(local, g);
    }

    #[test]
    fn layout_is_window_aligned_and_monotone() {
        let g = generators::barabasi_albert(777, 4, 3).with_self_loops();
        let p = partition(&g, 3, Strategy::BalancedTcb);
        for r in &p.ranges {
            let (local, halo) = build_shard(&g, r.clone());
            assert_eq!(halo.own_start % RW, 0, "own rows window-aligned");
            assert_eq!(local.n, halo.gather.len());
            // Referenced slots are globally monotone in local order.
            let refd: Vec<u32> = halo
                .gather
                .iter()
                .copied()
                .filter(|&s| s != PAD_ROW)
                .collect();
            assert!(refd.windows(2).all(|w| w[0] < w[1]));
            // Own rows sit at their claimed offsets.
            for i in 0..halo.own_rows {
                assert_eq!(
                    halo.gather[halo.own_start + i],
                    (halo.own_global_start + i) as u32
                );
            }
            // Halo rows carry no out-edges in the local graph.
            for i in 0..local.n {
                let own =
                    i >= halo.own_start && i < halo.own_start + halo.own_rows;
                if !own {
                    assert_eq!(local.degree(i), 0, "local row {i}");
                }
            }
        }
    }

    #[test]
    fn local_edges_mirror_global_edges() {
        let g = generators::erdos_renyi(500, 6.0, 7).with_self_loops();
        let p = partition(&g, 4, Strategy::Contiguous);
        let mut covered = 0usize;
        for r in &p.ranges {
            let (local, halo) = build_shard(&g, r.clone());
            covered += halo.own_rows;
            for i in 0..halo.own_rows {
                let grow = halo.own_global_start + i;
                let lrow = halo.own_start + i;
                let want: Vec<u32> = g.row(grow).to_vec();
                let got: Vec<u32> = local
                    .row(lrow)
                    .iter()
                    .map(|&lc| halo.gather[lc as usize])
                    .collect();
                assert_eq!(got, want, "global row {grow}");
            }
        }
        assert_eq!(covered, g.n);
    }

    #[test]
    fn gather_scatter_round_trip() {
        let g = generators::star(200).with_self_loops();
        let (local, halo) = build_shard(&g, 1..3); // rows 16..48, halo hub 0
        assert!(halo.halo_rows >= 1);
        let d = 4;
        let mut rng = Rng::new(9);
        let src = rng.normal_vec(g.n * d, 1.0);
        let mut localbuf = vec![f32::NAN; local.n * d];
        halo.gather_rows(&mut localbuf, &src, d);
        for (i, &s) in halo.gather.iter().enumerate() {
            let row = &localbuf[i * d..(i + 1) * d];
            if s == PAD_ROW {
                assert!(row.iter().all(|&v| v == 0.0));
            } else {
                assert_eq!(row, &src[s as usize * d..(s as usize + 1) * d]);
            }
        }
        // Scatter own rows into a fresh global buffer.
        let mut out = vec![0.0f32; g.n * d];
        halo.scatter_own(&mut out, &localbuf, d);
        for r in 16..48 {
            assert_eq!(
                &out[r * d..(r + 1) * d],
                &src[r * d..(r + 1) * d]
            );
        }
        assert!(out[..16 * d].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn ragged_tail_shard() {
        let g = generators::erdos_renyi(37, 3.0, 5).with_self_loops();
        let (_, halo) = build_shard(&g, 2..3); // rows 32..37
        assert_eq!(halo.own_rows, 5);
        assert_eq!(halo.own_global_start, 32);
        assert_eq!(halo.own_start % RW, 0);
    }
}
