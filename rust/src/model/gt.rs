//! Graph Transformer inference runtime (paper §4.4, Figure 8).
//!
//! The block structure follows Dwivedi & Bresson [5] as implemented in DGL:
//!
//! ```text
//! h  → qkv_proj → split heads → 3S attention per head → concat → o_proj
//!    → residual + LayerNorm → FFN (2d hidden, ReLU) → residual + LayerNorm
//! ```
//!
//! Every dense op is a fixed-shape row-tile executable (m = 1024 rows),
//! every attention is a pluggable [`Backend`] — swapping the backend is the
//! Figure-8 experiment.  Heads are d_head = 32 wide, so d ∈ {64, 128, 256}
//! gives 2/4/8 heads.  All heads of all layers share the per-graph
//! preprocessing (one [`Plan`], built once in
//! [`GraphTransformer::prepare`]), and each layer issues **one**
//! head-batched [`AttentionBatch`] call — the engine pipelines head h+1's
//! gather over head h's dispatch instead of idling between per-head calls
//! (the §4.5 amortization).

use std::time::Instant;

use anyhow::{bail, Result};

use crate::exec::Engine;
use crate::graph::CsrGraph;
use crate::kernels::{AttentionBatch, Backend, ExecCtx, Plan};
use crate::runtime::{Manifest, Runtime, Tensor};

use super::weights::GtWeights;
use super::D_HEAD;

/// Model configuration.
#[derive(Clone, Copy, Debug)]
pub struct GtConfig {
    pub d: usize,
    pub n_blocks: usize,
    pub backend: Backend,
    pub seed: u64,
}

impl Default for GtConfig {
    fn default() -> Self {
        // The paper's evaluation model: 10 transformer blocks.
        GtConfig { d: 64, n_blocks: 10, backend: Backend::Fused3S, seed: 0x617 }
    }
}

/// Timing breakdown of one inference (Figure 8b/8d's attention fraction).
#[derive(Clone, Copy, Debug, Default)]
pub struct GtTimings {
    pub total_s: f64,
    pub attention_s: f64,
    pub dense_s: f64,
}

impl GtTimings {
    pub fn attention_fraction(&self) -> f64 {
        if self.total_s == 0.0 {
            0.0
        } else {
            self.attention_s / self.total_s
        }
    }
}

/// A Graph Transformer prepared for one graph.
pub struct GraphTransformer {
    pub cfg: GtConfig,
    pub weights: GtWeights,
    plan: Plan,
    engine: Engine,
    n: usize,
    m_tile: usize,
}

impl GraphTransformer {
    /// Generate weights and preprocess the graph for the chosen backend.
    pub fn prepare(rt: &Runtime, g: &CsrGraph, cfg: GtConfig) -> Result<GraphTransformer> {
        if cfg.d % D_HEAD != 0 {
            bail!("d={} must be a multiple of d_head={}", cfg.d, D_HEAD);
        }
        if !rt.manifest().d_model.contains(&cfg.d) {
            bail!(
                "no dense-op artifacts for d={} (available: {:?})",
                cfg.d,
                rt.manifest().d_model
            );
        }
        let engine = Engine::auto();
        let plan = Plan::new(rt.manifest(), g, cfg.backend, &engine)?;
        Ok(GraphTransformer {
            weights: GtWeights::generate(cfg.seed, cfg.d, cfg.n_blocks),
            cfg,
            plan,
            engine,
            n: g.n,
            m_tile: rt.manifest().m_tile,
        })
    }

    /// Run inference over node features `h` (n × d), returning the output
    /// features and the attention/dense timing split.
    pub fn infer(&self, rt: &Runtime, h: &[f32]) -> Result<(Vec<f32>, GtTimings)> {
        let (n, d) = (self.n, self.cfg.d);
        if h.len() != n * d {
            bail!("h: expected {} elements, got {}", n * d, h.len());
        }
        let mut t = GtTimings::default();
        let t_all = Instant::now();
        let mut h = h.to_vec();
        for blk in &self.weights.blocks {
            // --- attention sub-block -----------------------------------
            let t0 = Instant::now();
            let qkv = self.tiled_op3(
                rt,
                &Manifest::qkv_name(self.m_tile, d),
                &h,
                d,
                &blk.wqkv,
                &[d, 3 * d],
                &blk.bqkv,
                3 * d,
            )?;
            t.dense_s += t0.elapsed().as_secs_f64();

            let t0 = Instant::now();
            let n_heads = d / D_HEAD;
            let scale = 1.0 / (D_HEAD as f32).sqrt();
            // Slice head columns out of the fused QKV output (row layout:
            // [q | k | v] each d wide) into head-major buffers, then issue
            // ONE multi-head attention call for the whole layer.
            let mut qh = vec![0.0f32; n_heads * n * D_HEAD];
            let mut kh = vec![0.0f32; n_heads * n * D_HEAD];
            let mut vh = vec![0.0f32; n_heads * n * D_HEAD];
            for head in 0..n_heads {
                let hb = head * n * D_HEAD;
                for row in 0..n {
                    let base = row * 3 * d + head * D_HEAD;
                    let dst = hb + row * D_HEAD;
                    qh[dst..dst + D_HEAD]
                        .copy_from_slice(&qkv[base..base + D_HEAD]);
                    kh[dst..dst + D_HEAD]
                        .copy_from_slice(&qkv[base + d..base + d + D_HEAD]);
                    vh[dst..dst + D_HEAD]
                        .copy_from_slice(&qkv[base + 2 * d..base + 2 * d + D_HEAD]);
                }
            }
            let x = AttentionBatch::new(
                n, D_HEAD, D_HEAD, n_heads, &qh, &kh, &vh, scale,
            );
            let o = self
                .plan
                .execute(&mut ExecCtx::pjrt(rt, &self.engine), &x)?;
            // Interleave the head-major output back into n × d.
            let mut att = vec![0.0f32; n * d];
            for head in 0..n_heads {
                let hb = head * n * D_HEAD;
                for row in 0..n {
                    att[row * d + head * D_HEAD..row * d + (head + 1) * D_HEAD]
                        .copy_from_slice(&o[hb + row * D_HEAD..hb + (row + 1) * D_HEAD]);
                }
            }
            t.attention_s += t0.elapsed().as_secs_f64();

            // --- projections / norms / FFN ------------------------------
            let t0 = Instant::now();
            let proj = self.tiled_op3(
                rt,
                &Manifest::linear_name(self.m_tile, d),
                &att,
                d,
                &blk.wo,
                &[d, d],
                &blk.bo,
                d,
            )?;
            let h1 = self.tiled_add_ln(rt, &h, &proj, &blk.g1, &blk.be1, d)?;
            let f = self.tiled_ffn(rt, &h1, blk, d)?;
            let h2 = self.tiled_add_ln(rt, &h1, &f, &blk.g2, &blk.be2, d)?;
            t.dense_s += t0.elapsed().as_secs_f64();
            h = h2;
        }
        t.total_s = t_all.elapsed().as_secs_f64();
        Ok((h, t))
    }

    /// Run a 3-input tile op (x, w, b) over all row tiles of x.
    #[allow(clippy::too_many_arguments)]
    fn tiled_op3(
        &self,
        rt: &Runtime,
        name: &str,
        x: &[f32],
        d_in: usize,
        w: &[f32],
        w_shape: &[usize],
        b: &[f32],
        d_out: usize,
    ) -> Result<Vec<f32>> {
        let n = self.n;
        let m = self.m_tile;
        let mut out = vec![0.0f32; n * d_out];
        let w_t = Tensor::f32(w.to_vec(), w_shape.to_vec());
        let b_t = Tensor::f32(b.to_vec(), vec![d_out]);
        for lo in (0..n).step_by(m) {
            let hi = (lo + m).min(n);
            let mut tile = vec![0.0f32; m * d_in];
            tile[..(hi - lo) * d_in].copy_from_slice(&x[lo * d_in..hi * d_in]);
            let outs = rt.run(
                name,
                &[Tensor::f32(tile, vec![m, d_in]), w_t.clone(), b_t.clone()],
            )?;
            let o = outs[0].as_f32()?;
            out[lo * d_out..hi * d_out].copy_from_slice(&o[..(hi - lo) * d_out]);
        }
        Ok(out)
    }

    fn tiled_add_ln(
        &self,
        rt: &Runtime,
        x: &[f32],
        y: &[f32],
        gamma: &[f32],
        beta: &[f32],
        d: usize,
    ) -> Result<Vec<f32>> {
        let n = self.n;
        let m = self.m_tile;
        let mut out = vec![0.0f32; n * d];
        let g_t = Tensor::f32(gamma.to_vec(), vec![d]);
        let b_t = Tensor::f32(beta.to_vec(), vec![d]);
        let name = Manifest::add_ln_name(m, d);
        for lo in (0..n).step_by(m) {
            let hi = (lo + m).min(n);
            let mut tx = vec![0.0f32; m * d];
            let mut ty = vec![0.0f32; m * d];
            tx[..(hi - lo) * d].copy_from_slice(&x[lo * d..hi * d]);
            ty[..(hi - lo) * d].copy_from_slice(&y[lo * d..hi * d]);
            let outs = rt.run(
                &name,
                &[
                    Tensor::f32(tx, vec![m, d]),
                    Tensor::f32(ty, vec![m, d]),
                    g_t.clone(),
                    b_t.clone(),
                ],
            )?;
            let o = outs[0].as_f32()?;
            out[lo * d..hi * d].copy_from_slice(&o[..(hi - lo) * d]);
        }
        Ok(out)
    }

    fn tiled_ffn(
        &self,
        rt: &Runtime,
        x: &[f32],
        blk: &super::weights::GtBlockWeights,
        d: usize,
    ) -> Result<Vec<f32>> {
        let n = self.n;
        let m = self.m_tile;
        let h = 2 * d;
        let mut out = vec![0.0f32; n * d];
        let w1 = Tensor::f32(blk.w1.clone(), vec![d, h]);
        let b1 = Tensor::f32(blk.b1.clone(), vec![h]);
        let w2 = Tensor::f32(blk.w2.clone(), vec![h, d]);
        let b2 = Tensor::f32(blk.b2.clone(), vec![d]);
        let name = Manifest::ffn_name(m, d);
        for lo in (0..n).step_by(m) {
            let hi = (lo + m).min(n);
            let mut tile = vec![0.0f32; m * d];
            tile[..(hi - lo) * d].copy_from_slice(&x[lo * d..hi * d]);
            let outs = rt.run(
                &name,
                &[
                    Tensor::f32(tile, vec![m, d]),
                    w1.clone(),
                    b1.clone(),
                    w2.clone(),
                    b2.clone(),
                ],
            )?;
            let o = outs[0].as_f32()?;
            out[lo * d..hi * d].copy_from_slice(&o[..(hi - lo) * d]);
        }
        Ok(out)
    }
}
