//! Graph Attention Network attention (paper §2.1, Eq. 2) on the fused
//! kernel.
//!
//! GAT's additive scores `e_ij = LeakyReLU(a_l·Wh_i + a_r·Wh_j)` are rank-2:
//! with `Q_i = [a_l·Wh_i, 1]` and `K_j = [1, a_r·Wh_j]` (d = 2),
//! `Q_i · K_j = a_l·Wh_i + a_r·Wh_j`.  The fused kernel applies LeakyReLU
//! pre-softmax (baked into the `fused3s_gat_*` artifacts) and aggregates
//! V = Wh at dv = 64 — so the *same* fused 3S machinery covers GAT, which is
//! the paper's point about the 3S abstraction.
//!
//! In plan/batch terms a GAT layer is a **one-head** `AttentionBatch` with
//! `d = 2 ≠ dv`; the dedicated GAT artifacts (LeakyReLU score activation)
//! keep it on its own dispatch loop rather than the generic
//! [`SparseAttentionOp`](crate::kernels::SparseAttentionOp) plans.

use anyhow::{bail, Context, Result};

use crate::bsb::bucket::{self, Plan};
use crate::bsb::reorder::Order;
use crate::bsb::{self, Bsb};
use crate::graph::CsrGraph;
use crate::kernels::gather::{self, CallBuffers};
use crate::kernels::AttentionProblem;
use crate::runtime::buffers::Arg;
use crate::runtime::{Manifest, Runtime};
use crate::{BITMAP_WORDS, TCB_C, TCB_R};

/// GAT layer parameters.
pub struct GatLayer {
    /// Feature projection W: (d_in, d_out) with d_out = 64 (artifact dim).
    pub w: Vec<f32>,
    pub d_in: usize,
    pub d_out: usize,
    /// Attention vectors a_l, a_r: (d_out,).
    pub a_l: Vec<f32>,
    pub a_r: Vec<f32>,
}

/// GAT buckets compiled by aot.py (GAT_T).
const GAT_BUCKETS: &[usize] = &[4, 8, 16, 32];

impl GatLayer {
    pub fn generate(seed: u64, d_in: usize, d_out: usize) -> GatLayer {
        let mut rng = crate::util::prng::Rng::new(seed);
        let s = 1.0 / (d_in as f32).sqrt();
        GatLayer {
            w: rng.normal_vec(d_in * d_out, s),
            d_in,
            d_out,
            a_l: rng.normal_vec(d_out, 1.0 / (d_out as f32).sqrt()),
            a_r: rng.normal_vec(d_out, 1.0 / (d_out as f32).sqrt()),
        }
    }
}

/// Preprocessed GAT attention over one graph.
pub struct GatAttention {
    bsb: Bsb,
    plan: Plan,
    batch: usize,
}

impl GatAttention {
    pub fn prepare(man: &Manifest, g: &CsrGraph) -> Result<GatAttention> {
        let bsb = bsb::build(g);
        let plan = bucket::plan(
            &bsb,
            GAT_BUCKETS,
            man.rw_batch,
            Order::ByTcbDesc,
            man.chunk_t,
        );
        if let Some(c) = plan.chunked.first() {
            bail!(
                "row window {} has {} TCBs > GAT bucket max {}: graph too \
                 dense for the compiled GAT suite",
                c.rw,
                bsb.rw_tcbs(c.rw as usize),
                GAT_BUCKETS.last().unwrap()
            );
        }
        Ok(GatAttention { bsb, plan, batch: man.rw_batch })
    }

    /// One GAT attention layer: h (n × d_in) → output (n × d_out).
    pub fn forward(
        &self,
        rt: &Runtime,
        layer: &GatLayer,
        h: &[f32],
        n: usize,
    ) -> Result<Vec<f32>> {
        if h.len() != n * layer.d_in {
            bail!("h: expected {} elements", n * layer.d_in);
        }
        // Wh on the host (a single GEMV-ish pass; the GT path shows the
        // tiled-executable variant — here we keep the focus on attention).
        let (din, dout) = (layer.d_in, layer.d_out);
        let mut wh = vec![0.0f32; n * dout];
        for i in 0..n {
            for c in 0..din {
                let x = h[i * din + c];
                if x != 0.0 {
                    let wrow = &layer.w[c * dout..(c + 1) * dout];
                    let orow = &mut wh[i * dout..(i + 1) * dout];
                    for (o, w) in orow.iter_mut().zip(wrow) {
                        *o += x * w;
                    }
                }
            }
        }
        // Rank-2 score embedding.
        let mut q2 = vec![0.0f32; n * 2];
        let mut k2 = vec![0.0f32; n * 2];
        for i in 0..n {
            let whi = &wh[i * dout..(i + 1) * dout];
            let sl: f32 = whi.iter().zip(&layer.a_l).map(|(a, b)| a * b).sum();
            let sr: f32 = whi.iter().zip(&layer.a_r).map(|(a, b)| a * b).sum();
            q2[i * 2] = sl;
            q2[i * 2 + 1] = 1.0;
            k2[i * 2] = 1.0;
            k2[i * 2 + 1] = sr;
        }
        let x = AttentionProblem {
            n,
            d: 2,
            dv: dout,
            q: &q2,
            k: &k2,
            v: &wh,
            scale: 1.0,
        };
        let mut out = vec![0.0f32; n * dout];
        let mut bufs = CallBuffers::default();
        for call in &self.plan.calls {
            let name = Manifest::gat_name(call.t_bucket, dout);
            let exe = rt
                .executable(&name)
                .with_context(|| format!("GAT artifact {name}"))?;
            gather::gather_call(&mut bufs, &call.rws, call.t_bucket, &self.bsb, &x, self.batch);
            let sq = [self.batch, TCB_R, 2];
            let sk = [self.batch, call.t_bucket * TCB_C, 2];
            let sv = [self.batch, call.t_bucket * TCB_C, dout];
            let sbm = [self.batch, call.t_bucket, BITMAP_WORDS];
            let outs = rt.run_exe_raw(
                &exe,
                &[
                    Arg::F32(&bufs.q, &sq),
                    Arg::F32(&bufs.k, &sk),
                    Arg::F32(&bufs.v, &sv),
                    Arg::I32(&bufs.bm, &sbm),
                ],
            )?;
            gather::scatter_call(&mut out, outs[0].as_f32()?, &call.rws, n, dout);
        }
        Ok(out)
    }
}

/// Host reference for tests: GAT attention with exact f64 softmax.
pub fn gat_reference(
    g: &CsrGraph,
    layer: &GatLayer,
    h: &[f32],
    n: usize,
) -> Vec<f32> {
    let (din, dout) = (layer.d_in, layer.d_out);
    let mut wh = vec![0.0f32; n * dout];
    for i in 0..n {
        for c in 0..din {
            for j in 0..dout {
                wh[i * dout + j] += h[i * din + c] * layer.w[c * dout + j];
            }
        }
    }
    let sl: Vec<f64> = (0..n)
        .map(|i| {
            wh[i * dout..(i + 1) * dout]
                .iter()
                .zip(&layer.a_l)
                .map(|(a, b)| (a * b) as f64)
                .sum()
        })
        .collect();
    let sr: Vec<f64> = (0..n)
        .map(|i| {
            wh[i * dout..(i + 1) * dout]
                .iter()
                .zip(&layer.a_r)
                .map(|(a, b)| (a * b) as f64)
                .sum()
        })
        .collect();
    let mut out = vec![0.0f32; n * dout];
    for i in 0..n {
        let nbrs = g.row(i);
        if nbrs.is_empty() {
            continue;
        }
        let scores: Vec<f64> = nbrs
            .iter()
            .map(|&j| {
                let e = sl[i] + sr[j as usize];
                if e >= 0.0 {
                    e
                } else {
                    0.2 * e
                }
            })
            .collect();
        let m = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = scores.iter().map(|s| (s - m).exp()).collect();
        let l: f64 = exps.iter().sum();
        for (e, &j) in exps.iter().zip(nbrs) {
            let w = (e / l) as f32;
            for c in 0..dout {
                out[i * dout + c] += w * wh[j as usize * dout + c];
            }
        }
    }
    out
}
