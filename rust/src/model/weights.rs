//! Deterministic weight generation (seeded Xavier-ish init).
//!
//! The paper benchmarks *inference time* on randomly initialised models
//! (timings are weight-independent); we generate weights from a seed so
//! every run and every backend sees identical parameters.

use crate::util::prng::Rng;

/// One Graph Transformer block's parameters (layout matches
/// `python/compile/model.py::gt_block_ref`).
#[derive(Clone)]
pub struct GtBlockWeights {
    pub wqkv: Vec<f32>, // (d, 3d)
    pub bqkv: Vec<f32>, // (3d,)
    pub wo: Vec<f32>,   // (d, d)
    pub bo: Vec<f32>,   // (d,)
    pub w1: Vec<f32>,   // (d, 2d)
    pub b1: Vec<f32>,   // (2d,)
    pub w2: Vec<f32>,   // (2d, d)
    pub b2: Vec<f32>,   // (d,)
    pub g1: Vec<f32>,   // (d,)
    pub be1: Vec<f32>,  // (d,)
    pub g2: Vec<f32>,   // (d,)
    pub be2: Vec<f32>,  // (d,)
}

impl GtBlockWeights {
    pub fn generate(rng: &mut Rng, d: usize) -> GtBlockWeights {
        let h = 2 * d;
        let s_d = 1.0 / (d as f32).sqrt();
        let s_h = 1.0 / (h as f32).sqrt();
        GtBlockWeights {
            wqkv: rng.normal_vec(d * 3 * d, s_d),
            bqkv: vec![0.0; 3 * d],
            wo: rng.normal_vec(d * d, s_d),
            bo: vec![0.0; d],
            w1: rng.normal_vec(d * h, s_d),
            b1: vec![0.0; h],
            w2: rng.normal_vec(h * d, s_h),
            b2: vec![0.0; d],
            g1: vec![1.0; d],
            be1: vec![0.0; d],
            g2: vec![1.0; d],
            be2: vec![0.0; d],
        }
    }
}

/// Full model weights.
#[derive(Clone)]
pub struct GtWeights {
    pub d: usize,
    pub blocks: Vec<GtBlockWeights>,
}

impl GtWeights {
    pub fn generate(seed: u64, d: usize, n_blocks: usize) -> GtWeights {
        let mut rng = Rng::new(seed);
        GtWeights {
            d,
            blocks: (0..n_blocks)
                .map(|i| GtBlockWeights::generate(&mut rng.fork(i as u64), d))
                .collect(),
        }
    }
}

/// Random node features (the model input H).
pub fn random_features(seed: u64, n: usize, d: usize) -> Vec<f32> {
    Rng::new(seed).normal_vec(n * d, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = GtWeights::generate(7, 64, 3);
        let b = GtWeights::generate(7, 64, 3);
        assert_eq!(a.blocks[2].wqkv, b.blocks[2].wqkv);
        let c = GtWeights::generate(8, 64, 3);
        assert_ne!(a.blocks[0].wqkv, c.blocks[0].wqkv);
    }

    #[test]
    fn blocks_differ() {
        let w = GtWeights::generate(7, 64, 2);
        assert_ne!(w.blocks[0].wqkv, w.blocks[1].wqkv);
    }

    #[test]
    fn shapes() {
        let w = GtWeights::generate(1, 128, 1);
        let b = &w.blocks[0];
        assert_eq!(b.wqkv.len(), 128 * 384);
        assert_eq!(b.w1.len(), 128 * 256);
        assert_eq!(b.w2.len(), 256 * 128);
        assert_eq!(b.g1.len(), 128);
    }

    #[test]
    fn init_scale_reasonable() {
        let w = GtWeights::generate(2, 64, 1);
        let std: f32 = {
            let v = &w.blocks[0].wqkv;
            let m = v.iter().sum::<f32>() / v.len() as f32;
            (v.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / v.len() as f32)
                .sqrt()
        };
        assert!((std - 0.125).abs() < 0.01, "std {std}"); // 1/sqrt(64)
    }
}
