//! AGNN attention (paper §2.1, Eq. 3): cosine-similarity attention with a
//! learnable temperature β, Q = K = V = H.
//!
//! `s_ij = β · cos(h_i, h_j) = β · ĥ_i · ĥ_j` — so after row-normalising H
//! and folding β into the score scale, AGNN *is* the 3S kernel.  This is
//! the clearest demonstration that the paper's 3S abstraction unifies the
//! model zoo: no new kernel needed.

use anyhow::Result;

use crate::exec::Engine;
use crate::graph::CsrGraph;
use crate::kernels::{AttentionBatch, AttentionProblem, Backend, ExecCtx, Plan};
use crate::runtime::Runtime;

/// One AGNN propagation layer prepared for a graph.
pub struct AgnnLayer {
    pub beta: f32,
    plan: Plan,
    engine: Engine,
}

impl AgnnLayer {
    pub fn prepare(rt: &Runtime, g: &CsrGraph, beta: f32) -> Result<AgnnLayer> {
        let engine = Engine::serial();
        let plan = Plan::new(rt.manifest(), g, Backend::Fused3S, &engine)?;
        Ok(AgnnLayer { beta, plan, engine })
    }

    /// H^{t+1} = softmax(β cos(H, Hᵀ) ⊙ A) H
    pub fn forward(&self, rt: &Runtime, h: &[f32], n: usize, d: usize) -> Result<Vec<f32>> {
        let hn = row_normalize(h, n, d);
        let x = AttentionProblem {
            n,
            d,
            dv: d,
            q: &hn,
            k: &hn,
            v: h,
            scale: self.beta,
        };
        let out = self
            .plan
            .execute(&mut ExecCtx::pjrt(rt, &self.engine), &AttentionBatch::single(&x))?;
        Ok(out)
    }
}

/// L2-normalise rows (zero rows stay zero).
pub fn row_normalize(h: &[f32], n: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * d];
    for i in 0..n {
        let row = &h[i * d..(i + 1) * d];
        let norm: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm > 0.0 {
            for (o, x) in out[i * d..(i + 1) * d].iter_mut().zip(row) {
                *o = x / norm;
            }
        }
    }
    out
}

/// Host reference for tests.
pub fn agnn_reference(g: &CsrGraph, h: &[f32], n: usize, d: usize, beta: f32) -> Vec<f32> {
    let hn = row_normalize(h, n, d);
    let mut out = vec![0.0f32; n * d];
    for i in 0..n {
        let nbrs = g.row(i);
        if nbrs.is_empty() {
            continue;
        }
        let qi = &hn[i * d..(i + 1) * d];
        let scores: Vec<f64> = nbrs
            .iter()
            .map(|&j| {
                let kj = &hn[j as usize * d..(j as usize + 1) * d];
                qi.iter().zip(kj).map(|(a, b)| (a * b) as f64).sum::<f64>()
                    * beta as f64
            })
            .collect();
        let m = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = scores.iter().map(|s| (s - m).exp()).collect();
        let l: f64 = exps.iter().sum();
        for (e, &j) in exps.iter().zip(nbrs) {
            let w = (e / l) as f32;
            for c in 0..d {
                out[i * d + c] += w * h[j as usize * d + c];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_unit_rows() {
        let h = vec![3.0, 4.0, 0.0, 0.0];
        let out = row_normalize(&h, 2, 2);
        assert!((out[0] - 0.6).abs() < 1e-6);
        assert!((out[1] - 0.8).abs() < 1e-6);
        assert_eq!(&out[2..], &[0.0, 0.0]);
    }

    #[test]
    fn reference_cosine_bounded() {
        // cos in [-1,1] scaled by beta: with V=H the output stays in the
        // convex hull of neighbour features.
        let g = crate::graph::generators::ring(32).with_self_loops();
        let mut rng = crate::util::prng::Rng::new(5);
        let h = rng.normal_vec(32 * 8, 1.0);
        let out = agnn_reference(&g, &h, 32, 8, 2.0);
        let max_h = h.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let max_o = out.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert!(max_o <= max_h + 1e-5);
    }
}
