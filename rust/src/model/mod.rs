//! Model inference runtimes built on the 3S kernel drivers — the paper's
//! §2.1 model zoo and the §4.4 end-to-end experiment.
//!
//! * [`gt`] — the Graph Transformer of Dwivedi & Bresson [5]: 10 blocks of
//!   multi-head sparse attention + FFN + LayerNorm, every dense op running
//!   through AOT row-tile executables and every attention through a
//!   pluggable 3S backend (the Figure-8 experiment).
//! * [`gat`] — Graph Attention Network attention (Eq. 2): rank-2 additive
//!   scores + LeakyReLU, expressed on the same fused kernel.
//! * [`agnn`] — Attention-based GNN (Eq. 3): cosine-similarity attention.
//! * [`weights`] — deterministic (seeded) weight generation; there is no
//!   checkpoint ecosystem offline, so models are random-initialised exactly
//!   like the paper's inference benchmarks.

pub mod agnn;
pub mod gat;
pub mod gt;
pub mod weights;

pub use gt::{GraphTransformer, GtConfig, GtTimings};

/// Head width shared with `python/compile/model.py` (D_HEAD).
pub const D_HEAD: usize = 32;
