//! Structured per-request tracing: a process-global, seeded-sampling,
//! lock-free bounded ring buffer of [`TraceEvent`]s (DESIGN.md §15).
//!
//! The serving stack's metrics ([`crate::coordinator::metrics`]) answer
//! *aggregate* questions; this subsystem answers "where did request X
//! spend its 40 ms".  Spans are emitted at every existing seam — net
//! session decode, admission, coalesce wait, plan-cache hit/miss, BSB
//! build / incremental splice, planner decision (per-backend predicted
//! costs and the winner), gather/dispatch/scatter per engine stage,
//! per-shard preparation, retry/fallback ladder steps, reply encode —
//! and exported as Chrome `trace_event` JSON loadable in
//! `chrome://tracing` / Perfetto ([`Tracer::chrome_json`], surfaced by
//! `repro trace`).
//!
//! Design mirrors the fault layer ([`crate::fault`]) exactly:
//!
//! * **Disarmed cost**: every hook is one relaxed atomic load when no
//!   tracer is installed, and compiles out entirely without the
//!   default-on `tracing` feature (`benches/trace_overhead.rs` pins both
//!   costs, same disarmed-vs-armed pattern as `fault_overhead`).
//! * **Seeded sampling**: whether a request is traced is a pure function
//!   of `splitmix64(seed ^ id)` against `sample_rate`, so traced runs
//!   are reproducible — and a differential test pins that tracing-armed
//!   outputs stay bit-identical to tracing-disabled outputs.
//! * **RAII guard**: [`install`] arms a process-global [`Tracer`] and
//!   returns a [`TraceGuard`] that disarms on drop (latest install wins;
//!   a stale guard dropping does not disarm a newer tracer).
//!
//! **Ring-buffer overflow semantics**: event slots are claimed by a
//! wrapping atomic cursor; once more than `capacity` events have been
//! recorded, new events overwrite the oldest (the tail of a long run
//! survives, the head is dropped — [`Tracer::dropped`] counts the
//! casualties).  Writers never block and never allocate.  A snapshot
//! taken while writers are still active may observe a slot mid-overwrite;
//! such torn slots are detected by their sequence stamp and skipped, so
//! exports are race-free but should be taken after the workload
//! quiesces for a complete picture.
//!
//! Span ids are u64s threaded through
//! [`AttnRequest`](crate::coordinator::AttnRequest) /
//! [`AttnResponse`](crate::coordinator::AttnResponse) (`0` = untraced);
//! every emission helper no-ops on span 0, so the sampling decision made
//! once at admission gates all downstream instrumentation.  Stages that
//! cannot thread the id through their call signature (plan preparation,
//! engine gather/dispatch/scatter) inherit it from a thread-ambient slot
//! ([`with_span`] / [`current_span`]).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::json::{arr, num, obj, s, Json};
use crate::util::sync::lock_unpoisoned;

/// Where in the stack a trace event was emitted.  Names are stable — they
/// are the `name` field of the Chrome export and the vocabulary DESIGN.md
/// §15 documents.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceSite {
    /// Whole request: begin at admission ([`Coordinator::submit`]), end
    /// when the response is sent.
    ///
    /// [`Coordinator::submit`]: crate::coordinator::Coordinator::submit
    Request,
    /// A submit frame decoded on a net session (instant; a = request id).
    NetDecode,
    /// A response frame encoded + flushed by a session forwarder.
    NetEncode,
    /// Batcher admission: deadline check + `Backend::Auto` resolution.
    Admission,
    /// Time a request waited in the coalescer before its batch was
    /// prepared (instant; a = waited µs, b = batch size).
    CoalesceWait,
    /// The planner's verdict (instant; a = backend code, b = predicted ns).
    PlannerDecision,
    /// One candidate's line on the planner scoreboard (instant; a =
    /// backend code, b = predicted ns; emitted once per feasible
    /// candidate right before its [`TraceSite::PlannerDecision`]).
    PlannerScore,
    /// Plan-cache hit (instant; a = graph fingerprint).
    CacheHit,
    /// Plan-cache miss (instant; a = graph fingerprint).
    CacheMiss,
    /// BSB + bucket-plan build on a cache miss (span; a = n).
    BsbBuild,
    /// Incremental BSB rebuild for a graph delta (span; a = dirty RWs).
    BsbSplice,
    /// Whole preprocessing of one batch (merge + plan ladder).
    Prepare,
    /// One shard's plan preparation inside a sharded prepare (a = shard
    /// index, within the parent request's span).
    ShardPrepare,
    /// Whole kernel execution of one batch.
    Execute,
    /// Engine pipeline stage: one item's K/V/feature gather (a = item).
    Gather,
    /// Engine pipeline stage: one item's kernel dispatch (a = item).
    Dispatch,
    /// Engine pipeline stage: one item's output scatter (a = item).
    Scatter,
    /// Degradation ladder: a retry of a failed prepare/execute (instant).
    Retry,
    /// Degradation ladder: re-resolution onto a fallback backend
    /// (instant; a = backend code of the fallback).
    Fallback,
    /// Degradation ladder: a `(fingerprint, backend)` pair quarantined
    /// (instant; a = backend code).
    Quarantine,
    /// A deadline shed at any queueing point (instant).
    DeadlineShed,
    /// The response handed to the reply channel (instant; a = 1 ok / 0
    /// err, b = batch size).
    Respond,
}

/// Every site, in stable order (the discriminant is the wire/export code).
pub const TRACE_SITES: [TraceSite; 22] = [
    TraceSite::Request,
    TraceSite::NetDecode,
    TraceSite::NetEncode,
    TraceSite::Admission,
    TraceSite::CoalesceWait,
    TraceSite::PlannerDecision,
    TraceSite::PlannerScore,
    TraceSite::CacheHit,
    TraceSite::CacheMiss,
    TraceSite::BsbBuild,
    TraceSite::BsbSplice,
    TraceSite::Prepare,
    TraceSite::ShardPrepare,
    TraceSite::Execute,
    TraceSite::Gather,
    TraceSite::Dispatch,
    TraceSite::Scatter,
    TraceSite::Retry,
    TraceSite::Fallback,
    TraceSite::Quarantine,
    TraceSite::DeadlineShed,
    TraceSite::Respond,
];

impl TraceSite {
    /// Stable index (used to pack events into ring slots).
    pub fn index(self) -> usize {
        match self {
            TraceSite::Request => 0,
            TraceSite::NetDecode => 1,
            TraceSite::NetEncode => 2,
            TraceSite::Admission => 3,
            TraceSite::CoalesceWait => 4,
            TraceSite::PlannerDecision => 5,
            TraceSite::PlannerScore => 6,
            TraceSite::CacheHit => 7,
            TraceSite::CacheMiss => 8,
            TraceSite::BsbBuild => 9,
            TraceSite::BsbSplice => 10,
            TraceSite::Prepare => 11,
            TraceSite::ShardPrepare => 12,
            TraceSite::Execute => 13,
            TraceSite::Gather => 14,
            TraceSite::Dispatch => 15,
            TraceSite::Scatter => 16,
            TraceSite::Retry => 17,
            TraceSite::Fallback => 18,
            TraceSite::Quarantine => 19,
            TraceSite::DeadlineShed => 20,
            TraceSite::Respond => 21,
        }
    }

    fn from_index(i: usize) -> TraceSite {
        TRACE_SITES[i.min(TRACE_SITES.len() - 1)]
    }

    /// The span/event name used in the Chrome export.
    pub fn name(self) -> &'static str {
        match self {
            TraceSite::Request => "request",
            TraceSite::NetDecode => "net-decode",
            TraceSite::NetEncode => "net-encode",
            TraceSite::Admission => "admission",
            TraceSite::CoalesceWait => "coalesce-wait",
            TraceSite::PlannerDecision => "planner-decision",
            TraceSite::PlannerScore => "planner-score",
            TraceSite::CacheHit => "cache-hit",
            TraceSite::CacheMiss => "cache-miss",
            TraceSite::BsbBuild => "bsb-build",
            TraceSite::BsbSplice => "bsb-splice",
            TraceSite::Prepare => "prepare",
            TraceSite::ShardPrepare => "shard-prepare",
            TraceSite::Execute => "execute",
            TraceSite::Gather => "gather",
            TraceSite::Dispatch => "dispatch",
            TraceSite::Scatter => "scatter",
            TraceSite::Retry => "retry",
            TraceSite::Fallback => "fallback",
            TraceSite::Quarantine => "quarantine",
            TraceSite::DeadlineShed => "deadline-shed",
            TraceSite::Respond => "respond",
        }
    }
}

/// Event phase, matching Chrome `trace_event` `ph` values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// Span begin (`"B"`).
    Begin,
    /// Span end (`"E"`).
    End,
    /// Instant event (`"i"`).
    Instant,
}

impl TraceKind {
    /// The Chrome `trace_event` phase letter for this kind.
    pub fn ph(self) -> &'static str {
        match self {
            TraceKind::Begin => "B",
            TraceKind::End => "E",
            TraceKind::Instant => "i",
        }
    }
}

/// One recorded event (the snapshot form read back out of the ring).
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    /// Microseconds since the tracer was installed (monotonic clock).
    pub ts_us: u64,
    pub kind: TraceKind,
    pub site: TraceSite,
    /// The request's span id (`tid` in the Chrome export); never 0.
    pub span: u64,
    /// First numeric payload (meaning per [`TraceSite`] docs).
    pub a: u64,
    /// Second numeric payload.
    pub b: u64,
}

/// Tracer configuration.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// Seed of the splitmix64 sampling hash.
    pub seed: u64,
    /// Fraction of requests traced in `[0, 1]`; `>= 1.0` traces every
    /// request, `0.0` arms the seams but samples nothing (the
    /// overhead-bench configuration).
    pub sample_rate: f64,
    /// Ring capacity in events; oldest events are overwritten past it.
    pub capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> TraceConfig {
        TraceConfig { seed: 0x7ACE_5EED, sample_rate: 1.0, capacity: 65_536 }
    }
}

/// One ring slot: a sequence stamp plus the packed event words.  `seq`
/// is `claim_index + 1` (0 = never written) and is stored *last* with
/// release ordering, so a reader that observes a consistent stamp
/// observes the matching payload.
struct Slot {
    seq: AtomicU64,
    ts_us: AtomicU64,
    /// `kind << 8 | site_index`.
    code: AtomicU64,
    span: AtomicU64,
    a: AtomicU64,
    b: AtomicU64,
}

impl Slot {
    fn empty() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            ts_us: AtomicU64::new(0),
            code: AtomicU64::new(0),
            span: AtomicU64::new(0),
            a: AtomicU64::new(0),
            b: AtomicU64::new(0),
        }
    }
}

/// The process-global trace recorder: sampling decisions, span-id
/// allocation, and the lock-free bounded event ring.
pub struct Tracer {
    cfg: TraceConfig,
    epoch: Instant,
    /// Next claim index (monotonic; slot = index % capacity).
    cursor: AtomicU64,
    /// Next span id minus one (span ids start at 1; 0 = untraced).
    spans: AtomicU64,
    slots: Vec<Slot>,
}

impl Tracer {
    pub fn new(cfg: TraceConfig) -> Tracer {
        let capacity = cfg.capacity.max(1);
        Tracer {
            cfg,
            epoch: Instant::now(),
            cursor: AtomicU64::new(0),
            spans: AtomicU64::new(0),
            slots: (0..capacity).map(|_| Slot::empty()).collect(),
        }
    }

    /// The sampling verdict for request `id` — a pure function of
    /// `(seed, id)`, so the same workload traces the same requests on
    /// every run.  Returns a fresh nonzero span id when sampled, 0
    /// otherwise.
    pub fn sample_request(&self, id: u64) -> u64 {
        if self.cfg.sample_rate <= 0.0 {
            return 0;
        }
        if self.cfg.sample_rate < 1.0 {
            let x = splitmix64(self.cfg.seed ^ id);
            let u = (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
            if u >= self.cfg.sample_rate {
                return 0;
            }
        }
        self.spans.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Record one event.  Never blocks, never allocates; a full ring
    /// overwrites its oldest slot.
    pub fn record(&self, kind: TraceKind, site: TraceSite, span: u64, a: u64, b: u64) {
        if span == 0 {
            return;
        }
        let idx = self.cursor.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(idx % self.slots.len() as u64) as usize];
        let ts = self.epoch.elapsed().as_micros() as u64;
        slot.ts_us.store(ts, Ordering::Relaxed);
        slot.code.store(
            ((kind as u64) << 8) | site.index() as u64,
            Ordering::Relaxed,
        );
        slot.span.store(span, Ordering::Relaxed);
        slot.a.store(a, Ordering::Relaxed);
        slot.b.store(b, Ordering::Relaxed);
        slot.seq.store(idx + 1, Ordering::Release);
    }

    /// Total events recorded since install (including overwritten ones).
    pub fn recorded(&self) -> u64 {
        self.cursor.load(Ordering::Relaxed)
    }

    /// Events lost to ring overflow (oldest-first overwrite).
    pub fn dropped(&self) -> u64 {
        self.recorded().saturating_sub(self.slots.len() as u64)
    }

    /// Read the surviving events back out, oldest first.  Slots whose
    /// stamp doesn't match an expected live claim index (mid-overwrite
    /// tears, unwritten slots) are skipped, so this is safe concurrent
    /// with writers — but take it after quiescence for a complete trace.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        let end = self.cursor.load(Ordering::Acquire);
        let cap = self.slots.len() as u64;
        let start = end.saturating_sub(cap);
        let mut out = Vec::with_capacity((end - start) as usize);
        for idx in start..end {
            let slot = &self.slots[(idx % cap) as usize];
            if slot.seq.load(Ordering::Acquire) != idx + 1 {
                continue; // torn or already overwritten again
            }
            let code = slot.code.load(Ordering::Relaxed);
            let kind = match code >> 8 {
                0 => TraceKind::Begin,
                1 => TraceKind::End,
                _ => TraceKind::Instant,
            };
            out.push(TraceEvent {
                ts_us: slot.ts_us.load(Ordering::Relaxed),
                kind,
                site: TraceSite::from_index((code & 0xFF) as usize),
                span: slot.span.load(Ordering::Relaxed),
                a: slot.a.load(Ordering::Relaxed),
                b: slot.b.load(Ordering::Relaxed),
            });
        }
        out
    }

    /// The snapshot in Chrome `trace_event` JSON object format:
    /// `{"traceEvents": [...]}`, loadable in `chrome://tracing` and
    /// Perfetto.  Span id = `tid`, so each traced request reads as one
    /// horizontal track with its prepare/execute/shard children nested
    /// inside the request span.
    pub fn chrome_json(&self) -> Json {
        let events = self
            .snapshot()
            .into_iter()
            .map(|e| {
                let mut fields = vec![
                    ("name", s(e.site.name())),
                    ("ph", s(e.kind.ph())),
                    ("pid", num(1.0)),
                    ("tid", num(e.span as f64)),
                    ("ts", num(e.ts_us as f64)),
                    (
                        "args",
                        obj(vec![
                            ("a", num(e.a as f64)),
                            ("b", num(e.b as f64)),
                        ]),
                    ),
                ];
                if e.kind == TraceKind::Instant {
                    fields.push(("s", s("t"))); // thread-scoped instant
                }
                obj(fields)
            })
            .collect();
        obj(vec![
            ("traceEvents", arr(events)),
            ("displayTimeUnit", s("ms")),
            ("otherData", obj(vec![
                ("recorded", num(self.recorded() as f64)),
                ("dropped", num(self.dropped() as f64)),
                ("seed", num(self.cfg.seed as f64)),
                ("sample_rate", num(self.cfg.sample_rate)),
            ])),
        ])
    }
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static TRACER: Mutex<Option<Arc<Tracer>>> = Mutex::new(None);

/// RAII handle for an installed tracer: keeps the [`Tracer`] alive (and
/// readable — it derefs) and disarms the global hook on drop, unless a
/// newer tracer has been installed since (latest install wins).
pub struct TraceGuard {
    tracer: Arc<Tracer>,
}

impl std::ops::Deref for TraceGuard {
    type Target = Tracer;
    fn deref(&self) -> &Tracer {
        &self.tracer
    }
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        let mut slot = lock_unpoisoned(&TRACER);
        if slot.as_ref().is_some_and(|t| Arc::ptr_eq(t, &self.tracer)) {
            *slot = None;
            ACTIVE.store(false, Ordering::SeqCst);
        }
    }
}

/// Arm the process-global tracer.  Hooks flip from one relaxed load to
/// live recording until the returned guard drops.
pub fn install(cfg: TraceConfig) -> TraceGuard {
    let tracer = Arc::new(Tracer::new(cfg));
    let mut slot = lock_unpoisoned(&TRACER);
    *slot = Some(tracer.clone());
    ACTIVE.store(true, Ordering::SeqCst);
    TraceGuard { tracer }
}

/// Whether a tracer is armed — the single relaxed load every disarmed
/// hook costs.
#[inline]
pub fn enabled() -> bool {
    #[cfg(feature = "tracing")]
    {
        ACTIVE.load(Ordering::Relaxed)
    }
    #[cfg(not(feature = "tracing"))]
    {
        false
    }
}

/// The armed tracer, if any.
#[inline]
pub fn active() -> Option<Arc<Tracer>> {
    #[cfg(feature = "tracing")]
    {
        if !ACTIVE.load(Ordering::Relaxed) {
            return None;
        }
        lock_unpoisoned(&TRACER).clone()
    }
    #[cfg(not(feature = "tracing"))]
    {
        None
    }
}

/// Sample request `id` against the armed tracer: a nonzero span id when
/// this request should be traced, 0 when unsampled or disarmed.
#[inline]
pub fn sample_request(id: u64) -> u64 {
    match active() {
        Some(t) => t.sample_request(id),
        None => 0,
    }
}

/// Emit a span-begin event (no-op when disarmed or `span == 0`).
#[inline]
pub fn begin(site: TraceSite, span: u64, a: u64) {
    if span != 0 {
        if let Some(t) = active() {
            t.record(TraceKind::Begin, site, span, a, 0);
        }
    }
}

/// Emit a span-end event (no-op when disarmed or `span == 0`).
#[inline]
pub fn end(site: TraceSite, span: u64) {
    if span != 0 {
        if let Some(t) = active() {
            t.record(TraceKind::End, site, span, 0, 0);
        }
    }
}

/// Emit an instant event (no-op when disarmed or `span == 0`).
#[inline]
pub fn instant(site: TraceSite, span: u64, a: u64, b: u64) {
    if span != 0 {
        if let Some(t) = active() {
            t.record(TraceKind::Instant, site, span, a, b);
        }
    }
}

/// RAII span: begin on construction, end on drop.  Cheap to construct
/// when disarmed (one relaxed load, no allocation).
pub struct Span {
    site: TraceSite,
    span: u64,
}

/// Open an RAII span (no-ops throughout when `span == 0` or disarmed).
#[inline]
pub fn span(site: TraceSite, span_id: u64, a: u64) -> Span {
    begin(site, span_id, a);
    Span { site, span: if enabled() { span_id } else { 0 } }
}

impl Drop for Span {
    fn drop(&mut self) {
        end(self.site, self.span);
    }
}

thread_local! {
    /// The span id of the request this thread is currently working for —
    /// how stages whose signatures can't carry the id (plan preparation,
    /// engine pipeline stages) attribute their events.
    static AMBIENT: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Run `f` with `span` as this thread's ambient span id.
pub fn with_span<R>(span: u64, f: impl FnOnce() -> R) -> R {
    let prev = AMBIENT.with(|c| c.replace(span));
    let r = f();
    AMBIENT.with(|c| c.set(prev));
    r
}

/// This thread's ambient span id (0 outside any [`with_span`]).
#[inline]
pub fn current_span() -> u64 {
    AMBIENT.with(|c| c.get())
}

/// A compact numeric code for a backend, for event payloads (the Chrome
/// export carries numbers only).  Codes are stable and documented in
/// DESIGN.md §15.
pub fn backend_code(b: crate::kernels::Backend) -> u64 {
    use crate::kernels::Backend::*;
    match b {
        Fused3S => 1,
        Hybrid => 2,
        Fused3SNoReorder => 3,
        Fused3SSplitR => 4,
        DfGnnLike => 5,
        UnfusedNaive => 6,
        UnfusedStable => 7,
        Dense => 8,
        CpuCsr => 9,
        Auto => 0,
    }
}

/// Seconds → integer nanoseconds, saturating (event payload encoding for
/// predicted costs).
pub fn ns(seconds: f64) -> u64 {
    if seconds.is_finite() && seconds > 0.0 {
        (seconds * 1e9).min(u64::MAX as f64) as u64
    } else {
        0
    }
}

/// Same mix as the fault layer's sampler (Steele et al.'s SplitMix64):
/// every bit of the seed affects every bit of the output, so nearby
/// request ids decorrelate.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_is_deterministic_and_rate_bounded() {
        let t = Tracer::new(TraceConfig {
            seed: 42,
            sample_rate: 0.25,
            capacity: 16,
        });
        let t2 = Tracer::new(TraceConfig {
            seed: 42,
            sample_rate: 0.25,
            capacity: 16,
        });
        let mut sampled = 0usize;
        for id in 0..4096u64 {
            let a = t.sample_request(id);
            let b = t2.sample_request(id);
            assert_eq!(a != 0, b != 0, "sampling differs for id {id}");
            if a != 0 {
                sampled += 1;
            }
        }
        // 25% ± generous slack; the point is "neither 0 nor 100%".
        assert!((700..=1400).contains(&sampled), "sampled {sampled}/4096");
    }

    #[test]
    fn rate_extremes() {
        let all = Tracer::new(TraceConfig {
            seed: 1,
            sample_rate: 1.0,
            capacity: 4,
        });
        let none = Tracer::new(TraceConfig {
            seed: 1,
            sample_rate: 0.0,
            capacity: 4,
        });
        for id in 0..64 {
            assert_ne!(all.sample_request(id), 0);
            assert_eq!(none.sample_request(id), 0);
        }
    }

    #[test]
    fn span_ids_unique_and_nonzero() {
        let t = Tracer::new(TraceConfig::default());
        let mut seen = std::collections::HashSet::new();
        for id in 0..100 {
            let s = t.sample_request(id);
            assert!(s != 0 && seen.insert(s), "span {s} reused");
        }
    }

    #[test]
    fn ring_overwrites_oldest() {
        let t = Tracer::new(TraceConfig {
            seed: 0,
            sample_rate: 1.0,
            capacity: 8,
        });
        for i in 0..20u64 {
            t.record(TraceKind::Instant, TraceSite::Respond, 7, i, 0);
        }
        assert_eq!(t.recorded(), 20);
        assert_eq!(t.dropped(), 12);
        let evs = t.snapshot();
        assert_eq!(evs.len(), 8);
        // Oldest-first, and only the 8 newest survive.
        let args: Vec<u64> = evs.iter().map(|e| e.a).collect();
        assert_eq!(args, (12..20).collect::<Vec<u64>>());
    }

    #[test]
    fn span_zero_is_never_recorded() {
        let t = Tracer::new(TraceConfig::default());
        t.record(TraceKind::Begin, TraceSite::Prepare, 0, 0, 0);
        assert_eq!(t.recorded(), 0);
        assert!(t.snapshot().is_empty());
    }

    #[test]
    fn chrome_json_shape() {
        let t = Tracer::new(TraceConfig {
            seed: 0,
            sample_rate: 1.0,
            capacity: 16,
        });
        t.record(TraceKind::Begin, TraceSite::Request, 3, 0, 0);
        t.record(TraceKind::Instant, TraceSite::CacheMiss, 3, 99, 0);
        t.record(TraceKind::End, TraceSite::Request, 3, 0, 0);
        let j = t.chrome_json();
        let evs = j
            .req("traceEvents")
            .and_then(|v| v.as_arr())
            .expect("traceEvents array");
        assert_eq!(evs.len(), 3);
        let field = |i: usize, k: &str| -> String {
            evs[i]
                .req(k)
                .and_then(|v| v.as_str())
                .expect("string field")
                .to_string()
        };
        assert_eq!(field(0, "ph"), "B");
        assert_eq!(field(0, "name"), "request");
        assert_eq!(field(1, "ph"), "i");
        assert_eq!(field(1, "s"), "t");
        assert_eq!(field(2, "ph"), "E");
        let tid = evs[0]
            .req("tid")
            .and_then(|v| v.as_f64())
            .expect("tid number");
        assert_eq!(tid, 3.0);
    }

    #[test]
    fn site_roundtrip_and_names_distinct() {
        let mut names = std::collections::HashSet::new();
        for i in 0..22 {
            let site = TraceSite::from_index(i);
            assert_eq!(site.index(), i, "index roundtrip for {site:?}");
            assert!(names.insert(site.name()), "duplicate name {}", site.name());
        }
    }

    // The install/disarm global-hook test lives with the differential
    // suite (rust/tests/tracing_differential.rs), which verify.sh runs
    // serialized — the hook is process-global.
}
