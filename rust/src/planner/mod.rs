//! The adaptive backend planner — structure-aware kernel selection.
//!
//! The paper's headline observation (and HC-SpMM's, see PAPERS.md) is that
//! **the best 3S strategy varies per graph**: fused BSB wins on scale-free
//! sparsity, denser regular inputs favour other layouts, tiny graphs are
//! dominated by launch overhead, and mega-hub rows force the chunked
//! partial-softmax path.  Until this subsystem existed the coordinator ran
//! whatever [`Backend`] the client guessed; now a request may carry
//! [`Backend::Auto`] and the stack chooses:
//!
//! 1. **Profile** — [`GraphProfile::from_csr`] condenses the graph into the
//!    features the choice depends on (density, TCB/RW histogram + CV, hub
//!    skew, oversize-chunk count) *without* building a BSB;
//! 2. **Score** — [`CostModel::predict_s`] prices each candidate backend
//!    with a two-constant affine model over structure-derived cost cells,
//!    with structural infeasibility (unfused × oversize rows, dense × large
//!    n) built in;
//! 3. **Decide** — [`Planner::decide`] picks the cheapest feasible backend
//!    (deterministic tie-break in [`COST_FAMILIES`] order) and reports the
//!    full scoreboard in the returned [`Decision`];
//! 4. **Refine** — the coordinator measures every auto-planned batch it
//!    executes and feeds the latency back via [`Planner::observe`], so the
//!    calibration converges from the factory (paper-device) constants to
//!    the substrate actually running; the tuned table persists across
//!    restarts via [`Planner::save`] / [`CostModel::load`].
//!
//! Resolution happens **before** coalescing and caching: the coordinator
//! rewrites `Backend::Auto` to the decided backend at admission, so
//! auto-resolved requests coalesce with explicitly-routed ones and share
//! [`DriverCache`](crate::coordinator::DriverCache) entries under the
//! *resolved* key.  Standalone callers get the same seam through
//! [`Backend::plan`](crate::kernels::Backend::plan), which resolves `Auto`
//! with the factory model over the candidates its manifest can actually
//! dispatch (no dense fallback without compiled dense executables).  See
//! DESIGN.md §5 for the decision flow with a worked example per backend.

pub mod cost;
pub mod profile;

use std::path::Path;
use std::sync::Mutex;

use anyhow::Result;

use crate::graph::CsrGraph;
use crate::kernels::Backend;
use crate::util::sync::lock_unpoisoned;

pub use cost::{
    cells, effective_cells, family, sharded_cells, Calibration, CostModel,
    COST_FAMILIES, HALO_CELLS_PER_ROW, REF_D,
};
pub use profile::{GraphProfile, DEFAULT_BUCKETS, DEFAULT_CHUNK_T};

/// One candidate's line on the scoreboard of a [`Decision`].
#[derive(Clone, Copy, Debug)]
pub struct Score {
    pub backend: Backend,
    /// Cost cells the backend would execute; `None` = structurally
    /// infeasible for this graph (never selected).
    pub cells: Option<f64>,
    /// Predicted latency (`None` iff infeasible).
    pub predicted_s: Option<f64>,
}

/// The planner's verdict for one graph.
#[derive(Clone, Debug)]
pub struct Decision {
    /// The chosen concrete backend (never [`Backend::Auto`]).
    pub backend: Backend,
    /// Predicted latency of the chosen backend.
    pub predicted_s: f64,
    /// Cost cells of the chosen backend (what [`Planner::observe`] expects
    /// back alongside the measured latency).
    pub cells: f64,
    /// Whether the chosen (fused) backend will route oversize row windows
    /// through the chunked partial-softmax path — the "fused chunked"
    /// execution shape for mega-hub graphs.
    pub chunked: bool,
    /// Every candidate's score, in candidate order (for logs/experiments).
    pub scores: Vec<Score>,
}

/// The planner's verdict for a graph that must run sharded (see
/// [`Planner::resolve_sharded`]): which backend every shard runs, how many
/// shards, and the halo replication the TCB-balanced partition costs.
#[derive(Clone, Copy, Debug)]
pub struct ShardDecision {
    /// Concrete per-shard backend (never [`Backend::Auto`] or dense).
    pub backend: Backend,
    /// Shard count (≥ the minimum forced by the node cap, ≤ the RW count).
    pub shards: usize,
    /// Predicted sharded latency ([`CostModel::predict_sharded_s`]).
    pub predicted_s: f64,
    /// Replicated K/V rows ÷ n of the scored partition.
    pub halo_fraction: f64,
}

/// Thread-safe wrapper holding the candidate set and the (mutable,
/// online-refined) [`CostModel`].  The coordinator owns one behind an
/// `Arc`; standalone resolution uses [`resolve`] / [`resolve_offline`].
pub struct Planner {
    candidates: Vec<Backend>,
    model: Mutex<CostModel>,
}

impl Planner {
    /// A planner over every cost family (PJRT-backed serving, where the
    /// dense fallback's compiled executables are available).
    pub fn new(model: CostModel) -> Planner {
        Planner::with_candidates(model, COST_FAMILIES.to_vec())
    }

    /// A planner for artifact-free execution ([`ExecutorKind::HostEmulation`],
    /// benches, tests): the dense fallback has no offline host emulation,
    /// so it is not a candidate.  The hybrid-geometry backend IS one here —
    /// it executes through the host lane kernels — whereas the PJRT set
    /// ([`Planner::new`]) excludes it until lane artifacts exist.
    ///
    /// [`ExecutorKind::HostEmulation`]: crate::coordinator::ExecutorKind
    pub fn offline(model: CostModel) -> Planner {
        Planner::with_candidates(
            model,
            vec![
                Backend::Fused3S,
                Backend::Hybrid,
                Backend::UnfusedStable,
                Backend::CpuCsr,
            ],
        )
    }

    /// A planner restricted to an explicit candidate set (candidates are
    /// scored in the given order; earlier wins ties).
    pub fn with_candidates(model: CostModel, candidates: Vec<Backend>) -> Planner {
        assert!(!candidates.is_empty(), "planner needs at least one candidate");
        Planner { candidates, model: Mutex::new(model) }
    }

    /// Profile `g` and decide its backend.
    pub fn resolve(&self, g: &CsrGraph) -> Decision {
        self.decide(&GraphProfile::from_csr(g))
    }

    /// [`Planner::resolve`] over the candidates *not* in `exclude` — the
    /// degradation ladder's re-resolution step: after a backend is
    /// quarantined for a graph, the coordinator re-plans over what
    /// remains (DESIGN.md §11).  `None` when exclusion empties the
    /// candidate set (the ladder then surfaces its last structured
    /// error, or falls back to the originally requested backend for
    /// fresh requests).
    pub fn resolve_excluding(
        &self,
        g: &CsrGraph,
        exclude: &[Backend],
    ) -> Option<Decision> {
        let remaining: Vec<Backend> = self
            .candidates
            .iter()
            .copied()
            .filter(|b| !exclude.contains(b))
            .collect();
        if remaining.is_empty() {
            return None;
        }
        Some(Planner::with_candidates(self.snapshot(), remaining).resolve(g))
    }

    /// Decide the backend for an already-extracted profile.
    ///
    /// If every candidate is structurally infeasible (possible only with a
    /// restricted [`Planner::with_candidates`] set — the default sets
    /// always contain an always-feasible backend), the *first* candidate
    /// is returned as a last resort and preparation surfaces the
    /// structural error.
    pub fn decide(&self, p: &GraphProfile) -> Decision {
        let model = lock_unpoisoned(&self.model);
        let scores: Vec<Score> = self
            .candidates
            .iter()
            .map(|&b| Score {
                backend: b,
                cells: cost::cells(b, p),
                predicted_s: model.predict_s(b, p),
            })
            .collect();
        drop(model);
        let best = scores
            .iter()
            .filter(|s| s.predicted_s.is_some())
            // `Ordering::Equal` on NaN keeps the decision total (and the
            // batcher thread alive) even if a pathological calibration
            // slipped through; ties favour earlier candidates.
            .min_by(|a, b| {
                a.predicted_s
                    .partial_cmp(&b.predicted_s)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .copied()
            .unwrap_or(Score {
                backend: self.candidates[0],
                cells: None,
                predicted_s: None,
            });
        Decision {
            backend: best.backend,
            predicted_s: best.predicted_s.unwrap_or(0.0),
            cells: best.cells.unwrap_or(0.0),
            chunked: matches!(
                family(best.backend),
                Backend::Fused3S | Backend::Hybrid
            ) && p.oversize_rws > 0,
            scores,
        }
    }

    /// Decide which backend a graph that must be **sharded** should run:
    /// score every candidate's sharded prediction
    /// ([`CostModel::predict_sharded_s`] — per-shard fixed overhead +
    /// compute + halo-gather cells over the TCB-balanced partition's
    /// measured [`halo_fraction`](crate::bsb::stats::halo_fraction)) at
    /// exactly the shard count the node cap forces (`ceil(n / cap)`,
    /// clamped to the row-window count) — the count the executor will
    /// actually run, so the backend comparison is priced on the partition
    /// that executes, never on a hypothetical one.  The dense fallback
    /// never shards; if every candidate is infeasible the first shardable
    /// candidate is returned as the last resort, exactly like
    /// [`Planner::decide`].
    ///
    /// The graph scans (profile, partition, halo count) all run *before*
    /// the cost-model lock is taken: oversize graphs are the largest ones
    /// served, and the executor's [`Planner::observe`] must not block on
    /// a mega-graph scan.
    pub fn resolve_sharded(
        &self,
        g: &CsrGraph,
        max_plan_nodes: usize,
    ) -> ShardDecision {
        use crate::shard::partition::{balanced_by_work, rw_tcb_counts};
        let p = GraphProfile::from_csr(g);
        let num_rw = g.n.div_ceil(crate::bsb::RW).max(1);
        let forced = g.n.div_ceil(max_plan_nodes.max(1)).clamp(1, num_rw);
        // One per-RW TCB scan feeds the partitioner directly (the same
        // counts a `partition()` call would recompute).
        let part = balanced_by_work(&rw_tcb_counts(g), forced);
        let halo = crate::bsb::stats::halo_fraction(g, &part.row_ranges(g.n));
        let model = lock_unpoisoned(&self.model);
        let mut best: Option<ShardDecision> = None;
        for &b in &self.candidates {
            let Some(sec) = model.predict_sharded_s(b, &p, part.shards(), halo)
            else {
                continue;
            };
            if best.as_ref().map_or(true, |d| sec < d.predicted_s) {
                best = Some(ShardDecision {
                    backend: b,
                    shards: part.shards(),
                    predicted_s: sec,
                    halo_fraction: halo,
                });
            }
        }
        drop(model);
        best.unwrap_or(ShardDecision {
            backend: *self
                .candidates
                .iter()
                .find(|&&b| family(b) != Backend::Dense)
                .unwrap_or(&self.candidates[0]),
            shards: part.shards(),
            predicted_s: 0.0,
            halo_fraction: halo,
        })
    }

    /// Fold one measured latency for an executed plan back into the model
    /// (the online refinement loop; see [`CostModel::observe`]).
    pub fn observe(&self, backend: Backend, cells: f64, measured_s: f64) {
        lock_unpoisoned(&self.model).observe(backend, cells, measured_s);
    }

    /// A snapshot of the current calibration table.
    pub fn snapshot(&self) -> CostModel {
        lock_unpoisoned(&self.model).clone()
    }

    /// Persist the current calibration table (see [`CostModel::save`]).
    pub fn save(&self, path: &Path) -> Result<()> {
        lock_unpoisoned(&self.model).save(path)
    }
}

/// Resolve a graph with the factory model over every cost family
/// (PJRT-backed callers; [`Backend::resolve_for`] narrows to
/// [`resolve_offline`]'s candidate set when its manifest has no compiled
/// dense executables).
///
/// [`Backend::resolve_for`]: crate::kernels::Backend::resolve_for
pub fn resolve(g: &CsrGraph) -> Decision {
    Planner::new(CostModel::default()).resolve(g)
}

/// Resolve with the factory model over the artifact-free candidate set
/// (what the host-emulation coordinator and the offline benches use).
pub fn resolve_offline(g: &CsrGraph) -> Decision {
    Planner::offline(CostModel::default()).resolve(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{self, clique};

    #[test]
    fn dense_clique_resolves_to_dense() {
        let d = resolve(&clique(200));
        assert_eq!(d.backend, Backend::Dense, "scores: {:?}", d.scores);
        assert!(!d.chunked);
    }

    #[test]
    fn hub_graph_resolves_to_fused_chunked() {
        let d = resolve(&generators::star(5000).with_self_loops());
        assert_eq!(d.backend, Backend::Fused3S, "scores: {:?}", d.scores);
        assert!(d.chunked, "mega-hub must take the chunked path");
        // The unfused candidate must be scored infeasible, not just losing.
        let unfused = d
            .scores
            .iter()
            .find(|s| s.backend == Backend::UnfusedStable)
            .unwrap();
        assert!(unfused.predicted_s.is_none());
    }

    #[test]
    fn tiny_graph_resolves_to_cpu() {
        let d = resolve_offline(&generators::ring(32));
        assert_eq!(d.backend, Backend::CpuCsr, "scores: {:?}", d.scores);
    }

    #[test]
    fn offline_planner_never_picks_dense() {
        let d = resolve_offline(&clique(200));
        assert_ne!(d.backend, Backend::Dense);
    }

    #[test]
    fn decision_never_returns_auto() {
        for g in [
            clique(64),
            generators::erdos_renyi(2048, 6.0, 1),
            generators::star(5000),
            generators::ring(16),
        ] {
            assert_ne!(resolve(&g).backend, Backend::Auto);
            assert_ne!(resolve_offline(&g).backend, Backend::Auto);
        }
    }

    #[test]
    fn resolve_sharded_respects_the_node_cap() {
        let g = generators::erdos_renyi(4096, 6.0, 11).with_self_loops();
        let planner = Planner::offline(CostModel::default());
        let d = planner.resolve_sharded(&g, 1024);
        assert!(d.shards >= 4, "cap 1024 over n=4096 forces >= 4 shards");
        assert_ne!(d.backend, Backend::Auto);
        assert_ne!(d.backend, Backend::Dense);
        assert!(d.predicted_s > 0.0);
        assert!(d.halo_fraction >= 0.0);
        // A mega-hub graph must never pick the (infeasible) unfused family.
        let hub = generators::star(5000).with_self_loops();
        let d = planner.resolve_sharded(&hub, 1000);
        assert_ne!(d.backend, Backend::UnfusedStable, "oversize RW");
    }

    #[test]
    fn refinement_flips_a_decision() {
        // Start from factory constants, then observe that (on this
        // hypothetical substrate) the scalar backend is essentially free:
        // the planner must eventually re-route a tensor-core-leaning graph,
        // whichever tensor-core family the factory model picked.
        let g = generators::erdos_renyi(2048, 6.0, 3).with_self_loops();
        let planner = Planner::offline(CostModel::default());
        let before = planner.resolve(&g);
        assert_ne!(before.backend, Backend::CpuCsr, "scores: {:?}", before.scores);
        let p = GraphProfile::from_csr(&g);
        let cpu_cells = cells(Backend::CpuCsr, &p).unwrap();
        let chosen_cells = cells(before.backend, &p).unwrap();
        for _ in 0..60 {
            planner.observe(Backend::CpuCsr, cpu_cells, 1e-6);
            planner.observe(before.backend, chosen_cells, 50e-3);
        }
        let after = planner.resolve(&g);
        assert_eq!(after.backend, Backend::CpuCsr, "scores: {:?}", after.scores);
    }

    #[test]
    fn hybrid_wins_offline_only_when_packing_pays() {
        // Scattered ER windows: the narrow router halves dispatched cells
        // (scripts/packing_model.py: ~131k vs ~262k cells), far beyond the
        // hybrid row's 15 µs fixed premium — offline auto routes hybrid.
        let d =
            resolve_offline(&generators::erdos_renyi(2048, 6.0, 7).with_self_loops());
        assert_eq!(d.backend, Backend::Hybrid, "scores: {:?}", d.scores);
        // Tiny regular ring: the savings are microscopic next to the fixed
        // premium, so hybrid must lose (to cpu_csr here).
        let d = resolve_offline(&generators::ring(64));
        assert_ne!(d.backend, Backend::Hybrid, "scores: {:?}", d.scores);
        // The PJRT candidate set must not offer hybrid at all (no lane
        // artifacts exist).
        let d = resolve(&generators::erdos_renyi(2048, 6.0, 7).with_self_loops());
        assert!(d.scores.iter().all(|s| s.backend != Backend::Hybrid));
    }
}
