//! Sparsity feature extraction — the planner's view of a graph.
//!
//! A [`GraphProfile`] condenses the structure the backends care about into
//! a handful of numbers: density, the row-window TCB distribution (mean,
//! CV, bucket histogram), hub skew, and how much of the dispatched work
//! would be bucket/chunk padding.  It can be computed two ways:
//!
//! * [`GraphProfile::from_csr`] — directly from the CSR adjacency, **before
//!   any BSB is built**.  This is the serving path: the coordinator must
//!   resolve [`Backend::Auto`](crate::kernels::Backend::Auto) *before*
//!   coalescing and before the preprocessing cache is consulted, so the
//!   profile cannot depend on the (possibly skipped) BSB build.  Per row
//!   window it counts the distinct columns across the window's 16 rows —
//!   exactly the column set BSB compaction keeps — so the estimated TCB
//!   counts **equal** the post-build `Bsb::tcbs_per_rw` values (pinned by a
//!   test below).
//! * [`GraphProfile::from_bsb`] — from an already-built [`Bsb`] via
//!   [`bsb::stats`](crate::bsb::stats), for callers that plan from cached
//!   preprocessing ([`Plan::from_bsb`](crate::kernels::Plan::from_bsb)).
//!
//! Extraction is O(nnz log deg) and allocation-light; on the serving path
//! it costs far less than the BSB build it steers.

use crate::bsb::geometry::{self, RouteParams, WindowShape};
use crate::bsb::stats::{compaction_stats, nnz_per_rw};
use crate::bsb::{Bsb, RW};
use crate::graph::CsrGraph;
use crate::util::stats as ustats;
use crate::TCB_C;

/// The bucket ladder the profile (and the default cost model) assume —
/// matches the offline manifest and the compiled AOT suite.
pub const DEFAULT_BUCKETS: &[usize] = &[4, 8, 16, 32, 64, 128];

/// Chunk capacity assumed for oversize row windows (the largest bucket,
/// which is the `chunk_t` every manifest in this repo uses).
pub const DEFAULT_CHUNK_T: usize = 128;

/// Structure features of one graph, as seen by the cost model.
///
/// "TCB" counts here are *post-compaction* tensor-core block counts: for a
/// row window with `c` distinct neighbour columns, `ceil(c / 8)` blocks.
#[derive(Clone, Debug, PartialEq)]
pub struct GraphProfile {
    /// Nodes (rows of the attention mask).
    pub n: usize,
    /// Stored edges (nonzeros).
    pub nnz: usize,
    /// nnz / n² — the dense-fallback viability axis.
    pub density: f64,
    /// Mean out-degree.
    pub avg_degree: f64,
    /// Maximum out-degree (the mega-hub detector).
    pub max_degree: usize,
    /// `max_degree / avg_degree` — hub skew (1 ≈ regular, ≫1 ≈ scale-free).
    pub hub_skew: f64,
    /// Total row windows (`ceil(n / 16)`), including empty ones.
    pub num_rw: usize,
    /// Row windows with at least one edge (the dispatched population).
    pub nonempty_rw: usize,
    /// Total post-compaction TCBs across all row windows.
    pub total_tcbs: usize,
    /// Mean TCBs per non-empty row window.
    pub tcb_per_rw_mean: f64,
    /// Coefficient of variation of TCBs/RW — the paper's Table-6
    /// irregularity axis (low = ER-like, high = power-law).
    pub tcb_per_rw_cv: f64,
    /// Coefficient of variation of nnz/RW (row-window *load* variance,
    /// which differs from the TCB variance when compaction density varies).
    pub nnz_per_rw_cv: f64,
    /// Row-window occupancy histogram: for each bucket capacity in
    /// `buckets`, how many row windows route to it.
    pub bucket_hist: Vec<(usize, usize)>,
    /// Row windows whose TCB count exceeds the largest bucket (these run
    /// through the chunked partial-softmax path under the fused backend and
    /// make the unfused baseline infeasible — its OOM analog).
    pub oversize_rws: usize,
    /// Total chunk dispatches the oversize row windows need at
    /// [`DEFAULT_CHUNK_T`].
    pub oversize_chunks: usize,
    /// Dispatched TCB *slots* for a fused-family run: every bucketed row
    /// window padded up to its bucket capacity, plus every chunk padded to
    /// the chunk capacity.  This — not `total_tcbs` — is what the fused
    /// kernels actually execute.
    pub dispatched_tcb_slots: usize,
    /// Dispatched *cells* (scalar MMA slots) for a hybrid-geometry run
    /// under the default router: wide TCBs at 128 cells, narrow tiles at
    /// 8, dense lanes at 16 (see [`crate::bsb::geometry`]).  Batch-free
    /// (structural only), so the CSR-side estimate equals the built plan's
    /// `PlanStats::structural_cells()` exactly.
    pub hybrid_dispatched_cells: usize,
    /// Structural padding cells within `hybrid_dispatched_cells`.
    pub hybrid_padded_cells: usize,
    /// Row windows the hybrid router sends to the narrow 8×1-tile path.
    pub narrow_rws: usize,
    /// Row windows the hybrid router sends to the dense 16×1-lane path.
    pub dense_rws: usize,
}

impl GraphProfile {
    /// Profile a CSR graph against [`DEFAULT_BUCKETS`] /
    /// [`DEFAULT_CHUNK_T`].
    pub fn from_csr(g: &CsrGraph) -> GraphProfile {
        GraphProfile::from_csr_with(g, DEFAULT_BUCKETS, DEFAULT_CHUNK_T)
    }

    /// Profile a CSR graph against an explicit bucket ladder.
    pub fn from_csr_with(
        g: &CsrGraph,
        buckets: &[usize],
        chunk_t: usize,
    ) -> GraphProfile {
        let num_rw = g.n.div_ceil(RW);
        let mut tcbs: Vec<usize> = Vec::with_capacity(num_rw);
        let mut nnz_rw: Vec<f64> = Vec::with_capacity(num_rw);
        let mut cols: Vec<u32> = Vec::new();
        for w in 0..num_rw {
            let lo = w * RW;
            let hi = ((w + 1) * RW).min(g.n);
            cols.clear();
            let mut z = 0usize;
            for r in lo..hi {
                let row = g.row(r);
                z += row.len();
                cols.extend_from_slice(row);
            }
            cols.sort_unstable();
            cols.dedup();
            tcbs.push(cols.len().div_ceil(TCB_C));
            if z > 0 {
                nnz_rw.push(z as f64);
            }
        }
        let shapes = geometry::window_shapes_from_csr(g);
        GraphProfile::from_parts(g.n, g.nnz(), &tcbs, &nnz_rw, buckets, chunk_t)
            .with_hybrid(&shapes, buckets, chunk_t)
            .with_degrees(g)
    }

    /// Profile from an already-built BSB (cached-preprocessing callers).
    /// Identical to [`GraphProfile::from_csr`] on the same graph.
    pub fn from_bsb(bsb: &Bsb) -> GraphProfile {
        GraphProfile::from_bsb_with(bsb, DEFAULT_BUCKETS, DEFAULT_CHUNK_T)
    }

    /// [`GraphProfile::from_bsb`] with an explicit bucket ladder.
    pub fn from_bsb_with(
        bsb: &Bsb,
        buckets: &[usize],
        chunk_t: usize,
    ) -> GraphProfile {
        let s = compaction_stats(bsb);
        let tcbs: Vec<usize> =
            bsb.tcbs_per_rw().iter().map(|&t| t as usize).collect();
        let nnz_rw: Vec<f64> = nnz_per_rw(bsb)
            .into_iter()
            .filter(|&z| z > 0)
            .map(|z| z as f64)
            .collect();
        let shapes = geometry::window_shapes_from_bsb(bsb);
        let mut p =
            GraphProfile::from_parts(s.nodes, s.edges, &tcbs, &nnz_rw, buckets, chunk_t)
                .with_hybrid(&shapes, buckets, chunk_t);
        // Degree features are not recoverable from a BSB (compaction merged
        // the per-row structure); approximate the hub detector with the
        // widest row window.
        let max_rw_nnz =
            nnz_rw.iter().cloned().fold(0.0f64, f64::max) as usize;
        p.max_degree = max_rw_nnz.div_ceil(RW.min(s.nodes.max(1)));
        p.hub_skew = if p.avg_degree > 0.0 {
            p.max_degree as f64 / p.avg_degree
        } else {
            1.0
        };
        p
    }

    fn from_parts(
        n: usize,
        nnz: usize,
        tcbs_per_rw: &[usize],
        nnz_rw: &[f64],
        buckets: &[usize],
        chunk_t: usize,
    ) -> GraphProfile {
        assert!(!buckets.is_empty(), "bucket ladder must be non-empty");
        let max_bucket = *buckets.last().expect("non-empty ladder");
        let mut hist = vec![0usize; buckets.len()];
        let (mut oversize_rws, mut oversize_chunks) = (0usize, 0usize);
        let mut slots = 0usize;
        let mut nonempty = Vec::with_capacity(tcbs_per_rw.len());
        for &t in tcbs_per_rw {
            if t == 0 {
                continue;
            }
            nonempty.push(t as f64);
            if t > max_bucket {
                oversize_rws += 1;
                let chunks = t.div_ceil(chunk_t);
                oversize_chunks += chunks;
                slots += chunks * chunk_t;
            } else {
                let bi = buckets
                    .iter()
                    .position(|&b| b >= t)
                    .expect("t <= max_bucket");
                hist[bi] += 1;
                slots += buckets[bi];
            }
        }
        let total_tcbs: usize = tcbs_per_rw.iter().sum();
        let avg_degree = if n == 0 { 0.0 } else { nnz as f64 / n as f64 };
        // Degree features need the CSR view: from_csr fills them via
        // with_degrees, from_bsb approximates from window loads.
        GraphProfile {
            n,
            nnz,
            density: if n == 0 { 0.0 } else { nnz as f64 / (n as f64 * n as f64) },
            avg_degree,
            max_degree: 0,
            hub_skew: 1.0,
            num_rw: tcbs_per_rw.len(),
            nonempty_rw: nonempty.len(),
            total_tcbs,
            tcb_per_rw_mean: ustats::mean(&nonempty),
            tcb_per_rw_cv: ustats::cv(&nonempty),
            nnz_per_rw_cv: ustats::cv(nnz_rw),
            bucket_hist: buckets.iter().copied().zip(hist).collect(),
            oversize_rws,
            oversize_chunks,
            dispatched_tcb_slots: slots,
            hybrid_dispatched_cells: 0,
            hybrid_padded_cells: 0,
            narrow_rws: 0,
            dense_rws: 0,
        }
    }

    /// Fill the hybrid-geometry cell estimate from window shapes (CSR- or
    /// BSB-derived — identical either way; see
    /// [`geometry::hybrid_cells`]).
    fn with_hybrid(
        mut self,
        shapes: &[WindowShape],
        buckets: &[usize],
        chunk_t: usize,
    ) -> GraphProfile {
        let hc = geometry::hybrid_cells(
            shapes,
            buckets,
            chunk_t,
            &RouteParams::default(),
        );
        self.hybrid_dispatched_cells = hc.structural_cells;
        self.hybrid_padded_cells = hc.padded_cells;
        self.narrow_rws = hc.narrow_rws;
        self.dense_rws = hc.dense_rws;
        self
    }
}

impl GraphProfile {
    /// Fill the degree-derived features from the CSR view (called by
    /// `from_csr*`; split out so `from_parts` stays format-agnostic).
    fn with_degrees(mut self, g: &CsrGraph) -> GraphProfile {
        self.max_degree = g.max_degree();
        self.hub_skew = if self.avg_degree > 0.0 {
            self.max_degree as f64 / self.avg_degree
        } else {
            1.0
        };
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsb::build;
    use crate::graph::generators;

    fn profile(g: &CsrGraph) -> GraphProfile {
        GraphProfile::from_csr(g)
    }

    #[test]
    fn csr_estimate_equals_bsb_exact() {
        // The from_csr distinct-column estimate must equal the post-build
        // TCB counts: compaction keeps exactly the distinct columns.
        for g in [
            generators::erdos_renyi(2048, 6.0, 1).with_self_loops(),
            generators::barabasi_albert(2048, 4, 2).with_self_loops(),
            generators::star(3000).with_self_loops(),
            generators::ring(64),
        ] {
            let p = profile(&g);
            let bsb = build(&g);
            assert_eq!(p.total_tcbs, bsb.total_tcbs(), "n={}", g.n);
            let b = GraphProfile::from_bsb(&bsb);
            assert_eq!(p.total_tcbs, b.total_tcbs);
            assert_eq!(p.bucket_hist, b.bucket_hist);
            assert_eq!(p.oversize_rws, b.oversize_rws);
            assert_eq!(p.dispatched_tcb_slots, b.dispatched_tcb_slots);
            assert_eq!(p.hybrid_dispatched_cells, b.hybrid_dispatched_cells);
            assert_eq!(p.hybrid_padded_cells, b.hybrid_padded_cells);
            assert_eq!(p.narrow_rws, b.narrow_rws);
            assert_eq!(p.dense_rws, b.dense_rws);
        }
    }

    #[test]
    fn hybrid_estimate_equals_built_plan() {
        // The profile's hybrid cell estimate must equal what plan_hybrid
        // actually accounts — the profile↔plan half of the DESIGN.md §12
        // pinning contract (the geometry module pins the shape half).
        use crate::bsb::geometry::plan_hybrid;
        use crate::bsb::reorder::Order;
        for g in [
            generators::erdos_renyi(1024, 6.0, 4).with_self_loops(),
            generators::star(4000).with_self_loops(),
            generators::power_law(1500, 7.0, 2.4, 8),
        ] {
            let p = profile(&g);
            let bsb = build(&g);
            let plan = plan_hybrid(
                &bsb,
                DEFAULT_BUCKETS,
                8,
                Order::ByTcbDesc,
                DEFAULT_CHUNK_T,
            );
            assert_eq!(p.hybrid_dispatched_cells, plan.stats.structural_cells());
            assert_eq!(p.narrow_rws, plan.stats.narrow_windows);
            assert_eq!(p.dense_rws, plan.stats.dense_windows);
        }
    }

    #[test]
    fn hub_graph_has_oversize_and_skew() {
        let g = generators::star(5000).with_self_loops();
        let p = profile(&g);
        assert!(p.oversize_rws >= 1, "hub RW must overflow the ladder");
        assert!(p.oversize_chunks >= 2);
        assert!(p.hub_skew > 100.0, "skew {}", p.hub_skew);
        let r = profile(&generators::ring(4096));
        assert_eq!(r.oversize_rws, 0);
        assert!(r.hub_skew < 1.5);
        assert!(r.tcb_per_rw_cv < p.tcb_per_rw_cv);
    }

    #[test]
    fn histogram_counts_every_nonempty_rw() {
        let g = generators::erdos_renyi(4096, 8.0, 3).with_self_loops();
        let p = profile(&g);
        let in_buckets: usize = p.bucket_hist.iter().map(|&(_, c)| c).sum();
        assert_eq!(in_buckets + p.oversize_rws, p.nonempty_rw);
        assert!(p.dispatched_tcb_slots >= p.total_tcbs);
    }

    #[test]
    fn empty_and_tiny_graphs() {
        let g = CsrGraph::from_edges(0, &[]).unwrap();
        let p = profile(&g);
        assert_eq!(p.num_rw, 0);
        assert_eq!(p.total_tcbs, 0);
        let g = CsrGraph::from_edges(40, &[(3, 7)]).unwrap();
        let p = profile(&g);
        assert_eq!(p.nonempty_rw, 1);
        assert_eq!(p.total_tcbs, 1);
    }
}
