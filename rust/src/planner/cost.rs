//! The calibratable per-backend cost model.
//!
//! Each backend's predicted latency is a two-constant affine model over
//! the graph's *cost cells* — the number of 16×8-block cell operations
//! (or their scalar/dense equivalents) the backend would execute:
//!
//! ```text
//! predicted_s(backend, profile) = fixed_s(backend)
//!                               + sec_per_cell(backend) × cells(backend, profile)
//! ```
//!
//! `cells` is pure structure (computed from a [`GraphProfile`], see
//! [`cells`]); the two constants are **calibration state**: they default to
//! the paper's device regime (tensor-core fused ≫ scalar CPU, Figure 5)
//! and are refined online from measured latencies
//! ([`CostModel::observe`] — the coordinator feeds each auto-planned
//! batch's measured execute time back in) so the model converges to
//! whatever substrate is actually running, e.g. the offline host
//! emulation.  The tuned table round-trips through
//! [`util::json`](crate::util::json) ([`CostModel::to_json`] /
//! [`CostModel::from_json`]) so a serving process can persist and reload
//! its calibration.
//!
//! Infeasibility is part of the model: the unfused baseline refuses
//! oversize row windows (its OOM analog) and the dense fallback caps at
//! the largest compiled dense bucket, so [`cells`] returns `None` for
//! those combinations and the planner never selects them.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::kernels::dense::DENSE_N;
use crate::kernels::Backend;
use crate::util::json::{self, Json};
use crate::{TCB_C, TCB_R};

use super::profile::GraphProfile;

/// The backends the cost model tracks in the PJRT serving path — one
/// calibration row each.  Every concrete [`Backend`] maps onto a family
/// via [`family`] (the fused ablation variants share the fused row, the
/// two unfused softmaxes share the unfused row).  [`Backend::Hybrid`] is
/// its own family with its own calibration row but is deliberately NOT in
/// this array: it has no PJRT artifacts, so only host-capable candidate
/// sets ([`Planner::offline`](super::Planner::offline),
/// [`Plan::from_bsb`](crate::kernels::Plan::from_bsb)) consider it.
pub const COST_FAMILIES: [Backend; 4] =
    [Backend::Fused3S, Backend::UnfusedStable, Backend::Dense, Backend::CpuCsr];

/// The reference feature dim the calibration constants are expressed at.
/// [`cells`] is pure *structure* (single-head, d-free): per-graph ranking
/// is unaffected by heads/d because every backend's work scales with them
/// by (to first order) the same factor.  Measured latencies are NOT
/// d-free, so observations must be normalised to the reference shape via
/// [`effective_cells`] before they reach [`CostModel::observe`] — else a
/// heads = 8, d = 128 batch would inflate its backend's learned rate
/// ~32× relative to one at the reference shape and mixed-shape traffic
/// would corrupt the table.
pub const REF_D: usize = 32;

/// Scale structure [`cells`] to an executed workload's shape: `heads`
/// head passes, each with work ∝ `d / REF_D`.  Pair the result with the
/// measured latency when calling [`CostModel::observe`].
pub fn effective_cells(cells: f64, heads: usize, d: usize) -> f64 {
    cells * heads.max(1) as f64 * d.max(1) as f64 / REF_D as f64
}

/// Map a concrete backend onto its cost family (see [`COST_FAMILIES`]).
/// [`Backend::Auto`] has no family — it is what the model resolves.
pub fn family(b: Backend) -> Backend {
    match b {
        Backend::Fused3S
        | Backend::Fused3SNoReorder
        | Backend::Fused3SSplitR
        | Backend::DfGnnLike => Backend::Fused3S,
        Backend::UnfusedNaive | Backend::UnfusedStable => Backend::UnfusedStable,
        Backend::Hybrid => Backend::Hybrid,
        Backend::Dense => Backend::Dense,
        Backend::CpuCsr => Backend::CpuCsr,
        Backend::Auto => Backend::Auto,
    }
}

/// Cost cells a backend executes for a graph, or `None` when the backend
/// is structurally infeasible for it:
///
/// * fused — dispatched TCB slots (bucket + chunk padding included) × 128
///   cells each, plus a per-chunk merge surcharge for the partial-softmax
///   combine;
/// * unfused — the same dispatched cells (the 3 passes live in its
///   calibration constant); infeasible when any row window overflows the
///   bucket ladder (the [`UnfusedError::Oversize`] OOM analog);
/// * dense — `n_pad²` cells at the smallest compiled dense size ≥ n;
///   infeasible above the largest;
/// * cpu_csr — one cell per stored edge (scalar gather–scatter).
///
/// [`UnfusedError::Oversize`]: crate::kernels::unfused::UnfusedError
pub fn cells(backend: Backend, p: &GraphProfile) -> Option<f64> {
    const CELLS_PER_TCB: f64 = (TCB_R * TCB_C) as f64;
    // Host-side merge cost of one oversize chunk, in cell equivalents
    // (the m/l rescale + output fold over a 16-row window).
    const CHUNK_MERGE_CELLS: f64 = 2.0 * CELLS_PER_TCB;
    match family(backend) {
        Backend::Fused3S => Some(
            p.dispatched_tcb_slots as f64 * CELLS_PER_TCB
                + p.oversize_chunks as f64 * CHUNK_MERGE_CELLS,
        ),
        Backend::UnfusedStable => (p.oversize_rws == 0)
            .then(|| p.dispatched_tcb_slots as f64 * CELLS_PER_TCB),
        // Hybrid: the router's structural cell count (wide TCBs at 128
        // cells, narrow tiles at 8, dense lanes at 16 — batch-slot padding
        // lives in the calibration constant like the other families'),
        // plus the same oversize-chunk merge surcharge as fused — chunked
        // row windows always stay on the wide path.
        Backend::Hybrid => Some(
            p.hybrid_dispatched_cells as f64
                + p.oversize_chunks as f64 * CHUNK_MERGE_CELLS,
        ),
        Backend::Dense => DENSE_N
            .iter()
            .find(|&&c| c >= p.n)
            .map(|&n_pad| (n_pad * n_pad) as f64),
        Backend::CpuCsr => Some(p.nnz as f64),
        Backend::Auto => None,
    }
}

/// Cell-equivalent cost of gathering one replicated halo K/V row at the
/// reference feature dim (a row copy of q/k/v ≈ a fraction of one 128-cell
/// TCB's tensor-core work; the constant is deliberately coarse — the
/// calibrated `sec_per_cell` absorbs the substrate).
pub const HALO_CELLS_PER_ROW: f64 = TCB_C as f64;

/// Cost cells of a **sharded** run of `backend` over a profiled graph
/// whose partition replicates `halo_fraction` (replicated K/V rows ÷ n,
/// see [`bsb::stats::halo_fraction`](crate::bsb::stats::halo_fraction)):
/// the unsharded compute cells — row partitioning never changes the
/// dispatched TCB population, only who dispatches it — plus the halo
/// gather surcharge.  `None` when the backend is structurally infeasible
/// ([`cells`]) or cannot shard at all (the dense fallback's padded softmax
/// is whole-graph by construction).
pub fn sharded_cells(
    backend: Backend,
    p: &GraphProfile,
    halo_fraction: f64,
) -> Option<f64> {
    // Dense's padded softmax is whole-graph by construction; the hybrid
    // plan's lane sets index global row windows and are not
    // shard-decomposable either (see `shard::exec::shardable`).
    if matches!(family(backend), Backend::Dense | Backend::Hybrid) {
        return None;
    }
    let base = cells(backend, p)?;
    Some(base + halo_fraction * p.n as f64 * HALO_CELLS_PER_ROW)
}

/// One backend's calibration row.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Calibration {
    /// Per-call overhead: dispatch setup, pipeline fill/drain, launch.
    pub fixed_s: f64,
    /// Marginal seconds per cost cell.
    pub sec_per_cell: f64,
    /// Observations folded in so far (0 = factory default).
    pub samples: u64,
}

/// The per-backend calibration table + the EMA smoothing factor.
#[derive(Clone, Debug, PartialEq)]
pub struct CostModel {
    /// EMA weight of a new observation (0 < alpha ≤ 1).
    pub alpha: f64,
    rows: BTreeMap<&'static str, Calibration>,
}

impl Default for CostModel {
    /// Factory defaults encode the paper's *device* regime (Fig. 5): the
    /// fused tensor-core kernel is the cheapest per cell, the unfused
    /// baseline pays ~3 passes and materialised intermediates, the dense
    /// fallback is cheap per cell but executes n², and the scalar CPU
    /// baseline is ~50× the fused per-cell cost.  Fixed costs make the
    /// tiny-graph regime favour the launch-light scalar path.
    fn default() -> CostModel {
        let mut rows = BTreeMap::new();
        let row = |f, s| Calibration { fixed_s: f, sec_per_cell: s, samples: 0 };
        rows.insert(Backend::Fused3S.name(), row(30e-6, 1.0e-9));
        // Hybrid shares fused's per-cell rate (same tensor-core substrate)
        // but pays extra fixed cost: routing, two extra call families and
        // their pipeline fills.  It therefore wins only when the router
        // removes enough padded cells to cover the 15 µs premium — i.e.
        // exactly when the packing improvement is real.
        rows.insert(Backend::Hybrid.name(), row(45e-6, 1.0e-9));
        rows.insert(Backend::UnfusedStable.name(), row(50e-6, 3.5e-9));
        rows.insert(Backend::Dense.name(), row(20e-6, 0.7e-9));
        rows.insert(Backend::CpuCsr.name(), row(2e-6, 50e-9));
        CostModel { alpha: 0.25, rows }
    }
}

impl CostModel {
    /// The calibration row for a backend's cost family.
    pub fn calibration(&self, backend: Backend) -> Calibration {
        self.rows
            .get(family(backend).name())
            .copied()
            .unwrap_or(Calibration { fixed_s: 0.0, sec_per_cell: 1e-9, samples: 0 })
    }

    /// Predicted latency of `backend` on a profiled graph (`None` when the
    /// backend is infeasible for it).
    pub fn predict_s(&self, backend: Backend, p: &GraphProfile) -> Option<f64> {
        let c = cells(backend, p)?;
        let cal = self.calibration(backend);
        Some(cal.fixed_s + cal.sec_per_cell * c)
    }

    /// Predicted latency of a sharded run: every shard pays the backend's
    /// fixed (dispatch/pipeline-fill) cost, and the marginal rate covers
    /// the compute cells plus the halo-gather surcharge
    /// ([`sharded_cells`]).  `None` when the backend is infeasible or
    /// unshardable.  The per-shard fixed term is what makes one-shard
    /// execution win whenever the graph fits a single plan's working set —
    /// the sharded candidate only prices ahead when it must (or when halo
    /// replication is cheap relative to the imbalance it removes).
    pub fn predict_sharded_s(
        &self,
        backend: Backend,
        p: &GraphProfile,
        shards: usize,
        halo_fraction: f64,
    ) -> Option<f64> {
        let c = sharded_cells(backend, p, halo_fraction)?;
        let cal = self.calibration(backend);
        Some(cal.fixed_s * shards.max(1) as f64 + cal.sec_per_cell * c)
    }

    /// Fold one measured latency into the backend's calibration row: the
    /// marginal rate moves by an exponential moving average towards
    /// `(measured − fixed) / cells`.  Measurements below the fixed cost
    /// clamp the implied rate at a small positive floor instead of going
    /// negative.
    pub fn observe(&mut self, backend: Backend, cells: f64, measured_s: f64) {
        if !(cells > 0.0) || !measured_s.is_finite() || measured_s <= 0.0 {
            return;
        }
        let key = family(backend).name();
        let alpha = self.alpha;
        let row = self.rows.entry(key).or_insert(Calibration {
            fixed_s: 0.0,
            sec_per_cell: measured_s / cells,
            samples: 0,
        });
        let implied = ((measured_s - row.fixed_s) / cells).max(1e-12);
        row.sec_per_cell = (1.0 - alpha) * row.sec_per_cell + alpha * implied;
        row.samples += 1;
    }

    /// Serialise the calibration table (stable key order, versioned).
    pub fn to_json(&self) -> Json {
        let backends = Json::Obj(
            self.rows
                .iter()
                .map(|(name, c)| {
                    (
                        name.to_string(),
                        json::obj(vec![
                            ("fixed_s", json::num(c.fixed_s)),
                            ("sec_per_cell", json::num(c.sec_per_cell)),
                            ("samples", json::num(c.samples as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        json::obj(vec![
            ("version", json::num(1.0)),
            ("alpha", json::num(self.alpha)),
            ("backends", backends),
        ])
    }

    /// Rebuild a model from [`CostModel::to_json`] output.  Unknown backend
    /// names are ignored (forward compatibility); missing ones keep their
    /// factory defaults.  Non-finite or non-positive constants are
    /// rejected outright — a corrupt calibration file must fail the load
    /// (callers degrade to factory defaults), never poison the decision
    /// path with NaN predictions.
    pub fn from_json(v: &Json) -> Result<CostModel> {
        let mut model = CostModel::default();
        model.alpha = v.req("alpha")?.as_f64()?.clamp(0.0, 1.0);
        let Json::Obj(backends) = v.req("backends")? else {
            anyhow::bail!("'backends' must be an object");
        };
        for (name, row) in backends {
            let Ok(backend) = Backend::parse(name) else {
                continue; // calibration for a backend this build doesn't know
            };
            let fixed_s = row.req("fixed_s")?.as_f64()?;
            let sec_per_cell = row.req("sec_per_cell")?.as_f64()?;
            if !fixed_s.is_finite()
                || !sec_per_cell.is_finite()
                || fixed_s < 0.0
                || sec_per_cell <= 0.0
            {
                anyhow::bail!(
                    "calibration for '{name}' is not finite/positive \
                     (fixed_s={fixed_s}, sec_per_cell={sec_per_cell})"
                );
            }
            let cal = Calibration {
                fixed_s,
                sec_per_cell,
                samples: row.req("samples")?.as_f64()? as u64,
            };
            model.rows.insert(family(backend).name(), cal);
        }
        Ok(model)
    }

    /// Persist the calibration table to `path` as JSON.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, json::to_string(&self.to_json()))
            .with_context(|| format!("writing calibration to {}", path.display()))
    }

    /// Load a calibration table persisted by [`CostModel::save`].
    pub fn load(path: &Path) -> Result<CostModel> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading calibration from {}", path.display()))?;
        CostModel::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn profile(g: &crate::graph::CsrGraph) -> GraphProfile {
        GraphProfile::from_csr(g)
    }

    #[test]
    fn infeasibility_gates() {
        // Hub graph: oversize RW -> unfused infeasible, fused fine.
        let hub = profile(&generators::star(5000).with_self_loops());
        assert!(cells(Backend::UnfusedStable, &hub).is_none());
        assert!(cells(Backend::Fused3S, &hub).is_some());
        // Large graph: dense infeasible above the biggest compiled size.
        assert!(cells(Backend::Dense, &hub).is_none());
        let small = profile(&generators::ring(200));
        assert_eq!(cells(Backend::Dense, &small), Some(256.0 * 256.0));
    }

    #[test]
    fn hybrid_prices_packing_savings_not_hype() {
        let m = CostModel::default();
        // Hub-dominated star: the router cuts dispatched cells roughly in
        // half (scripts/packing_model.py), far more than the 15 µs fixed
        // premium — hybrid must price ahead of fused.
        let hub = profile(&generators::star(5000));
        let (ch, cf) = (
            cells(Backend::Hybrid, &hub).unwrap(),
            cells(Backend::Fused3S, &hub).unwrap(),
        );
        assert!(ch < cf, "hybrid cells {ch} !< fused {cf}");
        assert!(
            m.predict_s(Backend::Hybrid, &hub).unwrap()
                < m.predict_s(Backend::Fused3S, &hub).unwrap()
        );
        // Tiny regular ring: the cell savings are worth well under the
        // fixed-cost premium, so fused stays cheaper — the planner only
        // picks hybrid when the packing win is real.
        let ring = profile(&generators::ring(64));
        assert!(
            m.predict_s(Backend::Hybrid, &ring).unwrap()
                > m.predict_s(Backend::Fused3S, &ring).unwrap()
        );
        // Hybrid is never a sharding candidate.
        assert!(m.predict_sharded_s(Backend::Hybrid, &hub, 2, 0.1).is_none());
    }

    #[test]
    fn families_share_calibration() {
        let m = CostModel::default();
        assert_eq!(m.calibration(Backend::DfGnnLike), m.calibration(Backend::Fused3S));
        assert_eq!(
            m.calibration(Backend::UnfusedNaive),
            m.calibration(Backend::UnfusedStable)
        );
        let p = profile(&generators::erdos_renyi(1024, 4.0, 1));
        assert_eq!(cells(Backend::Fused3SSplitR, &p), cells(Backend::Fused3S, &p));
    }

    #[test]
    fn sharded_candidate_prices_overhead_and_halo() {
        let m = CostModel::default();
        let p = profile(&generators::erdos_renyi(4096, 6.0, 4).with_self_loops());
        let one = m.predict_sharded_s(Backend::Fused3S, &p, 1, 0.0).unwrap();
        let plain = m.predict_s(Backend::Fused3S, &p).unwrap();
        assert!((one - plain).abs() < 1e-12, "1 shard, no halo == unsharded");
        // More shards -> more fixed cost; more halo -> more cells.
        let four = m.predict_sharded_s(Backend::Fused3S, &p, 4, 0.0).unwrap();
        assert!(four > one);
        let halo = m.predict_sharded_s(Backend::Fused3S, &p, 4, 0.5).unwrap();
        assert!(halo > four);
        // Dense cannot shard; infeasible backends stay infeasible.
        assert!(m.predict_sharded_s(Backend::Dense, &p, 2, 0.1).is_none());
        let hub = profile(&generators::star(5000).with_self_loops());
        assert!(m.predict_sharded_s(Backend::UnfusedStable, &hub, 2, 0.1).is_none());
    }

    #[test]
    fn observe_converges_to_measured_rate() {
        let mut m = CostModel::default();
        let p = profile(&generators::erdos_renyi(2048, 6.0, 2));
        let c = cells(Backend::Fused3S, &p).unwrap();
        let measured = 5e-3; // pretend the substrate is much slower
        for _ in 0..50 {
            m.observe(Backend::Fused3S, c, measured);
        }
        let predicted = m.predict_s(Backend::Fused3S, &p).unwrap();
        assert!(
            (predicted - measured).abs() / measured < 0.05,
            "predicted {predicted} vs measured {measured}"
        );
        assert_eq!(m.calibration(Backend::Fused3S).samples, 50);
    }

    #[test]
    fn effective_cells_scales_by_shape() {
        // Identity at the reference shape; linear in heads and d.
        assert_eq!(effective_cells(1000.0, 1, REF_D), 1000.0);
        assert_eq!(effective_cells(1000.0, 4, REF_D), 4000.0);
        assert_eq!(effective_cells(1000.0, 1, 2 * REF_D), 2000.0);
        // Degenerate shapes clamp instead of zeroing the sample.
        assert!(effective_cells(1000.0, 0, 0) > 0.0);
    }

    #[test]
    fn observe_rejects_degenerate_samples() {
        let mut m = CostModel::default();
        let before = m.calibration(Backend::CpuCsr);
        m.observe(Backend::CpuCsr, 0.0, 1.0);
        m.observe(Backend::CpuCsr, 100.0, f64::NAN);
        m.observe(Backend::CpuCsr, 100.0, -1.0);
        assert_eq!(m.calibration(Backend::CpuCsr), before);
        // A measurement under the fixed cost clamps, never goes negative.
        m.observe(Backend::CpuCsr, 1e9, 1e-9);
        assert!(m.calibration(Backend::CpuCsr).sec_per_cell > 0.0);
    }

    #[test]
    fn from_json_rejects_degenerate_calibration() {
        for bad in [
            // negative rate
            r#"{"alpha":0.25,"backends":{"fused3s":
                {"fixed_s":0.0,"sec_per_cell":-1.0,"samples":1}}}"#,
            // overflow to +inf
            r#"{"alpha":0.25,"backends":{"fused3s":
                {"fixed_s":1e999,"sec_per_cell":1e-9,"samples":1}}}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(CostModel::from_json(&v).is_err(), "{bad}");
        }
    }

    #[test]
    fn json_round_trip() {
        let mut m = CostModel::default();
        m.observe(Backend::Fused3S, 1e6, 3e-3);
        m.observe(Backend::CpuCsr, 1e5, 9e-3);
        let j = m.to_json();
        let back = CostModel::from_json(&Json::parse(&json::to_string(&j)).unwrap())
            .unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn save_load_round_trip() {
        let mut m = CostModel::default();
        m.observe(Backend::UnfusedStable, 2e5, 4e-3);
        let dir = std::env::temp_dir().join("f3s_planner_cost_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("calibration.json");
        m.save(&path).unwrap();
        let back = CostModel::load(&path).unwrap();
        assert_eq!(m, back);
        std::fs::remove_file(&path).ok();
    }
}
