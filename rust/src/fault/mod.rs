//! Seeded, deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] describes *where* (the [`FaultSite`] seams: plan
//! preparation, the engine's gather/dispatch/scatter stages, batcher
//! admission), *what* (panic, structured [`AttnError`], delay) and *how
//! often* (per-site rates) faults fire.  Installing a plan arms a
//! process-global hook; the instrumented seams call [`fire`] /
//! [`fire_unit`], which roll a deterministic PRNG keyed by
//! `(seed, site, per-site visit counter)` — so a given plan injects the
//! same fault *count* per site across runs regardless of thread
//! interleaving, and every injected fault is appended to the plan's log
//! for post-hoc reconciliation against `Metrics.faults`.
//!
//! Cost when disarmed: one relaxed atomic load per seam.  With the
//! `fault-injection` cargo feature disabled (`--no-default-features`) the
//! hooks compile to nothing at all — `benches/fault_overhead.rs` pins the
//! armed-but-zero-rate and disarmed costs.
//!
//! The global hook is for *test processes* (the chaos suite installs it
//! around a coordinator run); library unit tests exercise
//! [`FaultPlan::roll`] purely, without installing.  See DESIGN.md §11 for
//! the failure model this layer exercises.

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::kernels::AttnError;
use crate::util::sync::lock_unpoisoned;

/// The instrumented seams a fault can fire at.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// Plan construction (`cached_plan`, and through it every per-shard
    /// plan built by `ShardedPlan::build`).
    Prepare,
    /// The engine pipeline's gather stage (runs on a scoped worker).
    Gather,
    /// The engine pipeline's dispatch stage (runs on the calling thread).
    Dispatch,
    /// The engine pipeline's scatter stage (runs on a scoped worker).
    Scatter,
    /// Batcher admission (the coordinator's single coalescing thread).
    Batch,
}

pub const FAULT_SITES: [FaultSite; 5] = [
    FaultSite::Prepare,
    FaultSite::Gather,
    FaultSite::Dispatch,
    FaultSite::Scatter,
    FaultSite::Batch,
];

impl FaultSite {
    fn index(self) -> usize {
        match self {
            FaultSite::Prepare => 0,
            FaultSite::Gather => 1,
            FaultSite::Dispatch => 2,
            FaultSite::Scatter => 3,
            FaultSite::Batch => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultSite::Prepare => "prepare",
            FaultSite::Gather => "gather",
            FaultSite::Dispatch => "dispatch",
            FaultSite::Scatter => "scatter",
            FaultSite::Batch => "batch",
        }
    }
}

/// What an injected fault does at its seam.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaultKind {
    /// `panic!` — exercises `catch_unwind` isolation and lock-poison
    /// recovery.
    Panic,
    /// Return a structured [`AttnError`] — exercises the retry/fallback
    /// ladder.  Only injectable at seams whose signature carries a
    /// `Result` ([`fire`]); unit seams ([`fire_unit`]) never roll it.
    Error,
    /// Sleep for the plan's delay — exercises deadline shedding and the
    /// pipeline's drain paths without failing anything.
    Delay,
}

/// One (site, kind, rate) injection rule.
#[derive(Clone, Copy, Debug)]
pub struct FaultSpec {
    pub site: FaultSite,
    pub kind: FaultKind,
    /// Probability in `[0, 1]` that a visit to `site` fires this kind.
    /// Rules for the same site stack; their rates must sum to ≤ 1.
    pub rate: f64,
}

/// One fault that actually fired (the reconciliation log entry).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct InjectedFault {
    pub site: FaultSite,
    pub kind: FaultKind,
    /// The site's visit counter at injection time.
    pub seq: u64,
}

/// A deterministic injection schedule: build with [`FaultPlan::new`] +
/// [`FaultPlan::with`] (or [`FaultPlan::uniform`]), arm with [`install`].
pub struct FaultPlan {
    seed: u64,
    specs: Vec<FaultSpec>,
    delay: Duration,
    /// Remaining injection budget; `i64::MAX` = unbounded.  A bounded
    /// budget makes single-shot failure scenarios exactly reproducible
    /// ("fail the first two prepares, then heal").
    budget: AtomicI64,
    /// Per-site visit counters — the deterministic roll input.
    seq: [AtomicU64; 5],
    log: Mutex<Vec<InjectedFault>>,
}

impl FaultPlan {
    /// An empty plan (no rules — nothing ever fires).
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            specs: Vec::new(),
            delay: Duration::from_millis(1),
            budget: AtomicI64::new(i64::MAX),
            seq: Default::default(),
            log: Mutex::new(Vec::new()),
        }
    }

    /// Add one injection rule.
    pub fn with(mut self, site: FaultSite, kind: FaultKind, rate: f64) -> FaultPlan {
        assert!((0.0..=1.0).contains(&rate), "rate must be in [0, 1]");
        self.specs.push(FaultSpec { site, kind, rate });
        self
    }

    /// How long a [`FaultKind::Delay`] injection sleeps (default 1 ms).
    pub fn with_delay(mut self, delay: Duration) -> FaultPlan {
        self.delay = delay;
        self
    }

    /// Cap the total number of injections across all sites (the
    /// "fail exactly N times, then heal" schedule).
    pub fn with_budget(mut self, budget: u64) -> FaultPlan {
        self.budget = AtomicI64::new(budget.min(i64::MAX as u64) as i64);
        self
    }

    /// The chaos-grid plan: every site faults with total probability
    /// `rate` per visit, split evenly over the kinds that site supports
    /// (unit seams — gather/scatter — cannot inject `Error`).
    pub fn uniform(seed: u64, rate: f64) -> FaultPlan {
        let mut plan = FaultPlan::new(seed);
        for site in FAULT_SITES {
            let kinds: &[FaultKind] = match site {
                FaultSite::Gather | FaultSite::Scatter => {
                    &[FaultKind::Panic, FaultKind::Delay]
                }
                _ => &[FaultKind::Panic, FaultKind::Error, FaultKind::Delay],
            };
            for &kind in kinds {
                plan = plan.with(site, kind, rate / kinds.len() as f64);
            }
        }
        plan
    }

    /// Deterministically decide whether this visit to `site` faults.
    /// Advances the site's visit counter; `allow_error` excludes
    /// [`FaultKind::Error`] rules (unit seams).  A hit is logged and
    /// consumes budget.
    pub fn roll(&self, site: FaultSite, allow_error: bool) -> Option<FaultKind> {
        let idx = site.index();
        let seq = self.seq[idx].fetch_add(1, Ordering::Relaxed);
        let x = splitmix64(self.seed ^ ((idx as u64 + 1) << 56) ^ seq);
        let u = (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let mut cum = 0.0;
        for s in &self.specs {
            if s.site != site
                || (s.kind == FaultKind::Error && !allow_error)
            {
                continue;
            }
            cum += s.rate;
            if u < cum {
                if !self.consume_budget() {
                    return None;
                }
                lock_unpoisoned(&self.log).push(InjectedFault {
                    site,
                    kind: s.kind,
                    seq,
                });
                return Some(s.kind);
            }
        }
        None
    }

    fn consume_budget(&self) -> bool {
        let prev = self.budget.fetch_sub(1, Ordering::Relaxed);
        if prev <= 0 {
            self.budget.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        true
    }

    /// The delay a [`FaultKind::Delay`] injection sleeps.
    pub fn delay(&self) -> Duration {
        self.delay
    }

    /// Every fault injected so far (reconciliation input).
    pub fn log(&self) -> Vec<InjectedFault> {
        lock_unpoisoned(&self.log).clone()
    }

    /// Injected faults of `kind` (any site).
    pub fn injected_of_kind(&self, kind: FaultKind) -> usize {
        lock_unpoisoned(&self.log).iter().filter(|f| f.kind == kind).count()
    }

    /// Injected faults at `site` (any kind).
    pub fn injected_at(&self, site: FaultSite) -> usize {
        lock_unpoisoned(&self.log).iter().filter(|f| f.site == site).count()
    }
}

/// Convert a `catch_unwind` payload into a readable message (panics carry
/// `&str` or `String`; anything else gets a placeholder).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<Arc<FaultPlan>>> = Mutex::new(None);

/// RAII handle for an installed [`FaultPlan`]: dropping it disarms the
/// global hook (if this plan is still the installed one) while keeping the
/// plan — and its injection log — readable through [`FaultGuard::plan`].
pub struct FaultGuard {
    plan: Arc<FaultPlan>,
}

impl FaultGuard {
    pub fn plan(&self) -> &Arc<FaultPlan> {
        &self.plan
    }
}

impl std::ops::Deref for FaultGuard {
    type Target = FaultPlan;
    fn deref(&self) -> &FaultPlan {
        &self.plan
    }
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        let mut slot = lock_unpoisoned(&PLAN);
        if slot.as_ref().is_some_and(|p| Arc::ptr_eq(p, &self.plan)) {
            ACTIVE.store(false, Ordering::SeqCst);
            *slot = None;
        }
    }
}

/// Arm `plan` process-wide.  Replaces any previously installed plan (whose
/// guard then becomes inert).  Intended for dedicated test processes (the
/// chaos suite); never called on production paths.
pub fn install(plan: FaultPlan) -> FaultGuard {
    let plan = Arc::new(plan);
    let mut slot = lock_unpoisoned(&PLAN);
    *slot = Some(plan.clone());
    ACTIVE.store(true, Ordering::SeqCst);
    drop(slot);
    FaultGuard { plan }
}

#[cfg(feature = "fault-injection")]
fn active_plan() -> Option<Arc<FaultPlan>> {
    if !ACTIVE.load(Ordering::Relaxed) {
        return None;
    }
    lock_unpoisoned(&PLAN).clone()
}

/// Injection hook for seams returning `Result<_, AttnError>`: may panic,
/// sleep, or return a site-appropriate structured error.  A no-op (one
/// relaxed atomic load) when no plan is armed; compiled out entirely
/// without the `fault-injection` feature.
#[inline]
pub fn fire(site: FaultSite) -> Result<(), AttnError> {
    #[cfg(feature = "fault-injection")]
    {
        if let Some(plan) = active_plan() {
            match plan.roll(site, true) {
                Some(FaultKind::Panic) => {
                    panic!("fault-injection: seeded panic at {}", site.name())
                }
                Some(FaultKind::Delay) => std::thread::sleep(plan.delay()),
                Some(FaultKind::Error) => {
                    let msg = format!(
                        "fault-injection: seeded {} failure",
                        site.name()
                    );
                    return Err(match site {
                        FaultSite::Prepare => AttnError::Prepare(msg),
                        _ => AttnError::Execute(msg),
                    });
                }
                None => {}
            }
        }
    }
    let _ = site;
    Ok(())
}

/// Injection hook for unit-returning seams (the engine's gather/scatter
/// closures): may panic or sleep, never errors.
#[inline]
pub fn fire_unit(site: FaultSite) {
    #[cfg(feature = "fault-injection")]
    {
        if let Some(plan) = active_plan() {
            match plan.roll(site, false) {
                Some(FaultKind::Panic) => {
                    panic!("fault-injection: seeded panic at {}", site.name())
                }
                Some(FaultKind::Delay) => std::thread::sleep(plan.delay()),
                _ => {}
            }
        }
    }
    let _ = site;
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests exercise `FaultPlan` values directly — nothing installs
    // the global hook, so they are safe under the parallel test harness.

    #[test]
    fn empty_plan_never_fires() {
        let plan = FaultPlan::new(1);
        for _ in 0..1000 {
            assert_eq!(plan.roll(FaultSite::Dispatch, true), None);
        }
        assert!(plan.log().is_empty());
    }

    #[test]
    fn rolls_are_deterministic_per_seed() {
        let outcomes = |seed: u64| -> Vec<Option<FaultKind>> {
            let plan = FaultPlan::uniform(seed, 0.25);
            (0..200).map(|_| plan.roll(FaultSite::Prepare, true)).collect()
        };
        assert_eq!(outcomes(42), outcomes(42));
        assert_ne!(outcomes(42), outcomes(43), "seeds must differ");
    }

    #[test]
    fn rate_is_roughly_honoured_and_logged() {
        let plan = FaultPlan::uniform(7, 0.25);
        let n = 4000;
        let mut hits = 0;
        for _ in 0..n {
            if plan.roll(FaultSite::Dispatch, true).is_some() {
                hits += 1;
            }
        }
        let rate = hits as f64 / n as f64;
        assert!((0.18..0.32).contains(&rate), "rate {rate}");
        assert_eq!(plan.log().len(), hits);
        assert_eq!(plan.injected_at(FaultSite::Dispatch), hits);
    }

    #[test]
    fn unit_seams_never_roll_error() {
        let plan = FaultPlan::new(3).with(
            FaultSite::Gather,
            FaultKind::Error,
            1.0,
        );
        for _ in 0..100 {
            assert_eq!(plan.roll(FaultSite::Gather, false), None);
        }
        // The same rule *is* reachable when errors are allowed.
        let plan = FaultPlan::new(3).with(
            FaultSite::Gather,
            FaultKind::Error,
            1.0,
        );
        assert_eq!(plan.roll(FaultSite::Gather, true), Some(FaultKind::Error));
    }

    #[test]
    fn budget_caps_total_injections() {
        let plan = FaultPlan::new(9)
            .with(FaultSite::Prepare, FaultKind::Error, 1.0)
            .with_budget(2);
        let hits: usize = (0..50)
            .filter(|_| plan.roll(FaultSite::Prepare, true).is_some())
            .count();
        assert_eq!(hits, 2);
        assert_eq!(plan.log().len(), 2);
    }

    #[test]
    fn guard_install_and_disarm() {
        // Serialized with nothing: this is the only lib test touching the
        // global hook, and it never leaves it armed.
        let guard = install(
            FaultPlan::new(5).with(FaultSite::Batch, FaultKind::Error, 1.0),
        );
        let plan = guard.plan().clone();
        assert!(fire(FaultSite::Batch).is_err());
        drop(guard);
        assert!(fire(FaultSite::Batch).is_ok(), "disarmed after drop");
        assert_eq!(plan.injected_of_kind(FaultKind::Error), 1);
    }

    #[test]
    fn panic_message_extracts_strs_and_strings() {
        let p: Box<dyn std::any::Any + Send> = Box::new("static msg");
        assert_eq!(panic_message(p.as_ref()), "static msg");
        let p: Box<dyn std::any::Any + Send> = Box::new(String::from("owned"));
        assert_eq!(panic_message(p.as_ref()), "owned");
        let p: Box<dyn std::any::Any + Send> = Box::new(17u32);
        assert_eq!(panic_message(p.as_ref()), "non-string panic payload");
    }
}

/// SplitMix64 — the same mixer `util::prng` seeds with; replicated here so
/// the roll path has no state beyond the per-site counters.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}
