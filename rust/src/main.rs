//! `repro` — the Fused3S reproduction CLI.
//!
//! One subcommand per paper table/figure plus serving/inference utilities:
//!
//! ```text
//! repro table3 [--dataset NAME]
//! repro table6 [--batched]
//! repro table7 [--datasets a,b,c]
//! repro fig5   [--datasets a,b,c] [--d 64] [--quick] [--backends x,y]
//! repro fig6   [--datasets a,b,c] [--d 64] [--quick]
//! repro fig7   [--datasets a,b]   [--sms 56]
//! repro fig8   [--datasets a,b]   [--dims 64,128,256] [--blocks 10] [--quick]
//! repro ablate-split|ablate-reorder|ablate-compaction|ablate-buckets
//! repro stability
//! repro plan   [--datasets a,b,c]   # adaptive-planner decision audit
//! repro shard  [--datasets a,b,c] [--shards 2,4,8]  # sharding audit
//! repro datasets            # list the calibrated suite
//! repro infer  --dataset X --d 64 --blocks 10 [--backend fused3s|auto]
//! repro serve  --requests 64 [--workers 2]   # serving-loop demo
//! ```
//!
//! Results print as aligned tables and are mirrored to `results/*.json`.

use anyhow::{bail, Result};

use fused3s::experiments::{
    ablations, fig5, fig7, fig8, planner, report, shard, stability, table3,
    table6, table7,
};
use fused3s::graph::datasets::{self, Dataset};
use fused3s::kernels::Backend;
use fused3s::runtime::Runtime;
use fused3s::util::cli::Args;
use fused3s::util::timing::BenchConfig;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_list(args: &Args, key: &str, default: &[&str]) -> Vec<String> {
    args.get(key)
        .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
        .unwrap_or_else(|| default.iter().map(|s| s.to_string()).collect())
}

fn bench_config(args: &Args) -> BenchConfig {
    if args.bool("quick") {
        BenchConfig::quick()
    } else {
        BenchConfig::default()
    }
}

fn select_datasets(names: &[String], batched: bool) -> Result<Vec<Dataset>> {
    if names.len() == 1 && names[0] == "all" {
        Ok(if batched {
            datasets::suite_batched()
        } else {
            datasets::suite_single()
        })
    } else {
        names.iter().map(|n| datasets::by_name(n)).collect()
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        print_usage();
        return Ok(());
    };

    match cmd {
        "datasets" => {
            println!("single-graph suite (Table 6 analogs):");
            for d in datasets::suite_single() {
                println!(
                    "  {:<22} ~ {:<16} n={:<8} nnz={}",
                    d.name,
                    d.paper_name,
                    d.graph.n,
                    d.graph.nnz()
                );
            }
            println!("batched suites (Fig. 6 analogs):");
            for d in datasets::suite_batched() {
                println!(
                    "  {:<22} ~ {:<16} n={:<8} nnz={}",
                    d.name,
                    d.paper_name,
                    d.graph.n,
                    d.graph.nnz()
                );
            }
        }
        "table3" => {
            let j = table3::run(args.get("dataset"))?;
            let p = report::write_json("table3", &j)?;
            println!("\nwrote {}", p.display());
        }
        "table6" => {
            let j = table6::run(args.bool("batched"))?;
            let p = report::write_json("table6", &j)?;
            println!("\nwrote {}", p.display());
        }
        "table7" => {
            let names = parse_list(&args, "datasets", table7::DEFAULT_DATASETS);
            let j = table7::run(&names)?;
            let p = report::write_json("table7", &j)?;
            println!("\nwrote {}", p.display());
        }
        "fig5" | "fig6" => {
            let batched = cmd == "fig6";
            let names = parse_list(&args, "datasets", &["all"]);
            let suite = select_datasets(&names, batched)?;
            let d = args.usize_or("d", 64)?;
            let backends = match args.get("backends") {
                Some(list) => list
                    .split(',')
                    .map(Backend::parse)
                    .collect::<Result<Vec<_>>>()?,
                None => Backend::kernel_series(),
            };
            let rt = Runtime::from_default_artifacts()?;
            let j = fig5::run(&rt, &suite, &backends, d, &bench_config(&args), cmd)?;
            let p = report::write_json(cmd, &j)?;
            println!("\nwrote {}", p.display());
        }
        "fig7" => {
            let names = parse_list(&args, "datasets", fig7::DEFAULT_DATASETS);
            let sms = args.usize_or("sms", 56)?;
            let j = fig7::run(&names, sms)?;
            let p = report::write_json("fig7", &j)?;
            println!("\nwrote {}", p.display());
        }
        "fig8" => {
            let names = parse_list(
                &args,
                "datasets",
                &["cora-sim", "pubmed-sim", "github-sim", "molhiv-sim"],
            );
            let suite: Vec<Dataset> =
                names.iter().map(|n| datasets::by_name(n)).collect::<Result<_>>()?;
            let dims: Vec<usize> = parse_list(&args, "dims", &["64", "128", "256"])
                .iter()
                .map(|s| s.parse().map_err(|_| anyhow::anyhow!("bad dim {s}")))
                .collect::<Result<_>>()?;
            let blocks = args.usize_or("blocks", 10)?;
            let rt = Runtime::from_default_artifacts()?;
            let j = fig8::run(
                &rt,
                &suite,
                &dims,
                &fig8::series(),
                blocks,
                &bench_config(&args),
            )?;
            let p = report::write_json("fig8", &j)?;
            println!("\nwrote {}", p.display());
        }
        "ablate-split" => {
            let names = parse_list(&args, "datasets", &["pubmed-sim", "github-sim"]);
            let rt = Runtime::from_default_artifacts()?;
            let j = ablations::split(&rt, &names, args.usize_or("d", 64)?, &bench_config(&args))?;
            report::write_json("ablate_split", &j)?;
        }
        "ablate-reorder" => {
            let names = parse_list(&args, "datasets", &["reddit-sim", "github-sim", "pubmed-sim"]);
            let rt = Runtime::from_default_artifacts()?;
            let j = ablations::reorder(&rt, &names, args.usize_or("d", 64)?, &bench_config(&args))?;
            report::write_json("ablate_reorder", &j)?;
        }
        "ablate-compaction" => {
            let names = parse_list(&args, "datasets", &["pubmed-sim", "github-sim"]);
            let rt = Runtime::from_default_artifacts()?;
            let j = ablations::compaction(&rt, &names, args.usize_or("d", 64)?, &bench_config(&args))?;
            report::write_json("ablate_compaction", &j)?;
        }
        "ablate-buckets" => {
            let names = parse_list(&args, "datasets", &["pubmed-sim", "github-sim", "reddit-sim"]);
            let j = ablations::buckets(&names)?;
            report::write_json("ablate_buckets", &j)?;
        }
        "stability" => {
            let rt = Runtime::from_default_artifacts()?;
            let j = stability::run(&rt)?;
            report::write_json("stability", &j)?;
        }
        "plan" => {
            let names = parse_list(
                &args,
                "datasets",
                &["cora-sim", "pubmed-sim", "github-sim", "reddit-sim", "molhiv-sim"],
            );
            let j = planner::run(&names)?;
            let p = report::write_json("plan", &j)?;
            println!("\nwrote {}", p.display());
        }
        "shard" => {
            let names = parse_list(
                &args,
                "datasets",
                &["pubmed-sim", "github-sim", "reddit-sim"],
            );
            let counts: Vec<usize> = parse_list(&args, "shards", &["2", "4", "8"])
                .iter()
                .map(|c| c.parse().map_err(|_| anyhow::anyhow!("bad shard count {c}")))
                .collect::<Result<_>>()?;
            let j = shard::run(&names, &counts)?;
            let p = report::write_json("shard", &j)?;
            println!("\nwrote {}", p.display());
        }
        "infer" => {
            infer(&args)?;
        }
        "serve" => {
            serve(&args)?;
        }
        other => {
            print_usage();
            bail!("unknown subcommand '{other}'");
        }
    }
    Ok(())
}

fn infer(args: &Args) -> Result<()> {
    use fused3s::model::weights::random_features;
    use fused3s::model::{GraphTransformer, GtConfig};
    let name = args.get_or("dataset", "cora-sim");
    let ds = datasets::by_name(&name)?;
    let cfg = GtConfig {
        d: args.usize_or("d", 64)?,
        n_blocks: args.usize_or("blocks", 10)?,
        backend: Backend::parse(&args.get_or("backend", "fused3s"))?,
        seed: args.u64_or("seed", 0x5EED)?,
    };
    let rt = Runtime::from_default_artifacts()?;
    println!(
        "GT inference: {} (n={}, nnz={}), d={}, {} blocks, backend={}",
        ds.name,
        ds.graph.n,
        ds.graph.nnz(),
        cfg.d,
        cfg.n_blocks,
        cfg.backend.name()
    );
    let model = GraphTransformer::prepare(&rt, &ds.graph, cfg)?;
    let h = random_features(1, ds.graph.n, cfg.d);
    let (_, warm) = model.infer(&rt, &h)?;
    println!("warmup (incl. executable compiles): {:.1} ms", warm.total_s * 1e3);
    let (out, t) = model.infer(&rt, &h)?;
    println!(
        "inference: {:.1} ms total, {:.1} ms attention ({:.0}%), {:.1} ms dense",
        t.total_s * 1e3,
        t.attention_s * 1e3,
        t.attention_fraction() * 100.0,
        t.dense_s * 1e3
    );
    let norm: f32 = out.iter().map(|x| x * x).sum::<f32>().sqrt();
    println!("output: {} values, L2 norm {norm:.2}", out.len());
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    use fused3s::coordinator::{AttnRequest, Coordinator, CoordinatorConfig};
    use fused3s::util::prng::Rng;
    use std::sync::mpsc::channel;

    let requests = args.usize_or("requests", 32)?;
    let workers = args.usize_or("workers", 2)?;
    let d = args.usize_or("d", 64)?;
    let coord = Coordinator::start(CoordinatorConfig {
        preprocess_workers: workers,
        ..CoordinatorConfig::default()
    })?;
    println!("coordinator up ({workers} preprocess workers); submitting {requests} requests");
    let mut rng = Rng::new(0x5E12);
    let (tx, rx) = channel();
    for i in 0..requests {
        let n = rng.range(64, 1024);
        let deg = 2.0 + rng.f64() * 8.0;
        let g = fused3s::graph::generators::erdos_renyi(n, deg, i as u64)
            .with_self_loops();
        let nd = g.n * d;
        coord.submit(AttnRequest::single_head(
            i as u64,
            g,
            d,
            rng.normal_vec(nd, 1.0),
            rng.normal_vec(nd, 1.0),
            rng.normal_vec(nd, 1.0),
            1.0 / (d as f32).sqrt(),
            Backend::Fused3S,
            tx.clone(),
        ))?;
    }
    drop(tx);
    let mut ok = 0;
    while let Ok(resp) = rx.recv() {
        if resp.result.is_ok() {
            ok += 1;
        }
    }
    println!("{ok}/{requests} succeeded");
    println!("{}", coord.metrics().report());
    let prep = coord.metrics().preprocess.snapshot();
    let exec = coord.metrics().execute.snapshot();
    println!(
        "preprocess p50={:.2}ms  execute p50={:.2}ms",
        prep.p50_s * 1e3,
        exec.p50_s * 1e3
    );
    coord.shutdown();
    Ok(())
}

fn print_usage() {
    println!(
        "repro — Fused3S reproduction harness\n\
         subcommands:\n  \
         datasets | table3 | table6 | table7 | fig5 | fig6 | fig7 | fig8 |\n  \
         ablate-split | ablate-reorder | ablate-compaction | ablate-buckets |\n  \
         stability | plan | shard | infer | serve\n\
         common flags: --datasets a,b,c  --d 64  --quick  --backends x,y"
    );
}
