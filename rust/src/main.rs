//! `repro` — the Fused3S reproduction CLI.
//!
//! One subcommand per paper table/figure plus serving/inference utilities:
//!
//! ```text
//! repro table3 [--dataset NAME]
//! repro table6 [--batched]
//! repro table7 [--datasets a,b,c]
//! repro fig5   [--datasets a,b,c] [--d 64] [--quick] [--backends x,y]
//! repro fig6   [--datasets a,b,c] [--d 64] [--quick]
//! repro fig7   [--datasets a,b]   [--sms 56]
//! repro fig8   [--datasets a,b]   [--dims 64,128,256] [--blocks 10] [--quick]
//! repro ablate-split|ablate-reorder|ablate-compaction|ablate-buckets
//! repro stability
//! repro plan   [--datasets a,b,c]   # adaptive-planner decision audit
//! repro shard  [--datasets a,b,c] [--shards 2,4,8]  # sharding audit
//! repro datasets            # list the calibrated suite
//! repro infer  --dataset X --d 64 --blocks 10 [--backend fused3s|auto]
//! repro serve  [--clients 4] [--requests 16] [--graphs 4] [--host]
//!              [--token T]             # TCP loopback loadgen (DESIGN.md §13)
//! repro serve  --listen ADDR [--host] [--token T]   # serve-only mode
//! repro stream [--steps 8] [--edits 24] [--requests 4] [--n 512] [--host]
//!                                        # streaming-delta audit (§14)
//! repro trace  [--clients 4] [--requests 16] [--rate 1.0] [--host]
//!              # Chrome trace_event capture -> results/trace.json (§15)
//! repro metrics --connect ADDR [--token T]
//!              # query a live server's metrics JSON over the wire
//! ```
//!
//! Results print as aligned tables and are mirrored to `results/*.json`.

use anyhow::{bail, Result};

use fused3s::experiments::{
    ablations, fig5, fig7, fig8, planner, report, shard, stability, table3,
    table6, table7,
};
use fused3s::graph::datasets::{self, Dataset};
use fused3s::kernels::Backend;
use fused3s::runtime::Runtime;
use fused3s::util::cli::Args;
use fused3s::util::timing::BenchConfig;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_list(args: &Args, key: &str, default: &[&str]) -> Vec<String> {
    args.get(key)
        .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
        .unwrap_or_else(|| default.iter().map(|s| s.to_string()).collect())
}

fn bench_config(args: &Args) -> BenchConfig {
    if args.bool("quick") {
        BenchConfig::quick()
    } else {
        BenchConfig::default()
    }
}

fn select_datasets(names: &[String], batched: bool) -> Result<Vec<Dataset>> {
    if names.len() == 1 && names[0] == "all" {
        Ok(if batched {
            datasets::suite_batched()
        } else {
            datasets::suite_single()
        })
    } else {
        names.iter().map(|n| datasets::by_name(n)).collect()
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        print_usage();
        return Ok(());
    };

    match cmd {
        "datasets" => {
            println!("single-graph suite (Table 6 analogs):");
            for d in datasets::suite_single() {
                println!(
                    "  {:<22} ~ {:<16} n={:<8} nnz={}",
                    d.name,
                    d.paper_name,
                    d.graph.n,
                    d.graph.nnz()
                );
            }
            println!("batched suites (Fig. 6 analogs):");
            for d in datasets::suite_batched() {
                println!(
                    "  {:<22} ~ {:<16} n={:<8} nnz={}",
                    d.name,
                    d.paper_name,
                    d.graph.n,
                    d.graph.nnz()
                );
            }
        }
        "table3" => {
            let j = table3::run(args.get("dataset"))?;
            let p = report::write_json("table3", &j)?;
            println!("\nwrote {}", p.display());
        }
        "table6" => {
            let j = table6::run(args.bool("batched"))?;
            let p = report::write_json("table6", &j)?;
            println!("\nwrote {}", p.display());
        }
        "table7" => {
            let names = parse_list(&args, "datasets", table7::DEFAULT_DATASETS);
            let j = table7::run(&names)?;
            let p = report::write_json("table7", &j)?;
            println!("\nwrote {}", p.display());
        }
        "fig5" | "fig6" => {
            let batched = cmd == "fig6";
            let names = parse_list(&args, "datasets", &["all"]);
            let suite = select_datasets(&names, batched)?;
            let d = args.usize_or("d", 64)?;
            let backends = match args.get("backends") {
                Some(list) => list
                    .split(',')
                    .map(Backend::parse)
                    .collect::<Result<Vec<_>>>()?,
                None => Backend::kernel_series(),
            };
            let rt = Runtime::from_default_artifacts()?;
            let j = fig5::run(&rt, &suite, &backends, d, &bench_config(&args), cmd)?;
            let p = report::write_json(cmd, &j)?;
            println!("\nwrote {}", p.display());
        }
        "fig7" => {
            let names = parse_list(&args, "datasets", fig7::DEFAULT_DATASETS);
            let sms = args.usize_or("sms", 56)?;
            let j = fig7::run(&names, sms)?;
            let p = report::write_json("fig7", &j)?;
            println!("\nwrote {}", p.display());
        }
        "fig8" => {
            let names = parse_list(
                &args,
                "datasets",
                &["cora-sim", "pubmed-sim", "github-sim", "molhiv-sim"],
            );
            let suite: Vec<Dataset> =
                names.iter().map(|n| datasets::by_name(n)).collect::<Result<_>>()?;
            let dims: Vec<usize> = parse_list(&args, "dims", &["64", "128", "256"])
                .iter()
                .map(|s| s.parse().map_err(|_| anyhow::anyhow!("bad dim {s}")))
                .collect::<Result<_>>()?;
            let blocks = args.usize_or("blocks", 10)?;
            let rt = Runtime::from_default_artifacts()?;
            let j = fig8::run(
                &rt,
                &suite,
                &dims,
                &fig8::series(),
                blocks,
                &bench_config(&args),
            )?;
            let p = report::write_json("fig8", &j)?;
            println!("\nwrote {}", p.display());
        }
        "ablate-split" => {
            let names = parse_list(&args, "datasets", &["pubmed-sim", "github-sim"]);
            let rt = Runtime::from_default_artifacts()?;
            let j = ablations::split(&rt, &names, args.usize_or("d", 64)?, &bench_config(&args))?;
            report::write_json("ablate_split", &j)?;
        }
        "ablate-reorder" => {
            let names = parse_list(&args, "datasets", &["reddit-sim", "github-sim", "pubmed-sim"]);
            let rt = Runtime::from_default_artifacts()?;
            let j = ablations::reorder(&rt, &names, args.usize_or("d", 64)?, &bench_config(&args))?;
            report::write_json("ablate_reorder", &j)?;
        }
        "ablate-compaction" => {
            let names = parse_list(&args, "datasets", &["pubmed-sim", "github-sim"]);
            let rt = Runtime::from_default_artifacts()?;
            let j = ablations::compaction(&rt, &names, args.usize_or("d", 64)?, &bench_config(&args))?;
            report::write_json("ablate_compaction", &j)?;
        }
        "ablate-buckets" => {
            let names = parse_list(&args, "datasets", &["pubmed-sim", "github-sim", "reddit-sim"]);
            let j = ablations::buckets(&names)?;
            report::write_json("ablate_buckets", &j)?;
        }
        "stability" => {
            let rt = Runtime::from_default_artifacts()?;
            let j = stability::run(&rt)?;
            report::write_json("stability", &j)?;
        }
        "plan" => {
            let names = parse_list(
                &args,
                "datasets",
                &["cora-sim", "pubmed-sim", "github-sim", "reddit-sim", "molhiv-sim"],
            );
            let j = planner::run(&names)?;
            let p = report::write_json("plan", &j)?;
            println!("\nwrote {}", p.display());
        }
        "shard" => {
            let names = parse_list(
                &args,
                "datasets",
                &["pubmed-sim", "github-sim", "reddit-sim"],
            );
            let counts: Vec<usize> = parse_list(&args, "shards", &["2", "4", "8"])
                .iter()
                .map(|c| c.parse().map_err(|_| anyhow::anyhow!("bad shard count {c}")))
                .collect::<Result<_>>()?;
            let j = shard::run(&names, &counts)?;
            let p = report::write_json("shard", &j)?;
            println!("\nwrote {}", p.display());
        }
        "infer" => {
            infer(&args)?;
        }
        "serve" => {
            serve(&args)?;
        }
        "stream" => {
            stream(&args)?;
        }
        "trace" => {
            trace(&args)?;
        }
        "metrics" => {
            metrics(&args)?;
        }
        other => {
            print_usage();
            bail!("unknown subcommand '{other}'");
        }
    }
    Ok(())
}

fn infer(args: &Args) -> Result<()> {
    use fused3s::model::weights::random_features;
    use fused3s::model::{GraphTransformer, GtConfig};
    let name = args.get_or("dataset", "cora-sim");
    let ds = datasets::by_name(&name)?;
    let cfg = GtConfig {
        d: args.usize_or("d", 64)?,
        n_blocks: args.usize_or("blocks", 10)?,
        backend: Backend::parse(&args.get_or("backend", "fused3s"))?,
        seed: args.u64_or("seed", 0x5EED)?,
    };
    let rt = Runtime::from_default_artifacts()?;
    println!(
        "GT inference: {} (n={}, nnz={}), d={}, {} blocks, backend={}",
        ds.name,
        ds.graph.n,
        ds.graph.nnz(),
        cfg.d,
        cfg.n_blocks,
        cfg.backend.name()
    );
    let model = GraphTransformer::prepare(&rt, &ds.graph, cfg)?;
    let h = random_features(1, ds.graph.n, cfg.d);
    let (_, warm) = model.infer(&rt, &h)?;
    println!("warmup (incl. executable compiles): {:.1} ms", warm.total_s * 1e3);
    let (out, t) = model.infer(&rt, &h)?;
    println!(
        "inference: {:.1} ms total, {:.1} ms attention ({:.0}%), {:.1} ms dense",
        t.total_s * 1e3,
        t.attention_s * 1e3,
        t.attention_fraction() * 100.0,
        t.dense_s * 1e3
    );
    let norm: f32 = out.iter().map(|x| x * x).sum::<f32>().sqrt();
    println!("output: {} values, L2 norm {norm:.2}", out.len());
    Ok(())
}

/// `repro serve` — the TCP serving layer (DESIGN.md §13).
///
/// Two modes:
///
/// * default: loopback loadgen — starts a coordinator + listener in this
///   process, drives it with `--clients` concurrent wire clients, and
///   reports throughput + the fingerprint handshake's upload savings.
/// * `--listen ADDR`: serve-only — binds `ADDR` and serves until stdin
///   reaches EOF (`repro serve --listen 127.0.0.1:7433 < /dev/null` for a
///   bind check; pipe nothing to keep it up), then drains gracefully.
fn serve(args: &Args) -> Result<()> {
    use fused3s::coordinator::{Coordinator, CoordinatorConfig, ExecutorKind};
    use fused3s::experiments::serve_load::{self, LoadSpec};
    use fused3s::net::{NetConfig, NetServer};
    use std::sync::Arc;

    let mut coord_cfg = CoordinatorConfig {
        preprocess_workers: args.usize_or("workers", 2)?,
        ..CoordinatorConfig::default()
    };
    // --host runs the kernels through the offline host emulation, so the
    // serving path is drivable with no AOT artifacts (tests do the same).
    if args.bool("host") {
        coord_cfg.executor = ExecutorKind::HostEmulation;
    }
    let token = args.get_or("token", "");
    let auth_tokens = if token.is_empty() {
        Vec::new()
    } else {
        vec![token.clone()]
    };

    if let Some(addr) = args.get("listen") {
        let coord = Arc::new(Coordinator::start(coord_cfg)?);
        let server = NetServer::serve(
            coord.clone(),
            NetConfig {
                addr: addr.to_string(),
                auth_tokens,
                ..NetConfig::default()
            },
        )?;
        println!(
            "serving on {} ({}); EOF on stdin shuts down",
            server.local_addr(),
            if token.is_empty() { "open" } else { "token auth" }
        );
        // Block until the operator closes stdin (^D or the pipe ends).
        let mut sink = String::new();
        let _ = std::io::Read::read_to_string(
            &mut std::io::stdin().lock(),
            &mut sink,
        );
        println!("stdin closed; draining");
        server.shutdown();
        coord.shutdown();
        println!("{}", coord.metrics().report());
        return Ok(());
    }

    let spec = LoadSpec {
        clients: args.usize_or("clients", 4)?,
        requests_per_client: args.usize_or("requests", 16)?,
        graphs: args.usize_or("graphs", 4)?,
        d: args.usize_or("d", 32)?,
        backend: Backend::parse(&args.get_or("backend", "auto"))?,
        seed: args.u64_or("seed", 0x5E12_F00D)?,
        token: token.clone(),
    };
    let j = serve_load::run(
        coord_cfg,
        NetConfig { auth_tokens, ..NetConfig::default() },
        &spec,
    )?;
    let p = report::write_json("serve", &j)?;
    println!("\nwrote {}", p.display());
    Ok(())
}

/// `repro stream` — the streaming-update audit (DESIGN.md §14): a
/// loopback server absorbing batched edge deltas over the wire while a
/// client verifies fingerprint agreement and replays requests against
/// each patched version.
fn stream(args: &Args) -> Result<()> {
    use fused3s::coordinator::{CoordinatorConfig, ExecutorKind};
    use fused3s::experiments::streaming::{self, StreamSpec};
    use fused3s::net::NetConfig;

    let mut coord_cfg = CoordinatorConfig {
        preprocess_workers: args.usize_or("workers", 2)?,
        ..CoordinatorConfig::default()
    };
    if args.bool("host") {
        coord_cfg.executor = ExecutorKind::HostEmulation;
    }
    let spec = StreamSpec {
        n: args.usize_or("n", 512)?,
        steps: args.usize_or("steps", 8)?,
        edits_per_step: args.usize_or("edits", 24)?,
        requests_per_step: args.usize_or("requests", 4)?,
        d: args.usize_or("d", 32)?,
        backend: Backend::parse(&args.get_or("backend", "fused3s"))?,
        seed: args.u64_or("seed", 0x57AE_A119)?,
    };
    let j = streaming::run(coord_cfg, NetConfig::default(), &spec)?;
    let p = report::write_json("stream", &j)?;
    println!("\nwrote {}", p.display());
    Ok(())
}

/// `repro trace` — record a loopback serving workload under the armed
/// tracer (DESIGN.md §15) and write the Chrome `trace_event` export to
/// `results/trace.json`.
fn trace(args: &Args) -> Result<()> {
    use fused3s::coordinator::{CoordinatorConfig, ExecutorKind};
    use fused3s::experiments::serve_load::LoadSpec;
    use fused3s::experiments::trace_capture;
    use fused3s::net::NetConfig;
    use fused3s::trace::TraceConfig;

    let mut coord_cfg = CoordinatorConfig {
        preprocess_workers: args.usize_or("workers", 2)?,
        ..CoordinatorConfig::default()
    };
    if args.bool("host") {
        coord_cfg.executor = ExecutorKind::HostEmulation;
    }
    let spec = LoadSpec {
        clients: args.usize_or("clients", 4)?,
        requests_per_client: args.usize_or("requests", 16)?,
        graphs: args.usize_or("graphs", 4)?,
        d: args.usize_or("d", 32)?,
        backend: Backend::parse(&args.get_or("backend", "auto"))?,
        seed: args.u64_or("seed", 0x5E12_F00D)?,
        token: args.get_or("token", ""),
    };
    let trace_cfg = TraceConfig {
        seed: args.u64_or("trace-seed", TraceConfig::default().seed)?,
        sample_rate: args.f64_or("rate", 1.0)?,
        capacity: args.usize_or("capacity", TraceConfig::default().capacity)?,
    };
    let j = trace_capture::run(
        coord_cfg,
        NetConfig::default(),
        &spec,
        trace_cfg,
    )?;
    let p = report::write_json("trace", &j)?;
    println!("\nwrote {} (load it in chrome://tracing or Perfetto)", p.display());
    Ok(())
}

/// `repro metrics --connect ADDR` — query a live server's full metrics
/// JSON over the wire (protocol tags 10/11) and print it.
fn metrics(args: &Args) -> Result<()> {
    use fused3s::net::NetClient;
    use fused3s::util::json;

    let Some(addr) = args.get("connect") else {
        bail!("metrics requires --connect ADDR (e.g. 127.0.0.1:7433)");
    };
    let token = args.get_or("token", "");
    let mut client = NetClient::connect(addr, &token)
        .map_err(|e| anyhow::anyhow!("connect {addr}: {e}"))?;
    let report = client
        .metrics()
        .map_err(|e| anyhow::anyhow!("metrics query: {e}"))?;
    client.close();
    println!("{}", json::to_string(&report));
    Ok(())
}

fn print_usage() {
    println!(
        "repro — Fused3S reproduction harness\n\
         subcommands:\n  \
         datasets | table3 | table6 | table7 | fig5 | fig6 | fig7 | fig8 |\n  \
         ablate-split | ablate-reorder | ablate-compaction | ablate-buckets |\n  \
         stability | plan | shard | infer | serve | stream | trace | metrics\n\
         common flags: --datasets a,b,c  --d 64  --quick  --backends x,y\n\
         serve: loopback loadgen by default (--clients N --requests R \
         --graphs G --host --token T); --listen ADDR for serve-only\n\
         stream: loopback streaming-delta audit (--steps N --edits E \
         --requests R --n NODES --host)\n\
         trace: Chrome trace_event capture of a loopback workload \
         (--rate F --capacity E --host) -> results/trace.json\n\
         metrics: query a live server (--connect ADDR [--token T])"
    );
}
