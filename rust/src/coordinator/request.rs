//! Request/response types crossing the coordinator's queues.

use std::sync::mpsc::Sender;
use std::time::Duration;

use crate::graph::CsrGraph;
use crate::kernels::{AttentionBatch, AttnError, Backend};

/// A sparse-attention request: one graph + head-major Q/K/V features for
/// `heads` attention heads (head-major: head `h`'s rows at
/// `q[h*n*d .. (h+1)*n*d]`, matching
/// [`AttentionBatch`](crate::kernels::AttentionBatch)).
pub struct AttnRequest {
    pub id: u64,
    pub graph: CsrGraph,
    /// Q/K feature dim (per head).
    pub d: usize,
    /// V / output feature dim (= d except for GAT-style rank-2 scores).
    pub dv: usize,
    /// Attention heads sharing this graph's preprocessing (≥ 1).
    pub heads: usize,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub scale: f32,
    /// Which execution strategy to use.  [`Backend::Auto`] delegates the
    /// choice to the adaptive planner ([`crate::planner`]): the coordinator
    /// resolves it at admission, so the request coalesces and caches under
    /// whatever concrete backend the planner picked.
    pub backend: Backend,
    /// Optional per-request deadline, measured from submission.  A request
    /// still queued (parked in the coalescer or waiting on a preprocessing
    /// worker) past its deadline is shed with
    /// [`AttnError::DeadlineExceeded`] instead of executing — the caller
    /// has already given up, so computing the answer only steals capacity
    /// from live requests.  `None` (the default) never sheds.  A request
    /// whose execution has already started is allowed to finish.
    pub deadline: Option<Duration>,
    /// Tracing span id (DESIGN.md §15).  `0` — the default — means "not
    /// yet sampled": [`Coordinator::submit`](crate::coordinator::Coordinator::submit)
    /// rolls the seeded sampling decision and stamps a nonzero id iff the
    /// request is traced.  Front ends that sample earlier (the net
    /// session, at decode time) pass their id through here.
    pub span: u64,
    /// Where to deliver the result.
    pub reply: Sender<AttnResponse>,
}

/// The computed output (or a structured failure).  Successful payloads are
/// head-major (`heads × n × dv`), which for the backward-compatible
/// single-head request is exactly the old `n × d` shape.
pub struct AttnResponse {
    pub id: u64,
    pub result: Result<Vec<f32>, AttnError>,
    /// End-to-end latency in seconds (admission → response, including any
    /// time parked in the coalescing queue).
    pub latency_s: f64,
    /// Time spent in preprocessing (BSB build + plan; shared by the whole
    /// batch this request rode in).
    pub preprocess_s: f64,
    /// Time spent executing kernels (also batch-shared).
    pub execute_s: f64,
    /// How many requests were coalesced into the block-diagonal batch that
    /// served this one (1 = ran alone).
    pub batch_size: usize,
    /// The concrete backend that produced a successful result.  Usually
    /// the resolved request backend, but the degradation ladder may have
    /// served this request on a fallback after the primary failed —
    /// callers comparing against golden outputs should gate bit-exactness
    /// on this matching what they asked for.  `None` when the request
    /// failed before any backend executed (validation, shedding, queue
    /// teardown).
    pub backend: Option<Backend>,
    /// The request's tracing span id (`0` = untraced), echoed back so
    /// front ends (the net session's reply encoder) can attribute their
    /// own events to the same span.
    pub span: u64,
}

impl AttnRequest {
    /// Build a single-head request with `dv = d` — the pre-multi-head call
    /// shape, kept as the backward-compatible default constructor.
    #[allow(clippy::too_many_arguments)]
    pub fn single_head(
        id: u64,
        graph: CsrGraph,
        d: usize,
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
        scale: f32,
        backend: Backend,
        reply: Sender<AttnResponse>,
    ) -> AttnRequest {
        AttnRequest {
            id,
            graph,
            d,
            dv: d,
            heads: 1,
            q,
            k,
            v,
            scale,
            backend,
            deadline: None,
            span: 0,
            reply,
        }
    }

    /// Validate feature buffer sizes against the graph by delegating to
    /// [`AttentionBatch::validate`] over a zero-copy view: `q`/`k` against
    /// `heads × n × d` and `v` against `heads × n × dv` (rank-2 GAT-style
    /// scores carry `dv ≠ d`, so `v` must NOT be checked against `d`).
    /// One shape rule, shared with the kernel layer.
    pub fn validate(&self) -> Result<(), AttnError> {
        AttentionBatch {
            n: self.graph.n,
            d: self.d,
            dv: self.dv,
            heads: self.heads,
            q: &self.q,
            k: &self.k,
            v: &self.v,
            scale: self.scale,
        }
        .validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use std::sync::mpsc::channel;

    #[test]
    fn validation() {
        let (tx, _rx) = channel();
        let g = generators::ring(32);
        let good = AttnRequest {
            id: 1,
            d: 4,
            dv: 4,
            heads: 1,
            q: vec![0.0; 128],
            k: vec![0.0; 128],
            v: vec![0.0; 128],
            scale: 1.0,
            backend: Backend::Fused3S,
            deadline: None,
            span: 0,
            reply: tx.clone(),
            graph: g.clone(),
        };
        assert!(good.validate().is_ok());
        let bad = AttnRequest { q: vec![0.0; 12], ..good };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn validate_checks_v_against_dv_not_d() {
        // GAT-style rank-2 scores: d = 2, dv = 8.  The old validator
        // compared v against n*d and would reject the correct buffer.
        let (tx, _rx) = channel();
        let g = generators::ring(16);
        let req = AttnRequest {
            id: 2,
            d: 2,
            dv: 8,
            heads: 1,
            q: vec![0.0; 32],
            k: vec![0.0; 32],
            v: vec![0.0; 128],
            scale: 1.0,
            backend: Backend::CpuCsr,
            deadline: None,
            span: 0,
            reply: tx.clone(),
            graph: g.clone(),
        };
        assert!(req.validate().is_ok());
        // v sized n*d (the shape the old bug accepted) must now fail.
        let bad = AttnRequest { v: vec![0.0; 32], ..req };
        assert!(matches!(bad.validate(), Err(AttnError::BadShape(_))));
    }

    #[test]
    fn multi_head_sizes_and_zero_heads() {
        let (tx, _rx) = channel();
        let g = generators::ring(8);
        let req = AttnRequest {
            id: 3,
            d: 4,
            dv: 4,
            heads: 3,
            q: vec![0.0; 96],
            k: vec![0.0; 96],
            v: vec![0.0; 96],
            scale: 1.0,
            backend: Backend::Fused3S,
            deadline: None,
            span: 0,
            reply: tx.clone(),
            graph: g.clone(),
        };
        assert!(req.validate().is_ok());
        let bad = AttnRequest { heads: 0, ..req };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn single_head_helper_defaults() {
        let (tx, _rx) = channel();
        let g = generators::ring(8);
        let req = AttnRequest::single_head(
            4,
            g,
            4,
            vec![0.0; 32],
            vec![0.0; 32],
            vec![0.0; 32],
            0.5,
            Backend::Fused3S,
            tx,
        );
        assert_eq!(req.dv, 4);
        assert_eq!(req.heads, 1);
        assert!(req.validate().is_ok());
    }
}
