//! Request/response types crossing the coordinator's queues.

use std::sync::mpsc::Sender;

use crate::graph::CsrGraph;
use crate::kernels::Backend;

/// A sparse-attention request: one graph + its Q/K/V features.
pub struct AttnRequest {
    pub id: u64,
    pub graph: CsrGraph,
    pub d: usize,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub scale: f32,
    /// Which execution strategy to use (defaults to Fused3S).
    pub backend: Backend,
    /// Where to deliver the result.
    pub reply: Sender<AttnResponse>,
}

/// The computed output (or a structured failure).
pub struct AttnResponse {
    pub id: u64,
    pub result: Result<Vec<f32>, String>,
    /// End-to-end latency in seconds (admission → response, including any
    /// time parked in the coalescing queue).
    pub latency_s: f64,
    /// Time spent in preprocessing (BSB build + plan; shared by the whole
    /// batch this request rode in).
    pub preprocess_s: f64,
    /// Time spent executing kernels (also batch-shared).
    pub execute_s: f64,
    /// How many requests were coalesced into the block-diagonal batch that
    /// served this one (1 = ran alone).
    pub batch_size: usize,
}

impl AttnRequest {
    /// Validate feature buffer sizes against the graph.
    pub fn validate(&self) -> Result<(), String> {
        let want = self.graph.n * self.d;
        for (name, buf) in [("q", &self.q), ("k", &self.k), ("v", &self.v)] {
            if buf.len() != want {
                return Err(format!(
                    "{name}: expected {} elements (n={} × d={}), got {}",
                    want,
                    self.graph.n,
                    self.d,
                    buf.len()
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use std::sync::mpsc::channel;

    #[test]
    fn validation() {
        let (tx, _rx) = channel();
        let g = generators::ring(32);
        let good = AttnRequest {
            id: 1,
            d: 4,
            q: vec![0.0; 128],
            k: vec![0.0; 128],
            v: vec![0.0; 128],
            scale: 1.0,
            backend: Backend::Fused3S,
            reply: tx.clone(),
            graph: g.clone(),
        };
        assert!(good.validate().is_ok());
        let bad = AttnRequest { q: vec![0.0; 12], ..good };
        assert!(bad.validate().is_err());
    }
}
