//! Quarantine registry — the memory of the backend degradation ladder.
//!
//! When a `(graph fingerprint, backend)` pair fails prepare or execute
//! twice in a row (first failure is retried once), the ladder quarantines
//! the pair here before re-resolving onto a different backend.  While a
//! pair is quarantined, new requests for that structure skip the backend
//! at plan time instead of rediscovering the failure — a panic in a
//! driver or a poisoned device context otherwise turns into one
//! retry-storm per request.
//!
//! Entries expire after a TTL ([`CoordinatorConfig::quarantine_ttl`]):
//! most failures the ladder sees are transient (an evicted device buffer,
//! a raced context teardown), so a quarantined backend is re-admitted
//! automatically and re-proven by the next request after expiry.  A
//! deterministic failure simply re-quarantines on its next attempt —
//! bounded re-probing, not a permanent blacklist.
//!
//! [`CoordinatorConfig::quarantine_ttl`]: super::CoordinatorConfig::quarantine_ttl

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::kernels::Backend;
use crate::util::sync::lock_unpoisoned;

/// TTL-expiring set of `(fingerprint, backend)` pairs the degradation
/// ladder has taken out of service.
pub struct Quarantine {
    ttl: Duration,
    entries: Mutex<HashMap<(u64, Backend), Instant>>,
}

impl Quarantine {
    pub fn new(ttl: Duration) -> Quarantine {
        Quarantine { ttl, entries: Mutex::new(HashMap::new()) }
    }

    /// Quarantine `(fp, backend)` for the configured TTL (refreshes the
    /// clock if already present).
    pub fn insert(&self, fp: u64, backend: Backend) {
        lock_unpoisoned(&self.entries).insert((fp, backend), Instant::now());
    }

    /// Is `(fp, backend)` currently quarantined?  Expired entries are
    /// evicted on the way through, so the registry stays bounded by the
    /// live failure set.
    pub fn contains(&self, fp: u64, backend: Backend) -> bool {
        let mut entries = lock_unpoisoned(&self.entries);
        match entries.get(&(fp, backend)) {
            Some(since) if since.elapsed() < self.ttl => true,
            Some(_) => {
                entries.remove(&(fp, backend));
                false
            }
            None => false,
        }
    }

    /// Every backend currently quarantined for `fp` — the exclusion set
    /// handed to [`Planner::resolve_excluding`].  Sweeps expired entries.
    ///
    /// [`Planner::resolve_excluding`]: crate::planner::Planner::resolve_excluding
    pub fn quarantined_for(&self, fp: u64) -> Vec<Backend> {
        let mut entries = lock_unpoisoned(&self.entries);
        entries.retain(|_, since| since.elapsed() < self.ttl);
        entries
            .keys()
            .filter(|(f, _)| *f == fp)
            .map(|(_, b)| *b)
            .collect()
    }

    /// Number of live (non-expired) entries.
    pub fn len(&self) -> usize {
        let mut entries = lock_unpoisoned(&self.entries);
        entries.retain(|_, since| since.elapsed() < self.ttl);
        entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_contains_and_scoping() {
        let q = Quarantine::new(Duration::from_secs(60));
        assert!(!q.contains(7, Backend::Fused3S));
        q.insert(7, Backend::Fused3S);
        assert!(q.contains(7, Backend::Fused3S));
        // Scoped per (fp, backend): neither neighbour is affected.
        assert!(!q.contains(7, Backend::CpuCsr));
        assert!(!q.contains(8, Backend::Fused3S));
        assert_eq!(q.quarantined_for(7), vec![Backend::Fused3S]);
        assert!(q.quarantined_for(8).is_empty());
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn entries_expire_after_ttl() {
        let q = Quarantine::new(Duration::from_millis(30));
        q.insert(1, Backend::Fused3S);
        q.insert(1, Backend::UnfusedStable);
        assert_eq!(q.quarantined_for(1).len(), 2);
        std::thread::sleep(Duration::from_millis(60));
        assert!(!q.contains(1, Backend::Fused3S), "re-admitted after TTL");
        assert!(q.quarantined_for(1).is_empty());
        assert!(q.is_empty(), "expired entries are swept, not retained");
    }

    #[test]
    fn reinsert_refreshes_the_clock() {
        let q = Quarantine::new(Duration::from_millis(80));
        q.insert(3, Backend::CpuCsr);
        std::thread::sleep(Duration::from_millis(50));
        q.insert(3, Backend::CpuCsr);
        std::thread::sleep(Duration::from_millis(50));
        // 100ms after first insert but only 50ms after the refresh.
        assert!(q.contains(3, Backend::CpuCsr));
    }
}
