//! BSB preprocessing cache — repeated graphs skip the build entirely.
//!
//! The serving steady state replays the same structures over and over
//! (fixed molecule vocabularies, recurring batch compositions), so the
//! coordinator keys prepared [`Plan`]s — BSB + bucket plan, the expensive
//! per-graph preprocessing — by [`CsrGraph::fingerprint`] + backend and
//! reuses them across requests (and, since plans execute head-batched
//! problems, across every head of every request).  Entries are
//! `Arc`-shared: preprocessing workers insert, the executor runs them
//! concurrently, eviction never invalidates an in-flight run.
//!
//! Collision safety: a 64-bit content fingerprint collides with ~2⁻⁶⁴
//! probability, and a stored entry is additionally cross-checked against
//! the request's node *and* edge counts, so a mismatched collision
//! degrades to a spurious rebuild.  A colliding pair that also matches
//! (n, nnz) would be served wrongly — at these odds the serving path
//! deliberately skips a full structural compare.
//!
//! [`CsrGraph::fingerprint`]: crate::graph::CsrGraph::fingerprint

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::kernels::{Backend, Plan};
use crate::util::sync::lock_unpoisoned;

struct Slot {
    plan: Arc<Plan>,
    last_used: u64,
    /// Keyed graph's (node, edge) counts — the collision cross-check.
    n: usize,
    nnz: usize,
}

struct Inner {
    map: HashMap<(u64, Backend), Slot>,
    tick: u64,
}

/// LRU cache of prepared plans, shared by the preprocessing workers.
pub struct DriverCache {
    capacity: usize,
    inner: Mutex<Inner>,
}

impl DriverCache {
    /// `capacity == 0` disables caching (every lookup misses, inserts are
    /// dropped).
    pub fn new(capacity: usize) -> DriverCache {
        DriverCache {
            capacity,
            inner: Mutex::new(Inner { map: HashMap::new(), tick: 0 }),
        }
    }

    /// Look up a prepared plan; refreshes LRU recency on hit.  `n`/`nnz`
    /// are the requesting graph's node/edge counts (collision cross-check).
    pub fn get(
        &self,
        fp: u64,
        backend: Backend,
        n: usize,
        nnz: usize,
    ) -> Option<Arc<Plan>> {
        if self.capacity == 0 {
            return None;
        }
        let mut inner = lock_unpoisoned(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        let slot = inner.map.get_mut(&(fp, backend))?;
        if slot.n != n || slot.nnz != nnz {
            return None; // fingerprint collision: treat as a miss
        }
        slot.last_used = tick;
        Some(slot.plan.clone())
    }

    /// Insert a freshly prepared plan for a graph with `n` nodes and
    /// `nnz` edges, evicting least-recently-used entries to stay within
    /// capacity.  Returns how many were evicted.
    pub fn insert(
        &self,
        fp: u64,
        backend: Backend,
        n: usize,
        nnz: usize,
        plan: Arc<Plan>,
    ) -> u64 {
        if self.capacity == 0 {
            return 0;
        }
        let mut inner = lock_unpoisoned(&self.inner);
        let mut evicted = 0u64;
        while inner.map.len() >= self.capacity
            && !inner.map.contains_key(&(fp, backend))
        {
            let oldest = inner
                .map
                .iter()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| *k)
                // invariant: the loop condition guarantees len >= capacity
                // >= 1, so the map cannot be empty here.
                .expect("non-empty map");
            inner.map.remove(&oldest);
            evicted += 1;
        }
        inner.tick += 1;
        let tick = inner.tick;
        inner
            .map
            .insert((fp, backend), Slot { plan, last_used: tick, n, nnz });
        evicted
    }

    /// Drop the entry for `(fp, backend)` if present — the degradation
    /// ladder's poisoned-plan eviction: a cached plan whose execution
    /// failed (or panicked) must not be served to the next request with
    /// the same structure.  Returns whether an entry was removed.
    pub fn evict(&self, fp: u64, backend: Backend) -> bool {
        if self.capacity == 0 {
            return false;
        }
        lock_unpoisoned(&self.inner).map.remove(&(fp, backend)).is_some()
    }

    /// Every backend currently holding a plan for `fp` (sorted by backend
    /// name for determinism) — the set `update_graph` rebuilds under the
    /// patched fingerprint before the old version is evicted.
    pub fn backends_for(&self, fp: u64) -> Vec<Backend> {
        let inner = lock_unpoisoned(&self.inner);
        let mut out: Vec<Backend> = inner
            .map
            .keys()
            .filter(|(k, _)| *k == fp)
            .map(|&(_, b)| b)
            .collect();
        out.sort_by_key(|b| b.name());
        out
    }

    /// Drop every backend's entry for `fp` — the version-swap eviction:
    /// once a graph has been patched to a new fingerprint, no request will
    /// ever carry the old one again, so all its plans leave the cache in
    /// one step (in-flight executions keep their `Arc<Plan>`).  Returns
    /// how many entries were removed.
    pub fn evict_all(&self, fp: u64) -> usize {
        if self.capacity == 0 {
            return 0;
        }
        let mut inner = lock_unpoisoned(&self.inner);
        let stale: Vec<(u64, Backend)> = inner
            .map
            .keys()
            .filter(|(k, _)| *k == fp)
            .copied()
            .collect();
        for key in &stale {
            inner.map.remove(key);
        }
        stale.len()
    }

    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{offline_manifest, Engine};
    use crate::graph::generators;

    /// A ring(n) has n nodes and 2n edges.
    fn driver_for(n: usize) -> Arc<Plan> {
        let man = offline_manifest(8, &[4, 8, 16, 32, 64, 128], 128);
        let g = generators::ring(n);
        Arc::new(
            Plan::new(&man, &g, Backend::Fused3S, &Engine::serial()).unwrap(),
        )
    }

    #[test]
    fn hit_after_insert_and_collision_guards() {
        let cache = DriverCache::new(4);
        assert!(cache.get(42, Backend::Fused3S, 32, 64).is_none());
        cache.insert(42, Backend::Fused3S, 32, 64, driver_for(32));
        assert!(cache.get(42, Backend::Fused3S, 32, 64).is_some());
        // Same key, different backend: distinct entries.
        assert!(cache.get(42, Backend::CpuCsr, 32, 64).is_none());
        // Collision cross-checks: wrong n or wrong nnz is a miss, never a
        // wrong-structure driver.
        assert!(cache.get(42, Backend::Fused3S, 64, 64).is_none());
        assert!(cache.get(42, Backend::Fused3S, 32, 48).is_none());
    }

    #[test]
    fn lru_eviction_order() {
        let cache = DriverCache::new(2);
        cache.insert(1, Backend::Fused3S, 16, 32, driver_for(16));
        cache.insert(2, Backend::Fused3S, 16, 32, driver_for(16));
        // Touch 1 so 2 becomes the LRU entry.
        assert!(cache.get(1, Backend::Fused3S, 16, 32).is_some());
        let evicted = cache.insert(3, Backend::Fused3S, 16, 32, driver_for(16));
        assert_eq!(evicted, 1);
        assert_eq!(cache.len(), 2);
        assert!(cache.get(1, Backend::Fused3S, 16, 32).is_some());
        assert!(cache.get(2, Backend::Fused3S, 16, 32).is_none());
        assert!(cache.get(3, Backend::Fused3S, 16, 32).is_some());
    }

    #[test]
    fn evict_removes_only_the_named_entry() {
        let cache = DriverCache::new(4);
        cache.insert(1, Backend::Fused3S, 16, 32, driver_for(16));
        cache.insert(1, Backend::CpuCsr, 16, 32, driver_for(16));
        assert!(cache.evict(1, Backend::Fused3S));
        assert!(!cache.evict(1, Backend::Fused3S), "already gone");
        assert!(cache.get(1, Backend::Fused3S, 16, 32).is_none());
        assert!(cache.get(1, Backend::CpuCsr, 16, 32).is_some());
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn per_fingerprint_enumeration_and_bulk_evict() {
        let cache = DriverCache::new(8);
        cache.insert(1, Backend::Fused3S, 16, 32, driver_for(16));
        cache.insert(1, Backend::CpuCsr, 16, 32, driver_for(16));
        cache.insert(2, Backend::Fused3S, 16, 32, driver_for(16));
        let mut b = cache.backends_for(1);
        b.sort_by_key(|x| x.name());
        assert_eq!(b, vec![Backend::CpuCsr, Backend::Fused3S]);
        assert_eq!(cache.backends_for(3), vec![]);
        assert_eq!(cache.evict_all(1), 2);
        assert_eq!(cache.evict_all(1), 0);
        assert!(cache.get(1, Backend::Fused3S, 16, 32).is_none());
        assert!(cache.get(2, Backend::Fused3S, 16, 32).is_some());
    }

    #[test]
    fn zero_capacity_disables() {
        let cache = DriverCache::new(0);
        assert_eq!(cache.insert(7, Backend::Fused3S, 16, 32, driver_for(16)), 0);
        assert!(cache.get(7, Backend::Fused3S, 16, 32).is_none());
        assert!(cache.is_empty());
    }
}
