//! The coordinator server: preprocessing workers + a PJRT executor thread.
//!
//! Ownership model: `xla::PjRtClient` is not `Sync`, so exactly one executor
//! thread owns the [`Runtime`]; preprocessing (BSB build + bucket planning,
//! pure CPU) happens on a small worker pool in front of it.  This mirrors
//! the paper's split between per-graph preprocessing ("negligible overhead,
//! done once per input graph") and kernel execution.
//!
//! Host parallelism: one shared [`Engine`] (worker pool + call-buffer
//! arena, EXPERIMENTS.md §Perf) is threaded through both stages — the
//! preprocessing workers shard each request's BSB build across it, and the
//! executor runs every driver through its gather/dispatch/scatter pipeline —
//! instead of each stage spawning ad-hoc threads with private buffers.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::exec::{Engine, ExecPolicy};
use crate::kernels::{AttentionProblem, Driver};
use crate::runtime::{Manifest, Runtime};

use super::metrics::Metrics;
use super::request::{AttnRequest, AttnResponse};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub artifacts_dir: PathBuf,
    /// Preprocessing worker threads.
    pub preprocess_workers: usize,
    /// Bound on the ingress queue before `submit` blocks the caller
    /// (backpressure).
    pub queue_capacity: usize,
    /// Host execution policy shared by preprocessing and the executor.
    pub exec: ExecPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifacts_dir: PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
            preprocess_workers: 2,
            queue_capacity: 64,
            exec: ExecPolicy::auto(),
        }
    }
}

/// A preprocessed request waiting for the executor.
struct PreparedRequest {
    req: AttnRequest,
    driver: Result<Driver, String>,
    enqueued: Instant,
    preprocess_s: f64,
}

/// Handle to a running coordinator.
pub struct Coordinator {
    ingress: Sender<AttnRequest>,
    metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    workers: Vec<JoinHandle<()>>,
    executor: Option<JoinHandle<()>>,
}

impl Coordinator {
    /// Start the worker pool and executor.  The executor compiles
    /// executables lazily; call [`Runtime::warmup`] patterns via a first
    /// dummy request if cold-start latency matters.
    pub fn start(cfg: CoordinatorConfig) -> Result<Coordinator> {
        // Validate the manifest eagerly so startup fails fast.  The PJRT
        // client itself is constructed *inside* the executor thread: the xla
        // client is reference-counted and not Send.
        let manifest = Arc::new(
            Manifest::load(&cfg.artifacts_dir)
                .context("coordinator startup: loading artifacts")?,
        );

        let metrics = Arc::new(Metrics::new());
        let shutdown = Arc::new(AtomicBool::new(false));
        // One engine for the whole coordinator: preprocessing shards BSB
        // builds across its pool, the executor pipelines calls through it,
        // and its buffer arena recycles staging memory across requests.
        let engine = Arc::new(Engine::new(cfg.exec));
        let (ingress_tx, ingress_rx) = channel::<AttnRequest>();
        let (prep_tx, prep_rx) = channel::<PreparedRequest>();
        let ingress_rx = Arc::new(std::sync::Mutex::new(ingress_rx));
        let mut workers = Vec::new();
        for _ in 0..cfg.preprocess_workers.max(1) {
            let rx = ingress_rx.clone();
            let tx = prep_tx.clone();
            let stop = shutdown.clone();
            let man = manifest.clone();
            let eng = engine.clone();
            workers.push(std::thread::spawn(move || {
                preprocess_worker(rx, tx, stop, man, eng)
            }));
        }
        drop(prep_tx);

        // Executor stage: constructs and owns the PJRT runtime on its own
        // thread; startup errors are reported back before `start` returns.
        let m2 = metrics.clone();
        let dir = cfg.artifacts_dir.clone();
        let eng = engine.clone();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let executor = std::thread::spawn(move || {
            let rt = match Runtime::new(&dir) {
                Ok(rt) => {
                    let _ = ready_tx.send(Ok(()));
                    rt
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            executor_loop(rt, prep_rx, m2, eng)
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("executor thread died at startup"))?
            .map_err(|e| anyhow::anyhow!("executor startup: {e}"))?;

        Ok(Coordinator {
            ingress: ingress_tx,
            metrics,
            shutdown,
            workers,
            executor: Some(executor),
        })
    }

    /// Submit a request (non-blocking; the reply arrives on `req.reply`).
    pub fn submit(&self, req: AttnRequest) -> Result<()> {
        self.ingress
            .send(req)
            .map_err(|_| anyhow::anyhow!("coordinator is shut down"))
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Drain queues and stop all threads.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        drop(std::mem::replace(&mut self.ingress, channel().0));
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(e) = self.executor.take() {
            let _ = e.join();
        }
    }
}

fn preprocess_worker(
    rx: Arc<std::sync::Mutex<Receiver<AttnRequest>>>,
    tx: Sender<PreparedRequest>,
    stop: Arc<AtomicBool>,
    man: Arc<Manifest>,
    engine: Arc<Engine>,
) {
    loop {
        let req = {
            let guard = rx.lock().unwrap();
            match guard.recv_timeout(std::time::Duration::from_millis(50)) {
                Ok(r) => r,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    if stop.load(Ordering::SeqCst) {
                        return;
                    }
                    continue;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => return,
            }
        };
        let enqueued = Instant::now();
        let t0 = Instant::now();
        let driver = match req.validate() {
            Err(e) => Err(e),
            Ok(()) => Driver::prepare_on(&man, &req.graph, req.backend, &engine)
                .map_err(|e| format!("{e:#}")),
        };
        let prepared = PreparedRequest {
            preprocess_s: t0.elapsed().as_secs_f64(),
            req,
            driver,
            enqueued,
        };
        if tx.send(prepared).is_err() {
            return;
        }
    }
}

fn executor_loop(
    rt: Runtime,
    rx: Receiver<PreparedRequest>,
    metrics: Arc<Metrics>,
    engine: Arc<Engine>,
) {
    while let Ok(p) = rx.recv() {
        let t0 = Instant::now();
        let result = match p.driver {
            Err(e) => Err(e),
            Ok(driver) => {
                let x = AttentionProblem::new(
                    p.req.graph.n,
                    p.req.d,
                    &p.req.q,
                    &p.req.k,
                    &p.req.v,
                    p.req.scale,
                );
                driver.run_with(&rt, &x, &engine).map_err(|e| format!("{e:#}"))
            }
        };
        let execute_s = t0.elapsed().as_secs_f64();
        let latency_s = p.enqueued.elapsed().as_secs_f64() + p.preprocess_s;
        metrics.request_done(result.is_ok());
        metrics.latency.record(latency_s);
        metrics.preprocess.record(p.preprocess_s);
        metrics.execute.record(execute_s);
        let _ = p.req.reply.send(AttnResponse {
            id: p.req.id,
            result,
            latency_s,
            preprocess_s: p.preprocess_s,
            execute_s,
        });
    }
}
